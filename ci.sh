#!/usr/bin/env bash
# Repo CI: format check, lints, build, tests. Run from anywhere.
#
# Mirrors the ROADMAP tier-1 gate: `cargo build --release && cargo test -q`
# (both fatal), with lints and compile-only bench smoke around it.
#
# * `cargo fmt --check` is advisory (non-fatal): the tree predates rustfmt
#   enforcement and carries hand-aligned tables/diagrams; drift is printed
#   so it stays visible without blocking merges.
# * clippy runs with -D warnings plus a small documented allow-list of
#   style lints the codebase deliberately does not follow. The serving
#   path additionally carries an in-source scoped gate: coordinator/ and
#   server/ deny clippy::unwrap_used / clippy::expect_used in non-test
#   code (inner attributes in their mod.rs), so a stray `.unwrap()` on
#   the fault-tolerant path fails this leg — recoverable errors must
#   travel as JobError/ErrCode, not panics.
#   Style allow-list:
#     - needless_range_loop: index loops mirror the hardware column/lane
#       structure and are clearer than iterator chains there;
#     - too_many_arguments: netlist builder helpers take per-signal args;
#     - type_complexity: engine/factory types are spelled out once;
#     - new_without_default: `new()` constructors without Default impls.
# * `cargo build --release` is the first half of the tier-1 gate and must
#   succeed before tests run.
# * `cargo bench --no-run` compile-checks every bench target (the bench
#   harness is `harness = false`, so nothing executes) — benches stay
#   buildable without spending CI minutes running them.
# * `cargo test -q` is the second half of the tier-1 gate and must pass.
# * Trace smoke: a demo serve run with SFCMUL_TRACE set must write a
#   Chrome trace-event file that `sfcmul trace --input ... --min-events 1`
#   validates — the observability layer stays wired end to end.
# * Golden lock: after the test leg, rust/tests/golden/pipeline.tsv must
#   carry blessed data rows AND match the committed copy. The
#   golden_pipeline test blesses the working-tree file on its first
#   toolchain run, so the file itself always looks blessed post-test; the
#   lock is only real once those rows are committed — a post-test
#   `git diff` on the file is the gate. Until the blessed rows land in a
#   commit, CI stays red and uploads them as the golden-pipeline artifact.
# * Gate-stats lock: after the test leg, `sfcmul tables --id gates`
#   renders the per-design netlist cost table (raw vs optimized) into
#   out/gates.tsv. rust/tests/golden/gates.tsv is the committed baseline
#   (blessed like pipeline.tsv: first toolchain run copies the table in,
#   CI stays red until the file is committed). Once the baseline is live,
#   the leg fails if any design's *optimized* gate count exceeds the
#   committed figure — the optimization pipeline must never regress.
#   Hosted CI uploads both files as the gate-stats artifact.
# * Provenance regression guard (toolchain-independent, runs first): once
#   a golden file (pipeline.tsv / gates.tsv / proposed8.v) carries
#   committed blessed rows, or a BENCH_*.json carries committed measured
#   timings, the working tree must never take them back to the
#   bootstrap/UNMEASURED placeholder state — that would silently disarm
#   the locks above. Files still in bootstrap state only warn (the
#   per-file legs below already gate the first blessing).
# * `--bench-json`: after a green gate, additionally run the bench_conv,
#   bench_nn, and bench_coordinator groups in quick mode with
#   SFCMUL_BENCH_JSON pointing at BENCH_conv.json / BENCH_nn.json /
#   BENCH_coordinator.json, refreshing the machine-readable perf
#   trajectory at the repo root (hosted CI uploads all three as artifacts
#   per run; see EXPERIMENTS.md). bench_coordinator includes the socket
#   saturation rows (N streaming clients through the TCP front-end vs the
#   in-process equivalent).

set -uo pipefail
cd "$(dirname "$0")"

bench_json=0
for arg in "$@"; do
    case "$arg" in
        --bench-json) bench_json=1 ;;
        *) echo "usage: ./ci.sh [--bench-json]" >&2; exit 2 ;;
    esac
done

status=0

echo "== provenance regression guard (blessed/measured files must not regress) =="
# Blessed-state predicates read from stdin so the same test serves the
# committed copy (git show) and the working tree (cat).
has_golden_rows() { grep -q -v -e '^#' -e '^design' -e '^[[:space:]]*$'; }
has_verilog_body() { grep -q -v -e '^[[:space:]]*//' -e '^[[:space:]]*$'; }
# Measured = at least one non-null median; the bootstrap placeholder has
# "median_ns": null in every row.
measured_bench() { grep -q '"median_ns": [0-9]'; }
if git rev-parse --is-inside-work-tree >/dev/null 2>&1; then
    check_regress() {
        # $1 = file, $2 = predicate returning 0 when the content is
        # blessed/measured, $3 = human label of the blessed state
        local f="$1" pred="$2" label="$3"
        local head_ok=1 work_ok=1 tmp
        # Stage the committed copy in a temp file: piping `git show`
        # straight into `grep -q` can die of SIGPIPE under pipefail once
        # the blessed file outgrows the pipe buffer.
        tmp=$(mktemp)
        if git show "HEAD:$f" > "$tmp" 2>/dev/null; then
            "$pred" < "$tmp" && head_ok=0
        fi
        rm -f "$tmp"
        [ -f "$f" ] && "$pred" < "$f" && work_ok=0
        if [ "$head_ok" -eq 0 ] && [ "$work_ok" -ne 0 ]; then
            echo "FAIL: $f regressed from $label back to the bootstrap placeholder state"
            echo "      (the committed copy is $label; never re-commit the placeholder)"
            status=1
        elif [ "$head_ok" -ne 0 ]; then
            echo "  $f: still bootstrap (first blessing gated by its own leg below)"
        else
            echo "  $f: $label and stable"
        fi
    }
    check_regress rust/tests/golden/pipeline.tsv has_golden_rows "blessed"
    check_regress rust/tests/golden/gates.tsv has_golden_rows "blessed"
    check_regress rust/tests/golden/proposed8.v has_verilog_body "blessed"
    check_regress BENCH_conv.json measured_bench "measured"
    check_regress BENCH_nn.json measured_bench "measured"
    check_regress BENCH_coordinator.json measured_bench "measured"
else
    echo "  (not a git checkout; guard skipped)"
fi

echo "== cargo fmt --check (advisory) =="
if ! cargo fmt --check 2>/dev/null; then
    echo "warning: rustfmt differences found (advisory only)"
fi

echo "== cargo clippy =="
if ! cargo clippy --all-targets -- -D warnings \
    -A clippy::needless_range_loop \
    -A clippy::too_many_arguments \
    -A clippy::type_complexity \
    -A clippy::new_without_default; then
    echo "FAIL: clippy"
    status=1
fi

echo "== cargo build --release (tier-1) =="
if ! cargo build --release; then
    echo "FAIL: release build (skipping bench smoke and tests: they would re-hit the same compile errors)"
    status=1
else
    echo "== cargo bench --no-run (compile smoke) =="
    if ! cargo bench --no-run; then
        echo "FAIL: bench targets do not compile"
        status=1
    fi

    echo "== cargo test (tier-1) =="
    if ! cargo test -q; then
        echo "FAIL: tests"
        status=1
    fi

    echo "== chaos soak (fault-injected fleet, release) =="
    # The chaos_soak target also runs under the tier-1 leg above; this
    # release-mode rerun is the robustness gate proper — panicking
    # FaultEngine tiles, an open circuit breaker, fallback rerouting and
    # socket clients under optimized timing, where lost-wakeup/teardown
    # races actually surface.
    if ! cargo test --release --test chaos_soak -q; then
        echo "FAIL: chaos soak"
        status=1
    fi

    echo "== golden pipeline lock =="
    # The golden_pipeline test blesses the *working-tree* file when the
    # committed copy is header-only, so checking the file alone would
    # always pass right after the test leg. The lock is only active once
    # the blessed rows are committed — so a post-test diff against the
    # committed copy is the actual gate.
    golden=rust/tests/golden/pipeline.tsv
    if ! [ -f "$golden" ] || ! grep -q -v -e '^#' -e '^[[:space:]]*$' "$golden"; then
        echo "FAIL: $golden has no blessed data rows after the test leg"
        status=1
    elif git rev-parse --is-inside-work-tree >/dev/null 2>&1; then
        # status --porcelain covers every not-yet-committed state
        # (modified, staged-only, untracked) — the lock is real only
        # when the blessed rows are in a commit.
        if [ -z "$(git status --porcelain -- "$golden")" ]; then
            echo "golden file is blessed and committed — exact-checksum locking active"
        else
            echo "FAIL: $golden was (re)blessed by this run but the rows are not committed;"
            echo "      commit the blessed file to activate exact-checksum locking"
            echo "      (hosted CI uploads it as the golden-pipeline artifact)"
            status=1
        fi
    else
        echo "golden file carries blessed rows (not a git checkout; commit check skipped)"
    fi

    echo "== gate stats (tables --id gates vs committed baseline) =="
    gates_golden=rust/tests/golden/gates.tsv
    mkdir -p out
    if ! target/release/sfcmul tables --id gates --seed 42 > out/gates.tsv; then
        echo "FAIL: sfcmul tables --id gates"
        status=1
    elif ! [ -f "$gates_golden" ] \
        || ! grep -q -v -e '^#' -e '^design' -e '^[[:space:]]*$' "$gates_golden"; then
        # Bootstrap: bless the measured table; the lock is real only once
        # the file is committed (same contract as the pipeline golden).
        cp out/gates.tsv "$gates_golden"
        echo "FAIL: $gates_golden had no blessed rows; blessed this run's table —"
        echo "      commit the file to activate the gate-count regression lock"
        echo "      (hosted CI uploads it as the gate-stats artifact)"
        status=1
    elif ! awk -F'\t' '
            FNR == NR {
                if ($0 !~ /^#/ && $1 != "design" && NF > 3) base[$1] = $4
                next
            }
            $0 !~ /^#/ && $1 != "design" && NF > 3 {
                seen[$1] = 1
                if (!($1 in base)) {
                    printf "  new design %s has no baseline row — rebless gates.tsv\n", $1
                    bad = 1
                } else if ($4 + 0 > base[$1] + 0) {
                    printf "  REGRESSION: %s optimized gate count %d > committed baseline %d\n", $1, $4, base[$1]
                    bad = 1
                }
            }
            END {
                for (d in base) if (!(d in seen)) {
                    printf "  stale baseline row %s — rebless gates.tsv\n", d
                    bad = 1
                }
                exit bad
            }
        ' "$gates_golden" out/gates.tsv; then
        echo "FAIL: optimized gate counts regressed against $gates_golden"
        echo "      (if the growth is intentional, copy out/gates.tsv over the baseline and commit)"
        status=1
    else
        echo "gate counts at or below the committed baseline"
    fi

    # The netlist_opt_equiv test blesses the proposed@8 Verilog golden on
    # its first run; like the other goldens, the byte-for-byte lock is
    # only real once the blessed file is committed.
    vgolden=rust/tests/golden/proposed8.v
    if ! [ -f "$vgolden" ] || ! grep -q -v -e '^[[:space:]]*//' -e '^[[:space:]]*$' "$vgolden"; then
        echo "FAIL: $vgolden has no blessed Verilog body after the test leg"
        status=1
    elif git rev-parse --is-inside-work-tree >/dev/null 2>&1 \
        && [ -n "$(git status --porcelain -- "$vgolden")" ]; then
        echo "FAIL: $vgolden was (re)blessed by this run but not committed;"
        echo "      commit the file to lock the Verilog export byte-for-byte"
        status=1
    else
        echo "Verilog golden is blessed — export locked"
    fi

    echo "== trace smoke (SFCMUL_TRACE demo serve -> sfcmul trace) =="
    # End-to-end observability gate: a demo serve run with the tracer on
    # (via the SFCMUL_TRACE env knob, exercising the same path as
    # --trace) must leave a Chrome trace-event file that the `trace`
    # subcommand validates — schema-checked, with at least one real
    # event recorded. The quality sampler rides along at n=1.
    if ! SFCMUL_TRACE=out/trace_smoke.json \
        target/release/sfcmul serve --demo --jobs 8 --quality-sample-n 1; then
        echo "FAIL: traced demo serve"
        status=1
    elif ! target/release/sfcmul trace --input out/trace_smoke.json --min-events 1; then
        echo "FAIL: demo serve produced no valid trace (out/trace_smoke.json)"
        status=1
    else
        echo "trace smoke OK (out/trace_smoke.json)"
    fi
fi

if [ "$bench_json" -eq 1 ] && [ "$status" -eq 0 ]; then
    echo "== bench_conv → BENCH_conv.json (quick mode) =="
    if ! SFCMUL_BENCH_QUICK=1 SFCMUL_BENCH_JSON=BENCH_conv.json \
        cargo bench --bench bench_conv; then
        echo "FAIL: bench_conv run"
        status=1
    fi
    echo "== bench_nn → BENCH_nn.json (quick mode) =="
    if ! SFCMUL_BENCH_QUICK=1 SFCMUL_BENCH_JSON=BENCH_nn.json \
        cargo bench --bench bench_nn; then
        echo "FAIL: bench_nn run"
        status=1
    fi
    echo "== bench_coordinator → BENCH_coordinator.json (quick mode, incl. socket saturation) =="
    if ! SFCMUL_BENCH_QUICK=1 SFCMUL_BENCH_JSON=BENCH_coordinator.json \
        cargo bench --bench bench_coordinator; then
        echo "FAIL: bench_coordinator run"
        status=1
    fi
fi

if [ "$status" -eq 0 ]; then
    echo "CI OK"
fi
exit "$status"

#!/usr/bin/env bash
# Repo CI: format check, lints, tests. Run from anywhere.
#
# * `cargo fmt --check` is advisory (non-fatal): the tree predates rustfmt
#   enforcement and carries hand-aligned tables/diagrams; drift is printed
#   so it stays visible without blocking merges.
# * clippy runs with -D warnings plus a small documented allow-list of
#   style lints the codebase deliberately does not follow:
#     - needless_range_loop: index loops mirror the hardware column/lane
#       structure and are clearer than iterator chains there;
#     - too_many_arguments: netlist builder helpers take per-signal args;
#     - type_complexity: engine/factory types are spelled out once;
#     - new_without_default: `new()` constructors without Default impls.
# * `cargo test -q` is the tier-1 gate and must pass.

set -uo pipefail
cd "$(dirname "$0")"

status=0

echo "== cargo fmt --check (advisory) =="
if ! cargo fmt --check 2>/dev/null; then
    echo "warning: rustfmt differences found (advisory only)"
fi

echo "== cargo clippy =="
if ! cargo clippy --all-targets -- -D warnings \
    -A clippy::needless_range_loop \
    -A clippy::too_many_arguments \
    -A clippy::type_complexity \
    -A clippy::new_without_default; then
    echo "FAIL: clippy"
    status=1
fi

echo "== cargo test =="
if ! cargo test -q; then
    echo "FAIL: tests"
    status=1
fi

if [ "$status" -eq 0 ]; then
    echo "CI OK"
fi
exit "$status"

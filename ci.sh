#!/usr/bin/env bash
# Repo CI: format check, lints, build, tests. Run from anywhere.
#
# Mirrors the ROADMAP tier-1 gate: `cargo build --release && cargo test -q`
# (both fatal), with lints and compile-only bench smoke around it.
#
# * `cargo fmt --check` is advisory (non-fatal): the tree predates rustfmt
#   enforcement and carries hand-aligned tables/diagrams; drift is printed
#   so it stays visible without blocking merges.
# * clippy runs with -D warnings plus a small documented allow-list of
#   style lints the codebase deliberately does not follow:
#     - needless_range_loop: index loops mirror the hardware column/lane
#       structure and are clearer than iterator chains there;
#     - too_many_arguments: netlist builder helpers take per-signal args;
#     - type_complexity: engine/factory types are spelled out once;
#     - new_without_default: `new()` constructors without Default impls.
# * `cargo build --release` is the first half of the tier-1 gate and must
#   succeed before tests run.
# * `cargo bench --no-run` compile-checks every bench target (the bench
#   harness is `harness = false`, so nothing executes) — benches stay
#   buildable without spending CI minutes running them.
# * `cargo test -q` is the second half of the tier-1 gate and must pass.
# * `--bench-json`: after a green gate, additionally run the bench_conv
#   group in quick mode with SFCMUL_BENCH_JSON=BENCH_conv.json, refreshing
#   the machine-readable perf trajectory at the repo root (hosted CI
#   uploads it as an artifact per run; see EXPERIMENTS.md).

set -uo pipefail
cd "$(dirname "$0")"

bench_json=0
for arg in "$@"; do
    case "$arg" in
        --bench-json) bench_json=1 ;;
        *) echo "usage: ./ci.sh [--bench-json]" >&2; exit 2 ;;
    esac
done

status=0

echo "== cargo fmt --check (advisory) =="
if ! cargo fmt --check 2>/dev/null; then
    echo "warning: rustfmt differences found (advisory only)"
fi

echo "== cargo clippy =="
if ! cargo clippy --all-targets -- -D warnings \
    -A clippy::needless_range_loop \
    -A clippy::too_many_arguments \
    -A clippy::type_complexity \
    -A clippy::new_without_default; then
    echo "FAIL: clippy"
    status=1
fi

echo "== cargo build --release (tier-1) =="
if ! cargo build --release; then
    echo "FAIL: release build (skipping bench smoke and tests: they would re-hit the same compile errors)"
    status=1
else
    echo "== cargo bench --no-run (compile smoke) =="
    if ! cargo bench --no-run; then
        echo "FAIL: bench targets do not compile"
        status=1
    fi

    echo "== cargo test (tier-1) =="
    if ! cargo test -q; then
        echo "FAIL: tests"
        status=1
    fi
fi

if [ "$bench_json" -eq 1 ] && [ "$status" -eq 0 ]; then
    echo "== bench_conv → BENCH_conv.json (quick mode) =="
    if ! SFCMUL_BENCH_QUICK=1 SFCMUL_BENCH_JSON=BENCH_conv.json \
        cargo bench --bench bench_conv; then
        echo "FAIL: bench_conv run"
        status=1
    fi
fi

if [ "$status" -eq 0 ]; then
    echo "CI OK"
fi
exit "$status"

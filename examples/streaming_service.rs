//! End-to-end driver (EXPERIMENTS.md §E2E): streams a batch of synthetic
//! camera frames through the full three-layer stack — the L3 coordinator
//! (tiling, dynamic batching, backpressure) serving *two named designs at
//! once* (`proposed@8` A/B'd against `exact@8`), each dispatched to the
//! AOT-compiled JAX/Pallas executable via PJRT when artifacts are present
//! (in-process LUT engine otherwise) — and reports aggregate plus
//! per-design throughput/latency and output fidelity.
//!
//! Run: `make artifacts && cargo run --release --example streaming_service`

use sfcmul::coordinator::{engines, Coordinator, CoordinatorConfig, EngineSpec, TileEngine};
use sfcmul::image::{edge_detect, psnr, synthetic_scene, Operator};
use sfcmul::multipliers::{registry, DesignSpec};
use std::sync::Arc;
use std::time::Instant;

const DESIGNS: [&str; 2] = ["proposed@8", "exact@8"];

fn main() {
    // Resolve each design through the one engines::resolve() path,
    // preferring PJRT and falling back to the in-process LUT engine.
    let mut named: Vec<(String, Arc<dyn TileEngine>)> = Vec::new();
    for design in DESIGNS {
        let spec: DesignSpec = design.parse().expect("valid spec");
        let (engine, backend) =
            engines::resolve_with_fallback(EngineSpec::Pjrt, &spec).expect("engine");
        println!("engine[{design}]: {backend}");
        named.push((design.to_string(), engine));
    }
    let coord = Coordinator::start_named(
        named,
        CoordinatorConfig { workers: 4, queue_capacity: 256, max_batch: 8, ..Default::default() },
    );

    const JOBS: usize = 64;
    const SIZE: usize = 256;
    println!("streaming {JOBS} frames of {SIZE}x{SIZE}, round-robin across {DESIGNS:?} ...");
    let t0 = Instant::now();
    let handles: Vec<_> = (0..JOBS)
        .map(|i| {
            let design = DESIGNS[i % DESIGNS.len()];
            coord
                .submit_to(synthetic_scene(SIZE, SIZE, i as u64), Some(design), Operator::Laplacian)
                .expect("registered design")
        })
        .collect();
    let mut results = Vec::new();
    for h in handles {
        results.push(h.wait().expect("healthy fleet completes every job"));
    }
    let wall = t0.elapsed();

    // fidelity check: job 0 (proposed) and job 1 (exact) against the
    // direct model paths
    let proposed = registry().build_str(DESIGNS[0]).unwrap();
    let exact = registry().build_str(DESIGNS[1]).unwrap();
    let direct_p = edge_detect(&synthetic_scene(SIZE, SIZE, 0), proposed.as_ref());
    let direct_e = edge_detect(&synthetic_scene(SIZE, SIZE, 1), exact.as_ref());
    assert_eq!(&results[0].edges, &direct_p, "served proposed == direct path");
    assert_eq!(&results[1].edges, &direct_e, "served exact == direct path");
    let reference = edge_detect(&synthetic_scene(SIZE, SIZE, 0), exact.as_ref());

    let m = coord.shutdown();
    let mpix = (JOBS * SIZE * SIZE) as f64 / wall.as_secs_f64() / 1e6;
    println!(
        "done: {} jobs / {} tiles in {:.2} s  ({mpix:.1} Mpix/s, {:.1} jobs/s)",
        m.jobs_completed,
        m.tiles_processed,
        wall.as_secs_f64(),
        JOBS as f64 / wall.as_secs_f64()
    );
    println!(
        "aggregate latency p50/p90/p99 = {:.1}/{:.1}/{:.1} ms, mean batch {:.2}, engine busy {:.2} s",
        m.latency_p50_ms, m.latency_p90_ms, m.latency_p99_ms, m.mean_batch_size,
        m.engine_busy.as_secs_f64()
    );
    for row in &m.per_engine {
        println!(
            "  {:<12} jobs {:>3}  tiles {:>5}  p50/p99 {:>6.1}/{:>6.1} ms  busy {:.2} s",
            row.name,
            row.jobs_completed,
            row.tiles_processed,
            row.latency_p50_ms,
            row.latency_p99_ms,
            row.engine_busy.as_secs_f64()
        );
    }
    println!(
        "fidelity: served == direct model path (bit-exact per design); \
         proposed PSNR vs exact multiplier: {:.2} dB",
        psnr(&reference, &results[0].edges)
    );
}

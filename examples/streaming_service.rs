//! End-to-end driver (EXPERIMENTS.md §E2E): streams a batch of synthetic
//! camera frames through the full three-layer stack — L3 tokio-style
//! coordinator (tiling, dynamic batching, backpressure) dispatching to
//! the AOT-compiled JAX/Pallas executable via PJRT when artifacts are
//! present (in-process LUT engine otherwise) — and reports throughput,
//! latency percentiles and output fidelity.
//!
//! Run: `make artifacts && cargo run --release --example streaming_service`

use sfcmul::coordinator::{Coordinator, CoordinatorConfig, LutTileEngine, TileEngine};
use sfcmul::image::{edge_detect, psnr, synthetic_scene};
use sfcmul::multipliers::{build_design, lut::product_table, DesignId};
use sfcmul::runtime::{artifacts_available, artifacts_dir, PjrtTileEngine};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let model = build_design(DesignId::Proposed, 8);
    let table = product_table(model.as_ref());

    let dir = artifacts_dir();
    let engine: Arc<dyn TileEngine> = if artifacts_available(&dir) {
        println!("engine: PJRT (AOT JAX/Pallas artifact from {dir:?})");
        Arc::new(PjrtTileEngine::new(&dir, "proposed", table.clone()).expect("pjrt"))
    } else {
        println!("engine: in-process LUT (run `make artifacts` for the PJRT path)");
        Arc::new(LutTileEngine::from_table("proposed", table.clone()))
    };

    let coord = Coordinator::start(
        engine,
        CoordinatorConfig { workers: 4, queue_capacity: 256, max_batch: 8 },
    );

    const JOBS: usize = 64;
    const SIZE: usize = 256;
    println!("streaming {JOBS} frames of {SIZE}x{SIZE} ...");
    let t0 = Instant::now();
    let handles: Vec<_> = (0..JOBS)
        .map(|i| coord.submit(synthetic_scene(SIZE, SIZE, i as u64)))
        .collect();
    let mut results = Vec::new();
    for h in handles {
        results.push(h.wait());
    }
    let wall = t0.elapsed();

    // fidelity check on one frame against the direct model path
    let check_img = synthetic_scene(SIZE, SIZE, 0);
    let direct = edge_detect(&check_img, model.as_ref());
    let served = &results[0].edges;
    assert_eq!(served, &direct, "served output must equal the direct path bit-for-bit");
    let exact = build_design(DesignId::Exact, 8);
    let reference = edge_detect(&check_img, exact.as_ref());

    let m = coord.shutdown();
    let mpix = (JOBS * SIZE * SIZE) as f64 / wall.as_secs_f64() / 1e6;
    println!(
        "done: {} jobs / {} tiles in {:.2} s  ({mpix:.1} Mpix/s, {:.1} jobs/s)",
        m.jobs_completed,
        m.tiles_processed,
        wall.as_secs_f64(),
        JOBS as f64 / wall.as_secs_f64()
    );
    println!(
        "latency p50/p90/p99 = {:.1}/{:.1}/{:.1} ms, mean batch {:.2}, engine busy {:.2} s",
        m.latency_p50_ms, m.latency_p90_ms, m.latency_p99_ms, m.mean_batch_size,
        m.engine_busy.as_secs_f64()
    );
    println!(
        "fidelity: served == direct model path (bit-exact); PSNR vs exact multiplier: {:.2} dB",
        psnr(&reference, served)
    );
}

//! Design-space exploration driven entirely by spec strings: every point
//! is named in the `family[@bits][:trunc=...][:comp=...]` grammar and
//! built through the registry — no hardcoded constructor list. Prints
//! error metrics and unit-gate hardware figures per spec, then the
//! classic ablation report and the Fig 10 PDP-vs-MRED trade-off.
//!
//! Run: `cargo run --release --example design_space`

use sfcmul::error::{error_metrics, error_metrics_sampled};
use sfcmul::hwmodel::raw_hw_for_spec;
use sfcmul::multipliers::{registry, DesignSpec};

/// The sweep: canonical paper designs, compensation/truncation variants,
/// and 16-bit scale-ups — all as plain strings.
const SWEEP: &[&str] = &[
    // paper comparison set (canonical)
    "exact@8",
    "d12@8",
    "d5@8",
    "d4@8",
    "d1@8",
    "d7@8",
    "d2@8",
    "proposed@8",
    // compensation ablation on the proposed design
    "proposed@8:comp=none",
    "proposed@8:comp=const",
    // truncation depth ablation (trunc=none auto-degenerates comp=paper)
    "proposed@8:trunc=none",
    "proposed@8:trunc=3",
    "proposed@8:trunc=5",
    // truncation-only reference (exact CSP compressors)
    "exact@8:trunc=7",
    // wider operands
    "proposed@16",
    "proposed@16:comp=const",
    "d2@16",
];

fn main() {
    println!("== Design-space sweep over spec strings ==");
    println!(
        "  {:<34} {:>8}  {:>8}  {:>9}  {:>7}",
        "spec", "NMED(%)", "MRED(%)", "area(GE)", "delay"
    );
    for s in SWEEP {
        let spec: DesignSpec = s.parse().expect("sweep entries are valid specs");
        let model = match registry().build(&spec) {
            Ok(m) => m,
            Err(e) => {
                println!("  {s:<34} unbuildable: {e}");
                continue;
            }
        };
        // exhaustive metrics to N=10; sampled beyond
        let e = if model.bits() <= 10 {
            error_metrics(model.as_ref())
        } else {
            error_metrics_sampled(model.as_ref(), 200_000, 42)
        };
        let hw = raw_hw_for_spec(&spec, 42).expect("buildable spec has hw figures");
        println!(
            "  {:<34} {:>8.3}  {:>8.2}  {:>9.1}  {:>7.1}",
            s,
            e.nmed * 100.0,
            e.mred * 100.0,
            hw.area_ge,
            hw.delay_units
        );
    }
    println!();
    print!("{}", sfcmul::tables::ablation_report(42));
    println!();
    print!("{}", sfcmul::tables::f10::render(42));
}

//! Design-space exploration: the ablation study behind DESIGN.md's
//! reconstruction choices plus the Fig 10 PDP-vs-MRED trade-off.
//!
//! Run: `cargo run --release --example design_space`

fn main() {
    print!("{}", sfcmul::tables::ablation_report(42));
    println!();
    print!("{}", sfcmul::tables::f10::render(42));
}

//! Load generator for the network serving front-end (EXPERIMENTS.md
//! §Saturation): N client threads stream mixed edge/GEMM frames over
//! real sockets and report per-client throughput, reply latency, and
//! the server's closing `/metrics` gauges.
//!
//! Two ways to run:
//!
//! * Self-contained (default): spins up an in-process two-design fleet
//!   (`proposed@8` A/B `exact@8`) behind a loopback server, drives it,
//!   tears it down. `cargo run --release --example load_gen`
//! * Against a live server: point it at `sfcmul serve --listen ADDR`.
//!   `cargo run --release --example load_gen -- --addr 127.0.0.1:7878`
//!
//! Options: `--clients N` (default 4), `--jobs J` per client (default
//! 32), `--size S` edge frames of SxS (default 128), `--gemm-every K`
//! (every K-th job is a GEMM, default 4; 0 disables),
//! `--quality-sample-n N` (self-contained mode only: shadow-sample 1
//! work unit in N for the live quality gauges, default 16; 0 off).
//!
//! The run closes with an observability digest scraped from
//! `/metrics`: per-engine live approximation quality (NMED, mismatch
//! rate over the sampled pairs) and per-stage mean latencies from the
//! `sfcmul_stage_latency_seconds` histograms.

use sfcmul::coordinator::{Coordinator, CoordinatorConfig, LutTileEngine, TileEngine};
use sfcmul::image::{synthetic_scene, Operator};
use sfcmul::multipliers::registry;
use sfcmul::nn::MatI8;
use sfcmul::server::{http_get, Client, ClientError, Server, ServerConfig};
use sfcmul::util::cli::Args;
use sfcmul::util::prng::Xoshiro256;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Instant;

const DESIGNS: [&str; 2] = ["proposed@8", "exact@8"];
const OPS: [Operator; 3] = [Operator::Laplacian, Operator::Sobel, Operator::Roberts];

struct ClientReport {
    ok: usize,
    busy: usize,
    quota: usize,
    other_err: usize,
    total_latency_us: u64,
}

fn drive_client(
    addr: SocketAddr,
    id: usize,
    jobs: usize,
    size: usize,
    gemm_every: usize,
) -> ClientReport {
    let mut report =
        ClientReport { ok: 0, busy: 0, quota: 0, other_err: 0, total_latency_us: 0 };
    let mut client = Client::connect(addr).expect("connect");
    let mut rng = Xoshiro256::seeded(0x10ad ^ id as u64);
    for j in 0..jobs {
        let design = DESIGNS[(id + j) % DESIGNS.len()];
        let outcome = if gemm_every > 0 && j % gemm_every == gemm_every - 1 {
            let a = MatI8::random(24, 16, &mut rng);
            let b = MatI8::random(16, 24, &mut rng);
            client.gemm(&a, &b, Some(design)).map(|r| r.latency_us)
        } else {
            let img = synthetic_scene(size, size, (id * jobs + j) as u64);
            let op = OPS[j % OPS.len()];
            client.edge(&img, Some(design), op).map(|r| r.latency_us)
        };
        match outcome {
            Ok(latency_us) => {
                report.ok += 1;
                report.total_latency_us += latency_us;
            }
            Err(ClientError::Server { code, .. }) if code == "busy" => report.busy += 1,
            Err(ClientError::Server { code, .. }) if code == "quota" => report.quota += 1,
            Err(_) => report.other_err += 1,
        }
    }
    let _ = client.quit();
    report
}

/// Value of the first sample line starting with `prefix` in a
/// Prometheus exposition, if present and numeric.
fn sample(body: &str, prefix: &str) -> Option<f64> {
    body.lines()
        .find(|l| l.starts_with(prefix))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
}

/// End-of-run observability digest: per-engine live approximation
/// quality and per-stage mean latencies, scraped from the exposition
/// text (so it works identically against a remote `--addr` server).
/// Engines are discovered from the `sfcmul_quality_nmed` series.
fn print_digest(body: &str) {
    let engines: Vec<&str> = body
        .lines()
        .filter_map(|l| l.strip_prefix("sfcmul_quality_nmed{engine=\""))
        .filter_map(|rest| rest.split('"').next())
        .collect();
    if engines.is_empty() {
        return;
    }
    println!("observability digest (per engine, from /metrics):");
    for engine in engines {
        let q = |name: &str| sample(body, &format!("{name}{{engine=\"{engine}\"}}"));
        let st = |name: &str, stage: &str| {
            sample(body, &format!("{name}{{engine=\"{engine}\",stage=\"{stage}\"}}"))
        };
        let pairs = q("sfcmul_quality_sampled_pairs_total").unwrap_or(0.0);
        if pairs > 0.0 {
            println!(
                "  {engine}: NMED {:.6}  mismatch {:.2}% over {pairs} sampled pairs  max|ED| {}",
                q("sfcmul_quality_nmed").unwrap_or(0.0),
                q("sfcmul_quality_mismatch_rate").unwrap_or(0.0) * 100.0,
                q("sfcmul_quality_max_ed").unwrap_or(0.0),
            );
        } else {
            println!(
                "  {engine}: quality sampler idle (serve with --quality-sample-n to light it up)"
            );
        }
        let mut stages = String::new();
        for stage in ["queue_wait", "compute", "e2e"] {
            let count = st("sfcmul_stage_latency_seconds_count", stage).unwrap_or(0.0);
            if count > 0.0 {
                let sum = st("sfcmul_stage_latency_seconds_sum", stage).unwrap_or(0.0);
                stages
                    .push_str(&format!("{stage} {:.2} ms ({count:.0})  ", sum / count * 1e3));
            }
        }
        if !stages.is_empty() {
            println!("    stage means: {}", stages.trim_end());
        }
    }
}

fn main() {
    let args = Args::from_env().expect("args");
    let clients = args.get_parse("clients", 4usize).unwrap_or(4);
    let jobs = args.get_parse("jobs", 32usize).unwrap_or(32);
    let size = args.get_parse("size", 128usize).unwrap_or(128);
    let gemm_every = args.get_parse("gemm-every", 4usize).unwrap_or(4);
    let quality_n = args.get_parse("quality-sample-n", 16u64).unwrap_or(16);

    // No --addr: stand up a local fleet + server to drive.
    let local = match args.get("addr") {
        Some(_) => None,
        None => {
            let named: Vec<(String, Arc<dyn TileEngine>)> = DESIGNS
                .iter()
                .map(|d| {
                    let model = registry().build_str(d).expect("design");
                    (d.to_string(), Arc::new(LutTileEngine::new(model.as_ref())) as _)
                })
                .collect();
            let coord = Arc::new(Coordinator::start_named(
                named,
                CoordinatorConfig {
                    workers: 4,
                    queue_capacity: 256,
                    max_batch: 8,
                    quality_sample_n: quality_n,
                    ..Default::default()
                },
            ));
            let server = Server::start(
                coord.clone(),
                ServerConfig {
                    conn_workers: clients.max(4),
                    max_inflight: 256,
                    ..ServerConfig::default()
                },
            )
            .expect("server");
            println!("self-contained mode: fleet {DESIGNS:?} behind {}", server.local_addr());
            Some((coord, server))
        }
    };
    let addr: SocketAddr = match &local {
        Some((_, server)) => server.local_addr(),
        None => args.get("addr").unwrap().parse().expect("--addr must be host:port"),
    };

    println!(
        "driving {clients} clients x {jobs} jobs ({size}x{size} edge frames, \
         GEMM every {gemm_every}) against {addr}"
    );
    let t0 = Instant::now();
    let reports: Vec<ClientReport> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|id| scope.spawn(move || drive_client(addr, id, jobs, size, gemm_every)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let wall = t0.elapsed();

    let ok: usize = reports.iter().map(|r| r.ok).sum();
    let busy: usize = reports.iter().map(|r| r.busy).sum();
    let quota: usize = reports.iter().map(|r| r.quota).sum();
    let other: usize = reports.iter().map(|r| r.other_err).sum();
    let lat_sum: u64 = reports.iter().map(|r| r.total_latency_us).sum();
    println!(
        "done in {:.2} s: {ok} ok ({:.1} jobs/s), {busy} busy, {quota} quota, {other} errors",
        wall.as_secs_f64(),
        ok as f64 / wall.as_secs_f64()
    );
    if ok > 0 {
        println!(
            "mean server-side job latency {:.2} ms",
            lat_sum as f64 / ok as f64 / 1e3
        );
    }

    // Close with the server's own view of the run.
    match http_get(addr, "/metrics") {
        Ok((200, body)) => {
            println!("GET /metrics highlights:");
            for line in body.lines().filter(|l| {
                l.starts_with("sfcmul_jobs_")
                    || l.starts_with("sfcmul_queue_depth")
                    || l.starts_with("sfcmul_server_")
                    || l.contains("quantile=\"0.99\"")
            }) {
                println!("  {line}");
            }
            print_digest(&body);
        }
        Ok((code, _)) => println!("GET /metrics -> HTTP {code}"),
        Err(e) => println!("GET /metrics failed: {e}"),
    }

    if let Some((coord, server)) = local {
        server.stop();
        if let Ok(c) = Arc::try_unwrap(coord) {
            c.shutdown();
        }
    }
}

//! Quickstart: build multipliers through the spec/registry API, multiply
//! some numbers, inspect error metrics, compressor statistics and
//! hardware figures.
//!
//! Run: `cargo run --release --example quickstart`

use sfcmul::compressors::{abc1_stats, abcd1_stats};
use sfcmul::error::error_metrics;
use sfcmul::hwmodel::raw_hw;
use sfcmul::multipliers::{registry, DesignSpec};

fn main() {
    // 1. Designs are built from declarative spec strings
    //    (`family[@bits][:trunc=...][:comp=...]`) through the registry.
    let proposed = registry().build_str("proposed@8").expect("registered design");
    let exact = registry().build_str("exact@8").expect("registered design");
    println!("a × b: exact vs proposed approximate (specs proposed@8 / exact@8)");
    for (a, b) in [(13i64, 27), (-100, 90), (127, -128), (7, -7)] {
        println!(
            "  {a:>5} × {b:>5} = {:>7} ≈ {:>7}  (err {:+})",
            exact.multiply(a, b),
            proposed.multiply(a, b),
            proposed.multiply(a, b) - exact.multiply(a, b)
        );
    }

    // Specs round-trip their string form, so they can live in configs,
    // job payloads, CLI flags...
    let spec: DesignSpec = "proposed@16:comp=const".parse().unwrap();
    println!(
        "\nparsed spec {spec}: {} bits, family {:?}, roundtrip {}",
        spec.bits,
        spec.compressors,
        spec.to_string().parse::<DesignSpec>().unwrap() == spec
    );
    let wide = registry().build(&spec).expect("16-bit variant");
    println!("  {} at N=16: 1000 × -999 ≈ {}", wide.name(), wide.multiply(1000, -999));

    // 2. Error metrics over all 65 536 operand pairs (paper Table 4 row).
    let e = error_metrics(proposed.as_ref());
    println!(
        "\nexhaustive error metrics: ER {:.2}%  NMED {:.3}%  MRED {:.2}%  ME {:+.1}",
        e.er * 100.0,
        e.nmed * 100.0,
        e.mred * 100.0,
        e.me
    );

    // 3. The sign-focused compressor cells (paper Tables 2/3).
    let abc1 = abc1_stats(&sfcmul::compressors::proposed::ProposedApproxAbc1);
    let abcd1 = abcd1_stats(&sfcmul::compressors::proposed::ProposedApproxAbcd1);
    println!(
        "compressors: A+B+C+1 P_E={:.4} E_mean={:+.4} | A+B+C+D+1 P_E={:.4} E_mean={:+.4}",
        abc1.error_probability, abc1.mean_error, abcd1.error_probability, abcd1.mean_error
    );

    // 4. Hardware figures (unit-gate model; see `sfcmul tables --id t5`
    //    for the calibrated Table 5).
    let hw_p = raw_hw(proposed.as_ref(), 42);
    let hw_e = raw_hw(exact.as_ref(), 42);
    println!(
        "hardware: area {:.0} GE (exact {:.0}), delay {:.1} (exact {:.1}), switched-cap {:.1} (exact {:.1})",
        hw_p.area_ge, hw_e.area_ge, hw_p.delay_units, hw_e.delay_units, hw_p.switched_cap, hw_e.switched_cap
    );
    println!(
        "\nnext: `cargo run --release --example design_space` sweeps the spec space;\n      `cargo run --release -- tables --id all` regenerates every paper table/figure"
    );
}

//! Edge detection with every multiplier design (paper §4 / Fig 9): runs
//! the Laplacian convolution over the synthetic scene with each design,
//! writes the edge maps as PGM files, and reports PSNR against the
//! exact-multiplier reference — then repeats the exercise with the
//! Sobel gradient-magnitude operator (|Gx|+|Gy|), the workload that
//! stresses the signed partial-product path hardest.
//!
//! Run: `cargo run --release --example edge_detection [-- <out_dir>]`

use sfcmul::image::ops::{apply_operator, Operator};
use sfcmul::image::{edge_detect, psnr, synthetic_scene};
use sfcmul::multipliers::{all_designs, build_design, DesignId};
use std::path::PathBuf;

fn main() {
    let out_dir = PathBuf::from(std::env::args().nth(1).unwrap_or_else(|| "out".into()));
    std::fs::create_dir_all(&out_dir).expect("create out dir");
    let img = synthetic_scene(256, 256, 11);
    img.write_pgm(&out_dir.join("scene.pgm")).unwrap();

    let exact = build_design(DesignId::Exact, 8);
    let reference = edge_detect(&img, exact.as_ref());
    reference.write_pgm(&out_dir.join("edges_exact.pgm")).unwrap();

    println!("design            PSNR vs exact edge map");
    let mut best = (DesignId::Exact, f64::NEG_INFINITY);
    for (id, model) in all_designs(8) {
        if id == DesignId::Exact {
            continue;
        }
        let edges = edge_detect(&img, model.as_ref());
        let db = psnr(&reference, &edges);
        let file = out_dir.join(format!("edges_{id:?}.pgm").to_lowercase());
        edges.write_pgm(&file).unwrap();
        println!("  {:<17} {db:>6.2} dB  -> {}", id.paper_name(), file.display());
        if db > best.1 {
            best = (id, db);
        }
    }
    println!(
        "highest PSNR: {} at {:.2} dB (paper: Proposed at 20.13 dB)",
        best.0.paper_name(),
        best.1
    );
    assert_eq!(best.0, DesignId::Proposed, "paper's Fig 9 ordering should hold");

    // Beyond the paper: the same scene through the Sobel gradient
    // magnitude — a signed two-pass workload served by the same operator
    // pipeline (`--op sobel` on the CLI).
    let sobel_ref = apply_operator(&img, Operator::Sobel, exact.as_ref());
    sobel_ref.write_pgm(&out_dir.join("sobel_exact.pgm")).unwrap();
    let proposed = build_design(DesignId::Proposed, 8);
    let sobel_prop = apply_operator(&img, Operator::Sobel, proposed.as_ref());
    let sobel_file = out_dir.join("sobel_proposed.pgm");
    sobel_prop.write_pgm(&sobel_file).unwrap();
    println!(
        "sobel |Gx|+|Gy| (proposed design): {:.2} dB vs exact -> {}",
        psnr(&sobel_ref, &sobel_prop),
        sobel_file.display()
    );
}

//! Edge detection with every multiplier design (paper §4 / Fig 9): runs
//! the Laplacian convolution over the synthetic scene with each design,
//! writes the edge maps as PGM files, and reports PSNR against the
//! exact-multiplier reference.
//!
//! Run: `cargo run --release --example edge_detection [-- <out_dir>]`

use sfcmul::image::{edge_detect, psnr, synthetic_scene};
use sfcmul::multipliers::{all_designs, build_design, DesignId};
use std::path::PathBuf;

fn main() {
    let out_dir = PathBuf::from(std::env::args().nth(1).unwrap_or_else(|| "out".into()));
    std::fs::create_dir_all(&out_dir).expect("create out dir");
    let img = synthetic_scene(256, 256, 11);
    img.write_pgm(&out_dir.join("scene.pgm")).unwrap();

    let exact = build_design(DesignId::Exact, 8);
    let reference = edge_detect(&img, exact.as_ref());
    reference.write_pgm(&out_dir.join("edges_exact.pgm")).unwrap();

    println!("design            PSNR vs exact edge map");
    let mut best = (DesignId::Exact, f64::NEG_INFINITY);
    for (id, model) in all_designs(8) {
        if id == DesignId::Exact {
            continue;
        }
        let edges = edge_detect(&img, model.as_ref());
        let db = psnr(&reference, &edges);
        let file = out_dir.join(format!("edges_{id:?}.pgm").to_lowercase());
        edges.write_pgm(&file).unwrap();
        println!("  {:<17} {db:>6.2} dB  -> {}", id.paper_name(), file.display());
        if db > best.1 {
            best = (id, db);
        }
    }
    println!(
        "highest PSNR: {} at {:.2} dB (paper: Proposed at 20.13 dB)",
        best.0.paper_name(),
        best.1
    );
    assert_eq!(best.0, DesignId::Proposed, "paper's Fig 9 ordering should hold");
}

//! Miniature property-based testing harness (stand-in for `proptest`).
//!
//! A property is a predicate over values drawn from a [`Gen`]. The runner
//! draws `cases` inputs; on the first failure it greedily *shrinks* the
//! counterexample (using the generator's shrink function) before panicking
//! with the minimal failing input, pretty-printed via `Debug`.
//!
//! ```
//! use sfcmul::util::prop::{forall, Gen};
//! forall("add commutes", 256, Gen::i8_pair(), |&(a, b)| {
//!     (a as i32 + b as i32) == (b as i32 + a as i32)
//! });
//! ```

use super::prng::Xoshiro256;
use std::fmt::Debug;

/// A generator bundles a sampling function and a shrinking function.
pub struct Gen<T> {
    sample: Box<dyn Fn(&mut Xoshiro256) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        sample: impl Fn(&mut Xoshiro256) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Self { sample: Box::new(sample), shrink: Box::new(shrink) }
    }

    /// Generator without shrinking support.
    pub fn no_shrink(sample: impl Fn(&mut Xoshiro256) -> T + 'static) -> Self {
        Self::new(sample, |_| Vec::new())
    }

    pub fn sample(&self, rng: &mut Xoshiro256) -> T {
        (self.sample)(rng)
    }

    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value (loses shrinking unless `f` is cheapish to
    /// re-apply; shrinks are mapped through `f` of shrunk *inputs* is not
    /// possible without an inverse, so mapped generators do not shrink).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::no_shrink(move |rng| f(self.sample(rng)))
    }
}

fn shrink_i64(v: i64) -> Vec<i64> {
    let mut out = Vec::new();
    if v != 0 {
        out.push(0);
        out.push(v / 2);
        if v > 0 {
            out.push(v - 1);
        } else {
            out.push(v + 1);
        }
        out.dedup();
        out.retain(|&x| x != v);
    }
    out
}

impl Gen<i64> {
    pub fn i64_range(lo: i64, hi: i64) -> Gen<i64> {
        Gen::new(
            move |rng| rng.range_i64(lo, hi),
            move |&v| shrink_i64(v).into_iter().filter(|&x| x >= lo && x <= hi).collect(),
        )
    }
}

impl Gen<i8> {
    pub fn i8_any() -> Gen<i8> {
        Gen::new(
            |rng| rng.next_i8(),
            |&v| shrink_i64(v as i64).into_iter().map(|x| x as i8).collect(),
        )
    }
}

impl Gen<(i8, i8)> {
    pub fn i8_pair() -> Gen<(i8, i8)> {
        Gen::new(
            |rng| (rng.next_i8(), rng.next_i8()),
            |&(a, b)| {
                let mut out: Vec<(i8, i8)> = Vec::new();
                for sa in shrink_i64(a as i64) {
                    out.push((sa as i8, b));
                }
                for sb in shrink_i64(b as i64) {
                    out.push((a, sb as i8));
                }
                out
            },
        )
    }
}

impl Gen<Vec<u8>> {
    /// Byte vectors with length in `[0, max_len]`; shrinks by halving length
    /// and zeroing elements.
    pub fn bytes(max_len: usize) -> Gen<Vec<u8>> {
        Gen::new(
            move |rng| {
                let n = rng.below(max_len as u64 + 1) as usize;
                (0..n).map(|_| rng.next_u64() as u8).collect()
            },
            |v: &Vec<u8>| {
                let mut out = Vec::new();
                if !v.is_empty() {
                    out.push(v[..v.len() / 2].to_vec());
                    out.push(v[1..].to_vec());
                    if v.iter().any(|&b| b != 0) {
                        out.push(vec![0; v.len()]);
                    }
                }
                out
            },
        )
    }
}

/// Run `cases` random trials of `prop`; shrink and panic on failure.
///
/// The seed is derived from the property name so that failures are
/// reproducible run-to-run but distinct properties get distinct streams.
pub fn forall<T: Clone + Debug + 'static>(name: &str, cases: usize, gen: Gen<T>, prop: impl Fn(&T) -> bool) {
    let seed = name.bytes().fold(0xC0FF_EEu64, |h, b| {
        h.wrapping_mul(0x100_0000_01B3).wrapping_add(b as u64)
    });
    let mut rng = Xoshiro256::seeded(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if !prop(&input) {
            let minimal = shrink_failure(&gen, input, &prop);
            panic!(
                "property '{name}' failed at case {case}/{cases}; minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_failure<T: Clone + 'static>(gen: &Gen<T>, mut failing: T, prop: &impl Fn(&T) -> bool) -> T {
    // Greedy shrink: repeatedly take the first shrink candidate that still
    // fails, up to a budget to guarantee termination on cyclic shrinkers.
    let mut budget = 1000;
    'outer: while budget > 0 {
        for cand in gen.shrinks(&failing) {
            budget -= 1;
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
            if budget == 0 {
                break;
            }
        }
        break;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall("i8 square nonneg in i32", 512, Gen::i8_any(), |&a| {
            (a as i32) * (a as i32) >= 0
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_panics_with_counterexample() {
        forall("all i8 are small", 512, Gen::i8_any(), |&a| a.abs() < 5);
    }

    #[test]
    fn shrinker_reaches_small_values() {
        // The minimal |a| failing `a.abs() < 5` under our shrinker is 5 or -5
        // (shrink steps: 0, v/2, v∓1 — all monotonically decreasing in |v|).
        let gen = Gen::i8_any();
        let mut rng = Xoshiro256::seeded(99);
        let mut start = gen.sample(&mut rng);
        while (start as i32).abs() < 5 {
            start = gen.sample(&mut rng);
        }
        let minimal = shrink_failure(&gen, start, &|&a: &i8| (a as i32).abs() < 5);
        assert_eq!((minimal as i32).abs(), 5, "greedy shrink should reach the boundary");
    }

    #[test]
    fn bytes_generator_respects_max_len() {
        let gen = Gen::bytes(16);
        let mut rng = Xoshiro256::seeded(5);
        for _ in 0..200 {
            assert!(gen.sample(&mut rng).len() <= 16);
        }
    }
}

//! Micro-benchmark harness (stand-in for `criterion`).
//!
//! Usage inside a `harness = false` bench target:
//!
//! ```no_run
//! use sfcmul::util::bench::Bench;
//! let mut b = Bench::new("bench_example");
//! b.bench("mul_exact_fast", || {
//!     // workload under test; return a value to defeat DCE
//!     std::hint::black_box(3i16 * 4i16)
//! });
//! b.finish();
//! ```
//!
//! The harness (1) warms up, (2) calibrates an iteration count so each
//! sample runs ≥ `sample_target`, (3) collects `samples` timed samples and
//! reports median / mean ± sd / p90 and derived throughput. Results are
//! printed in a stable table format and can be appended as JSON lines to
//! `target/bench-results.jsonl` for the EXPERIMENTS.md record.
//!
//! Two environment knobs make bench runs scriptable:
//!
//! * `SFCMUL_BENCH_QUICK=1` — shrink warmup/sample budgets (CI mode);
//! * `SFCMUL_BENCH_JSON=path` — on [`Bench::finish`], additionally write
//!   the whole group as one machine-readable JSON document (schema
//!   `sfcmul-bench-v1`) to `path`. This is how `ci.sh --bench-json`
//!   produces the committed `BENCH_conv.json` perf trajectory.

use super::json::Json;
use super::stats;
use std::hint::black_box;
use std::io::Write;
use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    /// nanoseconds per iteration
    pub median_ns: f64,
    pub mean_ns: f64,
    pub sd_ns: f64,
    pub p90_ns: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
    /// optional elements processed per iteration (for throughput reporting)
    pub elems: Option<u64>,
}

impl BenchResult {
    pub fn throughput_m_elems(&self) -> Option<f64> {
        self.elems.map(|e| e as f64 / self.median_ns * 1e3)
    }
}

pub struct Bench {
    group: String,
    quick: bool,
    warmup: Duration,
    sample_target: Duration,
    samples: usize,
    results: Vec<BenchResult>,
    /// elements per iteration for the *next* registered bench
    next_elems: Option<u64>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // Quick mode for CI-ish runs: SFCMUL_BENCH_QUICK=1 shrinks budgets.
        let quick = std::env::var("SFCMUL_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
        let header = format!("== bench group: {group} ==");
        println!("{header}");
        Self {
            group: group.to_string(),
            quick,
            warmup: if quick { Duration::from_millis(20) } else { Duration::from_millis(150) },
            sample_target: if quick { Duration::from_millis(5) } else { Duration::from_millis(25) },
            samples: if quick { 8 } else { 20 },
            results: Vec::new(),
            next_elems: None,
        }
    }

    /// Results recorded so far (bench binaries use this to derive and
    /// print cross-bench ratios, e.g. the colsum-vs-9-lookup speedup).
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Declare elements-per-iteration for the next `bench()` call so the
    /// report includes Melem/s throughput.
    pub fn throughput(&mut self, elems: u64) -> &mut Self {
        self.next_elems = Some(elems);
        self
    }

    /// Time `f`, which should return a value (passed through `black_box`).
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) {
        // Warmup and calibration.
        let warm_end = Instant::now() + self.warmup;
        let mut warm_iters: u64 = 0;
        let warm_start = Instant::now();
        while Instant::now() < warm_end {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64;
        let iters = ((self.sample_target.as_nanos() as f64 / per_iter.max(0.5)).ceil() as u64).max(1);

        let mut sample_ns: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            sample_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let mut sorted = sample_ns.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            name: name.to_string(),
            median_ns: stats::percentile_sorted(&sorted, 0.5),
            mean_ns: stats::mean(&sample_ns),
            sd_ns: stats::stddev(&sample_ns),
            p90_ns: stats::percentile_sorted(&sorted, 0.9),
            iters_per_sample: iters,
            samples: self.samples,
            elems: self.next_elems.take(),
        };
        let tp = res
            .throughput_m_elems()
            .map(|t| format!("  {t:10.2} Melem/s"))
            .unwrap_or_default();
        println!(
            "  {:<44} {:>12} median  {:>12} ±{:>10}  p90 {:>12}{tp}",
            res.name,
            fmt_ns(res.median_ns),
            fmt_ns(res.mean_ns),
            fmt_ns(res.sd_ns),
            fmt_ns(res.p90_ns),
        );
        self.results.push(res);
    }

    /// One result as a `sfcmul-bench-v1` JSON object.
    fn result_json(r: &BenchResult) -> Json {
        Json::obj()
            .set("name", r.name.as_str())
            .set("median_ns", r.median_ns)
            .set("mean_ns", r.mean_ns)
            .set("sd_ns", r.sd_ns)
            .set("p90_ns", r.p90_ns)
            .set("iters", Json::Int(r.iters_per_sample as i64))
            .set("samples", r.samples)
            .set("elems", r.elems.map(|e| Json::Int(e as i64)).unwrap_or(Json::Null))
            .set(
                "melems_per_s",
                r.throughput_m_elems().map(Json::Num).unwrap_or(Json::Null),
            )
    }

    /// Print a footer, append JSONL results under `target/`, and — when
    /// `SFCMUL_BENCH_JSON=path` is set — write the whole group as one
    /// machine-readable JSON document to `path` (the `BENCH_conv.json`
    /// perf-trajectory format; see EXPERIMENTS.md for regeneration).
    pub fn finish(self) {
        if let Ok(json_path) = std::env::var("SFCMUL_BENCH_JSON") {
            if !json_path.is_empty() {
                let doc = Json::obj()
                    .set("schema", "sfcmul-bench-v1")
                    .set("group", self.group.as_str())
                    .set("quick", self.quick)
                    .set("provenance", "measured")
                    .set("os", std::env::consts::OS)
                    .set("arch", std::env::consts::ARCH)
                    .set("results", Json::Arr(self.results.iter().map(Self::result_json).collect()));
                match std::fs::write(&json_path, format!("{doc}\n")) {
                    Ok(()) => println!("  wrote {json_path} ({} results)", self.results.len()),
                    Err(e) => eprintln!("  could not write {json_path}: {e}"),
                }
            }
        }
        let path = std::path::Path::new("target").join("bench-results.jsonl");
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        if let Ok(mut fh) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            for r in &self.results {
                let elems = r.elems.map(|e| e.to_string()).unwrap_or_else(|| "null".into());
                let _ = writeln!(
                    fh,
                    "{{\"group\":\"{}\",\"name\":\"{}\",\"median_ns\":{:.3},\"mean_ns\":{:.3},\"sd_ns\":{:.3},\"p90_ns\":{:.3},\"iters\":{},\"elems\":{}}}",
                    self.group, r.name, r.median_ns, r.mean_ns, r.sd_ns, r.p90_ns, r.iters_per_sample, elems
                );
            }
        }
        println!("== bench group {} done ({} benchmarks) ==", self.group, self.results.len());
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:8.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:8.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:8.2} ms", ns / 1e6)
    } else {
        format!("{:8.3} s ", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Env vars are process-global and `cargo test` is multi-threaded:
    /// every test that mutates `SFCMUL_BENCH_*` or calls `finish()` (which
    /// reads them) takes this lock so runs can't observe each other's
    /// variables or race on the JSON output path.
    static ENV_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn bench_runs_and_records() {
        let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::set_var("SFCMUL_BENCH_QUICK", "1");
        let mut b = Bench::new("selftest");
        b.throughput(64).bench("noop_sum", || (0..64u64).sum::<u64>());
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].median_ns > 0.0);
        assert!(b.results[0].throughput_m_elems().unwrap() > 0.0);
        b.finish();
    }

    #[test]
    fn result_json_covers_schema_fields() {
        let r = BenchResult {
            name: "conv_x".into(),
            median_ns: 10.0,
            mean_ns: 11.5,
            sd_ns: 1.0,
            p90_ns: 12.0,
            iters_per_sample: 5,
            samples: 8,
            elems: Some(65536),
        };
        let s = Bench::result_json(&r).to_string();
        assert!(s.contains("\"name\":\"conv_x\""));
        assert!(s.contains("\"median_ns\":10"));
        assert!(s.contains("\"elems\":65536"));
        assert!(s.contains("\"melems_per_s\":"));
        let none = BenchResult { elems: None, ..r };
        assert!(Bench::result_json(&none).to_string().contains("\"melems_per_s\":null"));
    }

    #[test]
    fn bench_json_env_writes_group_document() {
        let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::set_var("SFCMUL_BENCH_QUICK", "1");
        let path = std::env::temp_dir().join(format!("sfcmul_bench_{}.json", std::process::id()));
        std::env::set_var("SFCMUL_BENCH_JSON", &path);
        let mut b = Bench::new("jsontest");
        b.throughput(16).bench("sum16", || (0..16u64).sum::<u64>());
        b.finish();
        std::env::remove_var("SFCMUL_BENCH_JSON");
        let doc = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(doc.contains("\"schema\":\"sfcmul-bench-v1\""));
        assert!(doc.contains("\"group\":\"jsontest\""));
        assert!(doc.contains("\"name\":\"sum16\""));
        assert!(doc.contains("\"provenance\":\"measured\""));
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains("s "));
    }
}

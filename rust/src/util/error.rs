//! Minimal chained error type (stand-in for `anyhow` — the build
//! environment is offline, so the crate carries its own).
//!
//! [`Error`] is a message plus an optional boxed source. It converts from
//! `String`, `&str` and `std::io::Error`, so fallible code can write
//! `Err(format!("...").into())` and use `?` on I/O results inside
//! functions returning [`crate::Result`].

use std::fmt;

/// Crate-wide error: a human-readable message with an optional cause.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Error from a plain message.
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into(), source: None }
    }

    /// Error wrapping a cause with added context.
    pub fn wrap(
        context: impl Into<String>,
        source: impl std::error::Error + Send + Sync + 'static,
    ) -> Self {
        Self { msg: context.into(), source: Some(Box::new(source)) }
    }

    /// Add context, keeping `self` as the cause.
    pub fn context(self, context: impl Into<String>) -> Self {
        Self { msg: context.into(), source: Some(Box::new(self)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.source.as_deref();
        while let Some(c) = cause {
            write!(f, ": {c}")?;
            cause = c.source();
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `unwrap()`/`expect()` print Debug; show the full chain there too.
        fmt::Display::fmt(self, f)
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source
            .as_ref()
            .map(|s| s.as_ref() as &(dyn std::error::Error + 'static))
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Self {
        Error::msg(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Self {
        Error::msg(msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::wrap("I/O error", e)
    }
}

impl From<super::cli::CliError> for Error {
    fn from(e: super::cli::CliError) -> Self {
        Error::wrap("argument error", e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_shows_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = Error::wrap("reading config", io);
        let s = format!("{e}");
        assert!(s.contains("reading config"));
        assert!(s.contains("gone"));
    }

    #[test]
    fn conversions_work() {
        fn fails() -> crate::Result<()> {
            Err(format!("bad {}", 7).into())
        }
        assert!(format!("{}", fails().unwrap_err()).contains("bad 7"));

        fn io_fails() -> crate::Result<Vec<u8>> {
            Ok(std::fs::read("/definitely/not/a/path/sfcmul")?)
        }
        assert!(io_fails().is_err());
    }

    #[test]
    fn context_keeps_cause() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer: inner");
        assert!(std::error::Error::source(&e).is_some());
    }
}

//! Self-contained infrastructure substrates.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! (`rand`, `proptest`, `criterion`, `clap`, `serde`, `anyhow`, `tokio`,
//! `rayon`) are unavailable. Everything this crate needs from them is
//! implemented here from scratch, small and auditable:
//!
//! * [`prng`] — SplitMix64 / xoshiro256** pseudo-random generators.
//! * [`prop`] — a miniature property-based testing harness with shrinking.
//! * [`bench`] — a micro-benchmark harness (warmup, calibrated iteration
//!   counts, robust statistics) used by `cargo bench`.
//! * [`cli`] — a flag/option command-line parser.
//! * [`error`] — the chained error type behind [`crate::Result`]
//!   (stands in for anyhow).
//! * [`json`] — a tiny JSON value builder/serialiser for machine-readable
//!   reports.
//! * [`pool`] — a bounded-queue thread pool plus MPMC channel used by the
//!   L3 coordinator (stands in for tokio).
//! * [`stats`] — mean/percentile/stddev helpers shared by bench + metrics.
//! * [`sync`] — poison-tolerant `Mutex` locking used by the fault-isolated
//!   coordinator and server paths.

pub mod error;
pub mod prng;
pub mod prop;
pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod stats;
pub mod sync;

//! Bounded MPMC channel + worker thread pool (stand-in for tokio/rayon).
//!
//! The L3 coordinator needs: (1) a bounded queue providing *backpressure*
//! (senders block when the queue is full — the paper's Fig 8 streaming
//! pipeline relies on line-buffer backpressure the same way), (2) a pool of
//! worker threads draining that queue, and (3) graceful shutdown. This is a
//! small, correct condvar-based implementation.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

struct ChannelInner<T> {
    queue: VecDeque<T>,
    capacity: usize,
    closed: bool,
    senders: usize,
}

struct Shared<T> {
    inner: Mutex<ChannelInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

/// Sending half of a bounded channel. Cloneable (MPMC).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half of a bounded channel. Cloneable (MPMC).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SendError<T> {
    /// Channel closed by all receivers dropping or an explicit `close()`.
    Closed(T),
}

#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    Full(T),
    Closed(T),
}

/// Outcome of [`Receiver::recv_timeout`].
#[derive(Debug, PartialEq, Eq)]
pub enum RecvTimeout<T> {
    /// An item arrived within the deadline.
    Value(T),
    /// Channel closed (or all senders dropped) and drained.
    Closed,
    /// Deadline elapsed with the channel still open and empty.
    TimedOut,
}

/// Create a bounded channel with the given capacity (≥ 1).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity >= 1);
    let shared = Arc::new(Shared {
        inner: Mutex::new(ChannelInner {
            queue: VecDeque::with_capacity(capacity),
            capacity,
            closed: false,
            senders: 1,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.inner.lock().unwrap().senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut inner = self.shared.inner.lock().unwrap();
        inner.senders -= 1;
        if inner.senders == 0 {
            // Last sender gone: wake all receivers so they can observe
            // drain-then-None.
            drop(inner);
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Sender<T> {
    /// Blocking send; applies backpressure when the queue is full.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err(SendError::Closed(value));
            }
            if inner.queue.len() < inner.capacity {
                inner.queue.push_back(value);
                drop(inner);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            inner = self.shared.not_full.wait(inner).unwrap();
        }
    }

    /// Non-blocking send.
    pub fn try_send(&self, value: T) -> Result<(), TrySendError<T>> {
        let mut inner = self.shared.inner.lock().unwrap();
        if inner.closed {
            return Err(TrySendError::Closed(value));
        }
        if inner.queue.len() >= inner.capacity {
            return Err(TrySendError::Full(value));
        }
        inner.queue.push_back(value);
        drop(inner);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Close the channel: further sends fail, receivers drain then see None.
    pub fn close(&self) {
        self.shared.inner.lock().unwrap().closed = true;
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }

    /// Current queue depth (diagnostic; racy by nature).
    pub fn len(&self) -> usize {
        self.shared.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive. Returns `None` once the channel is closed (or all
    /// senders dropped) *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if let Some(v) = inner.queue.pop_front() {
                drop(inner);
                self.shared.not_full.notify_one();
                return Some(v);
            }
            if inner.closed || inner.senders == 0 {
                return None;
            }
            inner = self.shared.not_empty.wait(inner).unwrap();
        }
    }

    /// Receive with a deadline. Distinguishes a drained-and-closed channel
    /// from a timeout so callers can map the two to different errors.
    pub fn recv_timeout(&self, timeout: Duration) -> RecvTimeout<T> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if let Some(v) = inner.queue.pop_front() {
                drop(inner);
                self.shared.not_full.notify_one();
                return RecvTimeout::Value(v);
            }
            if inner.closed || inner.senders == 0 {
                return RecvTimeout::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return RecvTimeout::TimedOut;
            }
            let (guard, _res) = self
                .shared
                .not_empty
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = guard;
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut inner = self.shared.inner.lock().unwrap();
        let v = inner.queue.pop_front();
        if v.is_some() {
            drop(inner);
            self.shared.not_full.notify_one();
        }
        v
    }

    /// Receive up to `max` items, blocking for the first one only — the
    /// primitive under the coordinator's dynamic batcher.
    pub fn recv_batch(&self, max: usize) -> Vec<T> {
        let mut out = Vec::new();
        if max == 0 {
            return out;
        }
        match self.recv() {
            Some(first) => out.push(first),
            None => return out,
        }
        while out.len() < max {
            match self.try_recv() {
                Some(v) => out.push(v),
                None => break,
            }
        }
        out
    }
}

/// Fixed worker pool executing closures from a bounded queue.
pub struct ThreadPool {
    sender: Option<Sender<Box<dyn FnOnce() + Send>>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize, queue_capacity: usize) -> Self {
        assert!(threads >= 1);
        let (tx, rx) = bounded::<Box<dyn FnOnce() + Send>>(queue_capacity);
        let workers = (0..threads)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("sfcmul-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { sender: Some(tx), workers }
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool not shut down")
            .send(Box::new(job))
            .ok();
    }

    /// Parallel-map a slice by chunking it across the pool. Results are
    /// returned in input order. `f` is applied per element.
    pub fn map<T: Sync, R: Send + 'static>(
        &self,
        items: &[T],
        f: impl Fn(&T) -> R + Sync,
    ) -> Vec<R> {
        // Scoped execution: we block until all chunks are done, so borrowing
        // `items` and `f` is safe via std::thread::scope semantics. We use a
        // simple two-phase protocol over our channel instead, with results
        // collected through a mutexed Vec<Option<R>>.
        let n = items.len();
        let results: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
        let threads = self.workers.len().max(1);
        let chunk = n.div_ceil(threads).max(1);
        std::thread::scope(|scope| {
            for (ci, chunk_items) in items.chunks(chunk).enumerate() {
                let results = &results;
                let f = &f;
                scope.spawn(move || {
                    let base = ci * chunk;
                    let mut local: Vec<(usize, R)> = Vec::with_capacity(chunk_items.len());
                    for (i, item) in chunk_items.iter().enumerate() {
                        local.push((base + i, f(item)));
                    }
                    let mut guard = results.lock().unwrap();
                    for (idx, r) in local {
                        guard[idx] = Some(r);
                    }
                });
            }
        });
        results.into_inner().unwrap().into_iter().map(|r| r.unwrap()).collect()
    }

    /// Graceful shutdown: close the queue and join workers.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if let Some(tx) = self.sender.take() {
            tx.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn channel_roundtrip_in_order_single_consumer() {
        let (tx, rx) = bounded(4);
        std::thread::spawn(move || {
            for i in 0..100 {
                tx.send(i).unwrap();
            }
        });
        let got: Vec<i32> = std::iter::from_fn(|| rx.recv()).collect();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn try_send_reports_full() {
        let (tx, _rx) = bounded(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert!(matches!(tx.try_send(3), Err(TrySendError::Full(3))));
    }

    #[test]
    fn send_blocks_until_receiver_drains() {
        let (tx, rx) = bounded(1);
        tx.send(1).unwrap();
        let t = std::thread::spawn(move || {
            tx.send(2).unwrap(); // blocks until rx.recv()
            true
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert!(t.join().unwrap());
    }

    #[test]
    fn close_wakes_receivers() {
        let (tx, rx) = bounded::<i32>(1);
        let t = std::thread::spawn(move || rx.recv());
        std::thread::sleep(Duration::from_millis(20));
        tx.close();
        assert_eq!(t.join().unwrap(), None);
    }

    #[test]
    fn dropping_all_senders_ends_stream() {
        let (tx, rx) = bounded(8);
        let tx2 = tx.clone();
        tx.send(1).unwrap();
        tx2.send(2).unwrap();
        drop(tx);
        drop(tx2);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn recv_timeout_distinguishes_value_closed_timeout() {
        let (tx, rx) = bounded(2);
        tx.send(7).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(50)), RecvTimeout::Value(7));
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), RecvTimeout::TimedOut);
        tx.close();
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), RecvTimeout::<i32>::Closed);
    }

    #[test]
    fn recv_timeout_wakes_on_late_send() {
        let (tx, rx) = bounded(1);
        let t = std::thread::spawn(move || rx.recv_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        tx.send(42).unwrap();
        assert_eq!(t.join().unwrap(), RecvTimeout::Value(42));
    }

    #[test]
    fn recv_batch_takes_available() {
        let (tx, rx) = bounded(16);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let batch = rx.recv_batch(10);
        assert_eq!(batch, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn pool_map_preserves_order() {
        let pool = ThreadPool::new(4, 16);
        let items: Vec<u64> = (0..1000).collect();
        let out = pool.map(&items, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pool_submit_executes_everything() {
        let pool = ThreadPool::new(3, 4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn mpmc_multiple_consumers_see_all_items() {
        let (tx, rx) = bounded(8);
        let total = Arc::new(AtomicUsize::new(0));
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                let total = total.clone();
                std::thread::spawn(move || {
                    while let Some(v) = rx.recv() {
                        total.fetch_add(v, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for _ in 0..300 {
            tx.send(1usize).unwrap();
        }
        drop(tx);
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 300);
    }
}

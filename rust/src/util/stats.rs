//! Small statistics helpers shared by the bench harness and the
//! coordinator's latency metrics.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on a *sorted* slice; `q` in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Convenience: sorts a copy and reports (p50, p90, p99).
pub fn p50_p90_p99(xs: &[f64]) -> (f64, f64, f64) {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        percentile_sorted(&s, 0.50),
        percentile_sorted(&s, 0.90),
        percentile_sorted(&s, 0.99),
    )
}

/// Median absolute deviation — robust spread estimate used by the bench
/// harness to detect noisy runs.
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = percentile_sorted(&s, 0.5);
    let mut dev: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&dev, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&s, 0.0), 1.0);
        assert_eq!(percentile_sorted(&s, 1.0), 4.0);
        assert!((percentile_sorted(&s, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn p_triplet_is_ordered() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let (p50, p90, p99) = p50_p90_p99(&xs);
        assert!(p50 <= p90 && p90 <= p99);
        assert!((p50 - 49.5).abs() < 1e-9);
    }

    #[test]
    fn mad_of_constant_is_zero() {
        assert_eq!(mad(&[5.0; 10]), 0.0);
    }
}

//! Poison-tolerant locking.
//!
//! A `Mutex` is poisoned when a thread panics while holding it. The
//! coordinator isolates engine panics with `catch_unwind`, so a poisoned
//! lock means "a panic happened nearby", not "the data is torn" — every
//! guarded section in this crate either completes its mutation before any
//! fallible call or only reads. Recovering the guard keeps the fleet
//! serving instead of cascading the panic into every other worker, which
//! is the whole point of the fault-tolerance layer.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(5usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock(&m), 5);
    }
}

//! Minimal command-line parsing (stand-in for `clap`).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Option names that are always boolean flags: they never consume the next
/// token even when followed by a positional argument. Extend when adding
/// new flags to the binary.
pub const BOOL_FLAGS: &[&str] = &[
    "verbose", "quiet", "demo", "help", "quick", "exhaustive", "write-images", "json", "no-pjrt",
];

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    InvalidValue { key: String, value: String, reason: String },
    MissingRequired(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(name) => write!(f, "missing value for option --{name}"),
            CliError::InvalidValue { key, value, reason } => {
                write!(f, "invalid value for --{key}: {value:?} ({reason})")
            }
            CliError::MissingRequired(name) => write!(f, "missing required option --{name}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an explicit token list (first token may be a subcommand —
    /// any leading token that does not start with `-`).
    ///
    /// `--name value` binds greedily; names listed in [`BOOL_FLAGS`] are
    /// always parsed as boolean flags and never consume the next token.
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self, CliError> {
        let mut subcommand = None;
        let mut positional = Vec::new();
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut it = tokens.into_iter().peekable();
        let mut first = true;
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else if BOOL_FLAGS.contains(&stripped) {
                    flags.push(stripped.to_string());
                } else {
                    // `--key value` if the next token exists and is not an
                    // option; else a boolean flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            options.insert(stripped.to_string(), v);
                        }
                        _ => flags.push(stripped.to_string()),
                    }
                }
            } else if first {
                subcommand = Some(tok);
            } else {
                positional.push(tok);
            }
            first = false;
        }
        Ok(Self { subcommand, positional, options, flags })
    }

    /// Parse from the process arguments (skipping argv[0]).
    pub fn from_env() -> Result<Self, CliError> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError::MissingRequired(name.to_string()))
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| CliError::InvalidValue {
                key: name.to_string(),
                value: v.to_string(),
                reason: e.to_string(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("tables --id t4 --seed=42 --verbose out.txt");
        assert_eq!(a.subcommand.as_deref(), Some("tables"));
        assert_eq!(a.get("id"), Some("t4"));
        assert_eq!(a.get("seed"), Some("42"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.txt"]);
    }

    #[test]
    fn typed_access_and_defaults() {
        let a = parse("bench --n 128");
        assert_eq!(a.get_parse("n", 0usize).unwrap(), 128);
        assert_eq!(a.get_parse("m", 7usize).unwrap(), 7);
        assert!(a.get_parse::<usize>("n", 0).is_ok());
    }

    #[test]
    fn invalid_value_is_reported() {
        let a = parse("x --n notanumber");
        let err = a.get_parse::<usize>("n", 0).unwrap_err();
        assert!(matches!(err, CliError::InvalidValue { .. }));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("serve --demo");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert!(a.flag("demo"));
    }

    #[test]
    fn required_option_errors_when_absent() {
        let a = parse("edge");
        assert!(a.require("input").is_err());
    }
}

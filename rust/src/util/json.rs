//! Tiny JSON value builder + serialiser (stand-in for `serde_json`).
//!
//! Only what the reporting paths need: objects, arrays, strings, numbers,
//! bools, null, with correct string escaping and stable (insertion-ordered)
//! object keys so diffs of generated reports stay readable.

use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key on an object; panics on non-objects —
    /// builder misuse is a programming error.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = value.into();
                } else {
                    pairs.push((key.to_string(), value.into()));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serialisation goes through `Display`, so `.to_string()` comes from the
/// blanket `ToString` impl.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Int(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Int(v as i64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_nested_objects() {
        let j = Json::obj()
            .set("name", "t4")
            .set("rows", vec![1i64, 2, 3])
            .set("ok", true)
            .set("nested", Json::obj().set("x", 1.5));
        assert_eq!(
            j.to_string(),
            r#"{"name":"t4","rows":[1,2,3],"ok":true,"nested":{"x":1.5}}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(j.to_string(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn set_replaces_existing_key() {
        let j = Json::obj().set("k", 1i64).set("k", 2i64);
        assert_eq!(j.to_string(), r#"{"k":2}"#);
    }
}

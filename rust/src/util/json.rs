//! Tiny JSON value builder + serialiser + parser (stand-in for
//! `serde_json`).
//!
//! Only what the reporting paths need: objects, arrays, strings, numbers,
//! bools, null, with correct string escaping and stable (insertion-ordered)
//! object keys so diffs of generated reports stay readable. The parser
//! ([`Json::parse`]) exists for the observability tooling — the Chrome
//! trace-event schema check (`sfcmul trace`, the ci.sh smoke leg, and the
//! trace tests) round-trips documents this module itself emitted, so it
//! handles exactly standard JSON, nothing more.

use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key on an object; panics on non-objects —
    /// builder misuse is a programming error.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = value.into();
                } else {
                    pairs.push((key.to_string(), value.into()));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Object field lookup; `None` on non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Numeric view: accepts both `Int` and `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Parse a JSON document. Integral numbers without fraction/exponent
    /// parse as [`Json::Int`], everything else numeric as [`Json::Num`].
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serialisation goes through `Display`, so `.to_string()` comes from the
/// blanket `ToString` impl.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Recursive-descent JSON parser over the raw bytes (ASCII structure;
/// string contents pass through as UTF-8).
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            _ => Err(format!("unexpected byte at {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            pairs.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.pos += 4;
                            // Surrogate pairs are not emitted by our writer;
                            // map lone surrogates to U+FFFD.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte sequences pass
                    // through unvalidated — input is &str, already valid).
                    let start = self.pos;
                    self.pos += 1;
                    while self
                        .bytes
                        .get(self.pos)
                        .is_some_and(|&b| b & 0xC0 == 0x80)
                    {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| "invalid utf-8".to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut fractional = false;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    fractional = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "invalid number".to_string())?;
        if !fractional {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("invalid number at byte {start}"))
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Int(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Int(v as i64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_nested_objects() {
        let j = Json::obj()
            .set("name", "t4")
            .set("rows", vec![1i64, 2, 3])
            .set("ok", true)
            .set("nested", Json::obj().set("x", 1.5));
        assert_eq!(
            j.to_string(),
            r#"{"name":"t4","rows":[1,2,3],"ok":true,"nested":{"x":1.5}}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(j.to_string(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn set_replaces_existing_key() {
        let j = Json::obj().set("k", 1i64).set("k", 2i64);
        assert_eq!(j.to_string(), r#"{"k":2}"#);
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let j = Json::obj()
            .set("name", "t4 \"quoted\"\npath\\x")
            .set("rows", vec![1i64, -2, 3])
            .set("ratio", 1.5)
            .set("ok", true)
            .set("missing", Json::Null)
            .set("nested", Json::obj().set("x", -0.25));
        let parsed = Json::parse(&j.to_string()).expect("roundtrip");
        assert_eq!(parsed, j);
    }

    #[test]
    fn parse_accepts_whitespace_and_types() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5 , \"s\" , null , false ] } ").unwrap();
        let arr = j.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(arr[2].as_str(), Some("s"));
        assert_eq!(arr[3], Json::Null);
        assert_eq!(arr[4], Json::Bool(false));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} trailing").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\\u00e9\"").unwrap(), Json::Str("Aé".into()));
        assert_eq!(Json::parse("\"\\ud800\"").unwrap(), Json::Str("\u{fffd}".into()));
    }

    #[test]
    fn accessors_on_wrong_types_return_none() {
        let j = Json::parse("{\"n\": 3}").unwrap();
        assert!(j.get("missing").is_none());
        assert!(j.as_str().is_none());
        assert!(Json::Str("x".into()).as_f64().is_none());
        assert_eq!(j.get("n").and_then(Json::as_f64), Some(3.0));
    }
}

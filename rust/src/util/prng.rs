//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding and xoshiro256** as the workhorse generator —
//! both are public-domain algorithms (Blackman & Vigna). Determinism
//! matters: every experiment in EXPERIMENTS.md records its seed, and the
//! test suite relies on reproducible streams.

/// SplitMix64: tiny, fast, passes BigCrush when used for seeding.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the crate-wide general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the authors.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection method.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.below(span) as i64)
    }

    /// Uniform random signed 8-bit value — the multiplier input domain.
    #[inline]
    pub fn next_i8(&mut self) -> i8 {
        self.next_u64() as i8
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_stream_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_streams_differ_by_seed() {
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4, "independent seeds should rarely collide");
    }

    #[test]
    fn below_is_in_bounds_and_covers() {
        let mut r = Xoshiro256::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn range_is_inclusive() {
        let mut r = Xoshiro256::seeded(3);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::seeded(9);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Xoshiro256::seeded(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

//! Declarative design specification + registry — the construction API
//! every multiplier in the system is built through.
//!
//! The paper's proposed multiplier is one point in a design space spanned
//! by compressor choice × truncation depth × compensation × bitwidth. A
//! [`DesignSpec`] names such a point declaratively and round-trips a
//! compact string form; the [`Registry`] maps design-family names to
//! factories and builds any spec'd configuration. New baselines register
//! without touching core files.
//!
//! # Spec grammar
//!
//! ```text
//! spec    := family [ '@' bits ] ( ':' option )*
//! family  := 'exact' | 'proposed' | 'd1' | 'd2' | 'd4' | 'd5' | 'd7'
//!          | 'd12' | IDENT                 (IDENT: custom registered family)
//! bits    := integer in 2..=32 (approximate families: 4..=32); default 8
//! option  := 'trunc=' ( 'paper' | 'none' | COLS )   -- truncated LSP columns
//!          | 'comp='  ( 'paper' | 'none' | 'const' )-- error compensation
//!          | 'opt='   ( 'none' | 'fold' | 'full' )  -- netlist optimization
//! ```
//!
//! `trunc=paper` (default) truncates the paper's `N-1` low columns;
//! `trunc=none` keeps every column; `trunc=K` (K ≤ N-1) truncates exactly
//! `K` columns. `comp=paper` (default) is the CSP-constant scheme of
//! Eq. (5) — when nothing is truncated it degenerates to no compensation,
//! since the constant it injects exists only to cancel truncation loss;
//! `comp=const` additionally places the literal §3.3 constant bit at
//! column `N-2` ([`Compensation::Literal`]); `comp=none` disables
//! compensation. `opt=full` (default) runs the whole graph pass pipeline
//! ([`OptLevel::Full`]: constant folding ↔ CSE to a fixpoint + dead-gate
//! sweep) over the built netlist; `opt=fold` stops after one folding
//! round (the legacy builder behaviour); `opt=none` keeps the raw
//! generator output — the functional model is identical at every level,
//! only the gate-level structure differs. Options at their defaults are
//! omitted from the canonical string form, so `Display` → `FromStr`
//! round-trips exactly.
//!
//! Examples: `proposed@8`, `exact@16`, `d2@8:trunc=none`,
//! `proposed@16:comp=const`, `exact@8:trunc=7:comp=none`,
//! `proposed@8:opt=none`.
//!
//! The `exact` family is special-cased: at its canonical spec it builds
//! the plain [`ExactBaughWooley`] multiplier; with non-default options it
//! builds the shared truncated framework with *exact* CSP compressors
//! (approximation error comes from truncation alone).

use super::approx::{ApproxMulConfig, ApproxSignedMultiplier, Compensation, Sf3Mode};
use super::exact::ExactBaughWooley;
use super::traits::MultiplierModel;
use crate::compressors::baselines::{
    Ac1Esposito4, Ac2Guo5, Ac3Strollo12, Ac5Du2, DualQualityApprox1Abcd1, ProbBased7Abcd1,
};
use crate::compressors::exact::{ExactAbc1, ExactAbcd1};
use crate::compressors::proposed::{ProposedApproxAbc1, ProposedApproxAbcd1};
use crate::netlist::prelude::{optimize_netlist, Netlist, OptLevel};
use crate::util::error::Error;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, OnceLock};

/// Which compressor family occupies the CSP slots of the truncated +
/// compensated framework (paper §5.1 swaps exactly this).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum CompressorChoice {
    /// Exact CSP compressors (canonical form: plain Baugh-Wooley).
    Exact,
    /// The paper's proposed approximate sign-focused compressors.
    Proposed,
    /// Strollo et al. TCAS-I 2020 — "Design [12]".
    D12,
    /// Guo et al. SOCC 2019 — "Design [5]".
    D5,
    /// Esposito et al. TCAS-I 2018 — "Design [4]".
    D4,
    /// Akbari et al. TVLSI 2017 dual-quality 4:2 — "Design [1]".
    D1,
    /// Krishna et al. ESL 2024 probability-based 4:2 — "Design [7]".
    D7,
    /// Du et al. APCCAS 2022 — "Design [2]" (best existing).
    D2,
    /// A custom family registered at runtime under this name.
    Named(String),
}

impl CompressorChoice {
    /// Canonical registry key (`exact`, `proposed`, `d1`..`d12`, or the
    /// custom name).
    pub fn key(&self) -> &str {
        match self {
            CompressorChoice::Exact => "exact",
            CompressorChoice::Proposed => "proposed",
            CompressorChoice::D12 => "d12",
            CompressorChoice::D5 => "d5",
            CompressorChoice::D4 => "d4",
            CompressorChoice::D1 => "d1",
            CompressorChoice::D7 => "d7",
            CompressorChoice::D2 => "d2",
            CompressorChoice::Named(name) => name,
        }
    }

    /// Row name as the paper prints it.
    pub fn paper_name(&self) -> &str {
        match self {
            CompressorChoice::Exact => "Exact",
            CompressorChoice::Proposed => "Proposed Design",
            CompressorChoice::D12 => "Design [12]",
            CompressorChoice::D5 => "Design [5]",
            CompressorChoice::D4 => "Design [4]",
            CompressorChoice::D1 => "Design [1]",
            CompressorChoice::D7 => "Design [7]",
            CompressorChoice::D2 => "Design [2]",
            CompressorChoice::Named(name) => name,
        }
    }

    /// The built-in families, Table-5 row order.
    pub fn builtin() -> [CompressorChoice; 8] {
        [
            CompressorChoice::Exact,
            CompressorChoice::D4,
            CompressorChoice::D1,
            CompressorChoice::D5,
            CompressorChoice::D12,
            CompressorChoice::D7,
            CompressorChoice::D2,
            CompressorChoice::Proposed,
        ]
    }

    /// Parse a family name (case-insensitive; accepts CLI aliases such as
    /// `design [2]` or a bare `2`). Unknown identifiers become
    /// [`CompressorChoice::Named`], resolved against the registry at build
    /// time.
    fn from_key(s: &str) -> Result<Self, Error> {
        let lower = s.trim().to_lowercase();
        Ok(match lower.as_str() {
            "exact" => CompressorChoice::Exact,
            "proposed" | "prop" => CompressorChoice::Proposed,
            "d12" | "design [12]" | "12" => CompressorChoice::D12,
            "d5" | "design [5]" | "5" => CompressorChoice::D5,
            "d4" | "design [4]" | "4" => CompressorChoice::D4,
            "d1" | "design [1]" | "1" => CompressorChoice::D1,
            "d7" | "design [7]" | "7" => CompressorChoice::D7,
            "d2" | "design [2]" | "2" => CompressorChoice::D2,
            _ => {
                if lower.is_empty()
                    || !lower.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
                {
                    return Err(Error::msg(format!("invalid design family {s:?}")));
                }
                CompressorChoice::Named(lower)
            }
        })
    }
}

/// How many low (LSP) columns are truncated.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TruncMode {
    /// The paper's scheme: truncate the `N-1` lowest columns.
    Paper,
    /// Keep every column (no truncation).
    None,
    /// Truncate exactly this many columns.
    Cols(u8),
}

impl TruncMode {
    /// Concrete truncated-column count at width `n`.
    pub fn columns(self, n: usize) -> usize {
        match self {
            TruncMode::Paper => n - 1,
            TruncMode::None => 0,
            TruncMode::Cols(k) => k as usize,
        }
    }
}

/// A point in the multiplier design space. `Display` renders the compact
/// canonical string form; `FromStr` parses it back (see the module docs
/// for the grammar).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct DesignSpec {
    /// Operand width N in bits.
    pub bits: usize,
    /// Compressor family in the CSP slots.
    pub compressors: CompressorChoice,
    /// LSP truncation depth.
    pub truncation: TruncMode,
    /// Error-compensation scheme (paper Eq. (5) ablation knob).
    pub compensation: Compensation,
    /// Netlist optimization pipeline applied after construction.
    pub opt: OptLevel,
}

impl DesignSpec {
    /// The canonical (paper-default) spec of a family at width `bits`.
    pub fn canonical(compressors: CompressorChoice, bits: usize) -> Self {
        Self {
            bits,
            compressors,
            truncation: TruncMode::Paper,
            compensation: Compensation::Paper,
            opt: OptLevel::Full,
        }
    }

    /// True when every option is at its paper default — such specs build
    /// the exact Table-4/5 configurations and carry the paper row names.
    pub fn is_canonical(&self) -> bool {
        self.truncation == TruncMode::Paper
            && self.compensation == Compensation::Paper
            && self.opt == OptLevel::Full
    }

    /// Model display name: the paper's row name for canonical specs, the
    /// spec string otherwise.
    pub fn display_name(&self) -> String {
        if self.is_canonical() {
            self.compressors.paper_name().to_string()
        } else {
            self.to_string()
        }
    }
}

impl fmt::Display for DesignSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}", self.compressors.key(), self.bits)?;
        match self.truncation {
            TruncMode::Paper => {}
            TruncMode::None => write!(f, ":trunc=none")?,
            TruncMode::Cols(k) => write!(f, ":trunc={k}")?,
        }
        match self.compensation {
            Compensation::Paper => {}
            Compensation::None => write!(f, ":comp=none")?,
            Compensation::Literal => write!(f, ":comp=const")?,
        }
        if self.opt != OptLevel::Full {
            write!(f, ":opt={}", self.opt)?;
        }
        Ok(())
    }
}

impl std::str::FromStr for DesignSpec {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self, Error> {
        let s = s.trim();
        let mut parts = s.split(':');
        let head = parts.next().unwrap_or_default();
        if head.is_empty() {
            return Err(Error::msg(format!("empty design spec {s:?}")));
        }
        let (family_s, bits) = match head.split_once('@') {
            Some((f, b)) => {
                let bits: usize = b
                    .parse()
                    .map_err(|_| Error::msg(format!("invalid bitwidth {b:?} in spec {s:?}")))?;
                (f, bits)
            }
            None => (head, 8),
        };
        if !(2..=32).contains(&bits) {
            return Err(Error::msg(format!(
                "unsupported bitwidth {bits} in spec {s:?} (supported: 2..=32)"
            )));
        }
        let compressors = CompressorChoice::from_key(family_s)?;
        let mut spec = DesignSpec::canonical(compressors, bits);
        for opt in parts {
            let (key, value) = opt
                .split_once('=')
                .ok_or_else(|| Error::msg(format!("malformed option {opt:?} in spec {s:?}")))?;
            match key {
                "trunc" => {
                    spec.truncation = match value {
                        "paper" => TruncMode::Paper,
                        "none" => TruncMode::None,
                        _ => {
                            let k: u8 = value.parse().map_err(|_| {
                                Error::msg(format!(
                                    "invalid truncation {value:?} in spec {s:?} \
                                     (paper | none | column count)"
                                ))
                            })?;
                            // Only columns below N-1 are in the truncated
                            // LSP region; deeper K would silently alias
                            // K = N-1 and fake distinct design points.
                            if k as usize >= bits {
                                return Err(Error::msg(format!(
                                    "truncation {k} out of range for {bits}-bit operands \
                                     (max {})",
                                    bits - 1
                                )));
                            }
                            TruncMode::Cols(k)
                        }
                    };
                }
                "comp" => {
                    spec.compensation = match value {
                        "paper" => Compensation::Paper,
                        "none" => Compensation::None,
                        "const" | "literal" => Compensation::Literal,
                        _ => {
                            return Err(Error::msg(format!(
                                "invalid compensation {value:?} in spec {s:?} \
                                 (paper | none | const)"
                            )))
                        }
                    };
                }
                "opt" => {
                    spec.opt = value
                        .parse::<OptLevel>()
                        .map_err(|e| Error::msg(format!("{e} in spec {s:?}")))?;
                }
                _ => {
                    return Err(Error::msg(format!(
                        "unknown option {key:?} in spec {s:?} (trunc, comp, opt)"
                    )))
                }
            }
        }
        Ok(spec)
    }
}

/// A design factory: builds a model from a spec (the spec's family is
/// guaranteed to match the entry the factory was registered under).
pub type DesignFactory =
    Box<dyn Fn(&DesignSpec) -> crate::Result<Arc<dyn MultiplierModel>> + Send + Sync>;

struct Entry {
    family: CompressorChoice,
    factory: DesignFactory,
}

/// Name → factory registry. Construction of *every* multiplier goes
/// through here; [`registry`] returns the process-wide instance with the
/// paper's comparison set pre-registered.
pub struct Registry {
    /// Insertion order (drives [`Registry::specs`] listing order).
    entries: Vec<Entry>,
    /// Lowercased key → entry index.
    index: BTreeMap<String, usize>,
}

impl Registry {
    /// An empty registry (custom setups; most callers want
    /// [`Registry::with_paper_designs`] or the global [`registry`]).
    pub fn new() -> Self {
        Self { entries: Vec::new(), index: BTreeMap::new() }
    }

    /// A registry with every design of the paper's evaluation registered
    /// (Table-5 row order), each buildable at any supported bitwidth.
    pub fn with_paper_designs() -> Self {
        let mut reg = Self::new();
        for family in CompressorChoice::builtin() {
            let fam = family.clone();
            reg.register(family, move |spec| build_builtin(&fam, spec));
        }
        reg
    }

    /// Register a family under its canonical key. Custom
    /// [`CompressorChoice::Named`] families are normalised to lowercase —
    /// parsing lowercases family names, so this keeps the registered spec
    /// equal to its re-parsed string form (the Display → FromStr
    /// round-trip). Panics on a duplicate key (registration is static
    /// configuration).
    pub fn register(
        &mut self,
        family: CompressorChoice,
        factory: impl Fn(&DesignSpec) -> crate::Result<Arc<dyn MultiplierModel>>
            + Send
            + Sync
            + 'static,
    ) {
        let family = match family {
            CompressorChoice::Named(name) => CompressorChoice::Named(name.to_lowercase()),
            builtin => builtin,
        };
        let key = family.key().to_lowercase();
        assert!(
            !self.index.contains_key(&key),
            "design family {key:?} registered twice"
        );
        self.index.insert(key, self.entries.len());
        self.entries.push(Entry { family, factory: Box::new(factory) });
    }

    /// Canonical family keys in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.family.key()).collect()
    }

    /// True when `name` is a registered family key.
    pub fn contains(&self, name: &str) -> bool {
        self.index.contains_key(&name.to_lowercase())
    }

    /// The canonical spec of every registered family at width `bits`,
    /// in registration order.
    pub fn specs(&self, bits: usize) -> Vec<DesignSpec> {
        self.entries
            .iter()
            .map(|e| DesignSpec::canonical(e.family.clone(), bits))
            .collect()
    }

    /// Build the multiplier a spec describes.
    pub fn build(&self, spec: &DesignSpec) -> crate::Result<Arc<dyn MultiplierModel>> {
        // Re-validate width-dependent options: hand-constructed specs (or
        // parsed-then-mutated ones) never went through FromStr's checks.
        if let TruncMode::Cols(k) = spec.truncation {
            if k as usize >= spec.bits {
                return Err(Error::msg(format!(
                    "truncation {k} out of range for {}-bit operands (max {})",
                    spec.bits,
                    spec.bits - 1
                )));
            }
        }
        let key = spec.compressors.key().to_lowercase();
        let idx = self.index.get(&key).ok_or_else(|| {
            Error::msg(format!(
                "unknown design family {key:?} (registered: {})",
                self.names().join(", ")
            ))
        })?;
        let model = (self.entries[*idx].factory)(spec)?;
        // Factories build the raw generator netlist; the spec's `:opt=`
        // knob decides how much the graph pass pipeline shrinks it. The
        // wrapper is transparent to the functional model.
        Ok(match spec.opt {
            OptLevel::None => model,
            level => Arc::new(Optimized::new(model, level)),
        })
    }

    /// Parse a spec string and build it in one step.
    pub fn build_str(&self, spec: &str) -> crate::Result<Arc<dyn MultiplierModel>> {
        self.build(&spec.parse::<DesignSpec>()?)
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// The process-wide registry, paper designs pre-registered.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::with_paper_designs)
}

/// Factory behind every built-in family. Reproduces the seed's
/// `build_design` configurations exactly for canonical specs (paper
/// Tables 4/5 are byte-identical), then applies the spec's truncation and
/// compensation knobs.
fn build_builtin(
    family: &CompressorChoice,
    spec: &DesignSpec,
) -> crate::Result<Arc<dyn MultiplierModel>> {
    let n = spec.bits;
    if *family == CompressorChoice::Exact && spec.is_canonical() {
        // Plain exact Baugh-Wooley (no truncated framework around it).
        return Ok(Arc::new(ExactBaughWooley::new(n)));
    }
    if !(4..=32).contains(&n) {
        return Err(Error::msg(format!(
            "the truncated framework supports widths 4..=32 (spec {spec})"
        )));
    }
    let name = spec.display_name();
    let mut cfg = ApproxMulConfig::paper_default(
        &name,
        n,
        Arc::new(ExactAbcd1),
        Arc::new(ExactAbc1),
        false,
    );
    // The third compressor slot is the exact x+y+z+1 encoder ("a few
    // adders", §3.3) for every design — the §5.1 comparison swaps only the
    // CSP sign-focused compressors.
    cfg.sf3 = Sf3Mode::ExactEncoder;
    match family {
        CompressorChoice::Exact => {
            // Exact CSP cells stay; no §3.2 NAND→1 replacement, so the only
            // approximation left is the truncation the spec asks for.
            cfg.sf3 = Sf3Mode::Skip;
        }
        CompressorChoice::Proposed => {
            cfg.abcd1 = Arc::new(ProposedApproxAbcd1);
            cfg.abc1 = Arc::new(ProposedApproxAbc1);
        }
        CompressorChoice::D12 => {
            cfg.abc1 = Arc::new(Ac3Strollo12);
            cfg.abcd_as_abc = true;
        }
        CompressorChoice::D5 => {
            cfg.abc1 = Arc::new(Ac2Guo5);
            cfg.abcd_as_abc = true;
        }
        CompressorChoice::D4 => {
            cfg.abc1 = Arc::new(Ac1Esposito4);
            cfg.abcd_as_abc = true;
        }
        CompressorChoice::D1 => {
            // Table 4 evaluates the dual-quality cell in its low-quality
            // (approximate) configuration — the accurate mode would be
            // error-free in the CSP and indistinguishable from exact CSP.
            cfg.abcd1 = Arc::new(DualQualityApprox1Abcd1);
            cfg.abc1 = Arc::new(ExactAbc1);
        }
        CompressorChoice::D7 => {
            cfg.abcd1 = Arc::new(ProbBased7Abcd1);
            cfg.abc1 = Arc::new(ExactAbc1);
        }
        CompressorChoice::D2 => {
            cfg.abc1 = Arc::new(Ac5Du2);
            cfg.abcd_as_abc = true;
        }
        CompressorChoice::Named(other) => {
            return Err(Error::msg(format!(
                "design family {other:?} has no built-in factory"
            )))
        }
    }
    cfg.truncate_cols = spec.truncation.columns(n);
    cfg.compensation = spec.compensation;
    // The paper's compensation constant exists solely to cancel truncation
    // loss (Eq. (5)); with nothing truncated it would inject a spurious
    // +2^(N-1) bias into every product, so `comp=paper` degenerates to no
    // compensation (mirroring the seed ablation's `truncate 0 columns`
    // row). An explicit `comp=const` is honoured as written.
    if cfg.truncate_cols == 0 && cfg.compensation == Compensation::Paper {
        cfg.compensation = Compensation::None;
    }
    Ok(Arc::new(ApproxSignedMultiplier::new(cfg)))
}

/// Transparent optimization wrapper: delegates the functional model and
/// identity to the inner design, and runs the inner netlist through the
/// graph pass pipeline ([`optimize_netlist`]) at the chosen level.
/// [`Registry::build`] wraps every factory-built model with this per the
/// spec's `:opt=` knob (`:opt=none` skips the wrapper entirely), so every
/// registry consumer — the bitsim engines, the hardware models, the
/// Verilog exporter — sees the optimized gate program by default.
pub struct Optimized {
    inner: Arc<dyn MultiplierModel>,
    level: OptLevel,
}

impl Optimized {
    pub fn new(inner: Arc<dyn MultiplierModel>, level: OptLevel) -> Self {
        Self { inner, level }
    }

    pub fn level(&self) -> OptLevel {
        self.level
    }

    /// The wrapped (raw-netlist) model.
    pub fn inner(&self) -> &Arc<dyn MultiplierModel> {
        &self.inner
    }
}

impl MultiplierModel for Optimized {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn bits(&self) -> usize {
        self.inner.bits()
    }

    fn multiply(&self, a: i64, b: i64) -> i64 {
        self.inner.multiply(a, b)
    }

    fn build_netlist(&self) -> Netlist {
        optimize_netlist(&self.inner.build_netlist(), self.level).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> DesignSpec {
        s.parse().unwrap_or_else(|e| panic!("{s:?}: {e}"))
    }

    #[test]
    fn parses_canonical_and_defaults() {
        assert_eq!(
            parse("proposed@8"),
            DesignSpec::canonical(CompressorChoice::Proposed, 8)
        );
        // bare family defaults to 8 bits
        assert_eq!(parse("exact"), DesignSpec::canonical(CompressorChoice::Exact, 8));
        // CLI aliases still resolve
        assert_eq!(parse("design [2]").compressors, CompressorChoice::D2);
        assert_eq!(parse("12@16").compressors, CompressorChoice::D12);
    }

    #[test]
    fn parses_options() {
        let s = parse("d2@8:trunc=none");
        assert_eq!(s.truncation, TruncMode::None);
        assert_eq!(s.compensation, Compensation::Paper);
        let s = parse("proposed@16:comp=const");
        assert_eq!(s.bits, 16);
        assert_eq!(s.compensation, Compensation::Literal);
        let s = parse("exact@8:trunc=7:comp=none");
        assert_eq!(s.truncation, TruncMode::Cols(7));
        assert_eq!(s.compensation, Compensation::None);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "@8",
            "proposed@99",
            "proposed@x",
            "d2@8:trunc=nope",
            "d2@8:comp=wat",
            "d2@8:frob=1",
            "d2@8:opt=wat",
            "d2@8:trunc",
            "proposed@8:trunc=16", // beyond the LSP region
            "proposed@8:trunc=8",  // == bits: would alias trunc=7
            "we!rd@8",
        ] {
            assert!(bad.parse::<DesignSpec>().is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn display_roundtrips_every_variant() {
        let variants = [
            "proposed@8",
            "exact@16",
            "d2@8:trunc=none",
            "proposed@16:comp=const",
            "d5@12:trunc=3:comp=none",
            "exact@8:trunc=7",
            "proposed@8:opt=none",
            "exact@8:trunc=none:opt=fold",
        ];
        for s in variants {
            let spec = parse(s);
            assert_eq!(spec.to_string(), s, "canonical form");
            assert_eq!(parse(&spec.to_string()), spec, "roundtrip");
        }
    }

    #[test]
    fn registry_builds_all_paper_designs_at_8_and_16() {
        for bits in [8usize, 16] {
            for spec in registry().specs(bits) {
                let m = registry().build(&spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
                assert_eq!(m.bits(), bits, "{spec}");
                // canonical specs carry the paper row names
                assert_eq!(m.name(), spec.compressors.paper_name(), "{spec}");
            }
        }
    }

    #[test]
    fn unknown_family_is_a_build_error_not_a_parse_error() {
        let spec = parse("mystery@8");
        assert_eq!(
            spec.compressors,
            CompressorChoice::Named("mystery".into())
        );
        assert!(registry().build(&spec).is_err());
    }

    #[test]
    fn custom_family_registration() {
        let mut reg = Registry::new();
        reg.register(CompressorChoice::Named("wallace".into()), |spec| {
            Ok(Arc::new(ExactBaughWooley::new(spec.bits)))
        });
        assert!(reg.contains("wallace"));
        let m = reg.build_str("wallace@8").unwrap();
        assert_eq!(m.multiply(-3, 5), -15);
        assert!(reg.build_str("proposed@8").is_err(), "paper set not registered here");
    }

    /// Registration keys are case-normalised: a family registered under a
    /// mixed-case name is reachable from (lowercased) parsed specs.
    #[test]
    fn mixed_case_registration_is_reachable() {
        let mut reg = Registry::new();
        reg.register(CompressorChoice::Named("Wallace".into()), |spec| {
            Ok(Arc::new(ExactBaughWooley::new(spec.bits)))
        });
        assert!(reg.contains("wallace"));
        assert!(reg.contains("Wallace"));
        assert_eq!(reg.build_str("wallace@8").unwrap().multiply(6, 7), 42);
    }

    #[test]
    fn variant_specs_change_behaviour() {
        let canonical = registry().build_str("proposed@8").unwrap();
        let no_trunc = registry().build_str("proposed@8:trunc=none:comp=none").unwrap();
        // with every column kept, small products survive untruncated
        assert_ne!(canonical.multiply(3, 5), no_trunc.multiply(3, 5));
        assert_eq!(no_trunc.multiply(1, 1), 1);
        // exact CSP + full truncation == the truncation-only configuration
        let trunc_only = registry().build_str("exact@8:trunc=7").unwrap();
        let err = trunc_only.multiply(3, 5) - 15;
        assert!(err.abs() <= 769 + 192, "truncation-bound error, got {err}");
    }

    /// With nothing truncated, the default paper compensation degenerates
    /// to none — no spurious bias constant — and the exact family is
    /// genuinely exact.
    #[test]
    fn paper_compensation_degenerates_without_truncation() {
        let e = registry().build_str("exact@8:trunc=none").unwrap();
        let p = registry().build_str("proposed@8:trunc=none").unwrap();
        for (a, b) in [(1i64, 1), (0, 0), (3, 5), (-7, 9), (127, -128)] {
            assert_eq!(e.multiply(a, b), a * b, "exact {a}*{b}");
        }
        assert_eq!(p.multiply(1, 1), 1, "no +2^(N-1) bias on untruncated proposed");
        // an explicit comp=const is honoured as written
        let lit = registry().build_str("proposed@8:trunc=none:comp=const").unwrap();
        assert_ne!(lit.multiply(1, 1), 1, "literal constant stays by request");
    }

    /// The `:opt=` knob: default is `full` (omitted from the canonical
    /// string form), every level parses, and the built models share one
    /// functional behaviour while their netlists shrink monotonically.
    #[test]
    fn opt_knob_parses_and_defaults_to_full() {
        assert_eq!(parse("proposed@8").opt, OptLevel::Full);
        assert_eq!(parse("proposed@8:opt=full"), parse("proposed@8"));
        assert_eq!(parse("proposed@8:opt=none").opt, OptLevel::None);
        assert_eq!(parse("proposed@8:opt=fold").opt, OptLevel::Fold);
        assert_eq!(parse("proposed@8:opt=none").to_string(), "proposed@8:opt=none");
    }

    #[test]
    fn opt_levels_shrink_netlists_monotonically() {
        let raw = registry().build_str("proposed@8:opt=none").unwrap().build_netlist();
        let folded = registry().build_str("proposed@8:opt=fold").unwrap().build_netlist();
        let full = registry().build_str("proposed@8").unwrap().build_netlist();
        assert!(folded.logic_gate_count() < raw.logic_gate_count(), "fold shrinks raw");
        assert!(full.logic_gate_count() <= folded.logic_gate_count(), "full ≤ fold");
        // the functional model is level-independent
        let m_raw = registry().build_str("proposed@8:opt=none").unwrap();
        let m_full = registry().build_str("proposed@8").unwrap();
        for (a, b) in [(0i64, 0), (3, 5), (-7, 9), (127, -128), (-128, -128)] {
            assert_eq!(m_raw.multiply(a, b), m_full.multiply(a, b), "{a}*{b}");
        }
    }
}

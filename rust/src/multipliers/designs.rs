//! Named multiplier configurations — the comparison set of paper Tables
//! 4/5 and Figs 9/10.
//!
//! Per paper §5.1, every baseline compressor is integrated into the *same*
//! truncated + compensated framework; only the CSP compressor designs
//! differ. Rows are named exactly as the paper prints them.
//!
//! [`DesignId`] is a thin alias over canonical [`DesignSpec`]s for the
//! paper-table call sites: construction goes through the
//! [`super::spec::registry`] (`build_design(id, n)` ≡
//! `registry().build(&id.spec(n))`). The Table-5 *hardware* variants
//! ([`build_design_hw`]) model the baselines' original architectures with
//! knobs (LSP mode, third-slot mode) outside the spec grammar, so they
//! stay as explicit configurations here.

use super::approx::{ApproxMulConfig, ApproxSignedMultiplier, Compensation, LspMode, Sf3Mode};
use super::exact::ExactBaughWooley;
use super::spec::{registry, CompressorChoice, DesignSpec, Optimized};
use super::traits::MultiplierModel;
use crate::netlist::prelude::OptLevel;
use crate::compressors::baselines::*;
use crate::compressors::exact::{ExactAbc1, ExactAbcd1};
use std::sync::Arc;

/// Stable identifiers for the designs of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DesignId {
    Exact,
    /// Strollo et al. TCAS-I 2020 (stacking) — "Design [12]"
    D12,
    /// Guo et al. SOCC 2019 — "Design [5]"
    D5,
    /// Esposito et al. TCAS-I 2018 — "Design [4]"
    D4,
    /// Akbari et al. TVLSI 2017 dual-quality 4:2 — "Design [1]"
    D1,
    /// Krishna et al. ESL 2024 probability-based 4:2 — "Design [7]"
    D7,
    /// Du et al. APCCAS 2022 — "Design [2]" (best existing)
    D2,
    Proposed,
}

impl DesignId {
    pub fn paper_name(self) -> &'static str {
        match self {
            DesignId::Exact => "Exact",
            DesignId::D12 => "Design [12]",
            DesignId::D5 => "Design [5]",
            DesignId::D4 => "Design [4]",
            DesignId::D1 => "Design [1]",
            DesignId::D7 => "Design [7]",
            DesignId::D2 => "Design [2]",
            DesignId::Proposed => "Proposed Design",
        }
    }

    /// Table-4 row order.
    pub fn table4_order() -> [DesignId; 7] {
        [
            DesignId::D12,
            DesignId::D5,
            DesignId::D4,
            DesignId::D1,
            DesignId::D7,
            DesignId::D2,
            DesignId::Proposed,
        ]
    }

    /// Table-5 row order (includes Exact).
    pub fn table5_order() -> [DesignId; 8] {
        [
            DesignId::Exact,
            DesignId::D4,
            DesignId::D1,
            DesignId::D5,
            DesignId::D12,
            DesignId::D7,
            DesignId::D2,
            DesignId::Proposed,
        ]
    }

    /// The registry family this id aliases.
    pub fn family(self) -> CompressorChoice {
        match self {
            DesignId::Exact => CompressorChoice::Exact,
            DesignId::D12 => CompressorChoice::D12,
            DesignId::D5 => CompressorChoice::D5,
            DesignId::D4 => CompressorChoice::D4,
            DesignId::D1 => CompressorChoice::D1,
            DesignId::D7 => CompressorChoice::D7,
            DesignId::D2 => CompressorChoice::D2,
            DesignId::Proposed => CompressorChoice::Proposed,
        }
    }

    /// The canonical spec of this design at width `n`.
    pub fn spec(self, n: usize) -> DesignSpec {
        DesignSpec::canonical(self.family(), n)
    }

    /// The id aliasing a registry family, if it is one of the paper's.
    pub fn from_family(family: &CompressorChoice) -> Option<DesignId> {
        DesignId::table5_order()
            .into_iter()
            .find(|id| id.family() == *family)
    }
}

/// Instantiate a design at width `n` (through the [`registry`]).
pub fn build_design(id: DesignId, n: usize) -> Arc<dyn MultiplierModel> {
    registry()
        .build(&id.spec(n))
        .unwrap_or_else(|e| panic!("paper design {id:?} at N={n}: {e}"))
}

/// All designs in Table-5 order at width `n`.
pub fn all_designs(n: usize) -> Vec<(DesignId, Arc<dyn MultiplierModel>)> {
    DesignId::table5_order()
        .into_iter()
        .map(|id| (id, build_design(id, n)))
        .collect()
}

/// Hardware-evaluation variant of each design (Table 5 / Fig 10's PDP
/// axis).
///
/// The paper evaluates *errors* with every compressor dropped into the
/// shared truncated framework (§5.1 → [`build_design`]) but synthesises
/// the baselines in their **original architectures** ("all the existing
/// designs were evaluated in the same technology node", §5.2). The
/// originals differ mainly in how they treat the low half:
///
/// * Proposed — truncates the lower N-1 columns (the headline saving);
/// * Design [2] — truncates one column less (their compensation keeps
///   column N-2 live);
/// * Design [5] — truncated lower part but shallower (N-3);
/// * Designs [4], [12], [7] — keep the full width, approximating the LSP
///   columns with cheap cells (modelled as OR-compression);
/// * Design [1] — dual-quality cells with the accurate path active: full
///   exact LSP plus per-cell mux overhead.
pub fn build_design_hw(id: DesignId, n: usize) -> Arc<dyn MultiplierModel> {
    // These variants bypass the registry, so they wrap themselves in the
    // full optimization pipeline — the synthesis sweep the paper's DC flow
    // would apply; Proposed routes through the registry and is wrapped
    // there.
    let with = |id: DesignId, f: &dyn Fn(&mut ApproxMulConfig)| -> Arc<dyn MultiplierModel> {
        let mut cfg = ApproxMulConfig::paper_default(
            id.paper_name(),
            n,
            Arc::new(ExactAbcd1),
            Arc::new(ExactAbc1),
            false,
        );
        f(&mut cfg);
        Arc::new(Optimized::new(
            Arc::new(ApproxSignedMultiplier::new(cfg)),
            OptLevel::Full,
        ))
    };
    match id {
        DesignId::Exact => Arc::new(Optimized::new(
            Arc::new(ExactBaughWooley::new(n)),
            OptLevel::Full,
        )),
        DesignId::Proposed => build_design(DesignId::Proposed, n),
        DesignId::D2 => with(id, &|c| {
            c.abc1 = Arc::new(Ac5Du2);
            c.abcd_as_abc = true;
            c.truncate_cols = n - 2;
        }),
        DesignId::D5 => with(id, &|c| {
            c.abc1 = Arc::new(Ac2Guo5);
            c.abcd_as_abc = true;
            c.truncate_cols = n - 3;
        }),
        DesignId::D4 => with(id, &|c| {
            c.abc1 = Arc::new(Ac1Esposito4);
            c.abcd_as_abc = true;
            c.lsp = LspMode::OrCompress;
            c.compensation = Compensation::None;
            c.sf3 = Sf3Mode::Skip;
        }),
        DesignId::D12 => with(id, &|c| {
            c.abc1 = Arc::new(Ac3Strollo12);
            c.abcd_as_abc = true;
            c.lsp = LspMode::OrCompress;
            c.compensation = Compensation::None;
            c.sf3 = Sf3Mode::Skip;
        }),
        DesignId::D7 => with(id, &|c| {
            c.abcd1 = Arc::new(ProbBased7Abcd1);
            c.abc1 = Arc::new(ExactAbc1);
            c.lsp = LspMode::OrCompress;
            c.compensation = Compensation::None;
            c.sf3 = Sf3Mode::Skip;
        }),
        DesignId::D1 => with(id, &|c| {
            // Dual-quality cells in accurate mode: near-exact accuracy with
            // a mild 2-column truncation standing in for the configurable
            // low cells — area just below exact, as in Table 5.
            c.abcd1 = Arc::new(DualQuality1Abcd1);
            c.abc1 = Arc::new(ExactAbc1);
            c.truncate_cols = 2;
            c.compensation = Compensation::None;
            c.sf3 = Sf3Mode::Skip;
        }),
    }
}

/// All hardware-evaluation variants in Table-5 order.
pub fn all_designs_hw(n: usize) -> Vec<(DesignId, Arc<dyn MultiplierModel>)> {
    DesignId::table5_order()
        .into_iter()
        .map(|id| (id, build_design_hw(id, n)))
        .collect()
}

/// Lookup by (case-insensitive) name or full spec string, for CLI use:
/// "exact", "proposed", "d2"/"design [2]", "proposed@16:comp=const", ...
/// A bare family name (no `@bits`) is built at width `n` — the width is
/// spliced into the string *before* parsing so option validation (e.g.
/// the `trunc=K < bits` bound) sees the width that will actually build.
pub fn design_by_name(name: &str, n: usize) -> Option<Arc<dyn MultiplierModel>> {
    let spec_str = if name.contains('@') {
        name.to_string()
    } else {
        match name.split_once(':') {
            Some((family, opts)) => format!("{family}@{n}:{opts}"),
            None => format!("{name}@{n}"),
        }
    };
    registry().build_str(&spec_str).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::verify::exhaustive_check;

    /// Every design's netlist must match its functional model on all
    /// 65 536 pairs — the backbone guarantee of the whole evaluation.
    #[test]
    fn every_design_netlist_matches_model_n8() {
        for (id, m) in all_designs(8) {
            exhaustive_check(m.as_ref()).unwrap_or_else(|e| panic!("{id:?}: {e}"));
        }
    }

    #[test]
    fn design_lookup_by_name() {
        assert!(design_by_name("proposed", 8).is_some());
        assert!(design_by_name("Exact", 8).is_some());
        assert!(design_by_name("d2", 8).is_some());
        assert!(design_by_name("nope", 8).is_none());
    }

    /// Options on a bare family name are validated against the *caller's*
    /// width, not the parser's default of 8.
    #[test]
    fn design_lookup_validates_options_at_caller_width() {
        // trunc=10 is legal at 16 bits (would be rejected at the default 8)
        let m = design_by_name("proposed:trunc=10", 16).expect("valid at N=16");
        assert_eq!(m.bits(), 16);
        // trunc=7 is out of range at 4 bits (would pass at the default 8)
        assert!(design_by_name("proposed:trunc=7", 4).is_none());
        // explicit @bits in the string wins over the width argument
        assert_eq!(design_by_name("proposed@16", 8).unwrap().bits(), 16);
    }

    /// Area ordering from the paper's Table 5 (hardware variants):
    /// proposed smallest, exact largest.
    #[test]
    fn area_ordering_proposed_smallest_exact_largest() {
        let designs = all_designs_hw(8);
        let areas: Vec<(DesignId, f64)> = designs
            .iter()
            .map(|(id, m)| (*id, m.build_netlist().area()))
            .collect();
        let exact = areas.iter().find(|(id, _)| *id == DesignId::Exact).unwrap().1;
        let proposed = areas.iter().find(|(id, _)| *id == DesignId::Proposed).unwrap().1;
        for (id, a) in &areas {
            if *id != DesignId::Exact {
                assert!(*a < exact, "{id:?} area {a} !< exact {exact}");
            }
            if *id != DesignId::Proposed {
                assert!(proposed <= *a + 1e-9, "proposed {proposed} !<= {id:?} {a}");
            }
        }
    }

    /// Hardware variants must also keep netlist ≡ functional model.
    #[test]
    fn hw_variant_netlists_match_models_n8() {
        for (id, m) in all_designs_hw(8) {
            exhaustive_check(m.as_ref()).unwrap_or_else(|e| panic!("hw {id:?}: {e}"));
        }
    }

    /// Design [1] in accurate mode errs only by its 2-column low-end
    /// configuration: |error| ≤ the mass of columns 0..1 (= 1 + 2·2 = 5).
    #[test]
    fn d1_hw_variant_is_nearly_exact() {
        let m = build_design_hw(DesignId::D1, 8);
        for a in (-128i64..128).step_by(7) {
            for b in -128i64..128 {
                let err = (m.multiply(a, b) - a * b).abs();
                assert!(err <= 5, "{a}*{b}: err {err}");
            }
        }
    }

    /// Approximate designs differ from exact somewhere (sanity: the
    /// configuration tweaks actually take effect).
    #[test]
    fn designs_are_pairwise_distinct_somewhere() {
        let designs = all_designs(8);
        let tables: Vec<Vec<i64>> = designs
            .iter()
            .map(|(_, m)| {
                let mut v = Vec::with_capacity(65536);
                for a in -128i64..128 {
                    for b in -128i64..128 {
                        v.push(m.multiply(a, b));
                    }
                }
                v
            })
            .collect();
        for i in 0..tables.len() {
            for j in (i + 1)..tables.len() {
                // D1 uses the exact 4:2 in the same slots as the generic
                // exact config; all *named* designs should still differ
                // except possibly where both are exact-CSP variants.
                if designs[i].0 == DesignId::D1 || designs[j].0 == DesignId::D1 {
                    continue;
                }
                assert!(
                    tables[i] != tables[j],
                    "{:?} and {:?} are identical",
                    designs[i].0,
                    designs[j].0
                );
            }
        }
    }
}

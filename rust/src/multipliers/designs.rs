//! Named multiplier configurations — the comparison set of paper Tables
//! 4/5 and Figs 9/10.
//!
//! Per paper §5.1, every baseline compressor is integrated into the *same*
//! truncated + compensated framework; only the CSP compressor designs
//! differ. Rows are named exactly as the paper prints them.

use super::approx::{ApproxMulConfig, ApproxSignedMultiplier, Compensation, LspMode, Sf3Mode};
use super::exact::ExactBaughWooley;
use super::traits::MultiplierModel;
use crate::compressors::baselines::*;
use crate::compressors::exact::{ExactAbc1, ExactAbcd1};
use crate::compressors::proposed::{ProposedApproxAbc1, ProposedApproxAbcd1};
use std::sync::Arc;

/// Stable identifiers for the designs of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DesignId {
    Exact,
    /// Strollo et al. TCAS-I 2020 (stacking) — "Design [12]"
    D12,
    /// Guo et al. SOCC 2019 — "Design [5]"
    D5,
    /// Esposito et al. TCAS-I 2018 — "Design [4]"
    D4,
    /// Akbari et al. TVLSI 2017 dual-quality 4:2 — "Design [1]"
    D1,
    /// Krishna et al. ESL 2024 probability-based 4:2 — "Design [7]"
    D7,
    /// Du et al. APCCAS 2022 — "Design [2]" (best existing)
    D2,
    Proposed,
}

impl DesignId {
    pub fn paper_name(self) -> &'static str {
        match self {
            DesignId::Exact => "Exact",
            DesignId::D12 => "Design [12]",
            DesignId::D5 => "Design [5]",
            DesignId::D4 => "Design [4]",
            DesignId::D1 => "Design [1]",
            DesignId::D7 => "Design [7]",
            DesignId::D2 => "Design [2]",
            DesignId::Proposed => "Proposed Design",
        }
    }

    /// Table-4 row order.
    pub fn table4_order() -> [DesignId; 7] {
        [
            DesignId::D12,
            DesignId::D5,
            DesignId::D4,
            DesignId::D1,
            DesignId::D7,
            DesignId::D2,
            DesignId::Proposed,
        ]
    }

    /// Table-5 row order (includes Exact).
    pub fn table5_order() -> [DesignId; 8] {
        [
            DesignId::Exact,
            DesignId::D4,
            DesignId::D1,
            DesignId::D5,
            DesignId::D12,
            DesignId::D7,
            DesignId::D2,
            DesignId::Proposed,
        ]
    }
}

/// Instantiate a design at width `n`.
pub fn build_design(id: DesignId, n: usize) -> Arc<dyn MultiplierModel> {
    match id {
        DesignId::Exact => Arc::new(ExactBaughWooley::new(n)),
        DesignId::D12 => approx(id, n, |c| {
            c.abc1 = Arc::new(Ac3Strollo12);
            c.abcd_as_abc = true;
        }),
        DesignId::D5 => approx(id, n, |c| {
            c.abc1 = Arc::new(Ac2Guo5);
            c.abcd_as_abc = true;
        }),
        DesignId::D4 => approx(id, n, |c| {
            c.abc1 = Arc::new(Ac1Esposito4);
            c.abcd_as_abc = true;
        }),
        DesignId::D1 => approx(id, n, |c| {
            // Table 4 evaluates the dual-quality cell in its low-quality
            // (approximate) configuration — the accurate mode would be
            // error-free in the CSP and indistinguishable from ExactCSP.
            c.abcd1 = Arc::new(DualQualityApprox1Abcd1);
            c.abc1 = Arc::new(ExactAbc1);
        }),
        DesignId::D7 => approx(id, n, |c| {
            c.abcd1 = Arc::new(ProbBased7Abcd1);
            c.abc1 = Arc::new(ExactAbc1);
        }),
        DesignId::D2 => approx(id, n, |c| {
            c.abc1 = Arc::new(Ac5Du2);
            c.abcd_as_abc = true;
        }),
        DesignId::Proposed => approx(id, n, |c| {
            c.abcd1 = Arc::new(ProposedApproxAbcd1);
            c.abc1 = Arc::new(ProposedApproxAbc1);
        }),
    }
}

fn approx(
    id: DesignId,
    n: usize,
    tweak: impl FnOnce(&mut ApproxMulConfig),
) -> Arc<dyn MultiplierModel> {
    let mut cfg = ApproxMulConfig::paper_default(
        id.paper_name(),
        n,
        Arc::new(ExactAbcd1),
        Arc::new(ExactAbc1),
        false,
    );
    // The third compressor slot is the exact x+y+z+1 encoder ("a few
    // adders", §3.3) for every design — the §5.1 comparison swaps only the
    // CSP sign-focused compressors.
    cfg.sf3 = Sf3Mode::ExactEncoder;
    tweak(&mut cfg);
    Arc::new(ApproxSignedMultiplier::new(cfg))
}

/// All designs in Table-5 order at width `n`.
pub fn all_designs(n: usize) -> Vec<(DesignId, Arc<dyn MultiplierModel>)> {
    DesignId::table5_order()
        .into_iter()
        .map(|id| (id, build_design(id, n)))
        .collect()
}

/// Hardware-evaluation variant of each design (Table 5 / Fig 10's PDP
/// axis).
///
/// The paper evaluates *errors* with every compressor dropped into the
/// shared truncated framework (§5.1 → [`build_design`]) but synthesises
/// the baselines in their **original architectures** ("all the existing
/// designs were evaluated in the same technology node", §5.2). The
/// originals differ mainly in how they treat the low half:
///
/// * Proposed — truncates the lower N-1 columns (the headline saving);
/// * Design [2] — truncates one column less (their compensation keeps
///   column N-2 live);
/// * Design [5] — truncated lower part but shallower (N-3);
/// * Designs [4], [12], [7] — keep the full width, approximating the LSP
///   columns with cheap cells (modelled as OR-compression);
/// * Design [1] — dual-quality cells with the accurate path active: full
///   exact LSP plus per-cell mux overhead.
pub fn build_design_hw(id: DesignId, n: usize) -> Arc<dyn MultiplierModel> {
    let with = |id: DesignId, f: &dyn Fn(&mut ApproxMulConfig)| -> Arc<dyn MultiplierModel> {
        let mut cfg = ApproxMulConfig::paper_default(
            id.paper_name(),
            n,
            Arc::new(ExactAbcd1),
            Arc::new(ExactAbc1),
            false,
        );
        f(&mut cfg);
        Arc::new(ApproxSignedMultiplier::new(cfg))
    };
    match id {
        DesignId::Exact => Arc::new(ExactBaughWooley::new(n)),
        DesignId::Proposed => build_design(DesignId::Proposed, n),
        DesignId::D2 => with(id, &|c| {
            c.abc1 = Arc::new(Ac5Du2);
            c.abcd_as_abc = true;
            c.truncate_cols = n - 2;
        }),
        DesignId::D5 => with(id, &|c| {
            c.abc1 = Arc::new(Ac2Guo5);
            c.abcd_as_abc = true;
            c.truncate_cols = n - 3;
        }),
        DesignId::D4 => with(id, &|c| {
            c.abc1 = Arc::new(Ac1Esposito4);
            c.abcd_as_abc = true;
            c.lsp = LspMode::OrCompress;
            c.compensation = Compensation::None;
            c.sf3 = Sf3Mode::Skip;
        }),
        DesignId::D12 => with(id, &|c| {
            c.abc1 = Arc::new(Ac3Strollo12);
            c.abcd_as_abc = true;
            c.lsp = LspMode::OrCompress;
            c.compensation = Compensation::None;
            c.sf3 = Sf3Mode::Skip;
        }),
        DesignId::D7 => with(id, &|c| {
            c.abcd1 = Arc::new(ProbBased7Abcd1);
            c.abc1 = Arc::new(ExactAbc1);
            c.lsp = LspMode::OrCompress;
            c.compensation = Compensation::None;
            c.sf3 = Sf3Mode::Skip;
        }),
        DesignId::D1 => with(id, &|c| {
            // Dual-quality cells in accurate mode: near-exact accuracy with
            // a mild 2-column truncation standing in for the configurable
            // low cells — area just below exact, as in Table 5.
            c.abcd1 = Arc::new(DualQuality1Abcd1);
            c.abc1 = Arc::new(ExactAbc1);
            c.truncate_cols = 2;
            c.compensation = Compensation::None;
            c.sf3 = Sf3Mode::Skip;
        }),
    }
}

/// All hardware-evaluation variants in Table-5 order.
pub fn all_designs_hw(n: usize) -> Vec<(DesignId, Arc<dyn MultiplierModel>)> {
    DesignId::table5_order()
        .into_iter()
        .map(|id| (id, build_design_hw(id, n)))
        .collect()
}

/// Lookup by (case-insensitive) name fragment, for CLI use:
/// "exact", "proposed", "d2"/"design [2]", ...
pub fn design_by_name(name: &str, n: usize) -> Option<Arc<dyn MultiplierModel>> {
    let lower = name.to_lowercase();
    let id = match lower.as_str() {
        "exact" => DesignId::Exact,
        "proposed" => DesignId::Proposed,
        "d12" | "design [12]" | "12" => DesignId::D12,
        "d5" | "design [5]" | "5" => DesignId::D5,
        "d4" | "design [4]" | "4" => DesignId::D4,
        "d1" | "design [1]" | "1" => DesignId::D1,
        "d7" | "design [7]" | "7" => DesignId::D7,
        "d2" | "design [2]" | "2" => DesignId::D2,
        _ => return None,
    };
    Some(build_design(id, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::verify::exhaustive_check;

    /// Every design's netlist must match its functional model on all
    /// 65 536 pairs — the backbone guarantee of the whole evaluation.
    #[test]
    fn every_design_netlist_matches_model_n8() {
        for (id, m) in all_designs(8) {
            exhaustive_check(m.as_ref()).unwrap_or_else(|e| panic!("{id:?}: {e}"));
        }
    }

    #[test]
    fn design_lookup_by_name() {
        assert!(design_by_name("proposed", 8).is_some());
        assert!(design_by_name("Exact", 8).is_some());
        assert!(design_by_name("d2", 8).is_some());
        assert!(design_by_name("nope", 8).is_none());
    }

    /// Area ordering from the paper's Table 5 (hardware variants):
    /// proposed smallest, exact largest.
    #[test]
    fn area_ordering_proposed_smallest_exact_largest() {
        let designs = all_designs_hw(8);
        let areas: Vec<(DesignId, f64)> = designs
            .iter()
            .map(|(id, m)| (*id, m.build_netlist().area()))
            .collect();
        let exact = areas.iter().find(|(id, _)| *id == DesignId::Exact).unwrap().1;
        let proposed = areas.iter().find(|(id, _)| *id == DesignId::Proposed).unwrap().1;
        for (id, a) in &areas {
            if *id != DesignId::Exact {
                assert!(*a < exact, "{id:?} area {a} !< exact {exact}");
            }
            if *id != DesignId::Proposed {
                assert!(proposed <= *a + 1e-9, "proposed {proposed} !<= {id:?} {a}");
            }
        }
    }

    /// Hardware variants must also keep netlist ≡ functional model.
    #[test]
    fn hw_variant_netlists_match_models_n8() {
        for (id, m) in all_designs_hw(8) {
            exhaustive_check(m.as_ref()).unwrap_or_else(|e| panic!("hw {id:?}: {e}"));
        }
    }

    /// Design [1] in accurate mode errs only by its 2-column low-end
    /// configuration: |error| ≤ the mass of columns 0..1 (= 1 + 2·2 = 5).
    #[test]
    fn d1_hw_variant_is_nearly_exact() {
        let m = build_design_hw(DesignId::D1, 8);
        for a in (-128i64..128).step_by(7) {
            for b in -128i64..128 {
                let err = (m.multiply(a, b) - a * b).abs();
                assert!(err <= 5, "{a}*{b}: err {err}");
            }
        }
    }

    /// Approximate designs differ from exact somewhere (sanity: the
    /// configuration tweaks actually take effect).
    #[test]
    fn designs_are_pairwise_distinct_somewhere() {
        let designs = all_designs(8);
        let tables: Vec<Vec<i64>> = designs
            .iter()
            .map(|(_, m)| {
                let mut v = Vec::with_capacity(65536);
                for a in -128i64..128 {
                    for b in -128i64..128 {
                        v.push(m.multiply(a, b));
                    }
                }
                v
            })
            .collect();
        for i in 0..tables.len() {
            for j in (i + 1)..tables.len() {
                // D1 uses the exact 4:2 in the same slots as the generic
                // exact config; all *named* designs should still differ
                // except possibly where both are exact-CSP variants.
                if designs[i].0 == DesignId::D1 || designs[j].0 == DesignId::D1 {
                    continue;
                }
                assert!(
                    tables[i] != tables[j],
                    "{:?} and {:?} are identical",
                    designs[i].0,
                    designs[j].0
                );
            }
        }
    }
}

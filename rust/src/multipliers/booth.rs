//! Exact radix-4 (modified) Booth multiplier — the *other* signed
//! multiplication algorithm the paper's introduction contrasts with
//! Baugh-Wooley (ref. [11]). Implemented as a full netlist + fast model so
//! the repo can quantify the paper's claim that Baugh-Wooley's regular
//! partial-product matrix suits approximate compressor design better
//! (`sfcmul tables --id t5` vs the Booth row printed by `examples/design_space`).
//!
//! Radix-4 recoding: digit i (i = 0..N/2-1) looks at bits
//! (b_{2i+1}, b_{2i}, b_{2i-1}) and encodes d ∈ {-2,-1,0,1,2}:
//!
//! ```text
//! one = b_{2i} ⊕ b_{2i-1}          |d| = 1
//! two = (b_{2i+1} ⊕ b_{2i}) & ~one |d| = 2
//! neg = b_{2i+1}                   d < 0 (as ones' complement + neg LSB)
//! ```
//!
//! Each partial product is ±a or ±2a at weight 4^i, realised as
//! mux → conditional invert, with the `+neg` correction bit and full
//! sign replication into the upper columns (correct mod 2^2N; the
//! reduction engine handles the repeated sign signal for free).

use super::traits::{from_bits, to_bits, MultiplierModel};
use crate::circuits::{reduce_columns, Columns};
use crate::netlist::Netlist;

/// Exact N×N radix-4 Booth multiplier (N even).
#[derive(Debug, Clone)]
pub struct BoothRadix4 {
    pub n: usize,
}

impl BoothRadix4 {
    pub fn new(n: usize) -> Self {
        assert!(n >= 4 && n % 2 == 0 && n <= 32, "N must be even, 4..=32");
        Self { n }
    }
}

impl MultiplierModel for BoothRadix4 {
    fn name(&self) -> String {
        "Booth-r4 exact".to_string()
    }

    fn bits(&self) -> usize {
        self.n
    }

    fn multiply(&self, a: i64, b: i64) -> i64 {
        // Functional model via explicit Booth recoding (not a*b, so the
        // recoding itself is under test against the netlist AND against
        // native multiplication).
        let n = self.n;
        let ub = to_bits(b, n);
        let mut acc: i64 = 0;
        for i in 0..n / 2 {
            let b_hi = (ub >> (2 * i + 1)) & 1;
            let b_mid = (ub >> (2 * i)) & 1;
            let b_lo = if i == 0 { 0 } else { (ub >> (2 * i - 1)) & 1 };
            let d: i64 = (b_mid + b_lo) as i64 - 2 * b_hi as i64;
            acc += (d * a) << (2 * i);
        }
        from_bits(to_bits(acc, 2 * n), 2 * n)
    }

    fn build_netlist(&self) -> Netlist {
        let n = self.n;
        let mut nl = Netlist::new(&format!("booth_r4_{n}x{n}"));
        let a = nl.input_bus("a", n);
        let b = nl.input_bus("b", n);
        let zero = nl.const0();
        let mut cols = Columns::new(2 * n);
        for i in 0..n / 2 {
            let b_hi = b[2 * i + 1];
            let b_mid = b[2 * i];
            let b_lo = if i == 0 { zero } else { b[2 * i - 1] };
            let one = nl.xor2(b_mid, b_lo);
            let hi_ne_mid = nl.xor2(b_hi, b_mid);
            let none = nl.not(one);
            let two = nl.and2(hi_ne_mid, none);
            let neg = b_hi;
            // partial product bits j = 0..N (N+1 bits covers ±2a)
            let mut sign_bit = zero;
            for j in 0..=n {
                let x1 = if j < n { a[j] } else { a[n - 1] };
                let x2 = if j == 0 {
                    zero
                } else if j <= n {
                    a[j - 1]
                } else {
                    unreachable!()
                };
                // mag = one ? x1 : (two ? x2 : 0)
                let t = nl.mux2(two, zero, x2);
                let mag = nl.mux2(one, t, x1);
                let ppb = nl.xor2(mag, neg);
                let w = 2 * i + j;
                if w < 2 * n {
                    cols.push(w, ppb);
                }
                if j == n {
                    sign_bit = ppb;
                }
            }
            // sign replication to the top (two's complement mod 2^2N)
            for w in (2 * i + n + 1)..(2 * n) {
                cols.push(w, sign_bit);
            }
            // +neg correction (ones' complement -> two's complement)
            cols.push(2 * i, neg);
        }
        let product = reduce_columns(&mut nl, cols);
        nl.output_bus("p", &product[..2 * n]);
        // Raw generator output; optimize through netlist::opt (the
        // registry wrapper does this for registered designs).
        nl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::verify::exhaustive_check;

    #[test]
    fn booth_fast_model_is_exact_n8() {
        let m = BoothRadix4::new(8);
        for a in -128i64..128 {
            for b in -128i64..128 {
                assert_eq!(m.multiply(a, b), a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    fn booth_netlist_matches_model_exhaustively() {
        exhaustive_check(&BoothRadix4::new(4)).unwrap();
        exhaustive_check(&BoothRadix4::new(6)).unwrap();
        exhaustive_check(&BoothRadix4::new(8)).unwrap();
    }

    #[test]
    fn booth_wide_sampled() {
        let m = BoothRadix4::new(16);
        let mut rng = crate::util::prng::Xoshiro256::seeded(5);
        for _ in 0..500 {
            let a = rng.range_i64(-32768, 32767);
            let b = rng.range_i64(-32768, 32767);
            assert_eq!(m.multiply(a, b), a * b);
        }
        let nl = m.build_netlist();
        for _ in 0..50 {
            let a = rng.range_i64(-32768, 32767);
            let b = rng.range_i64(-32768, 32767);
            assert_eq!(
                crate::multipliers::verify::netlist_multiply_one(&nl, 16, a, b),
                a * b
            );
        }
    }

    /// The paper's §1 motivation: Baugh-Wooley's matrix is the better host
    /// for column-compressor approximation. Quantify: Booth's recoded PPM
    /// reaches similar area at N=8 but through irregular rows (muxes),
    /// which the truncation scheme cannot exploit — we assert both exist
    /// and report the ratio rather than a winner (documented in DESIGN.md).
    #[test]
    fn booth_vs_bw_areas_are_comparable() {
        use crate::netlist::{optimize_netlist, OptLevel};
        let booth =
            optimize_netlist(&BoothRadix4::new(8).build_netlist(), OptLevel::Full).0;
        let bw = optimize_netlist(
            &crate::multipliers::ExactBaughWooley::new(8).build_netlist(),
            OptLevel::Full,
        )
        .0;
        let ratio = booth.area() / bw.area();
        assert!((0.5..2.5).contains(&ratio), "area ratio {ratio}");
    }
}

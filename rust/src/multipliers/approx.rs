//! The proposed truncated + compensated approximate signed multiplier
//! framework (paper §3.2–3.3, Figs 5 and 6), generic over N and over the
//! compressor designs occupying the CSP slots.
//!
//! The architecture is computed once as a *plan* — a list of structural
//! terms — which both the fast functional model and the netlist builder
//! interpret. This guarantees the two forms implement the same circuit;
//! [`crate::multipliers::verify::exhaustive_check`] then proves it
//! bit-exactly for N=8.
//!
//! Plan for width N (default configuration, see DESIGN.md §Reconstruction):
//!
//! * columns `0 .. N-2` (LSP, N-1 columns): truncated (paper §3.3);
//! * compensation: constant product bit at column `N-2` plus the constant
//!   `1` absorbed by the column-(N-1) sign-focused compressor — together
//!   `2^(N-1) + 2^(N-2)`, matching `T_T` of Eq. (5);
//! * column `N-1` (CSP-lo): `A+B+C+D+1` sign-focused compressor over
//!   (comp const; A=NAND(a0,b_{N-1}); the first three AND products);
//!   leftovers to the reduction tree;
//! * column `N` (CSP-hi): `A+B+C+D+1` over (BW const; A=NAND(a1,b_{N-1});
//!   three ANDs); `NAND(a_{N-1}, b1)` is *replaced by constant 1*
//!   (§3.2, P(NAND=1)=3/4) which fuels the third sign-focused compressor,
//!   an `A+B+C+1` over the next two ANDs;
//! * columns `N+1 .. 2N-2` (MSP): exact partial products reduced with the
//!   3:2 compressors of ref. [8]; BW constant at column `2N-1`;
//! * final stage: carry-save/ripple summation (inside `reduce_columns`).

use super::traits::{from_bits, pp_kind, to_bits, MultiplierModel, PpKind};
use crate::circuits::{reduce_columns, Columns};
use crate::compressors::{Abc1Compressor, Abcd1Compressor};
use crate::netlist::{Netlist, SigId};
use std::sync::Arc;

/// Error-compensation scheme (ablation knob; `Paper` is the default).
///
/// Eq. (5) asks for `T_T ≈ 2^(N-1) + 2^(N-2)`. In the shipped
/// reconstruction the first constant is the `+1` absorbed by the
/// column-(N-1) sign-focused compressor, and the second is the *expected
/// surplus* of the §3.2 NAND→1 replacement at column N:
/// `E[1 − NAND] · 2^N = 2^N/4 = 2^(N-2)` — the two mechanisms the paper
/// describes compose to exactly the compensation Eq. (5) derives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Compensation {
    /// No compensation: no CSP-lo compressor constant, no extra bits.
    None,
    /// The shipped scheme: CSP-lo compressor constant (2^(N-1)) +
    /// replacement surplus (expected 2^(N-2)).
    Paper,
    /// Literal §3.3 reading: `Paper` plus a standalone constant bit at
    /// column N-2 (over-compensates when the replacement is also on;
    /// kept for the ablation bench).
    Literal,
}

/// What occupies the third (A+B+C+1) compressor slot at column N, which
/// receives the §3.2 NAND→1 constant and the column's leftover AND
/// products (two of them at N=8 for 4-input CSP designs, three for
/// 3-input designs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sf3Mode {
    /// The configured `abc1` design cell.
    DesignCell,
    /// An exact `x+y(+z)+1` encoder (carry=OR/majority, sum=XNOR) — the
    /// "few adders" reading of §3.3; zero compressor error in this slot.
    ExactEncoder,
    /// No third compressor; the NAND product stays in the reduction tree
    /// (disables the replacement).
    Skip,
}

/// How the low (LSP) columns are handled. `Truncate` is the paper's
/// proposed scheme; the other modes model the *original* architectures of
/// the baseline designs for the Table-5 hardware comparison (the baseline
/// papers do not truncate — they approximate or keep the low half).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LspMode {
    /// Drop the partial products of the `truncate_cols` lowest columns.
    Truncate,
    /// Keep every LSP column but compress it to a single bit with an OR
    /// tree (the cheap approximate-lower-half style of refs. [4]/[12]).
    OrCompress,
    /// Keep the LSP exact (full reduction) — ref. [1]'s accurate mode.
    Exact,
}

/// Configuration of the approximate-multiplier framework. Instantiating it
/// with each baseline compressor reproduces the paper's §5.1 comparison.
#[derive(Clone)]
pub struct ApproxMulConfig {
    pub name: String,
    pub n: usize,
    /// Design for the two `A+B+C+D+1` CSP slots.
    pub abcd1: Arc<dyn Abcd1Compressor>,
    /// Design for the `A+B+C+1` CSP slot.
    pub abc1: Arc<dyn Abc1Compressor>,
    /// 3-input baselines (Table 2 designs) have no 4-input form: when set,
    /// the ABCD1 slots run the `abc1` design over (A,B,C) and push D to
    /// the exact reduction tree.
    pub abcd_as_abc: bool,
    /// Number of truncated low columns (paper: N-1). Only meaningful with
    /// `LspMode::Truncate`.
    pub truncate_cols: usize,
    /// Compensation scheme.
    pub compensation: Compensation,
    /// LSP handling (Table-5 baseline architecture variants).
    pub lsp: LspMode,
    /// Third-compressor slot behaviour.
    pub sf3: Sf3Mode,
}

impl ApproxMulConfig {
    /// Paper-default skeleton; callers fill in the compressor designs.
    pub fn paper_default(
        name: &str,
        n: usize,
        abcd1: Arc<dyn Abcd1Compressor>,
        abc1: Arc<dyn Abc1Compressor>,
        abcd_as_abc: bool,
    ) -> Self {
        assert!((4..=32).contains(&n), "supported widths: 4..=32");
        Self {
            name: name.to_string(),
            n,
            abcd1,
            abc1,
            abcd_as_abc,
            truncate_cols: n - 1,
            compensation: Compensation::Paper,
            lsp: LspMode::Truncate,
            sf3: Sf3Mode::DesignCell,
        }
    }
}

/// A partial product by coordinates; kind derives from Baugh-Wooley rules.
type Pp = (usize, usize);

/// Structural plan shared by the functional and netlist interpreters.
struct Plan {
    /// Plain partial products routed to the reduction tree: (i, j, weight).
    loose_pps: Vec<(Pp, usize)>,
    /// Constant one-bits at given weights (compensation, BW constants).
    const_bits: Vec<usize>,
    /// `A+B+C+D+1` compressor instances: (column, A, [B, C, D]).
    sf4: Vec<(usize, Pp, [Option<Pp>; 3])>,
    /// `A+B+C+1` compressor instances: (column, [A, B, C], use-exact-cell).
    sf3: Vec<(usize, [Option<Pp>; 3], bool)>,
    /// OR-compressed columns: (weight, partial products OR-ed together).
    or_groups: Vec<(usize, Vec<Pp>)>,
}

fn build_plan(cfg: &ApproxMulConfig) -> Plan {
    let n = cfg.n;
    let mut plan = Plan {
        loose_pps: Vec::new(),
        const_bits: Vec::new(),
        sf4: Vec::new(),
        sf3: Vec::new(),
        or_groups: Vec::new(),
    };

    // Partial products by column, ANDs and NANDs separated, in a fixed
    // deterministic order (increasing i).
    let mut col_and: Vec<Vec<Pp>> = vec![Vec::new(); 2 * n];
    let mut col_nand: Vec<Vec<Pp>> = vec![Vec::new(); 2 * n];
    for i in 0..n {
        for j in 0..n {
            let w = i + j;
            match pp_kind(i, j, n) {
                PpKind::And => col_and[w].push((i, j)),
                PpKind::Nand => col_nand[w].push((i, j)),
            }
        }
    }

    let csp_lo = n - 1;
    let csp_hi = n;

    for w in 0..2 * n {
        let mut ands = std::mem::take(&mut col_and[w]);
        let mut nands = std::mem::take(&mut col_nand[w]);
        if w < n - 1 && w != csp_lo && w != csp_hi {
            match cfg.lsp {
                LspMode::Truncate if w < cfg.truncate_cols => continue,
                LspMode::OrCompress => {
                    let group: Vec<Pp> = ands.drain(..).chain(nands.drain(..)).collect();
                    if !group.is_empty() {
                        plan.or_groups.push((w, group));
                    }
                    continue;
                }
                _ => {} // Exact, or Truncate columns above truncate_cols
            }
        }
        if w == csp_lo {
            // CSP-lo: SF4 #1 — A = NAND(a0, b_{n-1}) (first nand), B,C,D =
            // first three ANDs. Its +1 *is* the column-(N-1) compensation
            // constant, so this compressor exists only under the paper's
            // truncate-and-compensate scheme; other LSP modes have no
            // constant here and route the column to the reduction tree.
            if cfg.lsp == LspMode::Truncate && cfg.compensation != Compensation::None {
                let a = remove_pp(&mut nands, (0, n - 1)).expect("csp-lo NAND");
                let b = take_first(&mut ands);
                let c = take_first(&mut ands);
                let d = take_first(&mut ands);
                push_sf4(cfg, &mut plan, w, a, [b, c, d]);
            }
        } else if w == csp_hi {
            // CSP-hi: SF4 #2 — A = NAND(a1, b_{n-1}), +1 = BW constant.
            let a = remove_pp(&mut nands, (1, n - 1)).expect("csp-hi NAND");
            let b = take_first(&mut ands);
            let c = take_first(&mut ands);
            let d = take_first(&mut ands);
            push_sf4(cfg, &mut plan, w, a, [b, c, d]);
            // NAND(a_{n-1}, b1) → constant 1 feeding SF3 (§3.2), or kept
            // loose when the third slot is skipped.
            let low_nand = remove_pp(&mut nands, (n - 1, 1));
            match cfg.sf3 {
                Sf3Mode::Skip => {
                    if let Some(pp) = low_nand {
                        plan.loose_pps.push((pp, w));
                    }
                }
                mode => {
                    debug_assert!(low_nand.is_some());
                    let x = take_first(&mut ands);
                    let y = take_first(&mut ands);
                    let z = take_first(&mut ands);
                    plan.sf3.push((w, [x, y, z], mode == Sf3Mode::ExactEncoder));
                }
            }
        }
        // Whatever remains in this column goes to the exact reduction tree.
        for pp in ands.drain(..).chain(nands.drain(..)) {
            plan.loose_pps.push((pp, w));
        }
    }

    // Baugh-Wooley constants: column 2n-1 always; column n only when no
    // CSP compressor absorbed it (the SF4 at column n *is* that constant).
    plan.const_bits.push(2 * n - 1);

    // Standalone compensation bit (only in the literal §3.3 reading, and
    // only when the LSP is actually truncated).
    if cfg.compensation == Compensation::Literal && cfg.lsp == LspMode::Truncate && n >= 2 {
        plan.const_bits.push(n - 2);
    }

    plan
}

fn take_first(v: &mut Vec<Pp>) -> Option<Pp> {
    if v.is_empty() {
        None
    } else {
        Some(v.remove(0))
    }
}

fn remove_pp(v: &mut Vec<Pp>, pp: Pp) -> Option<Pp> {
    v.iter().position(|&x| x == pp).map(|idx| v.remove(idx))
}

fn push_sf4(cfg: &ApproxMulConfig, plan: &mut Plan, w: usize, a: Pp, bcd: [Option<Pp>; 3]) {
    if cfg.abcd_as_abc {
        // 3-input design in the 4-input slot: (A, B, C) through the
        // compressor, D loose.
        plan.sf3.push((w, [Some(a), bcd[0], bcd[1]], false));
        if let Some(d) = bcd[2] {
            plan.loose_pps.push((d, w));
        }
        // Mark the SF3 as "has a real negative A" by construction — the
        // design's value() handles it; nothing else to do.
    } else {
        plan.sf4.push((w, a, bcd));
    }
}

/// The approximate signed multiplier: fast model + netlist from one plan.
pub struct ApproxSignedMultiplier {
    cfg: ApproxMulConfig,
    plan: Plan,
}

impl ApproxSignedMultiplier {
    pub fn new(cfg: ApproxMulConfig) -> Self {
        let plan = build_plan(&cfg);
        Self { cfg, plan }
    }

    pub fn config(&self) -> &ApproxMulConfig {
        &self.cfg
    }

    #[inline]
    fn pp_bit(&self, ua: u64, ub: u64, pp: Pp) -> bool {
        super::traits::pp_value(ua, ub, pp.0, pp.1, self.cfg.n)
    }

    #[inline]
    fn pp_bit_opt(&self, ua: u64, ub: u64, pp: Option<Pp>) -> bool {
        pp.map(|p| self.pp_bit(ua, ub, p)).unwrap_or(false)
    }
}

impl MultiplierModel for ApproxSignedMultiplier {
    fn name(&self) -> String {
        self.cfg.name.clone()
    }

    fn bits(&self) -> usize {
        self.cfg.n
    }

    fn multiply(&self, a: i64, b: i64) -> i64 {
        let n = self.cfg.n;
        let ua = to_bits(a, n);
        let ub = to_bits(b, n);
        let mut acc: u64 = 0;
        for &(pp, w) in &self.plan.loose_pps {
            if self.pp_bit(ua, ub, pp) {
                acc = acc.wrapping_add(1 << w);
            }
        }
        for &w in &self.plan.const_bits {
            acc = acc.wrapping_add(1 << w);
        }
        for &(w, pa, bcd) in &self.plan.sf4 {
            let va = self.pp_bit(ua, ub, pa);
            let vb = self.pp_bit_opt(ua, ub, bcd[0]);
            let vc = self.pp_bit_opt(ua, ub, bcd[1]);
            let vd = self.pp_bit_opt(ua, ub, bcd[2]);
            let v = self.cfg.abcd1.value(va, vb, vc, vd) as u64;
            acc = acc.wrapping_add(v << w);
        }
        for &(w, abc, exact_cell) in &self.plan.sf3 {
            let va = self.pp_bit_opt(ua, ub, abc[0]);
            let vb = self.pp_bit_opt(ua, ub, abc[1]);
            let vc = self.pp_bit_opt(ua, ub, abc[2]);
            let v = if exact_cell {
                1 + va as u64 + vb as u64 + vc as u64
            } else {
                self.cfg.abc1.value(va, vb, vc) as u64
            };
            acc = acc.wrapping_add(v << w);
        }
        for (w, group) in &self.plan.or_groups {
            if group.iter().any(|&pp| self.pp_bit(ua, ub, pp)) {
                acc = acc.wrapping_add(1 << w);
            }
        }
        from_bits(acc, 2 * n)
    }

    fn build_netlist(&self) -> Netlist {
        let n = self.cfg.n;
        let mut nl = Netlist::new(&format!("approx_{}_{n}x{n}", self.cfg.name));
        let a_bus = nl.input_bus("a", n);
        let b_bus = nl.input_bus("b", n);
        let mut cols = Columns::new(2 * n);

        let pp_sig = |nl: &mut Netlist, pp: Pp| -> SigId {
            match pp_kind(pp.0, pp.1, n) {
                PpKind::And => nl.and2(a_bus[pp.0], b_bus[pp.1]),
                PpKind::Nand => nl.nand2(a_bus[pp.0], b_bus[pp.1]),
            }
        };
        let pp_sig_opt = |nl: &mut Netlist, pp: Option<Pp>| -> SigId {
            match pp {
                Some(p) => pp_sig(nl, p),
                None => nl.const0(),
            }
        };

        for &(pp, w) in &self.plan.loose_pps {
            let s = pp_sig_opt(&mut nl, Some(pp));
            cols.push(w, s);
        }
        for &w in &self.plan.const_bits {
            let k = nl.const1();
            cols.push(w, k);
        }
        for &(w, pa, bcd) in &self.plan.sf4 {
            let sa = pp_sig_opt(&mut nl, Some(pa));
            let sb = pp_sig_opt(&mut nl, bcd[0]);
            let sc = pp_sig_opt(&mut nl, bcd[1]);
            let sd = pp_sig_opt(&mut nl, bcd[2]);
            for ob in self.cfg.abcd1.build(&mut nl, sa, sb, sc, sd) {
                cols.push(w + ob.rel_weight as usize, ob.sig);
            }
        }
        for &(w, abc, exact_cell) in &self.plan.sf3 {
            let sa = pp_sig_opt(&mut nl, abc[0]);
            let sb = pp_sig_opt(&mut nl, abc[1]);
            let sc = pp_sig_opt(&mut nl, abc[2]);
            let cell: &dyn Abc1Compressor = if exact_cell {
                &crate::compressors::exact::ExactAbc1
            } else {
                self.cfg.abc1.as_ref()
            };
            for ob in cell.build(&mut nl, sa, sb, sc) {
                cols.push(w + ob.rel_weight as usize, ob.sig);
            }
        }
        for (w, group) in &self.plan.or_groups {
            let sigs: Vec<SigId> =
                group.iter().map(|&pp| pp_sig_opt(&mut nl, Some(pp))).collect();
            let or = nl.or_many(&sigs);
            cols.push(*w, or);
        }

        let product = reduce_columns(&mut nl, cols);
        // Low truncated bits are constant zero in the product bus.
        let zero = nl.const0();
        let mut out = vec![zero; 2 * n];
        for (w, &sig) in product.iter().enumerate().take(2 * n) {
            out[w] = sig;
        }
        // Columns below the lowest populated weight never appear in the
        // reduction result indices — reduce_columns returns a full-width
        // bus, so just take it (bits for empty low columns are const0 by
        // construction of the final ripple stage).
        nl.output_bus("p", &out);
        // Raw generator output: constant columns, speculative reduction
        // carries and duplicate cells stay in. The registry's `:opt=`
        // wrapper (default full pipeline) shrinks it — see netlist::opt.
        nl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::exact::{ExactAbc1, ExactAbcd1};
    use crate::compressors::proposed::{ProposedApproxAbc1, ProposedApproxAbcd1};
    use crate::multipliers::verify::exhaustive_check;

    fn proposed(n: usize) -> ApproxSignedMultiplier {
        ApproxSignedMultiplier::new(ApproxMulConfig::paper_default(
            "Proposed",
            n,
            Arc::new(ProposedApproxAbcd1),
            Arc::new(ProposedApproxAbc1),
            false,
        ))
    }

    #[test]
    fn netlist_matches_model_exhaustively_n8() {
        exhaustive_check(&proposed(8)).unwrap();
    }

    #[test]
    fn netlist_matches_model_exhaustively_n4_n6() {
        exhaustive_check(&proposed(4)).unwrap();
        exhaustive_check(&proposed(6)).unwrap();
    }

    #[test]
    fn mean_error_is_small_relative_to_scale() {
        // With compensation the average error over all pairs should be a
        // tiny fraction of the output scale 2^(2N-2).
        let m = proposed(8);
        let mut sum_err = 0f64;
        for a in -128i64..128 {
            for b in -128i64..128 {
                sum_err += (m.multiply(a, b) - a * b) as f64;
            }
        }
        let me = sum_err / 65536.0;
        assert!(
            me.abs() < 16384.0 * 0.02,
            "mean error {me} too large vs scale 16384"
        );
    }

    #[test]
    fn truncation_zeroes_low_bits_statistics() {
        // Bits 0..N-2 of the product must be zero for every input under
        // the shipped compensation scheme (no standalone constant bit; the
        // compensation lives in the CSP compressor constants).
        let m = proposed(8);
        for a in [-128i64, -77, -1, 0, 1, 99, 127] {
            for b in [-128i64, -3, 0, 5, 127] {
                let p = m.multiply(a, b);
                let up = to_bits(p, 16);
                assert_eq!(up & 0x7F, 0, "{a}*{b}: low bits {up:#x}");
            }
        }
        // The Literal ablation keeps the standalone bit at column N-2.
        let mut cfg = ApproxMulConfig::paper_default(
            "lit",
            8,
            Arc::new(ProposedApproxAbcd1),
            Arc::new(ProposedApproxAbc1),
            false,
        );
        cfg.compensation = Compensation::Literal;
        let lit = ApproxSignedMultiplier::new(cfg);
        let up = to_bits(lit.multiply(3, 5), 16);
        assert_eq!((up >> 6) & 1, 1, "literal scheme sets the bit");
    }

    #[test]
    fn exact_compressors_in_framework_still_approximate_only_by_truncation() {
        // With exact CSP compressors and no NAND replacement, every error
        // must come from the truncated LSP (plus compensation): the
        // product restricted to columns >= N-1 must match exact product's
        // high part within the truncation bound.
        let cfg = ApproxMulConfig {
            name: "ExactCSP".into(),
            n: 8,
            abcd1: Arc::new(ExactAbcd1),
            abc1: Arc::new(ExactAbc1),
            abcd_as_abc: false,
            truncate_cols: 7,
            compensation: Compensation::Paper,
            lsp: LspMode::Truncate,
            sf3: Sf3Mode::Skip,
        };
        let m = ApproxSignedMultiplier::new(cfg);
        exhaustive_check(&m).unwrap();
        let max_trunc: i64 = (0..7).map(|w| (w + 1) << w).sum::<usize>() as i64; // max truncated mass
        for a in -128i64..128 {
            for b in [-128i64, -55, 0, 33, 127] {
                let err = m.multiply(a, b) - a * b;
                assert!(
                    err.abs() <= max_trunc + 64 + 128,
                    "{a}*{b}: err {err} exceeds truncation bound"
                );
            }
        }
    }

    #[test]
    fn netlist_structure_sane() {
        use crate::netlist::{optimize_netlist, OptLevel};
        let raw = proposed(8).build_netlist();
        assert_eq!(raw.inputs().len(), 16);
        assert_eq!(raw.outputs().len(), 16);
        raw.validate().unwrap();
        // Compare optimized against optimized — the generator now emits
        // raw structure, the pass pipeline does the shrinking.
        let nl = optimize_netlist(&raw, OptLevel::Full).0;
        let exact_raw = crate::multipliers::exact::ExactBaughWooley::new(8).build_netlist();
        let exact = optimize_netlist(&exact_raw, OptLevel::Full).0;
        // The proposed multiplier must be substantially smaller than exact.
        assert!(
            nl.area() < 0.8 * exact.area(),
            "approx area {} vs exact {}",
            nl.area(),
            exact.area()
        );
    }
}

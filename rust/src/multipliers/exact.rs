//! Exact Baugh-Wooley signed multiplier, generic N (paper §2, Fig. 1,
//! Table 1).
//!
//! Partial products: `AND(a_i, b_j)` everywhere except the mixed sign
//! terms which are `NAND`ed; constants `1` are injected at columns `N` and
//! `2N-1`; the matrix is reduced with the 3:2 compressors of ref. [8] and a
//! final ripple stage ([`crate::circuits::reduce_columns`]).

use super::traits::{from_bits, pp_kind, to_bits, MultiplierModel, PpKind};
use crate::circuits::{reduce_columns, Columns};
use crate::netlist::Netlist;

/// Exact N×N Baugh-Wooley multiplier.
#[derive(Debug, Clone)]
pub struct ExactBaughWooley {
    pub n: usize,
}

impl ExactBaughWooley {
    pub fn new(n: usize) -> Self {
        assert!((2..=32).contains(&n), "supported operand widths: 2..=32");
        Self { n }
    }
}

impl MultiplierModel for ExactBaughWooley {
    fn name(&self) -> String {
        "Exact".to_string()
    }

    fn bits(&self) -> usize {
        self.n
    }

    fn multiply(&self, a: i64, b: i64) -> i64 {
        // The fast model *is* exact multiplication; the Baugh-Wooley
        // identity is separately verified in traits.rs and the netlist
        // equivalence in verify.rs.
        let n = self.n;
        debug_assert_eq!(from_bits(to_bits(a, n), n), a, "operand a out of range");
        debug_assert_eq!(from_bits(to_bits(b, n), n), b, "operand b out of range");
        a * b
    }

    fn build_netlist(&self) -> Netlist {
        let n = self.n;
        let mut nl = Netlist::new(&format!("bw_exact_{n}x{n}"));
        let a = nl.input_bus("a", n);
        let b = nl.input_bus("b", n);
        let mut cols = Columns::new(2 * n);
        for i in 0..n {
            for j in 0..n {
                let sig = match pp_kind(i, j, n) {
                    PpKind::And => nl.and2(a[i], b[j]),
                    PpKind::Nand => nl.nand2(a[i], b[j]),
                };
                cols.push(i + j, sig);
            }
        }
        let k1 = nl.const1();
        cols.push(n, k1);
        let k2 = nl.const1();
        cols.push(2 * n - 1, k2);
        let product = reduce_columns(&mut nl, cols);
        nl.output_bus("p", &product[..2 * n]);
        // Raw generator output; the registry's `:opt=` wrapper (default
        // full pipeline) folds the constant injections and sweeps the
        // speculative reduction carries — see netlist::opt.
        nl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::verify::netlist_multiply_all;

    /// Netlist equals a*b for all pairs, N=4 (exhaustive, 256 pairs).
    #[test]
    fn netlist_exact_n4_exhaustive() {
        let m = ExactBaughWooley::new(4);
        let nl = m.build_netlist();
        let products = netlist_multiply_all(&nl, 4);
        for (idx, &p) in products.iter().enumerate() {
            let a = from_bits((idx >> 4) as u64, 4);
            let b = from_bits((idx & 0xF) as u64, 4);
            assert_eq!(p, a * b, "{a}*{b}");
        }
    }

    /// Netlist equals a*b for all 65 536 pairs, N=8.
    #[test]
    fn netlist_exact_n8_exhaustive() {
        let m = ExactBaughWooley::new(8);
        let nl = m.build_netlist();
        let products = netlist_multiply_all(&nl, 8);
        for (idx, &p) in products.iter().enumerate() {
            let a = from_bits((idx >> 8) as u64, 8);
            let b = from_bits((idx & 0xFF) as u64, 8);
            assert_eq!(p, a * b, "{a}*{b}");
        }
    }

    /// Sampled check for wider operands (N=12, N=16).
    #[test]
    fn netlist_exact_wide_sampled() {
        for n in [12usize, 16] {
            let m = ExactBaughWooley::new(n);
            let nl = m.build_netlist();
            let mut rng = crate::util::prng::Xoshiro256::seeded(n as u64);
            let half = 1i64 << (n - 1);
            let cases: Vec<(i64, i64)> = (0..200)
                .map(|_| (rng.range_i64(-half, half - 1), rng.range_i64(-half, half - 1)))
                .chain([(-half, -half), (half - 1, half - 1), (-half, half - 1), (0, 0)])
                .collect();
            for (a, b) in cases {
                let p = crate::multipliers::verify::netlist_multiply_one(&nl, n, a, b);
                assert_eq!(p, a * b, "N={n}: {a}*{b}");
            }
        }
    }

    #[test]
    fn optimized_structure_has_no_dead_logic() {
        use crate::netlist::{optimize_netlist, OptLevel};
        let raw = ExactBaughWooley::new(8).build_netlist();
        assert_eq!(raw.inputs().len(), 16);
        assert_eq!(raw.outputs().len(), 16);
        let (nl, report) = optimize_netlist(&raw, OptLevel::Full);
        assert_eq!(nl.validate().unwrap(), 0, "pipeline leaves no dead logic");
        assert!(
            report.logic_after < report.logic_before,
            "pipeline strictly shrinks the raw exact netlist ({report:?})"
        );
    }
}

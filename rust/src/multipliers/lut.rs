//! Product-table (LUT) generation and export.
//!
//! The 256×256 signed product table of a multiplier model is the
//! interchange format between the Rust fast models and the JAX/Pallas
//! kernel: `python/compile/kernels/approx_mul.py` computes the same table
//! from its own bit-level model and `aot.py` embeds it in the lowered HLO;
//! `make test` cross-checks the two byte-for-byte via
//! `artifacts/<design>_lut.i32` (see python/tests/test_lut_crosscheck.py
//! and rust/tests/lut_crosscheck.rs).

use super::traits::MultiplierModel;
use std::io::Write;
use std::path::Path;

/// Full product table for an 8-bit design. Index = `(a_byte << 8) | b_byte`
/// where `a_byte`/`b_byte` are the operands' two's-complement bit patterns.
pub fn product_table(model: &dyn MultiplierModel) -> Vec<i32> {
    assert_eq!(model.bits(), 8, "LUT export is defined for N=8");
    let mut lut = Vec::with_capacity(65536);
    for a_byte in 0..256u32 {
        let a = a_byte as u8 as i8 as i64;
        for b_byte in 0..256u32 {
            let b = b_byte as u8 as i8 as i64;
            lut.push(model.multiply(a, b) as i32);
        }
    }
    lut
}

/// Write a table as little-endian i32, the layout the python side reads
/// with `np.fromfile(..., dtype='<i4').reshape(256, 256)`.
pub fn write_i32_le(path: &Path, lut: &[i32]) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for &v in lut {
        f.write_all(&v.to_le_bytes())?;
    }
    f.flush()
}

/// Read a table previously written with [`write_i32_le`].
pub fn read_i32_le(path: &Path) -> std::io::Result<Vec<i32>> {
    let bytes = std::fs::read(path)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::spec::registry;

    #[test]
    fn exact_table_is_products() {
        let lut = product_table(registry().build_str("exact@8").unwrap().as_ref());
        assert_eq!(lut.len(), 65536);
        assert_eq!(lut[0], 0); // 0*0
        let idx = |a: i8, b: i8| ((a as u8 as usize) << 8) | (b as u8 as usize);
        assert_eq!(lut[idx(-128, -128)], 16384);
        assert_eq!(lut[idx(127, -128)], -16256);
        assert_eq!(lut[idx(3, 7)], 21);
    }

    #[test]
    fn proposed_table_io_roundtrip() {
        let lut = product_table(registry().build_str("proposed@8").unwrap().as_ref());
        let dir = std::env::temp_dir().join("sfcmul_lut_test");
        let path = dir.join("proposed_lut.i32");
        write_i32_le(&path, &lut).unwrap();
        let back = read_i32_le(&path).unwrap();
        assert_eq!(lut, back);
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Multiplier model interface + shared Baugh-Wooley partial-product
//! helpers.

use crate::netlist::Netlist;

/// A signed N×N multiplier with coupled functional and gate-level forms.
pub trait MultiplierModel: Send + Sync {
    /// Display name as used in the paper's tables ("Proposed", "Design
    /// [2]", "Exact", ...).
    fn name(&self) -> String;

    /// Operand width N in bits.
    fn bits(&self) -> usize;

    /// Functional model. Operands are interpreted as signed N-bit values
    /// (callers pass values in `[-2^(N-1), 2^(N-1))`); the result is the
    /// (possibly approximate) signed 2N-bit product.
    fn multiply(&self, a: i64, b: i64) -> i64;

    /// Gate-level implementation with inputs `a0..a{N-1}, b0..b{N-1}`
    /// (LSB first) and outputs `p0..p{2N-1}`.
    fn build_netlist(&self) -> Netlist;
}

/// Kind of a Baugh-Wooley partial product.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PpKind {
    /// AND(a_i, b_j) — positive.
    And,
    /// NAND(a_i, b_j) — negative (exactly one operand is a sign bit).
    Nand,
}

/// Classify partial product (i, j) for an N-bit Baugh-Wooley matrix:
/// NAND iff exactly one of the operands is the sign bit (paper Eq. 3 /
/// Fig. 1 — black vs blue dots).
pub fn pp_kind(i: usize, j: usize, n: usize) -> PpKind {
    if (i == n - 1) ^ (j == n - 1) {
        PpKind::Nand
    } else {
        PpKind::And
    }
}

/// Functional value of partial product (i, j) for operands `a`, `b`
/// (bit-indexed from LSB; operands already wrapped to N bits).
#[inline]
pub fn pp_value(a: u64, b: u64, i: usize, j: usize, n: usize) -> bool {
    let bit = ((a >> i) & 1) & ((b >> j) & 1) != 0;
    match pp_kind(i, j, n) {
        PpKind::And => bit,
        PpKind::Nand => !bit,
    }
}

/// Wrap an i64 into N-bit two's complement (as unsigned bits).
#[inline]
pub fn to_bits(v: i64, n: usize) -> u64 {
    (v as u64) & mask(n)
}

/// Interpret the low `n` bits of `v` as signed two's complement.
#[inline]
pub fn from_bits(v: u64, n: usize) -> i64 {
    let m = mask(n);
    let v = v & m;
    if n < 64 && (v >> (n - 1)) & 1 == 1 {
        (v | !m) as i64
    } else {
        v as i64
    }
}

#[inline]
pub fn mask(n: usize) -> u64 {
    if n >= 64 {
        !0
    } else {
        (1u64 << n) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pp_kind_matches_bw_rule() {
        let n = 8;
        assert_eq!(pp_kind(0, 0, n), PpKind::And);
        assert_eq!(pp_kind(7, 3, n), PpKind::Nand);
        assert_eq!(pp_kind(3, 7, n), PpKind::Nand);
        assert_eq!(pp_kind(7, 7, n), PpKind::And, "sign×sign is positive");
    }

    #[test]
    fn bit_roundtrip() {
        for v in [-128i64, -1, 0, 1, 127] {
            assert_eq!(from_bits(to_bits(v, 8), 8), v);
        }
        for v in [-32768i64, -5, 0, 32767] {
            assert_eq!(from_bits(to_bits(v, 16), 16), v);
        }
    }

    /// The Baugh-Wooley identity: summing all partial products with the two
    /// constants reproduces the exact signed product for every pair —
    /// checked exhaustively for N=4 (Table 1's example generalised) and
    /// sampled for N=8.
    #[test]
    fn bw_identity_n4_exhaustive() {
        let n = 4;
        for a in -8i64..8 {
            for b in -8i64..8 {
                let ua = to_bits(a, n);
                let ub = to_bits(b, n);
                let mut acc: u64 = (1 << n) + (1 << (2 * n - 1)); // the two constants
                for i in 0..n {
                    for j in 0..n {
                        if pp_value(ua, ub, i, j, n) {
                            acc = acc.wrapping_add(1 << (i + j));
                        }
                    }
                }
                assert_eq!(from_bits(acc, 2 * n), a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    fn bw_identity_n8_sampled() {
        let n = 8;
        let mut rng = crate::util::prng::Xoshiro256::seeded(17);
        for _ in 0..2000 {
            let a = rng.next_i8() as i64;
            let b = rng.next_i8() as i64;
            let ua = to_bits(a, n);
            let ub = to_bits(b, n);
            let mut acc: u64 = (1 << n) + (1 << (2 * n - 1));
            for i in 0..n {
                for j in 0..n {
                    if pp_value(ua, ub, i, j, n) {
                        acc = acc.wrapping_add(1 << (i + j));
                    }
                }
            }
            assert_eq!(from_bits(acc, 2 * n), a * b, "{a}*{b}");
        }
    }
}

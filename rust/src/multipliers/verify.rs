//! Netlist ↔ functional-model equivalence checking.
//!
//! All netlist evaluation routes through the bitsliced engine
//! ([`crate::netlist::bitslice::BitSim`]): operand pairs are encoded as
//! input codes (`a` in bits `0..N`, `b` in bits `N..2N` — the netlists'
//! `a0..a{N-1}, b0..b{N-1}` input order), transposed into bit-planes 64
//! lanes at a time, and simulated in one pass per 64 pairs. The
//! exhaustive N=8 sweep (65 536 pairs) is ~1 000 passes; widths above 10
//! are checked by random sampling ([`sampled_check`]) — 10 000 pairs is
//! ~160 passes.

use super::traits::{from_bits, mask, to_bits, MultiplierModel};
use crate::netlist::prelude::{BitSim, Netlist};
use crate::util::prng::Xoshiro256;

/// Concatenated input code of an operand pair for an N-bit multiplier
/// netlist (inputs `a0..a{N-1}, b0..b{N-1}`, LSB first): bit `i` drives
/// `a_i`, bit `N+j` drives `b_j`.
#[inline]
pub fn operand_code(a: i64, b: i64, n: usize) -> u64 {
    debug_assert!(2 * n <= 64, "2N-bit code must fit one u64");
    to_bits(a, n) | (to_bits(b, n) << n)
}

/// Run one (a, b) pair through a multiplier netlist built with input buses
/// `a0..`, `b0..` and output bus `p0..p{2N-1}`.
pub fn netlist_multiply_one(nl: &Netlist, n: usize, a: i64, b: i64) -> i64 {
    let mut sim = BitSim::new(nl);
    bitsim_multiply_batch(&mut sim, n, &[(a, b)])[0]
}

/// Run a batch of pairs through a caller-held simulator (amortises the
/// [`BitSim`] construction across many batches on the hot path).
pub fn bitsim_multiply_batch(sim: &mut BitSim, n: usize, pairs: &[(i64, i64)]) -> Vec<i64> {
    let codes: Vec<u64> = pairs.iter().map(|&(a, b)| operand_code(a, b, n)).collect();
    sim.run_code_batch(&codes).into_iter().map(|c| from_bits(c, 2 * n)).collect()
}

/// Run a batch of pairs (up to arbitrary length) and return products in
/// order.
pub fn netlist_multiply_batch(nl: &Netlist, n: usize, pairs: &[(i64, i64)]) -> Vec<i64> {
    let mut sim = BitSim::new(nl);
    bitsim_multiply_batch(&mut sim, n, pairs)
}

/// Exhaustively evaluate an N≤10 multiplier netlist over all `4^N` operand
/// pairs. Result index = `(a_bits << N) | b_bits` (unsigned bit patterns).
pub fn netlist_multiply_all(nl: &Netlist, n: usize) -> Vec<i64> {
    assert!(n <= 10, "exhaustive sweep limited to N<=10");
    let total = 1usize << (2 * n);
    let m = mask(n);
    let mut sim = BitSim::new(nl);
    let mut out = Vec::with_capacity(total);
    let mut codes = [0u64; 64];
    let mut products = [0u64; 64];
    let mut idx = 0usize;
    while idx < total {
        let lanes = (total - idx).min(64);
        for (lane, c) in codes.iter_mut().take(lanes).enumerate() {
            let code = (idx + lane) as u64;
            // result index is (a << N) | b; the input code carries a in
            // its low N bits and b above
            *c = (code >> n) | ((code & m) << n);
        }
        sim.run_codes_into(&codes[..lanes], &mut products[..lanes]);
        for &p in &products[..lanes] {
            out.push(from_bits(p, 2 * n));
        }
        idx += lanes;
    }
    out
}

/// Verify that `model.multiply` and the built netlist agree on *every*
/// operand pair (N ≤ 10). Returns the first mismatch as an error message.
pub fn exhaustive_check(model: &dyn MultiplierModel) -> Result<(), String> {
    let n = model.bits();
    assert!(n <= 10);
    let nl = model.build_netlist();
    let hw = netlist_multiply_all(&nl, n);
    for (idx, &hw_p) in hw.iter().enumerate() {
        let a = from_bits((idx >> n) as u64, n);
        let b = from_bits((idx as u64) & super::traits::mask(n), n);
        let sw_p = model.multiply(a, b);
        if sw_p != hw_p {
            return Err(format!(
                "{}: {a} * {b}: functional model {sw_p}, netlist {hw_p}",
                model.name()
            ));
        }
    }
    Ok(())
}

/// Verify that `model.multiply` and the built netlist agree on `samples`
/// uniformly random operand pairs — the width-generic companion of
/// [`exhaustive_check`] for N > 8 (any N ≤ 31: the 2N-bit product must
/// fit the simulator's 64-bit integer lanes with sign headroom).
/// Returns the first mismatch as an error message.
pub fn sampled_check(
    model: &dyn MultiplierModel,
    samples: usize,
    seed: u64,
) -> Result<(), String> {
    let n = model.bits();
    assert!(n <= 31, "sampled check supports N<=31");
    let nl = model.build_netlist();
    let mut rng = Xoshiro256::seeded(seed);
    let half = 1i64 << (n - 1);
    let pairs: Vec<(i64, i64)> = (0..samples)
        .map(|_| (rng.range_i64(-half, half - 1), rng.range_i64(-half, half - 1)))
        .collect();
    let hw = netlist_multiply_batch(&nl, n, &pairs);
    for (&(a, b), &hw_p) in pairs.iter().zip(hw.iter()) {
        let sw_p = model.multiply(a, b);
        if sw_p != hw_p {
            return Err(format!(
                "{}: {a} * {b}: functional model {sw_p}, netlist {hw_p}",
                model.name()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::exact::ExactBaughWooley;

    #[test]
    fn sampled_check_agrees_with_exhaustive_at_n8() {
        sampled_check(&ExactBaughWooley::new(8), 2000, 11).unwrap();
    }

    #[test]
    fn sampled_check_passes_for_wide_exact() {
        sampled_check(&ExactBaughWooley::new(12), 1500, 5).unwrap();
    }

    #[test]
    fn batch_equals_one_by_one() {
        let m = ExactBaughWooley::new(6);
        let nl = m.build_netlist();
        let mut rng = crate::util::prng::Xoshiro256::seeded(3);
        let pairs: Vec<(i64, i64)> =
            (0..150).map(|_| (rng.range_i64(-32, 31), rng.range_i64(-32, 31))).collect();
        let batch = netlist_multiply_batch(&nl, 6, &pairs);
        for (i, &(a, b)) in pairs.iter().enumerate() {
            assert_eq!(batch[i], netlist_multiply_one(&nl, 6, a, b));
            assert_eq!(batch[i], a * b);
        }
    }

    #[test]
    fn exhaustive_check_passes_for_exact() {
        exhaustive_check(&ExactBaughWooley::new(4)).unwrap();
        exhaustive_check(&ExactBaughWooley::new(8)).unwrap();
    }
}

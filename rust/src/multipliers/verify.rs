//! Netlist ↔ functional-model equivalence checking.
//!
//! Uses the packed simulator to run 64 operand pairs per netlist pass, so
//! the exhaustive N=8 sweep (65 536 pairs) is ~1 000 passes. Widths above
//! 8 are checked by random sampling ([`sampled_check`]) — 10 000 pairs is
//! ~160 passes.

use super::traits::{from_bits, to_bits, MultiplierModel};
use crate::netlist::sim::{pack_int_lane, unpack_int_lane, PackedSim};
use crate::netlist::Netlist;
use crate::util::prng::Xoshiro256;

/// Run one (a, b) pair through a multiplier netlist built with input buses
/// `a0..`, `b0..` and output bus `p0..p{2N-1}`.
pub fn netlist_multiply_one(nl: &Netlist, n: usize, a: i64, b: i64) -> i64 {
    let mut sim = PackedSim::new(nl);
    let mut inputs = vec![0u64; 2 * n];
    pack_int_lane(&mut inputs, 0, 0, to_bits(a, n), n);
    pack_int_lane(&mut inputs, 0, n, to_bits(b, n), n);
    let outs = sim.run_outputs(nl, &inputs);
    from_bits(unpack_int_lane(&outs, 0), 2 * n)
}

/// Run a batch of pairs (up to arbitrary length) and return products in
/// order.
pub fn netlist_multiply_batch(nl: &Netlist, n: usize, pairs: &[(i64, i64)]) -> Vec<i64> {
    let mut sim = PackedSim::new(nl);
    let mut out = Vec::with_capacity(pairs.len());
    for chunk in pairs.chunks(64) {
        let mut inputs = vec![0u64; 2 * n];
        for (lane, &(a, b)) in chunk.iter().enumerate() {
            pack_int_lane(&mut inputs, lane, 0, to_bits(a, n), n);
            pack_int_lane(&mut inputs, lane, n, to_bits(b, n), n);
        }
        let outs = sim.run_outputs(nl, &inputs);
        for lane in 0..chunk.len() {
            out.push(from_bits(unpack_int_lane(&outs, lane), 2 * n));
        }
    }
    out
}

/// Exhaustively evaluate an N≤8 multiplier netlist over all `4^N` operand
/// pairs. Result index = `(a_bits << N) | b_bits` (unsigned bit patterns).
pub fn netlist_multiply_all(nl: &Netlist, n: usize) -> Vec<i64> {
    assert!(n <= 8, "exhaustive sweep limited to N<=8");
    let total = 1usize << (2 * n);
    let mut sim = PackedSim::new(nl);
    let mut out = Vec::with_capacity(total);
    let mut idx = 0usize;
    while idx < total {
        let lanes = (total - idx).min(64);
        let mut inputs = vec![0u64; 2 * n];
        for lane in 0..lanes {
            let code = (idx + lane) as u64;
            let ua = code >> n;
            let ub = code & super::traits::mask(n);
            pack_int_lane(&mut inputs, lane, 0, ua, n);
            pack_int_lane(&mut inputs, lane, n, ub, n);
        }
        let outs = sim.run_outputs(nl, &inputs);
        for lane in 0..lanes {
            out.push(from_bits(unpack_int_lane(&outs, lane), 2 * n));
        }
        idx += lanes;
    }
    out
}

/// Verify that `model.multiply` and the built netlist agree on *every*
/// operand pair (N ≤ 8). Returns the first mismatch as an error message.
pub fn exhaustive_check(model: &dyn MultiplierModel) -> Result<(), String> {
    let n = model.bits();
    assert!(n <= 8);
    let nl = model.build_netlist();
    let hw = netlist_multiply_all(&nl, n);
    for (idx, &hw_p) in hw.iter().enumerate() {
        let a = from_bits((idx >> n) as u64, n);
        let b = from_bits((idx as u64) & super::traits::mask(n), n);
        let sw_p = model.multiply(a, b);
        if sw_p != hw_p {
            return Err(format!(
                "{}: {a} * {b}: functional model {sw_p}, netlist {hw_p}",
                model.name()
            ));
        }
    }
    Ok(())
}

/// Verify that `model.multiply` and the built netlist agree on `samples`
/// uniformly random operand pairs — the width-generic companion of
/// [`exhaustive_check`] for N > 8 (any N ≤ 31: the 2N-bit product must
/// fit the simulator's 64-bit integer lanes with sign headroom).
/// Returns the first mismatch as an error message.
pub fn sampled_check(
    model: &dyn MultiplierModel,
    samples: usize,
    seed: u64,
) -> Result<(), String> {
    let n = model.bits();
    assert!(n <= 31, "sampled check supports N<=31");
    let nl = model.build_netlist();
    let mut rng = Xoshiro256::seeded(seed);
    let half = 1i64 << (n - 1);
    let pairs: Vec<(i64, i64)> = (0..samples)
        .map(|_| (rng.range_i64(-half, half - 1), rng.range_i64(-half, half - 1)))
        .collect();
    let hw = netlist_multiply_batch(&nl, n, &pairs);
    for (&(a, b), &hw_p) in pairs.iter().zip(hw.iter()) {
        let sw_p = model.multiply(a, b);
        if sw_p != hw_p {
            return Err(format!(
                "{}: {a} * {b}: functional model {sw_p}, netlist {hw_p}",
                model.name()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::exact::ExactBaughWooley;

    #[test]
    fn sampled_check_agrees_with_exhaustive_at_n8() {
        sampled_check(&ExactBaughWooley::new(8), 2000, 11).unwrap();
    }

    #[test]
    fn sampled_check_passes_for_wide_exact() {
        sampled_check(&ExactBaughWooley::new(12), 1500, 5).unwrap();
    }

    #[test]
    fn batch_equals_one_by_one() {
        let m = ExactBaughWooley::new(6);
        let nl = m.build_netlist();
        let mut rng = crate::util::prng::Xoshiro256::seeded(3);
        let pairs: Vec<(i64, i64)> =
            (0..150).map(|_| (rng.range_i64(-32, 31), rng.range_i64(-32, 31))).collect();
        let batch = netlist_multiply_batch(&nl, 6, &pairs);
        for (i, &(a, b)) in pairs.iter().enumerate() {
            assert_eq!(batch[i], netlist_multiply_one(&nl, 6, a, b));
            assert_eq!(batch[i], a * b);
        }
    }

    #[test]
    fn exhaustive_check_passes_for_exact() {
        exhaustive_check(&ExactBaughWooley::new(4)).unwrap();
        exhaustive_check(&ExactBaughWooley::new(8)).unwrap();
    }
}

//! Multiplier architectures (paper §2, §3 — the device under test for
//! Tables 4 and 5 and Figs 9/10).
//!
//! Every multiplier exists in two cross-checked forms:
//!
//! * a **fast functional model** ([`traits::MultiplierModel::multiply`])
//!   used by the error harness, LUT generation and the convolution paths;
//! * a **gate-level netlist** ([`traits::MultiplierModel::build_netlist`])
//!   used by the hardware model (area / delay / power).
//!
//! For N = 8 the two forms are verified identical over all 65 536 input
//! pairs (`tests/` + `verify::exhaustive_check`).
//!
//! Architecture inventory (see DESIGN.md §Reconstruction for the exact
//! CSP wiring):
//!
//! * [`exact`] — exact Baugh-Wooley multiplier, generic N.
//! * [`approx`] — the truncated + compensated sign-focused framework
//!   (paper Fig. 5/6), parameterised by which compressor designs occupy
//!   the CSP slots — instantiating it with each baseline compressor
//!   reproduces the paper's Table 4/5 comparison set (§5.1).
//! * [`spec`] — the construction API: [`DesignSpec`] (compressor family ×
//!   bitwidth × truncation × compensation × netlist-optimization level,
//!   round-tripping a compact string form) and the name → factory
//!   [`Registry`] every multiplier is built through. Factories emit the
//!   raw generator netlist; [`Registry::build`] wraps each model in
//!   [`spec::Optimized`] per the spec's `:opt=` knob (default: the full
//!   graph pass pipeline), so downstream consumers simulate and cost the
//!   optimized gate program.
//! * [`designs`] — the named paper configurations (Proposed, [12], [5],
//!   [4], [1], [7], [2]) as thin [`DesignId`] aliases over canonical
//!   specs, plus the Table-5 hardware variants.
//! * [`lut`] — 256×256 product-table export shared with the Pallas kernel.
//! * [`verify`] — netlist-vs-model equivalence checking (exhaustive for
//!   N ≤ 8, sampled for wider widths).

pub mod traits;
pub mod booth;
pub mod exact;
pub mod approx;
pub mod spec;
pub mod designs;
pub mod lut;
pub mod verify;

pub use approx::{ApproxMulConfig, ApproxSignedMultiplier, Compensation, LspMode, Sf3Mode};
pub use designs::{all_designs, all_designs_hw, build_design, build_design_hw, design_by_name, DesignId};
pub use booth::BoothRadix4;
pub use exact::ExactBaughWooley;
pub use spec::{registry, CompressorChoice, DesignSpec, Optimized, Registry, TruncMode};
pub use traits::MultiplierModel;

//! Netlist construction.
//!
//! A [`Netlist`] is an append-only DAG: every gate's operands must already
//! exist when the gate is added, so the gate vector is always in topological
//! order and simulation/timing are single forward passes. Signals are dense
//! `u32` ids.

use super::gate::GateKind;

pub type SigId = u32;

#[derive(Debug, Clone, Copy)]
pub struct Gate {
    pub kind: GateKind,
    /// Operands; only the first `kind.arity()` entries are meaningful.
    pub ins: [SigId; 3],
}

#[derive(Debug, Clone, Default)]
pub struct Netlist {
    pub name: String,
    gates: Vec<Gate>,
    input_ids: Vec<SigId>,
    input_names: Vec<String>,
    outputs: Vec<(String, SigId)>,
}

impl Netlist {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), ..Default::default() }
    }

    // ---- introspection ------------------------------------------------

    pub fn len(&self) -> usize {
        self.gates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gates.is_empty()
    }

    pub fn gates(&self) -> &[Gate] {
        &self.gates
    }

    pub fn inputs(&self) -> &[SigId] {
        &self.input_ids
    }

    pub fn input_names(&self) -> &[String] {
        &self.input_names
    }

    pub fn outputs(&self) -> &[(String, SigId)] {
        &self.outputs
    }

    pub fn output_ids(&self) -> Vec<SigId> {
        self.outputs.iter().map(|&(_, id)| id).collect()
    }

    /// Total area in gate equivalents.
    pub fn area(&self) -> f64 {
        self.gates.iter().map(|g| g.kind.area()).sum()
    }

    /// Gate count per kind (diagnostics, reports).
    pub fn kind_histogram(&self) -> Vec<(GateKind, usize)> {
        let mut hist: Vec<(GateKind, usize)> = Vec::new();
        for g in &self.gates {
            match hist.iter_mut().find(|(k, _)| *k == g.kind) {
                Some((_, n)) => *n += 1,
                None => hist.push((g.kind, 1)),
            }
        }
        hist
    }

    /// Count of two-input-equivalent logic gates (excludes inputs/consts).
    pub fn logic_gate_count(&self) -> usize {
        self.gates
            .iter()
            .filter(|g| !matches!(g.kind, GateKind::Input | GateKind::Const0 | GateKind::Const1))
            .count()
    }

    // ---- construction --------------------------------------------------

    fn push(&mut self, kind: GateKind, ins: [SigId; 3]) -> SigId {
        let arity = kind.arity();
        let id = self.gates.len() as SigId;
        for (slot, &op) in ins.iter().enumerate() {
            if slot < arity {
                assert!(
                    op < id,
                    "netlist {}: gate {id} ({kind:?}) references future signal {op}",
                    self.name
                );
            }
        }
        self.gates.push(Gate { kind, ins });
        id
    }

    /// Append a gate of any kind (the generic form behind the typed
    /// helpers below; [`Graph::compile`](super::graph::Graph::compile)
    /// re-linearises through this). Operands must already exist —
    /// append-only topological discipline. Inputs must go through
    /// [`Netlist::input`] so the name table stays consistent.
    pub fn push_gate(&mut self, kind: GateKind, ins: [SigId; 3]) -> SigId {
        assert!(
            kind != GateKind::Input,
            "netlist {}: use input()/input_bus() for primary inputs",
            self.name
        );
        self.push(kind, ins)
    }

    pub fn input(&mut self, name: &str) -> SigId {
        let id = self.push(GateKind::Input, [0; 3]);
        self.input_ids.push(id);
        self.input_names.push(name.to_string());
        id
    }

    /// Add `n` inputs named `prefix0..prefix{n-1}`.
    pub fn input_bus(&mut self, prefix: &str, n: usize) -> Vec<SigId> {
        (0..n).map(|i| self.input(&format!("{prefix}{i}"))).collect()
    }

    pub fn const0(&mut self) -> SigId {
        self.push(GateKind::Const0, [0; 3])
    }

    pub fn const1(&mut self) -> SigId {
        self.push(GateKind::Const1, [0; 3])
    }

    pub fn output(&mut self, name: &str, sig: SigId) {
        assert!((sig as usize) < self.gates.len(), "output of unknown signal");
        self.outputs.push((name.to_string(), sig));
    }

    /// Register a whole bus as outputs `prefix0..`, LSB first.
    pub fn output_bus(&mut self, prefix: &str, sigs: &[SigId]) {
        for (i, &s) in sigs.iter().enumerate() {
            self.output(&format!("{prefix}{i}"), s);
        }
    }

    // unary / binary / ternary helpers ------------------------------------

    pub fn not(&mut self, a: SigId) -> SigId {
        self.push(GateKind::Not, [a, 0, 0])
    }
    pub fn buf(&mut self, a: SigId) -> SigId {
        self.push(GateKind::Buf, [a, 0, 0])
    }
    pub fn and2(&mut self, a: SigId, b: SigId) -> SigId {
        self.push(GateKind::And2, [a, b, 0])
    }
    pub fn or2(&mut self, a: SigId, b: SigId) -> SigId {
        self.push(GateKind::Or2, [a, b, 0])
    }
    pub fn nand2(&mut self, a: SigId, b: SigId) -> SigId {
        self.push(GateKind::Nand2, [a, b, 0])
    }
    pub fn nor2(&mut self, a: SigId, b: SigId) -> SigId {
        self.push(GateKind::Nor2, [a, b, 0])
    }
    pub fn xor2(&mut self, a: SigId, b: SigId) -> SigId {
        self.push(GateKind::Xor2, [a, b, 0])
    }
    pub fn xnor2(&mut self, a: SigId, b: SigId) -> SigId {
        self.push(GateKind::Xnor2, [a, b, 0])
    }
    pub fn and3(&mut self, a: SigId, b: SigId, c: SigId) -> SigId {
        self.push(GateKind::And3, [a, b, c])
    }
    pub fn or3(&mut self, a: SigId, b: SigId, c: SigId) -> SigId {
        self.push(GateKind::Or3, [a, b, c])
    }
    pub fn nand3(&mut self, a: SigId, b: SigId, c: SigId) -> SigId {
        self.push(GateKind::Nand3, [a, b, c])
    }
    pub fn nor3(&mut self, a: SigId, b: SigId, c: SigId) -> SigId {
        self.push(GateKind::Nor3, [a, b, c])
    }
    pub fn maj3(&mut self, a: SigId, b: SigId, c: SigId) -> SigId {
        self.push(GateKind::Maj3, [a, b, c])
    }
    pub fn aoi21(&mut self, a: SigId, b: SigId, c: SigId) -> SigId {
        self.push(GateKind::Aoi21, [a, b, c])
    }
    pub fn oai21(&mut self, a: SigId, b: SigId, c: SigId) -> SigId {
        self.push(GateKind::Oai21, [a, b, c])
    }
    /// `if sel { b } else { a }`
    pub fn mux2(&mut self, sel: SigId, a: SigId, b: SigId) -> SigId {
        self.push(GateKind::Mux2, [sel, a, b])
    }

    /// XOR of three (two gate levels).
    pub fn xor3(&mut self, a: SigId, b: SigId, c: SigId) -> SigId {
        let ab = self.xor2(a, b);
        self.xor2(ab, c)
    }

    /// XNOR of three.
    pub fn xnor3(&mut self, a: SigId, b: SigId, c: SigId) -> SigId {
        let ab = self.xor2(a, b);
        self.xnor2(ab, c)
    }

    /// OR of a slice (balanced tree).
    pub fn or_many(&mut self, sigs: &[SigId]) -> SigId {
        match sigs.len() {
            0 => self.const0(),
            1 => sigs[0],
            2 => self.or2(sigs[0], sigs[1]),
            3 => self.or3(sigs[0], sigs[1], sigs[2]),
            n => {
                let (lo, hi) = sigs.split_at(n / 2);
                let l = self.or_many(lo);
                let r = self.or_many(hi);
                self.or2(l, r)
            }
        }
    }

    /// AND of a slice (balanced tree).
    pub fn and_many(&mut self, sigs: &[SigId]) -> SigId {
        match sigs.len() {
            0 => self.const1(),
            1 => sigs[0],
            2 => self.and2(sigs[0], sigs[1]),
            3 => self.and3(sigs[0], sigs[1], sigs[2]),
            n => {
                let (lo, hi) = sigs.split_at(n / 2);
                let l = self.and_many(lo);
                let r = self.and_many(hi);
                self.and2(l, r)
            }
        }
    }

    /// Constant propagation + trivial-identity elimination.
    ///
    /// Legacy entry point, kept so out-of-tree construction snippets
    /// still compile: it now routes through the graph pass pipeline
    /// ([`ConstFold`](super::opt::ConstFold) + a dead sweep), which
    /// strictly subsumes the old inline one-pass fold. Returns the number
    /// of gates removed.
    #[deprecated(
        note = "route through netlist::opt::optimize_netlist(&nl, OptLevel::Fold) \
                or run graph passes directly"
    )]
    pub fn fold_constants(&mut self) -> usize {
        let before = self.gates.len();
        let (out, _report) = super::opt::optimize_netlist(self, super::opt::OptLevel::Fold);
        *self = out;
        before.saturating_sub(self.gates.len())
    }

    /// Remove gates not reachable from any output (dead logic), remapping
    /// signal ids. Primary inputs are always kept (interface stability).
    ///
    /// Legacy entry point: now a thin wrapper over
    /// [`DeadGateElim`](super::opt::DeadGateElim) +
    /// [`Graph::compile`](super::graph::Graph::compile). Returns the
    /// number of gates removed.
    #[deprecated(
        note = "route through netlist::opt passes (DeadGateElim) or Graph::compile, \
                which sweeps dead gates implicitly"
    )]
    pub fn prune_dead(&mut self) -> usize {
        let before = self.gates.len();
        let mut g = super::graph::Graph::from(&*self);
        super::opt::Pass::run(&super::opt::DeadGateElim, &mut g);
        *self = g.compile();
        before.saturating_sub(self.gates.len())
    }

    /// Structural validation: operand bounds, arity discipline, outputs
    /// registered, at least one gate reachable from each output. Returns the
    /// number of gates *not* reachable from any output (dead logic) — useful
    /// for catching wasteful generators in tests.
    pub fn validate(&self) -> Result<usize, String> {
        for (i, g) in self.gates.iter().enumerate() {
            for slot in 0..g.kind.arity() {
                let op = g.ins[slot];
                if op as usize >= i {
                    return Err(format!("gate {i} operand {slot} forward-references {op}"));
                }
            }
        }
        for (name, id) in &self.outputs {
            if *id as usize >= self.gates.len() {
                return Err(format!("output {name} references unknown signal {id}"));
            }
        }
        // dead-logic sweep
        let mut live = vec![false; self.gates.len()];
        let mut stack: Vec<usize> = self.outputs.iter().map(|&(_, id)| id as usize).collect();
        while let Some(i) = stack.pop() {
            if live[i] {
                continue;
            }
            live[i] = true;
            let g = &self.gates[i];
            for slot in 0..g.kind.arity() {
                stack.push(g.ins[slot] as usize);
            }
        }
        let dead = self
            .gates
            .iter()
            .enumerate()
            .filter(|(i, g)| !live[*i] && !matches!(g.kind, GateKind::Input))
            .count();
        Ok(dead)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_simple_circuit() {
        let mut n = Netlist::new("toy");
        let a = n.input("a");
        let b = n.input("b");
        let x = n.xor2(a, b);
        n.output("x", x);
        assert_eq!(n.inputs().len(), 2);
        assert_eq!(n.outputs().len(), 1);
        assert_eq!(n.validate().unwrap(), 0);
        assert!(n.area() > 0.0);
    }

    #[test]
    #[should_panic(expected = "future signal")]
    fn forward_reference_panics() {
        let mut n = Netlist::new("bad");
        let a = n.input("a");
        n.push(GateKind::And2, [a, 99, 0]);
    }

    #[test]
    fn or_many_and_many_cover_arities() {
        for k in 0..6 {
            let mut n = Netlist::new("tree");
            let ins = n.input_bus("i", k);
            let o = n.or_many(&ins);
            let a = n.and_many(&ins);
            n.output("o", o);
            n.output("a", a);
            n.validate().unwrap();
        }
    }

    #[test]
    fn dead_logic_is_counted() {
        let mut n = Netlist::new("dead");
        let a = n.input("a");
        let b = n.input("b");
        let live = n.and2(a, b);
        let _dead = n.or2(a, b);
        n.output("x", live);
        assert_eq!(n.validate().unwrap(), 1);
    }

    #[test]
    #[allow(deprecated)] // exercises the legacy wrappers on purpose
    fn prune_dead_removes_and_remaps() {
        let mut n = Netlist::new("p");
        let a = n.input("a");
        let b = n.input("b");
        let live = n.xor2(a, b);
        let _dead1 = n.and2(a, b);
        let _dead2 = n.or2(a, b);
        n.output("x", live);
        let removed = n.prune_dead();
        assert_eq!(removed, 2);
        assert_eq!(n.validate().unwrap(), 0);
        // circuit still works
        let o = crate::netlist::sim::eval_outputs_bool(&n, &[true, false]);
        assert!(o[0]);
    }

    #[test]
    #[allow(deprecated)] // exercises the legacy wrappers on purpose
    fn fold_constants_simplifies_and_preserves_function() {
        use crate::netlist::sim::eval_outputs_bool;
        let mut n = Netlist::new("f");
        let a = n.input("a");
        let b = n.input("b");
        let one = n.const1();
        let zero = n.const0();
        let x1 = n.and2(a, one); // → a
        let x2 = n.or2(b, zero); // → b
        let x3 = n.xor2(x1, one); // → NOT a
        let fa_s = n.xor3(x1, x2, zero); // → a ⊕ b
        let fa_c = n.maj3(x1, x2, one); // → a | b
        let dead = n.and3(a, b, zero); // → 0
        let out = n.or2(x3, dead); // → NOT a
        n.output("s", fa_s);
        n.output("c", fa_c);
        n.output("o", out);
        let before: Vec<Vec<bool>> = (0..4)
            .map(|bits| eval_outputs_bool(&n, &[bits & 1 == 1, bits & 2 == 2]))
            .collect();
        n.fold_constants();
        n.prune_dead();
        let after: Vec<Vec<bool>> = (0..4)
            .map(|bits| eval_outputs_bool(&n, &[bits & 1 == 1, bits & 2 == 2]))
            .collect();
        assert_eq!(before, after, "folding must preserve function");
        // all constants and identities folded: expect xor, or(maj3→or2), not
        assert!(n.logic_gate_count() <= 3, "got {} gates", n.logic_gate_count());
        assert_eq!(n.validate().unwrap(), 0);
    }

    #[test]
    #[allow(deprecated)] // exercises the legacy wrappers on purpose
    fn fold_constants_random_circuits_preserve_function() {
        use crate::netlist::sim::eval_outputs_bool;
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::seeded(2024);
        for trial in 0..30 {
            // random DAG over 4 inputs with sprinkled constants
            let mut n = Netlist::new("r");
            let mut sigs: Vec<SigId> = (0..4).map(|i| n.input(&format!("i{i}"))).collect();
            let k0 = n.const0();
            let k1 = n.const1();
            sigs.push(k0);
            sigs.push(k1);
            for _ in 0..40 {
                let pick = |rng: &mut Xoshiro256, sigs: &[SigId]| {
                    sigs[rng.below(sigs.len() as u64) as usize]
                };
                let a = pick(&mut rng, &sigs);
                let b = pick(&mut rng, &sigs);
                let c = pick(&mut rng, &sigs);
                let s = match rng.below(10) {
                    0 => n.and2(a, b),
                    1 => n.or2(a, b),
                    2 => n.nand2(a, b),
                    3 => n.nor2(a, b),
                    4 => n.xor2(a, b),
                    5 => n.xnor2(a, b),
                    6 => n.maj3(a, b, c),
                    7 => n.mux2(a, b, c),
                    8 => n.aoi21(a, b, c),
                    _ => n.not(a),
                };
                sigs.push(s);
            }
            for (i, &s) in sigs.iter().rev().take(4).enumerate() {
                n.output(&format!("o{i}"), s);
            }
            let before: Vec<Vec<bool>> = (0..16)
                .map(|bits| {
                    eval_outputs_bool(
                        &n,
                        &[(bits & 1) != 0, (bits & 2) != 0, (bits & 4) != 0, (bits & 8) != 0],
                    )
                })
                .collect();
            n.fold_constants();
            let after: Vec<Vec<bool>> = (0..16)
                .map(|bits| {
                    eval_outputs_bool(
                        &n,
                        &[(bits & 1) != 0, (bits & 2) != 0, (bits & 4) != 0, (bits & 8) != 0],
                    )
                })
                .collect();
            assert_eq!(before, after, "trial {trial}");
        }
    }

    #[test]
    fn histogram_counts_kinds() {
        let mut n = Netlist::new("h");
        let a = n.input("a");
        let b = n.input("b");
        let x = n.and2(a, b);
        let y = n.and2(x, b);
        n.output("y", y);
        let hist = n.kind_histogram();
        let ands = hist.iter().find(|(k, _)| *k == GateKind::And2).unwrap().1;
        assert_eq!(ands, 2);
        assert_eq!(n.logic_gate_count(), 2);
    }
}

//! Gate-level netlist EDA toolkit.
//!
//! This is the substrate that replaces the paper's Verilog + Synopsys DC +
//! UMC 90nm evaluation flow (which we do not have). It provides:
//!
//! * [`gate`] — the cell library: gate kinds with unit-gate area, delay and
//!   switching-capacitance figures (documented in `gate.rs`).
//! * [`builder`] — [`Netlist`] construction: a netlist is an append-only DAG
//!   of gates; construction order is a topological order by design, so
//!   simulation and timing are single linear passes.
//! * [`sim`] — functional simulation: a scalar reference evaluator plus
//!   the word-level 64-lane [`sim::PackedSim`].
//! * [`bitslice`] — the bitsliced *batch* engine ([`bitslice::BitSim`]):
//!   each net is a `u64` bit-plane, so one pass over the gate list
//!   simulates 64 independent vectors, and a 64×64 bit-matrix transpose
//!   marshals whole operand batches between lane-major integer codes and
//!   plane-major simulator layout. An exhaustive 8×8-multiplier sweep
//!   (65 536 vectors) costs only 1024 netlist passes; this is the engine
//!   behind every operand-space sweep in the crate.
//! * [`timing`] — static timing analysis (longest path by unit delays).
//! * [`power`] — switching-activity power: toggle counts per net over a
//!   vector sequence, weighted by driven capacitance.
//!
//! All hardware numbers in Tables 5/Fig 10 derive from these models plus a
//! single linear calibration to the paper's exact-multiplier row (see
//! [`crate::hwmodel`]).

pub mod gate;
pub mod builder;
pub mod sim;
pub mod bitslice;
pub mod timing;
pub mod power;

pub use bitslice::BitSim;
pub use builder::{Netlist, SigId};
pub use gate::GateKind;

//! Gate-level netlist EDA toolkit.
//!
//! This is the substrate that replaces the paper's Verilog + Synopsys DC +
//! UMC 90nm evaluation flow (which we do not have). It provides:
//!
//! * [`gate`] — the cell library: gate kinds with unit-gate area, delay and
//!   switching-capacitance figures (documented in `gate.rs`).
//! * [`builder`] — [`Netlist`] construction: a netlist is an append-only DAG
//!   of gates; construction order is a topological order by design, so
//!   simulation and timing are single linear passes.
//! * [`graph`] — the mutable [`Graph`] netlist core: stable [`NodeId`]s,
//!   insert/replace/remove editing, fanout/DFS/topological traversal and
//!   structural hashing. Netlists convert losslessly
//!   (`Graph::from(&Netlist)` / [`Graph::compile`]); the optimizer works
//!   here.
//! * [`opt`] — the optimization pass pipeline over the graph:
//!   [`opt::ConstFold`], [`opt::Cse`], [`opt::DeadGateElim`], sequenced by
//!   [`opt::optimize`] per [`opt::OptLevel`] (the `:opt=` spec knob).
//!   Every registry design runs through it by default, so simulation and
//!   the hardware models see strictly fewer gates.
//! * [`verilog`] — [`verilog::export_verilog`]: deterministic,
//!   synthesizable structural Verilog for any netlist (`sfcmul export`),
//!   closing the loop back to an external synthesis flow.
//! * [`sim`] — functional simulation: a scalar reference evaluator plus
//!   the word-level 64-lane [`sim::PackedSim`].
//! * [`bitslice`] — the bitsliced *batch* engine ([`bitslice::BitSim`]):
//!   each net is a `u64` bit-plane, so one pass over the gate list
//!   simulates 64 independent vectors, and a 64×64 bit-matrix transpose
//!   marshals whole operand batches between lane-major integer codes and
//!   plane-major simulator layout. An exhaustive 8×8-multiplier sweep
//!   (65 536 vectors) costs only 1024 netlist passes; this is the engine
//!   behind every operand-space sweep in the crate.
//! * [`timing`] — static timing analysis (longest path by unit delays).
//! * [`power`] — switching-activity power: toggle counts per net over a
//!   vector sequence, weighted by driven capacitance.
//!
//! All hardware numbers in Tables 5/Fig 10 derive from these models plus a
//! single linear calibration to the paper's exact-multiplier row (see
//! [`crate::hwmodel`]).

pub mod gate;
pub mod builder;
pub mod graph;
pub mod opt;
pub mod verilog;
pub mod sim;
pub mod bitslice;
pub mod timing;
pub mod power;

pub use bitslice::BitSim;
pub use builder::{Netlist, SigId};
pub use gate::GateKind;
pub use graph::{Graph, Node, NodeId};
pub use opt::{optimize, optimize_netlist, OptLevel, OptReport, Pass};
pub use verilog::export_verilog;

/// One-stop import for netlist consumers:
/// `use sfcmul::netlist::prelude::*;` brings in construction
/// ([`Netlist`]), the mutable core ([`Graph`]/[`NodeId`]), the pass
/// pipeline, the Verilog exporter, and both simulation entry points.
pub mod prelude {
    pub use super::bitslice::BitSim;
    pub use super::builder::{Gate, Netlist, SigId};
    pub use super::gate::GateKind;
    pub use super::graph::{Graph, Node, NodeId};
    pub use super::opt::{
        optimize, optimize_netlist, ConstFold, Cse, DeadGateElim, OptLevel, OptReport, Pass,
    };
    pub use super::sim::{eval_outputs_bool, PackedSim};
    pub use super::verilog::export_verilog;
    pub use super::{power, timing};
}

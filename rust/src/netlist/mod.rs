//! Gate-level netlist EDA toolkit.
//!
//! This is the substrate that replaces the paper's Verilog + Synopsys DC +
//! UMC 90nm evaluation flow (which we do not have). It provides:
//!
//! * [`gate`] — the cell library: gate kinds with unit-gate area, delay and
//!   switching-capacitance figures (documented in `gate.rs`).
//! * [`builder`] — [`Netlist`] construction: a netlist is an append-only DAG
//!   of gates; construction order is a topological order by design, so
//!   simulation and timing are single linear passes.
//! * [`sim`] — functional simulation. The workhorse is *bit-parallel*
//!   evaluation: 64 independent test vectors are packed into each `u64`
//!   word, so an exhaustive 8×8-multiplier sweep (65 536 vectors) costs
//!   only 1024 netlist passes. A scalar reference evaluator cross-checks it.
//! * [`timing`] — static timing analysis (longest path by unit delays).
//! * [`power`] — switching-activity power: toggle counts per net over a
//!   vector sequence, weighted by driven capacitance.
//!
//! All hardware numbers in Tables 5/Fig 10 derive from these models plus a
//! single linear calibration to the paper's exact-multiplier row (see
//! [`crate::hwmodel`]).

pub mod gate;
pub mod builder;
pub mod sim;
pub mod timing;
pub mod power;

pub use builder::{Netlist, SigId};
pub use gate::GateKind;

//! Bitsliced batch simulation: 64 independent vectors per netlist pass,
//! with a word-level transposition layer for operand marshalling.
//!
//! [`super::sim::PackedSim`] evaluates 64 lanes per pass but leaves lane
//! packing to its callers, which assemble the per-input planes one bit at
//! a time ([`super::sim::pack_int_lane`] — O(lanes × bits) single-bit
//! stores per batch, plus a re-borrowed gate walk per call). [`BitSim`]
//! is the batch engine the operand-sweep hot paths run on. It keeps the
//! exact topological-order semantics of the scalar simulator
//! ([`super::sim::eval_bool`]) and adds:
//!
//! * an owned, compact copy of the gate program, so one instance streams
//!   arbitrarily many batches without touching the source [`Netlist`];
//! * a transposition layer that moves whole *input codes* — one `u64`
//!   per lane whose bit `i` drives primary input `i` — between
//!   lane-major and plane-major layout via a 64×64 bit-matrix transpose
//!   ([`transpose64`], O(64·log 64) word ops per batch);
//! * ragged-batch handling: batch lengths that are not a multiple of 64
//!   zero-pad the spare lanes and discard their outputs.
//!
//! The exhaustive and sampled multiplier sweeps
//! ([`crate::multipliers::verify`]), the error-metric tables and the
//! `bitsim` serving engine all route through this module.

use super::builder::{Gate, Netlist, SigId};
use super::gate::GateKind;

/// In-place transpose of a 64×64 bit matrix, LSB-first convention:
/// element `(r, c)` lives at bit `c` of `m[r]`, and after the call
/// `m[r]` bit `c` holds the old `m[c]` bit `r`.
///
/// Classic recursive block-swap (Hacker's Delight §7-3, mirrored for the
/// LSB-first layout): log2(64) = 6 rounds, each exchanging the
/// off-diagonal halves of progressively smaller blocks.
pub fn transpose64(m: &mut [u64; 64]) {
    let mut j = 32usize;
    let mut mask: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k = 0usize;
        while k < 64 {
            let t = ((m[k] >> j) ^ m[k | j]) & mask;
            m[k | j] ^= t;
            m[k] ^= t << j;
            k = ((k | j) + 1) & !j;
        }
        j >>= 1;
        mask ^= mask << j;
    }
}

/// Bitsliced netlist simulator: one `u64` bit-plane per signal, 64 lanes
/// per pass. Create once per netlist and reuse across batches — the gate
/// program is copied out of the [`Netlist`] at construction and the plane
/// buffer is recycled call to call.
pub struct BitSim {
    name: String,
    gates: Vec<Gate>,
    input_ids: Vec<SigId>,
    output_ids: Vec<SigId>,
    planes: Vec<u64>,
}

impl BitSim {
    pub fn new(netlist: &Netlist) -> Self {
        Self {
            name: netlist.name.clone(),
            gates: netlist.gates().to_vec(),
            input_ids: netlist.inputs().to_vec(),
            output_ids: netlist.output_ids(),
            planes: vec![0; netlist.len()],
        }
    }

    /// Name of the netlist this simulator was compiled from.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of primary inputs.
    pub fn num_inputs(&self) -> usize {
        self.input_ids.len()
    }

    /// Number of registered outputs.
    pub fn num_outputs(&self) -> usize {
        self.output_ids.len()
    }

    /// One forward pass over the gate list: `inputs[k]` is the 64-lane
    /// plane driving the k-th primary input. Returns the full plane
    /// vector (one word per signal); index with the netlist's signal ids.
    /// Identical semantics to [`super::sim::PackedSim::run`].
    pub fn run_planes(&mut self, inputs: &[u64]) -> &[u64] {
        assert_eq!(inputs.len(), self.input_ids.len(), "input arity mismatch");
        let gates = &self.gates;
        let planes = &mut self.planes;
        for (k, &id) in self.input_ids.iter().enumerate() {
            planes[id as usize] = inputs[k];
        }
        for (i, g) in gates.iter().enumerate() {
            if matches!(g.kind, GateKind::Input) {
                continue; // plane pre-filled above
            }
            let a = planes[g.ins[0] as usize];
            let b = planes[g.ins[1] as usize];
            let c = planes[g.ins[2] as usize];
            planes[i] = g.kind.eval_packed(a, b, c);
        }
        &self.planes
    }

    /// Evaluate up to 64 lanes given *input codes*: `codes[lane]` bit `i`
    /// drives primary input `i` of lane `lane`. Writes one *output code*
    /// per lane into `out` (bit `j` = registered output `j`). Spare lanes
    /// are driven with all-zero inputs. Requires ≤ 64 inputs and ≤ 64
    /// outputs (a 2N-bit multiplier bus fits for any N ≤ 32).
    pub fn run_codes_into(&mut self, codes: &[u64], out: &mut [u64]) {
        assert!(codes.len() <= 64, "at most 64 lanes per pass");
        assert_eq!(codes.len(), out.len());
        assert!(
            self.input_ids.len() <= 64 && self.output_ids.len() <= 64,
            "code interface requires <=64 inputs and outputs"
        );
        let mut lanes = [0u64; 64];
        lanes[..codes.len()].copy_from_slice(codes);
        transpose64(&mut lanes);
        // planes: lanes[i] bit l = codes[l] bit i — exactly input i's plane
        let num_inputs = self.input_ids.len();
        self.run_planes(&lanes[..num_inputs]);
        let mut gathered = [0u64; 64];
        for (j, &id) in self.output_ids.iter().enumerate() {
            gathered[j] = self.planes[id as usize];
        }
        transpose64(&mut gathered);
        // gathered[l] bit j = output j of lane l
        out.copy_from_slice(&gathered[..codes.len()]);
    }

    /// Run an arbitrary-length batch of input codes, 64 lanes per pass,
    /// returning one output code per input code in order. Ragged tails
    /// (batch length not a multiple of 64) are padded internally.
    pub fn run_code_batch(&mut self, codes: &[u64]) -> Vec<u64> {
        let mut out = vec![0u64; codes.len()];
        self.run_code_batch_into(codes, &mut out);
        out
    }

    /// Allocation-free form of [`BitSim::run_code_batch`] for serve-time
    /// hot loops: writes one output code per input code into `out`
    /// (same length), 64 lanes per gate-program pass.
    pub fn run_code_batch_into(&mut self, codes: &[u64], out: &mut [u64]) {
        assert_eq!(codes.len(), out.len());
        for (ic, oc) in codes.chunks(64).zip(out.chunks_mut(64)) {
            self.run_codes_into(ic, oc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::{eval_outputs_bool, pack_vectors, PackedSim};
    use crate::util::prng::Xoshiro256;

    /// Naive reference transpose under the LSB-first convention.
    fn transpose_naive(m: &[u64; 64]) -> [u64; 64] {
        let mut out = [0u64; 64];
        for r in 0..64 {
            for c in 0..64 {
                if (m[c] >> r) & 1 != 0 {
                    out[r] |= 1 << c;
                }
            }
        }
        out
    }

    #[test]
    fn transpose_matches_naive_on_random_matrices() {
        let mut rng = Xoshiro256::seeded(99);
        for _ in 0..20 {
            let mut m = [0u64; 64];
            for w in m.iter_mut() {
                *w = rng.next_u64();
            }
            let want = transpose_naive(&m);
            let mut got = m;
            transpose64(&mut got);
            assert_eq!(got, want);
            // involution: transposing twice restores the original
            transpose64(&mut got);
            assert_eq!(got, m);
        }
    }

    #[test]
    fn transpose_known_patterns() {
        // identity matrix is its own transpose
        let mut ident = [0u64; 64];
        for (r, w) in ident.iter_mut().enumerate() {
            *w = 1 << r;
        }
        let mut t = ident;
        transpose64(&mut t);
        assert_eq!(t, ident);
        // single off-diagonal bit moves to its mirrored position
        let mut m = [0u64; 64];
        m[3] = 1 << 17; // (r=3, c=17)
        transpose64(&mut m);
        assert_eq!(m[17], 1 << 3);
        assert_eq!(m.iter().map(|w| w.count_ones()).sum::<u32>(), 1);
    }

    fn toy_netlist() -> Netlist {
        // f = (a & b) ^ c ; g = maj(a, b, c) — as in sim.rs tests
        let mut n = Netlist::new("toy");
        let a = n.input("a");
        let b = n.input("b");
        let c = n.input("c");
        let ab = n.and2(a, b);
        let f = n.xor2(ab, c);
        let g = n.maj3(a, b, c);
        n.output("f", f);
        n.output("g", g);
        n
    }

    #[test]
    fn run_planes_matches_packed_sim() {
        let n = toy_netlist();
        let mut rng = Xoshiro256::seeded(7);
        let vectors: Vec<Vec<bool>> =
            (0..64).map(|_| (0..3).map(|_| rng.chance(0.5)).collect()).collect();
        let inputs = pack_vectors(&vectors, 3);
        let mut packed = PackedSim::new(&n);
        let mut bit = BitSim::new(&n);
        assert_eq!(packed.run(&n, &inputs), bit.run_planes(&inputs));
    }

    #[test]
    fn codes_match_scalar_truth_table() {
        let n = toy_netlist();
        let mut sim = BitSim::new(&n);
        // all 8 input combinations as one ragged chunk
        let codes: Vec<u64> = (0..8).collect();
        let out = sim.run_code_batch(&codes);
        for (lane, &oc) in out.iter().enumerate() {
            let bits = [lane & 1 != 0, lane & 2 != 0, lane & 4 != 0];
            let want = eval_outputs_bool(&n, &bits);
            assert_eq!(oc & 1 != 0, want[0], "lane {lane} output f");
            assert_eq!((oc >> 1) & 1 != 0, want[1], "lane {lane} output g");
            assert_eq!(oc >> 2, 0, "lane {lane}: only two outputs");
        }
    }

    #[test]
    fn ragged_chunk_equals_full_chunk_prefix() {
        let n = toy_netlist();
        let mut sim = BitSim::new(&n);
        let full: Vec<u64> = (0..64).map(|i| i % 8).collect();
        let want = sim.run_code_batch(&full);
        for len in [1usize, 5, 63] {
            let got = sim.run_code_batch(&full[..len]);
            assert_eq!(got, want[..len], "len {len}");
        }
    }

    #[test]
    fn reuse_does_not_leak_state() {
        let n = toy_netlist();
        let mut sim = BitSim::new(&n);
        let a = sim.run_code_batch(&[0b111, 0b000]);
        let noise = sim.run_code_batch(&[0b101; 64]);
        assert_eq!(noise.len(), 64);
        let b = sim.run_code_batch(&[0b111, 0b000]);
        assert_eq!(a, b);
        assert_eq!(sim.name(), "toy");
        assert_eq!(sim.num_inputs(), 3);
        assert_eq!(sim.num_outputs(), 2);
    }
}

//! Mutable graph netlist core.
//!
//! [`Netlist`](super::Netlist) is an *append-only* topological gate list —
//! perfect for construction and linear-pass analysis, but closed: once
//! built there is no way to rewrite, shrink, or restructure a circuit.
//! [`Graph`] is the mutable complement: nodes carry **stable ids** that
//! survive edits (removal tombstones a slot instead of renumbering), edges
//! may be rewired freely, and the optimization passes in
//! [`opt`](super::opt) operate on it. The two forms convert losslessly:
//!
//! ```text
//! Netlist --Graph::from--> Graph --passes--> Graph --compile()--> Netlist
//! ```
//!
//! `compile()` re-linearises the live, output-reachable subgraph into a
//! fresh append-only [`Netlist`](super::Netlist) (inputs first, then a
//! deterministic topological order), so every downstream consumer —
//! [`BitSim`](super::BitSim), [`PackedSim`](super::sim::PackedSim), the
//! timing and power models, the Verilog exporter — keeps its simple
//! linear-pass world view while the optimizer gets full graph mutability.

use super::builder::Netlist;
use super::gate::GateKind;

/// Stable handle to a node in a [`Graph`]. Ids are never reused or
/// renumbered by edits; removing a node tombstones its slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u32);

impl NodeId {
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One gate in the graph. Only the first `kind.arity()` operand slots are
/// meaningful (same convention as [`super::builder::Gate`]).
#[derive(Debug, Clone, Copy)]
pub struct Node {
    pub kind: GateKind,
    pub ins: [NodeId; 3],
}

impl Node {
    /// The meaningful operand slice.
    pub fn operands(&self) -> &[NodeId] {
        &self.ins[..self.kind.arity()]
    }
}

/// A mutable gate-level netlist graph with stable node ids.
///
/// Invariants maintained by the safe API (`add`, `replace_uses`,
/// `remove`): the graph is acyclic and every live edge points at a live
/// node. [`Graph::node_mut`] deliberately allows arbitrary rewrites for
/// pass authors; `topo_order` (and hence `compile`) panics if an edit
/// introduced a cycle, so corruption cannot silently propagate into
/// simulation results.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    nodes: Vec<Option<Node>>,
    inputs: Vec<NodeId>,
    input_names: Vec<String>,
    outputs: Vec<(String, NodeId)>,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), ..Default::default() }
    }

    // ---- introspection --------------------------------------------------

    /// Number of **live** (non-tombstoned) nodes.
    pub fn len(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Upper bound over ever-allocated ids (tombstones included); valid
    /// for sizing side tables indexed by [`NodeId::index`].
    pub fn id_bound(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, id: NodeId) -> Option<&Node> {
        self.nodes.get(id.index()).and_then(|n| n.as_ref())
    }

    /// Mutable node access for pass authors. The caller must keep the
    /// graph acyclic and must not point edges at tombstoned slots;
    /// [`Graph::topo_order`] verifies acyclicity at the next
    /// linearisation.
    pub fn node_mut(&mut self, id: NodeId) -> Option<&mut Node> {
        self.nodes.get_mut(id.index()).and_then(|n| n.as_mut())
    }

    pub fn is_live(&self, id: NodeId) -> bool {
        self.node(id).is_some()
    }

    pub fn inputs(&self) -> &[NodeId] {
        &self.inputs
    }

    pub fn input_names(&self) -> &[String] {
        &self.input_names
    }

    pub fn outputs(&self) -> &[(String, NodeId)] {
        &self.outputs
    }

    /// Iterate live nodes in id order.
    pub fn iter_live(&self) -> impl Iterator<Item = (NodeId, &Node)> {
        self.nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| n.as_ref().map(|n| (NodeId(i as u32), n)))
    }

    /// Total area (gate equivalents) over live nodes.
    pub fn area(&self) -> f64 {
        self.iter_live().map(|(_, n)| n.kind.area()).sum()
    }

    /// Live logic gates (excludes inputs and constants) — the headline
    /// count the optimization passes shrink.
    pub fn logic_gate_count(&self) -> usize {
        self.iter_live()
            .filter(|(_, n)| {
                !matches!(n.kind, GateKind::Input | GateKind::Const0 | GateKind::Const1)
            })
            .count()
    }

    // ---- construction / mutation ----------------------------------------

    /// Append a node. Operands must be live and exactly `kind.arity()`
    /// many; the new id is strictly fresh (never reused).
    pub fn add(&mut self, kind: GateKind, operands: &[NodeId]) -> NodeId {
        assert_eq!(
            operands.len(),
            kind.arity(),
            "graph {}: {kind:?} takes {} operands, got {}",
            self.name,
            kind.arity(),
            operands.len()
        );
        let mut ins = [NodeId(0); 3];
        for (slot, &op) in operands.iter().enumerate() {
            assert!(
                self.is_live(op),
                "graph {}: {kind:?} operand {slot} is dead/unknown node {op:?}",
                self.name
            );
            ins[slot] = op;
        }
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Some(Node { kind, ins }));
        id
    }

    pub fn input(&mut self, name: &str) -> NodeId {
        let id = self.add(GateKind::Input, &[]);
        self.inputs.push(id);
        self.input_names.push(name.to_string());
        id
    }

    pub fn const0(&mut self) -> NodeId {
        self.add(GateKind::Const0, &[])
    }

    pub fn const1(&mut self) -> NodeId {
        self.add(GateKind::Const1, &[])
    }

    pub fn output(&mut self, name: &str, id: NodeId) {
        assert!(self.is_live(id), "graph {}: output {name} of dead node", self.name);
        self.outputs.push((name.to_string(), id));
    }

    /// Redirect an existing output to a different driver (passes rewire
    /// outputs through their alias maps with this).
    pub fn set_output_driver(&mut self, index: usize, id: NodeId) {
        assert!(self.is_live(id), "graph {}: output driver is a dead node", self.name);
        self.outputs[index].1 = id;
    }

    /// Rewrite every use of `old` (operand edges and output drivers) to
    /// `new`. Panics if the rewrite would create a cycle (i.e. `old` is in
    /// the transitive fan-in of `new`). Returns the number of edges
    /// rewritten. `old` itself stays in the graph (typically removed by a
    /// following dead-gate sweep).
    pub fn replace_uses(&mut self, old: NodeId, new: NodeId) -> usize {
        assert!(self.is_live(old) && self.is_live(new), "replace_uses on dead node");
        if old == new {
            return 0;
        }
        assert!(
            !self.depends_on(new, old),
            "graph {}: replacing uses of {old:?} with {new:?} would create a cycle",
            self.name
        );
        let mut edges = 0;
        for slot in self.nodes.iter_mut().flatten() {
            let arity = slot.kind.arity();
            for op in slot.ins.iter_mut().take(arity) {
                if *op == old {
                    *op = new;
                    edges += 1;
                }
            }
        }
        for (_, id) in self.outputs.iter_mut() {
            if *id == old {
                *id = new;
                edges += 1;
            }
        }
        edges
    }

    /// Remove a node. Refuses (returns `false`) if the node is a primary
    /// input, still drives an output, or is referenced by any live node —
    /// use [`Graph::replace_uses`] first. Returns `true` on removal.
    pub fn remove(&mut self, id: NodeId) -> bool {
        let Some(node) = self.node(id) else { return false };
        if matches!(node.kind, GateKind::Input) {
            return false;
        }
        if self.outputs.iter().any(|&(_, o)| o == id) {
            return false;
        }
        let referenced = self
            .iter_live()
            .any(|(nid, n)| nid != id && n.operands().contains(&id));
        if referenced {
            return false;
        }
        self.nodes[id.index()] = None;
        true
    }

    /// Tombstone a set of nodes unconditionally (pass-internal bulk
    /// removal after a reachability sweep). Inputs are never removed.
    pub(crate) fn remove_unchecked(&mut self, ids: &[NodeId]) -> usize {
        let mut removed = 0;
        for &id in ids {
            if let Some(n) = self.node(id) {
                if !matches!(n.kind, GateKind::Input) {
                    self.nodes[id.index()] = None;
                    removed += 1;
                }
            }
        }
        removed
    }

    // ---- traversal ------------------------------------------------------

    /// Is `which` in the transitive fan-in of `of` (including `of == which`)?
    pub fn depends_on(&self, of: NodeId, which: NodeId) -> bool {
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![of];
        while let Some(id) = stack.pop() {
            if id == which {
                return true;
            }
            if std::mem::replace(&mut seen[id.index()], true) {
                continue;
            }
            if let Some(n) = self.node(id) {
                stack.extend(n.operands().iter().copied());
            }
        }
        false
    }

    /// Fan-out edge counts, indexed by [`NodeId::index`] (output drivers
    /// count as one use each).
    pub fn fanout_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.nodes.len()];
        for (_, n) in self.iter_live() {
            for op in n.operands() {
                counts[op.index()] += 1;
            }
        }
        for &(_, id) in &self.outputs {
            counts[id.index()] += 1;
        }
        counts
    }

    /// Live nodes that use `id` as an operand, in id order.
    pub fn fanout_of(&self, id: NodeId) -> Vec<NodeId> {
        self.iter_live()
            .filter(|(_, n)| n.operands().contains(&id))
            .map(|(nid, _)| nid)
            .collect()
    }

    /// Depth-first walk from the outputs backwards; returns the set of
    /// output-reachable node ids as a dense bitmap indexed by
    /// [`NodeId::index`].
    pub fn reachable_from_outputs(&self) -> Vec<bool> {
        let mut live = vec![false; self.nodes.len()];
        let mut stack: Vec<NodeId> = self.outputs.iter().map(|&(_, id)| id).collect();
        while let Some(id) = stack.pop() {
            if std::mem::replace(&mut live[id.index()], true) {
                continue;
            }
            if let Some(n) = self.node(id) {
                stack.extend(n.operands().iter().copied());
            }
        }
        live
    }

    /// Deterministic topological order over **all** live nodes (operands
    /// before users; ties broken by ascending id). Panics if a `node_mut`
    /// edit introduced a cycle.
    pub fn topo_order(&self) -> Vec<NodeId> {
        // Iterative DFS post-order, seeded in ascending id order.
        const WHITE: u8 = 0; // unvisited
        const GREY: u8 = 1; // on the current DFS path
        const BLACK: u8 = 2; // emitted
        let mut color = vec![WHITE; self.nodes.len()];
        let mut order = Vec::with_capacity(self.nodes.len());
        let mut stack: Vec<(NodeId, bool)> = Vec::new();
        for seed in 0..self.nodes.len() {
            if self.nodes[seed].is_none() || color[seed] != WHITE {
                continue;
            }
            stack.push((NodeId(seed as u32), false));
            while let Some((id, expanded)) = stack.pop() {
                let i = id.index();
                if expanded {
                    color[i] = BLACK;
                    order.push(id);
                    continue;
                }
                match color[i] {
                    BLACK => continue,
                    GREY => panic!("graph {}: cycle through node {id:?}", self.name),
                    _ => {}
                }
                color[i] = GREY;
                stack.push((id, true));
                let node = self.node(id).expect("live edge to dead node");
                // Push operands in reverse so the lowest id is visited
                // first — keeps the order deterministic.
                for &op in node.operands().iter().rev() {
                    match color[op.index()] {
                        BLACK => {}
                        GREY => panic!("graph {}: cycle through node {op:?}", self.name),
                        _ => stack.push((op, false)),
                    }
                }
            }
        }
        order
    }

    // ---- structural hashing ---------------------------------------------

    /// Per-node structural hashes (indexed by [`NodeId::index`]; dead
    /// slots hash to 0). Two nodes computing the same expression tree get
    /// the same hash: operand hashes are sorted first for fully symmetric
    /// kinds, so `And2(a,b)` and `And2(b,a)` collide on purpose. Inputs
    /// hash their position, constants their kind.
    pub fn node_hashes(&self) -> Vec<u64> {
        let mut hashes = vec![0u64; self.nodes.len()];
        let mut input_pos = vec![u64::MAX; self.nodes.len()];
        for (pos, id) in self.inputs.iter().enumerate() {
            input_pos[id.index()] = pos as u64;
        }
        for id in self.topo_order() {
            let node = self.node(id).expect("topo order yields live nodes");
            let mut ops: Vec<u64> =
                node.operands().iter().map(|op| hashes[op.index()]).collect();
            if kind_is_symmetric(node.kind) {
                ops.sort_unstable();
            } else if matches!(node.kind, GateKind::Aoi21 | GateKind::Oai21) {
                // first two operands commute, the third does not
                ops[..2].sort_unstable();
            }
            let mut h = fnv1a_u64(0xcbf2_9ce4_8422_2325, kind_tag(node.kind));
            if node.kind == GateKind::Input {
                h = fnv1a_u64(h, input_pos[id.index()]);
            }
            for op in ops {
                h = fnv1a_u64(h, op);
            }
            hashes[id.index()] = h;
        }
        hashes
    }

    /// One structural fingerprint for the whole graph: output names and
    /// their driver hashes, in output order. Stable across no-op edits
    /// (dead nodes, id renumbering) — changes when the computed function's
    /// structure changes.
    pub fn structural_hash(&self) -> u64 {
        let hashes = self.node_hashes();
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for (name, id) in &self.outputs {
            for b in name.bytes() {
                h = fnv1a_u64(h, b as u64);
            }
            h = fnv1a_u64(h, hashes[id.index()]);
        }
        h
    }

    // ---- conversion -----------------------------------------------------

    /// Re-linearise the live, output-reachable subgraph into an
    /// append-only [`Netlist`]: primary inputs first (declaration order),
    /// then the remaining reachable nodes in deterministic topological
    /// order. Dead and unreachable nodes are dropped — `compile` is
    /// implicitly a dead-gate sweep.
    pub fn compile(&self) -> Netlist {
        let reach = self.reachable_from_outputs();
        let mut out = Netlist::new(&self.name);
        let mut remap = vec![u32::MAX; self.nodes.len()];
        for (id, name) in self.inputs.iter().zip(&self.input_names) {
            remap[id.index()] = out.input(name);
        }
        for id in self.topo_order() {
            if !reach[id.index()] || remap[id.index()] != u32::MAX {
                continue;
            }
            let node = self.node(id).expect("topo order yields live nodes");
            let mut ins = [0u32; 3];
            for (slot, op) in node.operands().iter().enumerate() {
                ins[slot] = remap[op.index()];
                assert_ne!(ins[slot], u32::MAX, "operand emitted after user");
            }
            remap[id.index()] = out.push_gate(node.kind, ins);
        }
        for (name, id) in &self.outputs {
            out.output(name, remap[id.index()]);
        }
        out
    }
}

impl From<&Netlist> for Graph {
    /// Lossless import: gate `i` becomes node `NodeId(i)`.
    fn from(nl: &Netlist) -> Self {
        let mut g = Graph::new(&nl.name);
        let mut name_at = std::collections::HashMap::new();
        for (id, name) in nl.inputs().iter().zip(nl.input_names()) {
            name_at.insert(*id, name.clone());
        }
        for (i, gate) in nl.gates().iter().enumerate() {
            let id = if gate.kind == GateKind::Input {
                g.input(&name_at[&(i as u32)])
            } else {
                let ops: Vec<NodeId> = gate.ins[..gate.kind.arity()]
                    .iter()
                    .map(|&s| NodeId(s))
                    .collect();
                g.add(gate.kind, &ops)
            };
            debug_assert_eq!(id.index(), i);
        }
        for (name, id) in nl.outputs() {
            g.output(name, NodeId(*id));
        }
        g
    }
}

/// All operands commute (operand order never changes the function).
pub(crate) fn kind_is_symmetric(kind: GateKind) -> bool {
    use GateKind::*;
    matches!(
        kind,
        And2 | Or2 | Nand2 | Nor2 | Xor2 | Xnor2 | And3 | Or3 | Nand3 | Nor3 | Maj3
    )
}

/// Stable per-kind tag for hashing (decoupled from enum layout).
fn kind_tag(kind: GateKind) -> u64 {
    GateKind::all().iter().position(|&k| k == kind).expect("kind in GateKind::all") as u64
}

fn fnv1a_u64(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::eval_outputs_bool;

    fn toy() -> Graph {
        // x = (a & b) ^ c, y = a | b
        let mut g = Graph::new("toy");
        let a = g.input("a");
        let b = g.input("b");
        let c = g.input("c");
        let ab = g.add(GateKind::And2, &[a, b]);
        let x = g.add(GateKind::Xor2, &[ab, c]);
        let y = g.add(GateKind::Or2, &[a, b]);
        g.output("x", x);
        g.output("y", y);
        g
    }

    #[test]
    fn roundtrip_netlist_graph_netlist_preserves_function() {
        let g = toy();
        let nl = g.compile();
        let g2 = Graph::from(&nl);
        let nl2 = g2.compile();
        for bits in 0..8 {
            let v = [(bits & 1) != 0, (bits & 2) != 0, (bits & 4) != 0];
            assert_eq!(eval_outputs_bool(&nl, &v), eval_outputs_bool(&nl2, &v));
        }
        assert_eq!(nl.len(), nl2.len());
    }

    #[test]
    fn ids_are_stable_across_removal() {
        let mut g = toy();
        let dead = g.add(GateKind::Nand2, &[g.inputs()[0], g.inputs()[1]]);
        let x_driver = g.outputs()[0].1;
        assert!(g.remove(dead));
        // the surviving nodes keep their ids and the graph still compiles
        assert!(g.is_live(x_driver));
        assert_eq!(g.outputs()[0].1, x_driver);
        assert_eq!(g.compile().outputs().len(), 2);
        // a fresh add never reuses the tombstoned id
        let fresh = g.add(GateKind::Buf, &[g.inputs()[0]]);
        assert!(fresh.index() > dead.index());
    }

    #[test]
    fn remove_refuses_inputs_outputs_and_referenced_nodes() {
        let mut g = toy();
        let a = g.inputs()[0];
        let x = g.outputs()[0].1;
        let and = g.node(x).unwrap().ins[0]; // feeds the xor
        assert!(!g.remove(a), "inputs are interface, never removable");
        assert!(!g.remove(x), "output drivers stay");
        assert!(!g.remove(and), "referenced nodes stay");
    }

    #[test]
    fn replace_uses_rewires_and_guards_cycles() {
        let mut g = toy();
        let a = g.inputs()[0];
        let b = g.inputs()[1];
        let y = g.outputs()[1].1; // Or2(a, b)
        // replace all uses of b with a: y becomes Or2(a, a)
        let edges = g.replace_uses(b, a);
        assert!(edges >= 2); // and-gate + or-gate at least
        assert_eq!(g.node(y).unwrap().operands(), &[a, a]);
        let nl = g.compile();
        // function now ignores the b input
        let t = eval_outputs_bool(&nl, &[true, false, false]);
        assert!(t[1], "y = a | a = a");
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn replace_uses_panics_on_cycle() {
        let mut g = toy();
        let x = g.outputs()[0].1; // xor, depends on the and-gate
        let and = g.node(x).unwrap().ins[0];
        // rewiring the and-gate's uses to the xor would make xor self-dependent
        g.replace_uses(and, x);
    }

    #[test]
    fn topo_order_is_deterministic_and_respects_edges() {
        let g = toy();
        let order = g.topo_order();
        assert_eq!(order, g.topo_order());
        let pos: std::collections::HashMap<NodeId, usize> =
            order.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        for (id, n) in g.iter_live() {
            for op in n.operands() {
                assert!(pos[op] < pos[&id], "{op:?} must precede {id:?}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn topo_order_detects_cycles_after_raw_mutation() {
        let mut g = toy();
        let x = g.outputs()[0].1;
        let and = g.node(x).unwrap().ins[0];
        g.node_mut(and).unwrap().ins[0] = x; // raw edit creating a cycle
        let _ = g.topo_order();
    }

    #[test]
    fn fanout_counts_match_fanout_of() {
        let g = toy();
        let counts = g.fanout_counts();
        for (id, _) in g.iter_live() {
            let direct = g.fanout_of(id).len();
            let output_uses =
                g.outputs().iter().filter(|&&(_, o)| o == id).count();
            // fanout_of counts using nodes once even with two edges; the
            // toy graph has no double edges, so the counts line up.
            assert_eq!(counts[id.index()] as usize, direct + output_uses, "{id:?}");
        }
    }

    #[test]
    fn structural_hash_ignores_commutation_and_dead_nodes() {
        let mut g1 = Graph::new("h");
        let a = g1.input("a");
        let b = g1.input("b");
        let x = g1.add(GateKind::And2, &[a, b]);
        g1.output("x", x);
        let mut g2 = Graph::new("h");
        let a2 = g2.input("a");
        let b2 = g2.input("b");
        let x2 = g2.add(GateKind::And2, &[b2, a2]); // swapped operands
        let _dead = g2.add(GateKind::Or2, &[a2, b2]);
        g2.output("x", x2);
        assert_eq!(g1.structural_hash(), g2.structural_hash());
        // a genuinely different function hashes differently
        let mut g3 = Graph::new("h");
        let a3 = g3.input("a");
        let b3 = g3.input("b");
        let x3 = g3.add(GateKind::Or2, &[a3, b3]);
        g3.output("x", x3);
        assert_ne!(g1.structural_hash(), g3.structural_hash());
    }

    #[test]
    fn compile_drops_unreachable_nodes_but_keeps_inputs() {
        let mut g = toy();
        let a = g.inputs()[0];
        let _dead = g.add(GateKind::Not, &[a]);
        let nl = g.compile();
        assert_eq!(nl.validate().unwrap(), 0, "no dead logic after compile");
        assert_eq!(nl.inputs().len(), 3);
        assert_eq!(nl.input_names(), &["a", "b", "c"]);
    }
}

//! Functional simulation: scalar reference + 64-lane bit-parallel engine.

use super::builder::{Netlist, SigId};
use super::gate::GateKind;

/// Scalar (one-vector) evaluation. Slow; the reference the packed engine is
/// validated against.
pub fn eval_bool(netlist: &Netlist, inputs: &[bool]) -> Vec<bool> {
    assert_eq!(inputs.len(), netlist.inputs().len(), "input arity mismatch");
    let mut values = vec![false; netlist.len()];
    let mut next_input = 0;
    for (i, g) in netlist.gates().iter().enumerate() {
        values[i] = match g.kind {
            GateKind::Input => {
                let v = inputs[next_input];
                next_input += 1;
                v
            }
            kind => {
                let a = values[g.ins[0] as usize];
                let b = values[g.ins[1] as usize];
                let c = values[g.ins[2] as usize];
                kind.eval_bool(a, b, c)
            }
        };
    }
    values
}

/// Scalar evaluation returning only registered outputs (LSB-first order of
/// registration).
pub fn eval_outputs_bool(netlist: &Netlist, inputs: &[bool]) -> Vec<bool> {
    let values = eval_bool(netlist, inputs);
    netlist.outputs().iter().map(|&(_, id)| values[id as usize]).collect()
}

/// Bit-parallel simulator: each `u64` word carries 64 independent vectors.
///
/// Reuses its value buffer across calls — create once, call
/// [`PackedSim::run`] many times on the hot path.
pub struct PackedSim {
    values: Vec<u64>,
}

impl PackedSim {
    pub fn new(netlist: &Netlist) -> Self {
        Self { values: vec![0; netlist.len()] }
    }

    /// Evaluate 64 vectors at once. `inputs[k]` is the packed word for the
    /// k-th primary input. Returns the full value vector (one word per
    /// signal); use [`Netlist::outputs`] ids to extract outputs.
    pub fn run(&mut self, netlist: &Netlist, inputs: &[u64]) -> &[u64] {
        assert_eq!(inputs.len(), netlist.inputs().len(), "input arity mismatch");
        let values = &mut self.values;
        values.resize(netlist.len(), 0);
        let mut next_input = 0;
        for (i, g) in netlist.gates().iter().enumerate() {
            values[i] = match g.kind {
                GateKind::Input => {
                    let v = inputs[next_input];
                    next_input += 1;
                    v
                }
                kind => {
                    let a = values[g.ins[0] as usize];
                    let b = values[g.ins[1] as usize];
                    let c = values[g.ins[2] as usize];
                    kind.eval_packed(a, b, c)
                }
            };
        }
        values
    }

    /// Convenience: run and extract output words.
    pub fn run_outputs(&mut self, netlist: &Netlist, inputs: &[u64]) -> Vec<u64> {
        let out_ids: Vec<SigId> = netlist.output_ids();
        let values = self.run(netlist, inputs);
        out_ids.iter().map(|&id| values[id as usize]).collect()
    }
}

/// Pack a batch of ≤64 boolean vectors (each `vectors[v][i]` = value of
/// input `i` in vector `v`) into per-input words: `out[i]` bit `v`.
pub fn pack_vectors(vectors: &[Vec<bool>], num_inputs: usize) -> Vec<u64> {
    assert!(vectors.len() <= 64);
    let mut out = vec![0u64; num_inputs];
    for (v, vec) in vectors.iter().enumerate() {
        assert_eq!(vec.len(), num_inputs);
        for (i, &bit) in vec.iter().enumerate() {
            if bit {
                out[i] |= 1 << v;
            }
        }
    }
    out
}

/// Helper for integer-operand circuits: pack lane `v`'s operand bits from
/// an integer, LSB-first, into `words[bit_offset..bit_offset+bits]`.
#[inline]
pub fn pack_int_lane(words: &mut [u64], lane: usize, bit_offset: usize, value: u64, bits: usize) {
    debug_assert!(lane < 64);
    for b in 0..bits {
        if (value >> b) & 1 != 0 {
            words[bit_offset + b] |= 1 << lane;
        }
    }
}

/// Extract lane `v` of packed output words as an integer, LSB-first.
#[inline]
pub fn unpack_int_lane(words: &[u64], lane: usize) -> u64 {
    let mut out = 0u64;
    for (b, &w) in words.iter().enumerate() {
        out |= ((w >> lane) & 1) << b;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn toy_netlist() -> Netlist {
        // f = (a & b) ^ c ; g = maj(a, b, c)
        let mut n = Netlist::new("toy");
        let a = n.input("a");
        let b = n.input("b");
        let c = n.input("c");
        let ab = n.and2(a, b);
        let f = n.xor2(ab, c);
        let g = n.maj3(a, b, c);
        n.output("f", f);
        n.output("g", g);
        n
    }

    #[test]
    fn scalar_eval_truth_table() {
        let n = toy_netlist();
        for bits in 0..8u8 {
            let a = bits & 1 != 0;
            let b = bits & 2 != 0;
            let c = bits & 4 != 0;
            let out = eval_outputs_bool(&n, &[a, b, c]);
            assert_eq!(out[0], (a & b) ^ c);
            assert_eq!(out[1], (a & b) | (a & c) | (b & c));
        }
    }

    #[test]
    fn packed_matches_scalar_on_random_vectors() {
        let n = toy_netlist();
        let mut rng = Xoshiro256::seeded(1234);
        let vectors: Vec<Vec<bool>> =
            (0..64).map(|_| (0..3).map(|_| rng.chance(0.5)).collect()).collect();
        let packed_in = pack_vectors(&vectors, 3);
        let mut sim = PackedSim::new(&n);
        let packed_out = sim.run_outputs(&n, &packed_in);
        for (v, vec) in vectors.iter().enumerate() {
            let scalar_out = eval_outputs_bool(&n, vec);
            for (o, &word) in packed_out.iter().enumerate() {
                assert_eq!((word >> v) & 1 == 1, scalar_out[o], "vector {v} output {o}");
            }
        }
    }

    #[test]
    fn int_lane_roundtrip() {
        let mut words = vec![0u64; 16];
        pack_int_lane(&mut words, 5, 0, 0xABCD, 16);
        pack_int_lane(&mut words, 6, 0, 0x1234, 16);
        assert_eq!(unpack_int_lane(&words, 5), 0xABCD);
        assert_eq!(unpack_int_lane(&words, 6), 0x1234);
        assert_eq!(unpack_int_lane(&words, 7), 0);
    }

    #[test]
    fn sim_buffer_is_reusable() {
        let n = toy_netlist();
        let mut sim = PackedSim::new(&n);
        let a = sim.run_outputs(&n, &[!0, 0, 0]);
        let b = sim.run_outputs(&n, &[0, 0, !0]);
        assert_ne!(a, b);
        let a2 = sim.run_outputs(&n, &[!0, 0, 0]);
        assert_eq!(a, a2, "buffer reuse must not leak state");
    }
}

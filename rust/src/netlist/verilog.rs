//! Synthesizable gate-level Verilog export.
//!
//! [`export_verilog`] renders any [`Netlist`] as a flat structural
//! Verilog-2001 module — one continuous `assign` per gate, each internal
//! wire driven exactly once — so the paper's area/power/delay claims can
//! be re-checked through an external synthesis flow (the paper used
//! Synopsys DC on UMC 90nm; any modern flow accepts this output). The
//! text is fully deterministic (no timestamps, stable wire naming by gate
//! id), which is what lets `rust/tests/netlist_opt_equiv.rs` pin the
//! `proposed@8` export as a golden file.

use super::builder::Netlist;
use super::gate::GateKind;

/// Render a netlist as a synthesizable Verilog module named
/// `module_name`. Primary inputs and outputs become scalar ports in
/// declaration order; every gate output becomes `w<id>` driven by a
/// single continuous assignment.
pub fn export_verilog(nl: &Netlist, module_name: &str) -> String {
    let module = sanitize(module_name);
    let mut input_name = vec![None::<String>; nl.len()];
    for (id, name) in nl.inputs().iter().zip(nl.input_names()) {
        input_name[*id as usize] = Some(sanitize(name));
    }
    let sig = |id: u32| -> String {
        match &input_name[id as usize] {
            Some(port) => port.clone(),
            None => format!("w{id}"),
        }
    };

    let mut s = String::new();
    s.push_str(&format!(
        "// Gate-level netlist \"{}\" — {} gates, {:.1} GE (unit-gate area).\n\
         // Emitted by the sfcmul netlist core; structural Verilog-2001,\n\
         // one driver per wire. Deterministic output: safe to diff.\n",
        nl.name,
        nl.logic_gate_count(),
        nl.area()
    ));
    s.push_str(&format!("module {module} (\n"));
    let mut ports: Vec<String> = Vec::new();
    for name in nl.input_names() {
        ports.push(format!("    input  wire {}", sanitize(name)));
    }
    for (name, _) in nl.outputs() {
        ports.push(format!("    output wire {}", sanitize(name)));
    }
    s.push_str(&ports.join(",\n"));
    s.push_str("\n);\n\n");

    // Internal wires: every non-input gate gets one.
    let internal: Vec<u32> = (0..nl.len() as u32)
        .filter(|&id| input_name[id as usize].is_none())
        .collect();
    if !internal.is_empty() {
        for chunk in internal.chunks(12) {
            let names: Vec<String> = chunk.iter().map(|&id| format!("w{id}")).collect();
            s.push_str(&format!("    wire {};\n", names.join(", ")));
        }
        s.push('\n');
    }

    for (id, gate) in nl.gates().iter().enumerate() {
        let id = id as u32;
        if input_name[id as usize].is_some() {
            continue;
        }
        let a = || sig(gate.ins[0]);
        let b = || sig(gate.ins[1]);
        let c = || sig(gate.ins[2]);
        use GateKind::*;
        let expr = match gate.kind {
            Input => unreachable!("inputs are ports"),
            Const0 => "1'b0".to_string(),
            Const1 => "1'b1".to_string(),
            Not => format!("~{}", a()),
            Buf => a(),
            And2 => format!("{} & {}", a(), b()),
            Or2 => format!("{} | {}", a(), b()),
            Nand2 => format!("~({} & {})", a(), b()),
            Nor2 => format!("~({} | {})", a(), b()),
            Xor2 => format!("{} ^ {}", a(), b()),
            Xnor2 => format!("~({} ^ {})", a(), b()),
            And3 => format!("{} & {} & {}", a(), b(), c()),
            Or3 => format!("{} | {} | {}", a(), b(), c()),
            Nand3 => format!("~({} & {} & {})", a(), b(), c()),
            Nor3 => format!("~({} | {} | {})", a(), b(), c()),
            Maj3 => format!(
                "({0} & {1}) | ({0} & {2}) | ({1} & {2})",
                a(),
                b(),
                c()
            ),
            Aoi21 => format!("~(({} & {}) | {})", a(), b(), c()),
            Oai21 => format!("~(({} | {}) & {})", a(), b(), c()),
            // (sel, a, b) -> sel ? b : a
            Mux2 => format!("{} ? {} : {}", a(), c(), b()),
        };
        s.push_str(&format!("    assign w{id} = {expr};\n"));
    }

    s.push('\n');
    for (name, id) in nl.outputs() {
        s.push_str(&format!("    assign {} = {};\n", sanitize(name), sig(*id)));
    }
    s.push_str("endmodule\n");
    s
}

/// Make an arbitrary name a legal Verilog simple identifier.
fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for ch in name.chars() {
        if ch.is_ascii_alphanumeric() || ch == '_' || ch == '$' {
            out.push(ch);
        } else {
            out.push('_');
        }
    }
    if out.is_empty() || out.starts_with(|c: char| c.is_ascii_digit() || c == '$') {
        out.insert(0, '_');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::Netlist;

    fn toy() -> Netlist {
        let mut nl = Netlist::new("toy mul");
        let a = nl.input("a0");
        let b = nl.input("b0");
        let x = nl.xor2(a, b);
        let k = nl.const1();
        let y = nl.mux2(a, x, k);
        nl.output("p0", x);
        nl.output("p1", y);
        nl
    }

    #[test]
    fn module_is_structurally_well_formed() {
        let v = export_verilog(&toy(), "toy");
        assert_eq!(v.matches("module ").count(), 1);
        assert_eq!(v.matches("endmodule").count(), 1);
        assert!(v.contains("input  wire a0"));
        assert!(v.contains("output wire p1"));
        // every internal wire is driven exactly once
        for line in v.lines() {
            if let Some(rest) = line.trim().strip_prefix("assign ") {
                let lhs = rest.split('=').next().unwrap().trim();
                let drivers = v
                    .lines()
                    .filter(|l| {
                        l.trim()
                            .strip_prefix("assign ")
                            .map(|r| r.split('=').next().unwrap().trim() == lhs)
                            .unwrap_or(false)
                    })
                    .count();
                assert_eq!(drivers, 1, "{lhs} driven {drivers} times");
            }
        }
    }

    #[test]
    fn export_is_deterministic() {
        assert_eq!(export_verilog(&toy(), "toy"), export_verilog(&toy(), "toy"));
    }

    #[test]
    fn mux_and_const_render_with_verilog_semantics() {
        let v = export_verilog(&toy(), "toy");
        assert!(v.contains("1'b1"));
        // Mux2(sel=a, x, k): sel ? b-operand : a-operand = a ? k : x
        assert!(v.contains("a0 ? w3 : w2"), "{v}");
    }

    #[test]
    fn identifiers_are_sanitized() {
        let mut nl = Netlist::new("x");
        let a = nl.input("weird name!");
        nl.output("0out", a);
        let v = export_verilog(&nl, "9mod ule");
        assert!(v.contains("module _9mod_ule"));
        assert!(v.contains("weird_name_"));
        assert!(v.contains("_0out"));
    }
}

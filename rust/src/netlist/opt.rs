//! Optimization passes over the mutable [`Graph`] netlist core.
//!
//! Three synthesis-style passes, each a [`Pass`] over a [`Graph`]:
//!
//! * [`ConstFold`] — constant propagation and local identity rewriting
//!   (generalises the folding the append-only builder used to do inline:
//!   `AND(x,0)→0`, `XOR(x,1)→NOT x`, residual truth-table synthesis for
//!   three-input gates with constant operands, plus equal-operand
//!   identities like `XOR(x,x)→0` and `MAJ(x,x,c)→x` and double-negation
//!   elimination that only a graph view can express).
//! * [`Cse`] — common-subexpression sharing: structurally identical gates
//!   (operand order canonicalised for symmetric kinds) merge into one.
//! * [`DeadGateElim`] — backward sweep from the outputs; unreachable
//!   gates are tombstoned (primary inputs always survive — interface
//!   stability).
//!
//! [`optimize`] sequences them per [`OptLevel`] (the `:opt=` knob of
//! [`DesignSpec`](crate::multipliers::DesignSpec)): `none` leaves the
//! circuit as constructed, `fold` is one fold + dead sweep (the legacy
//! builder behaviour), `full` iterates fold ↔ CSE to a fixpoint. Every
//! pass is function-preserving by construction, and
//! `rust/tests/netlist_opt_equiv.rs` proves it exhaustively at 8 bit for
//! every registered design.

use super::builder::Netlist;
use super::gate::GateKind;
use super::graph::{kind_is_symmetric, Graph, NodeId};
use crate::util::error::Error;
use std::collections::HashMap;
use std::fmt;

/// How hard to optimize a netlist (the `:opt=` spec knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum OptLevel {
    /// The circuit exactly as the generator constructed it.
    None,
    /// One constant-folding pass + dead-gate sweep (the legacy inline
    /// builder behaviour).
    Fold,
    /// Fold ↔ CSE to a fixpoint, then the dead-gate sweep.
    #[default]
    Full,
}

impl OptLevel {
    /// Canonical spec-string key.
    pub fn key(self) -> &'static str {
        match self {
            OptLevel::None => "none",
            OptLevel::Fold => "fold",
            OptLevel::Full => "full",
        }
    }

    pub fn all() -> [OptLevel; 3] {
        [OptLevel::None, OptLevel::Fold, OptLevel::Full]
    }
}

impl fmt::Display for OptLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.key())
    }
}

impl std::str::FromStr for OptLevel {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self, Error> {
        match s.trim().to_lowercase().as_str() {
            "none" => Ok(OptLevel::None),
            "fold" => Ok(OptLevel::Fold),
            "full" => Ok(OptLevel::Full),
            other => Err(Error::msg(format!(
                "invalid optimization level {other:?} (none | fold | full)"
            ))),
        }
    }
}

/// A function-preserving rewrite over a [`Graph`]. `run` returns the
/// number of rewrites applied (0 means the pass found nothing — the
/// fixpoint signal).
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, g: &mut Graph) -> usize;
}

/// Per-pass accounting inside an [`OptReport`].
#[derive(Debug, Clone)]
pub struct PassStat {
    pub pass: &'static str,
    pub rewrites: usize,
}

/// What [`optimize`] did to a graph.
#[derive(Debug, Clone)]
pub struct OptReport {
    pub level: OptLevel,
    /// Live logic gates (inputs/constants excluded) before / after.
    pub logic_before: usize,
    pub logic_after: usize,
    /// Area in gate equivalents before / after.
    pub area_before: f64,
    pub area_after: f64,
    pub passes: Vec<PassStat>,
}

impl OptReport {
    pub fn gates_removed(&self) -> usize {
        self.logic_before.saturating_sub(self.logic_after)
    }
}

// ---------------------------------------------------------------------------
// Constant folding
// ---------------------------------------------------------------------------

/// Constant propagation + local identity rewriting (see module docs).
pub struct ConstFold;

/// Lattice value a node may resolve to during folding.
#[derive(Clone, Copy, PartialEq)]
enum Val {
    Unknown,
    /// Node is redundant: every use may be redirected to the target.
    Alias(NodeId),
}

struct Folder {
    val: Vec<Val>,
    k0: Option<NodeId>,
    k1: Option<NodeId>,
}

impl Folder {
    /// Follow alias links to the representative node.
    fn resolve(&self, mut id: NodeId) -> NodeId {
        loop {
            match self.val.get(id.index()) {
                Some(Val::Alias(t)) => id = *t,
                _ => return id,
            }
        }
    }

    /// Constant value of a resolved node, if it is one.
    fn const_of(&self, g: &Graph, id: NodeId) -> Option<bool> {
        match g.node(id).map(|n| n.kind) {
            Some(GateKind::Const0) => Some(false),
            Some(GateKind::Const1) => Some(true),
            _ => None,
        }
    }

    fn const_node(&mut self, g: &mut Graph, v: bool) -> NodeId {
        let slot = if v { &mut self.k1 } else { &mut self.k0 };
        *slot.get_or_insert_with(|| {
            if v {
                g.const1()
            } else {
                g.const0()
            }
        })
    }

    fn set_alias(&mut self, id: NodeId, to: NodeId) {
        self.val[id.index()] = Val::Alias(to);
    }
}

impl Pass for ConstFold {
    fn name(&self) -> &'static str {
        "const-fold"
    }

    fn run(&self, g: &mut Graph) -> usize {
        use GateKind::*;
        let order = g.topo_order();
        let mut f = Folder { val: vec![Val::Unknown; g.id_bound()], k0: None, k1: None };
        // Adopt the lowest pre-existing constant nodes as canonical.
        for (id, n) in g.iter_live() {
            match n.kind {
                Const0 => f.k0 = f.k0.or(Some(id)),
                Const1 => f.k1 = f.k1.or(Some(id)),
                _ => {}
            }
        }
        let mut changed = 0usize;

        for id in order {
            let node = *g.node(id).expect("topo order yields live nodes");
            let arity = node.kind.arity();
            if arity == 0 {
                continue; // inputs and constants drive themselves
            }
            // Resolve operands through the alias map and rewrite the edges
            // in place, so every later decision sees representatives only.
            let mut ops = [NodeId(0); 3];
            let mut konst = [None::<bool>; 3];
            for slot in 0..arity {
                let rid = f.resolve(node.ins[slot]);
                ops[slot] = rid;
                konst[slot] = f.const_of(g, rid);
                g.node_mut(id).unwrap().ins[slot] = rid;
            }

            // Fully constant gate → becomes a constant.
            if (0..arity).all(|s| konst[s].is_some()) {
                let v = node.kind.eval_bool(
                    konst[0].unwrap_or(false),
                    konst[1].unwrap_or(false),
                    konst[2].unwrap_or(false),
                );
                let canon = f.const_node(g, v);
                if canon == id {
                    continue; // it *is* the canonical constant already
                }
                f.set_alias(id, canon);
                changed += 1;
                continue;
            }

            match node.kind {
                Buf => {
                    f.set_alias(id, ops[0]);
                    changed += 1;
                }
                Not => {
                    // Double negation: NOT(NOT(x)) → x.
                    let inner = g.node(ops[0]).unwrap();
                    if inner.kind == Not {
                        let x = f.resolve(inner.ins[0]);
                        f.set_alias(id, x);
                        changed += 1;
                    }
                }
                And2 | Or2 | Nand2 | Nor2 | Xor2 | Xnor2 => {
                    if let Some((ki, kv)) =
                        (0..2).find_map(|s| konst[s].map(|v| (s, v)))
                    {
                        // One constant operand: 2-input identity table.
                        let x = ops[1 - ki];
                        match (node.kind, kv) {
                            (And2, false) | (Nor2, true) => {
                                let c = f.const_node(g, false);
                                f.set_alias(id, c);
                            }
                            (Or2, true) | (Nand2, false) => {
                                let c = f.const_node(g, true);
                                f.set_alias(id, c);
                            }
                            (And2, true) | (Or2, false) | (Xor2, false) | (Xnor2, true) => {
                                f.set_alias(id, x);
                            }
                            (Nand2, true) | (Nor2, false) | (Xor2, true) | (Xnor2, false) => {
                                let n = g.node_mut(id).unwrap();
                                n.kind = Not;
                                n.ins[0] = x;
                            }
                            _ => unreachable!("2-input kinds only"),
                        }
                        changed += 1;
                    } else if ops[0] == ops[1] {
                        // Equal operands.
                        match node.kind {
                            And2 | Or2 => f.set_alias(id, ops[0]),
                            Xor2 => {
                                let c = f.const_node(g, false);
                                f.set_alias(id, c);
                            }
                            Xnor2 => {
                                let c = f.const_node(g, true);
                                f.set_alias(id, c);
                            }
                            Nand2 | Nor2 => {
                                let x = ops[0];
                                let n = g.node_mut(id).unwrap();
                                n.kind = Not;
                                n.ins[0] = x;
                            }
                            _ => unreachable!("2-input kinds only"),
                        }
                        changed += 1;
                    } else {
                        // Complementary operands: one is NOT of the other.
                        let is_compl = |g: &Graph, f: &Folder, x: NodeId, y: NodeId| {
                            g.node(y)
                                .map(|n| n.kind == Not && f.resolve(n.ins[0]) == x)
                                .unwrap_or(false)
                        };
                        if is_compl(g, &f, ops[0], ops[1]) || is_compl(g, &f, ops[1], ops[0]) {
                            let v = match node.kind {
                                And2 | Nor2 | Xnor2 => false,
                                Or2 | Nand2 | Xor2 => true,
                                _ => unreachable!("2-input kinds only"),
                            };
                            let c = f.const_node(g, v);
                            f.set_alias(id, c);
                            changed += 1;
                        }
                    }
                }
                And3 | Or3 | Nand3 | Nor3 | Maj3 | Aoi21 | Oai21 | Mux2 => {
                    if let Some((ki, kv)) =
                        (0..3).find_map(|s| konst[s].map(|v| (s, v)))
                    {
                        // ≥1 constant operand: synthesise the residual
                        // function of the two remaining operands from its
                        // truth table (all 16 cases covered).
                        let rest: Vec<NodeId> =
                            (0..3).filter(|&s| s != ki).map(|s| ops[s]).collect();
                        let eval = |p: bool, q: bool| {
                            let mut abc = [false; 3];
                            abc[ki] = kv;
                            let mut it = [p, q].into_iter();
                            for (s, slot) in abc.iter_mut().enumerate() {
                                if s != ki {
                                    *slot = it.next().unwrap();
                                }
                            }
                            node.kind.eval_bool(abc[0], abc[1], abc[2])
                        };
                        let tt = (
                            eval(false, false),
                            eval(false, true),
                            eval(true, false),
                            eval(true, true),
                        );
                        let (p, q) = (rest[0], rest[1]);
                        let mut mutate = |g: &mut Graph, kind: GateKind, a: NodeId, b: NodeId| {
                            let n = g.node_mut(id).unwrap();
                            n.kind = kind;
                            n.ins[0] = a;
                            n.ins[1] = b;
                        };
                        match tt {
                            (false, false, false, false) => {
                                let c = f.const_node(g, false);
                                f.set_alias(id, c);
                            }
                            (true, true, true, true) => {
                                let c = f.const_node(g, true);
                                f.set_alias(id, c);
                            }
                            (false, false, true, true) => f.set_alias(id, p),
                            (false, true, false, true) => f.set_alias(id, q),
                            (true, true, false, false) => {
                                let n = g.node_mut(id).unwrap();
                                n.kind = Not;
                                n.ins[0] = p;
                            }
                            (true, false, true, false) => {
                                let n = g.node_mut(id).unwrap();
                                n.kind = Not;
                                n.ins[0] = q;
                            }
                            (false, false, false, true) => mutate(g, And2, p, q),
                            (false, true, true, true) => mutate(g, Or2, p, q),
                            (true, true, true, false) => mutate(g, Nand2, p, q),
                            (true, false, false, false) => mutate(g, Nor2, p, q),
                            (false, true, true, false) => mutate(g, Xor2, p, q),
                            (true, false, false, true) => mutate(g, Xnor2, p, q),
                            (false, false, true, false) => {
                                // p & !q
                                let nq = g.add(Not, &[q]);
                                mutate(g, And2, p, nq);
                            }
                            (false, true, false, false) => {
                                // !p & q
                                let np = g.add(Not, &[p]);
                                mutate(g, And2, np, q);
                            }
                            (true, true, false, true) => {
                                // !p | q
                                let np = g.add(Not, &[p]);
                                mutate(g, Or2, np, q);
                            }
                            (true, false, true, true) => {
                                // p | !q
                                let nq = g.add(Not, &[q]);
                                mutate(g, Or2, p, nq);
                            }
                        }
                        changed += 1;
                    } else {
                        // No constants: equal-operand identities.
                        let (a, b, c) = (ops[0], ops[1], ops[2]);
                        let mut mutate2 =
                            |g: &mut Graph, kind: GateKind, x: NodeId, y: NodeId| {
                                let n = g.node_mut(id).unwrap();
                                n.kind = kind;
                                n.ins[0] = x;
                                n.ins[1] = y;
                            };
                        let dup = if a == b {
                            Some((a, c))
                        } else if a == c {
                            Some((a, b))
                        } else if b == c {
                            Some((b, a))
                        } else {
                            None
                        };
                        match (node.kind, dup) {
                            (And3, Some((x, y))) => {
                                mutate2(g, And2, x, y);
                                changed += 1;
                            }
                            (Or3, Some((x, y))) => {
                                mutate2(g, Or2, x, y);
                                changed += 1;
                            }
                            (Nand3, Some((x, y))) => {
                                mutate2(g, Nand2, x, y);
                                changed += 1;
                            }
                            (Nor3, Some((x, y))) => {
                                mutate2(g, Nor2, x, y);
                                changed += 1;
                            }
                            (Maj3, Some((x, _))) => {
                                // Two equal votes decide the majority.
                                f.set_alias(id, x);
                                changed += 1;
                            }
                            (Aoi21, _) if a == b => {
                                // !((x & x) | c) = !(x | c)
                                mutate2(g, Nor2, a, c);
                                changed += 1;
                            }
                            (Oai21, _) if a == b => {
                                // !((x | x) & c) = !(x & c)
                                mutate2(g, Nand2, a, c);
                                changed += 1;
                            }
                            (Mux2, _) if b == c => {
                                // Equal branches: sel is irrelevant.
                                f.set_alias(id, b);
                                changed += 1;
                            }
                            (Mux2, _) if a == b => {
                                // sel ? c : sel  ==  sel & c
                                mutate2(g, And2, a, c);
                                changed += 1;
                            }
                            (Mux2, _) if a == c => {
                                // sel ? sel : b  ==  sel | b
                                mutate2(g, Or2, a, b);
                                changed += 1;
                            }
                            _ => {}
                        }
                    }
                }
                Input | Const0 | Const1 | Buf | Not => unreachable!("handled above"),
            }
        }

        // Rewire outputs through the alias map.
        for i in 0..g.outputs().len() {
            let driver = g.outputs()[i].1;
            let rid = f.resolve(driver);
            if rid != driver {
                g.set_output_driver(i, rid);
            }
        }
        changed
    }
}

// ---------------------------------------------------------------------------
// Common-subexpression elimination
// ---------------------------------------------------------------------------

/// Merge structurally identical gates (same kind, same operands up to
/// commutation). Constants of the same polarity merge; primary inputs
/// never do.
pub struct Cse;

impl Pass for Cse {
    fn name(&self) -> &'static str {
        "cse"
    }

    fn run(&self, g: &mut Graph) -> usize {
        let order = g.topo_order();
        let mut repr: Vec<Option<NodeId>> = vec![None; g.id_bound()];
        let mut table: HashMap<(GateKind, [u32; 3]), NodeId> = HashMap::new();
        let mut merged = 0usize;
        for id in order {
            let node = *g.node(id).expect("topo order yields live nodes");
            if node.kind == GateKind::Input {
                continue;
            }
            let arity = node.kind.arity();
            // Rewrite operands through earlier merges.
            let mut ops = [0u32; 3];
            for slot in 0..arity {
                let mut op = node.ins[slot];
                while let Some(r) = repr[op.index()] {
                    op = r;
                }
                g.node_mut(id).unwrap().ins[slot] = op;
                ops[slot] = op.0;
            }
            // Canonical operand order for the hash key.
            if kind_is_symmetric(node.kind) {
                ops[..arity].sort_unstable();
            } else if matches!(node.kind, GateKind::Aoi21 | GateKind::Oai21) {
                ops[..2].sort_unstable();
            }
            match table.entry((node.kind, ops)) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    repr[id.index()] = Some(*e.get());
                    merged += 1;
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(id);
                }
            }
        }
        for i in 0..g.outputs().len() {
            let mut driver = g.outputs()[i].1;
            let mut moved = false;
            while let Some(r) = repr[driver.index()] {
                driver = r;
                moved = true;
            }
            if moved {
                g.set_output_driver(i, driver);
            }
        }
        merged
    }
}

// ---------------------------------------------------------------------------
// Dead-gate elimination
// ---------------------------------------------------------------------------

/// Tombstone every gate not reachable from an output (primary inputs
/// always survive).
pub struct DeadGateElim;

impl Pass for DeadGateElim {
    fn name(&self) -> &'static str {
        "dead-gate-elim"
    }

    fn run(&self, g: &mut Graph) -> usize {
        let reach = g.reachable_from_outputs();
        let dead: Vec<NodeId> = g
            .iter_live()
            .filter(|(id, n)| !reach[id.index()] && n.kind != GateKind::Input)
            .map(|(id, _)| id)
            .collect();
        g.remove_unchecked(&dead)
    }
}

// ---------------------------------------------------------------------------
// The pipeline
// ---------------------------------------------------------------------------

/// Fixpoint cap for `full`: each productive fold↔CSE round strictly
/// shrinks the live logic, so this is never reached in practice — it
/// bounds the loop against pathological pass interactions.
const MAX_ROUNDS: usize = 8;

/// Run the pipeline the level asks for. Function-preserving at every
/// level.
pub fn optimize(g: &mut Graph, level: OptLevel) -> OptReport {
    let logic_before = g.logic_gate_count();
    let area_before = g.area();
    let mut passes = Vec::new();
    match level {
        OptLevel::None => {}
        OptLevel::Fold => {
            passes.push(PassStat { pass: ConstFold.name(), rewrites: ConstFold.run(g) });
            passes
                .push(PassStat { pass: DeadGateElim.name(), rewrites: DeadGateElim.run(g) });
        }
        OptLevel::Full => {
            for _ in 0..MAX_ROUNDS {
                let folds = ConstFold.run(g);
                passes.push(PassStat { pass: ConstFold.name(), rewrites: folds });
                let merges = Cse.run(g);
                passes.push(PassStat { pass: Cse.name(), rewrites: merges });
                if folds + merges == 0 {
                    break;
                }
            }
            passes
                .push(PassStat { pass: DeadGateElim.name(), rewrites: DeadGateElim.run(g) });
        }
    }
    OptReport {
        level,
        logic_before,
        logic_after: g.logic_gate_count(),
        area_before,
        area_after: g.area(),
        passes,
    }
}

/// Optimize an append-only [`Netlist`] through the graph core and
/// re-linearise: `Netlist → Graph → passes → compile`. `OptLevel::None`
/// returns the input unchanged (not even re-linearised), so `:opt=none`
/// really is the raw generator output.
pub fn optimize_netlist(nl: &Netlist, level: OptLevel) -> (Netlist, OptReport) {
    if level == OptLevel::None {
        let mut g = Graph::from(nl);
        let report = optimize(&mut g, level);
        return (nl.clone(), report);
    }
    let mut g = Graph::from(nl);
    let report = optimize(&mut g, level);
    (g.compile(), report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::eval_outputs_bool;
    use crate::util::prng::Xoshiro256;

    /// Random 4-input DAG with sprinkled constants (mirrors the legacy
    /// builder fold test's generator).
    fn random_graph(rng: &mut Xoshiro256) -> Graph {
        let mut g = Graph::new("r");
        let mut sigs: Vec<NodeId> = (0..4).map(|i| g.input(&format!("i{i}"))).collect();
        sigs.push(g.const0());
        sigs.push(g.const1());
        for _ in 0..40 {
            let pick = |rng: &mut Xoshiro256, sigs: &[NodeId]| {
                sigs[rng.below(sigs.len() as u64) as usize]
            };
            let a = pick(rng, &sigs);
            let b = pick(rng, &sigs);
            let c = pick(rng, &sigs);
            let s = match rng.below(12) {
                0 => g.add(GateKind::And2, &[a, b]),
                1 => g.add(GateKind::Or2, &[a, b]),
                2 => g.add(GateKind::Nand2, &[a, b]),
                3 => g.add(GateKind::Nor2, &[a, b]),
                4 => g.add(GateKind::Xor2, &[a, b]),
                5 => g.add(GateKind::Xnor2, &[a, b]),
                6 => g.add(GateKind::Maj3, &[a, b, c]),
                7 => g.add(GateKind::Mux2, &[a, b, c]),
                8 => g.add(GateKind::Aoi21, &[a, b, c]),
                9 => g.add(GateKind::Oai21, &[a, b, c]),
                10 => g.add(GateKind::And3, &[a, b, c]),
                _ => g.add(GateKind::Not, &[a]),
            };
            sigs.push(s);
        }
        for (i, &s) in sigs.iter().rev().take(4).enumerate() {
            g.output(&format!("o{i}"), s);
        }
        g
    }

    fn truth_table(nl: &crate::netlist::Netlist) -> Vec<Vec<bool>> {
        (0..16)
            .map(|bits| {
                eval_outputs_bool(
                    nl,
                    &[(bits & 1) != 0, (bits & 2) != 0, (bits & 4) != 0, (bits & 8) != 0],
                )
            })
            .collect()
    }

    #[test]
    fn every_level_preserves_function_on_random_dags() {
        let mut rng = Xoshiro256::seeded(7);
        for trial in 0..40 {
            let g = random_graph(&mut rng);
            let raw = g.compile();
            let reference = truth_table(&raw);
            for level in OptLevel::all() {
                let (opt, report) = optimize_netlist(&raw, level);
                assert_eq!(truth_table(&opt), reference, "trial {trial} level {level}");
                assert!(
                    report.logic_after <= report.logic_before,
                    "trial {trial} level {level}: optimization must never grow the circuit"
                );
            }
        }
    }

    #[test]
    fn full_subsumes_fold() {
        let mut rng = Xoshiro256::seeded(99);
        for _ in 0..20 {
            let g = random_graph(&mut rng);
            let raw = g.compile();
            let (folded, _) = optimize_netlist(&raw, OptLevel::Fold);
            let (full, _) = optimize_netlist(&raw, OptLevel::Full);
            assert!(full.logic_gate_count() <= folded.logic_gate_count());
        }
    }

    #[test]
    fn cse_merges_structural_duplicates_across_commutation() {
        let mut g = Graph::new("c");
        let a = g.input("a");
        let b = g.input("b");
        let x = g.add(GateKind::And2, &[a, b]);
        let y = g.add(GateKind::And2, &[b, a]); // same gate, swapped operands
        let z = g.add(GateKind::Xor2, &[x, y]); // = 0 once x and y merge
        g.output("z", z);
        let report = optimize(&mut g, OptLevel::Full);
        assert!(report.passes.iter().any(|p| p.pass == "cse" && p.rewrites > 0));
        let nl = g.compile();
        // XOR(x, x) folds to constant 0 after the merge
        assert_eq!(nl.logic_gate_count(), 0, "{:?}", nl.kind_histogram());
        assert!(!eval_outputs_bool(&nl, &[true, true])[0]);
        assert!(!eval_outputs_bool(&nl, &[true, false])[0]);
    }

    #[test]
    fn const_fold_handles_equal_operand_identities() {
        let mut g = Graph::new("e");
        let a = g.input("a");
        let b = g.input("b");
        let xor_aa = g.add(GateKind::Xor2, &[a, a]); // → 0
        let maj_aab = g.add(GateKind::Maj3, &[a, a, b]); // → a
        let mux_same = g.add(GateKind::Mux2, &[b, maj_aab, maj_aab]); // → a
        let or_ = g.add(GateKind::Or2, &[xor_aa, mux_same]); // → a
        g.output("o", or_);
        let report = optimize(&mut g, OptLevel::Full);
        assert!(report.logic_after == 0, "all identities fold: {report:?}");
        let nl = g.compile();
        assert!(eval_outputs_bool(&nl, &[true, false])[0]);
        assert!(!eval_outputs_bool(&nl, &[false, true])[0]);
    }

    #[test]
    fn double_negation_is_eliminated() {
        let mut g = Graph::new("nn");
        let a = g.input("a");
        let n1 = g.add(GateKind::Not, &[a]);
        let n2 = g.add(GateKind::Not, &[n1]);
        let n3 = g.add(GateKind::Not, &[n2]);
        g.output("o", n3); // !!!a = !a
        optimize(&mut g, OptLevel::Full);
        assert_eq!(g.logic_gate_count(), 1);
        let nl = g.compile();
        assert!(!eval_outputs_bool(&nl, &[true])[0]);
        assert!(eval_outputs_bool(&nl, &[false])[0]);
    }

    #[test]
    fn constant_outputs_materialise() {
        let mut g = Graph::new("k");
        let a = g.input("a");
        let na = g.add(GateKind::Not, &[a]);
        let always0 = g.add(GateKind::And2, &[a, na]); // a & !a = 0
        g.output("o", always0);
        optimize(&mut g, OptLevel::Full);
        let nl = g.compile();
        assert_eq!(nl.logic_gate_count(), 0);
        assert!(!eval_outputs_bool(&nl, &[true])[0]);
        assert!(!eval_outputs_bool(&nl, &[false])[0]);
    }

    #[test]
    fn opt_level_parses_and_displays() {
        for level in OptLevel::all() {
            let s = level.to_string();
            assert_eq!(s.parse::<OptLevel>().unwrap(), level);
        }
        assert!("aggressive".parse::<OptLevel>().is_err());
        assert_eq!(OptLevel::default(), OptLevel::Full);
    }
}

//! Cell library: gate kinds and their unit-gate cost model.
//!
//! The *unit-gate model* is the standard technology-independent accounting
//! used in arithmetic-circuit papers (e.g. Zimmermann's adder analyses and
//! the compressor literature the paper builds on): a 2-input NAND/NOR is
//! one *gate equivalent* (GE) of area and one unit of delay; an inverter is
//! half; XOR/XNOR are two (a transmission-gate XOR is ~1.5–2 GE and two
//! logic levels); compound AOI/OAI cells are 1.5. Dynamic power is modelled
//! as switching activity × driven capacitance, with capacitance taken
//! proportional to gate area — exactly the quantity Synopsys reports as
//! "dynamic power" up to a technology constant. The single technology
//! constant is calibrated in [`crate::hwmodel`] against the paper's exact
//! multiplier row (Table 5), so only *ratios* between designs are claimed.

/// Maximum fan-in any gate kind uses.
pub const MAX_FANIN: usize = 3;

/// Gate kinds. Inputs are ordered; `Mux2`'s operands are `(sel, a, b)` and
/// it computes `if sel { b } else { a }`. `Aoi21` computes `!((a & b) | c)`;
/// `Oai21` computes `!((a | b) & c)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GateKind {
    /// Primary input (no operands).
    Input,
    Const0,
    Const1,
    Not,
    Buf,
    And2,
    Or2,
    Nand2,
    Nor2,
    Xor2,
    Xnor2,
    And3,
    Or3,
    Nand3,
    Nor3,
    /// Majority of three — the carry core of a full adder (single complex
    /// cell in real libraries).
    Maj3,
    /// `!((a & b) | c)`
    Aoi21,
    /// `!((a | b) & c)`
    Oai21,
    /// `(sel, a, b) -> if sel { b } else { a }`
    Mux2,
}

impl GateKind {
    /// Number of operands.
    pub fn arity(self) -> usize {
        use GateKind::*;
        match self {
            Input | Const0 | Const1 => 0,
            Not | Buf => 1,
            And2 | Or2 | Nand2 | Nor2 | Xor2 | Xnor2 => 2,
            And3 | Or3 | Nand3 | Nor3 | Maj3 | Aoi21 | Oai21 | Mux2 => 3,
        }
    }

    /// Area in gate equivalents (GE). 1 GE = one 2-input NAND.
    pub fn area(self) -> f64 {
        use GateKind::*;
        match self {
            Input | Const0 | Const1 => 0.0,
            Not => 0.5,
            Buf => 1.0,
            Nand2 | Nor2 => 1.0,
            And2 | Or2 => 1.5,
            Xor2 | Xnor2 => 2.0,
            Nand3 | Nor3 => 1.5,
            And3 | Or3 => 2.0,
            Maj3 => 2.5,
            Aoi21 | Oai21 => 1.5,
            Mux2 => 2.5,
        }
    }

    /// Propagation delay in unit-gate delays.
    pub fn delay(self) -> f64 {
        use GateKind::*;
        match self {
            Input | Const0 | Const1 => 0.0,
            Not => 0.5,
            Buf => 1.0,
            Nand2 | Nor2 => 1.0,
            And2 | Or2 => 1.5,
            Xor2 | Xnor2 => 2.0,
            Nand3 | Nor3 => 1.4,
            And3 | Or3 => 1.9,
            Maj3 => 2.0,
            Aoi21 | Oai21 => 1.5,
            Mux2 => 2.0,
        }
    }

    /// Switched capacitance per output toggle, in arbitrary units
    /// (proportional to area — bigger cells drive/present more C).
    pub fn cap(self) -> f64 {
        self.area()
    }

    /// All kinds, for exhaustive tests / iteration.
    pub fn all() -> &'static [GateKind] {
        use GateKind::*;
        &[
            Input, Const0, Const1, Not, Buf, And2, Or2, Nand2, Nor2, Xor2, Xnor2, And3, Or3,
            Nand3, Nor3, Maj3, Aoi21, Oai21, Mux2,
        ]
    }

    /// Scalar semantics (reference model; the packed simulator must agree).
    pub fn eval_bool(self, a: bool, b: bool, c: bool) -> bool {
        use GateKind::*;
        match self {
            Input => unreachable!("inputs are driven externally"),
            Const0 => false,
            Const1 => true,
            Not => !a,
            Buf => a,
            And2 => a & b,
            Or2 => a | b,
            Nand2 => !(a & b),
            Nor2 => !(a | b),
            Xor2 => a ^ b,
            Xnor2 => !(a ^ b),
            And3 => a & b & c,
            Or3 => a | b | c,
            Nand3 => !(a & b & c),
            Nor3 => !(a | b | c),
            Maj3 => (a & b) | (a & c) | (b & c),
            Aoi21 => !((a & b) | c),
            Oai21 => !((a | b) & c),
            Mux2 => {
                if a {
                    c
                } else {
                    b
                }
            }
        }
    }

    /// Packed semantics over 64 lanes.
    #[inline(always)]
    pub fn eval_packed(self, a: u64, b: u64, c: u64) -> u64 {
        use GateKind::*;
        match self {
            Input => unreachable!("inputs are driven externally"),
            Const0 => 0,
            Const1 => !0,
            Not => !a,
            Buf => a,
            And2 => a & b,
            Or2 => a | b,
            Nand2 => !(a & b),
            Nor2 => !(a | b),
            Xor2 => a ^ b,
            Xnor2 => !(a ^ b),
            And3 => a & b & c,
            Or3 => a | b | c,
            Nand3 => !(a & b & c),
            Nor3 => !(a | b | c),
            Maj3 => (a & b) | (a & c) | (b & c),
            Aoi21 => !((a & b) | c),
            Oai21 => !((a | b) & c),
            Mux2 => (a & c) | (!a & b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The packed evaluator must agree with the scalar semantics on every
    /// kind and every operand combination — this is the foundation the
    /// whole hardware-evaluation stack rests on.
    #[test]
    fn packed_matches_scalar_for_all_kinds() {
        for &kind in GateKind::all() {
            if kind == GateKind::Input {
                continue;
            }
            for bits in 0..8u8 {
                let (a, b, c) = (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0);
                let scalar = kind.eval_bool(a, b, c);
                let pa = if a { !0u64 } else { 0 };
                let pb = if b { !0u64 } else { 0 };
                let pc = if c { !0u64 } else { 0 };
                let packed = kind.eval_packed(pa, pb, pc);
                assert_eq!(
                    packed,
                    if scalar { !0u64 } else { 0 },
                    "kind {kind:?} bits {bits:03b}"
                );
            }
        }
    }

    #[test]
    fn mux_semantics() {
        use GateKind::Mux2;
        // (sel, a, b): sel=0 -> a, sel=1 -> b
        assert!(!Mux2.eval_bool(false, false, true));
        assert!(Mux2.eval_bool(false, true, false));
        assert!(Mux2.eval_bool(true, false, true));
        assert!(!Mux2.eval_bool(true, true, false));
    }

    #[test]
    fn cost_model_sanity() {
        // NAND is the unit; XOR costs more than NAND; INV is cheapest
        // non-free cell; constants and inputs are free.
        assert_eq!(GateKind::Nand2.area(), 1.0);
        assert!(GateKind::Xor2.area() > GateKind::Nand2.area());
        assert!(GateKind::Not.area() < GateKind::Nand2.area());
        assert_eq!(GateKind::Input.area(), 0.0);
        assert_eq!(GateKind::Const1.delay(), 0.0);
        for &k in GateKind::all() {
            assert!(k.area() >= 0.0 && k.delay() >= 0.0 && k.cap() >= 0.0);
        }
    }

    #[test]
    fn arity_is_consistent_with_eval() {
        for &k in GateKind::all() {
            assert!(k.arity() <= MAX_FANIN);
        }
    }
}

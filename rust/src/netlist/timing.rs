//! Static timing analysis: longest path under the unit-gate delay model.
//!
//! Arrival time of a gate output = max over operands of their arrival +
//! this gate's propagation delay. Primary inputs arrive at t=0. The
//! critical path is the maximum arrival over registered outputs — the
//! quantity the paper reports as "Delay (ns)" (Table 5) up to the
//! technology calibration constant.

use super::builder::Netlist;
use super::gate::GateKind;

#[derive(Debug, Clone)]
pub struct TimingReport {
    /// Arrival time per signal.
    pub arrival: Vec<f64>,
    /// Max arrival over registered outputs.
    pub critical_delay: f64,
    /// Signal ids on the critical path, input → output.
    pub critical_path: Vec<u32>,
    /// Logic depth (gate count) along the critical path.
    pub depth: usize,
}

/// Compute arrival times and the critical path.
pub fn analyze(netlist: &Netlist) -> TimingReport {
    let n = netlist.len();
    let mut arrival = vec![0.0f64; n];
    let mut pred: Vec<Option<u32>> = vec![None; n];
    for (i, g) in netlist.gates().iter().enumerate() {
        match g.kind {
            GateKind::Input | GateKind::Const0 | GateKind::Const1 => {
                arrival[i] = 0.0;
            }
            kind => {
                let mut worst = 0.0f64;
                let mut worst_in = None;
                for slot in 0..kind.arity() {
                    let op = g.ins[slot];
                    let t = arrival[op as usize];
                    if t >= worst {
                        worst = t;
                        worst_in = Some(op);
                    }
                }
                arrival[i] = worst + kind.delay();
                pred[i] = worst_in;
            }
        }
    }
    // Critical output
    let (mut crit_sig, mut crit_t) = (None, -1.0f64);
    for &(_, id) in netlist.outputs() {
        if arrival[id as usize] > crit_t {
            crit_t = arrival[id as usize];
            crit_sig = Some(id);
        }
    }
    let mut path = Vec::new();
    let mut cur = crit_sig;
    while let Some(id) = cur {
        path.push(id);
        cur = pred[id as usize];
    }
    path.reverse();
    let depth = path
        .iter()
        .filter(|&&id| {
            !matches!(
                netlist.gates()[id as usize].kind,
                GateKind::Input | GateKind::Const0 | GateKind::Const1
            )
        })
        .count();
    TimingReport {
        arrival,
        critical_delay: crit_t.max(0.0),
        critical_path: path,
        depth,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_delay_accumulates() {
        let mut n = Netlist::new("chain");
        let a = n.input("a");
        let b = n.input("b");
        let mut x = n.nand2(a, b); // 1.0
        for _ in 0..3 {
            x = n.nand2(x, b); // +3.0
        }
        n.output("x", x);
        let t = analyze(&n);
        assert!((t.critical_delay - 4.0).abs() < 1e-12);
        assert_eq!(t.depth, 4);
    }

    #[test]
    fn critical_path_picks_longer_branch() {
        let mut n = Netlist::new("branch");
        let a = n.input("a");
        let b = n.input("b");
        // short branch: one NAND (1.0); long branch: XOR chain (2.0 + 2.0)
        let short = n.nand2(a, b);
        let x1 = n.xor2(a, b);
        let x2 = n.xor2(x1, b);
        let out = n.or2(short, x2); // +1.5 from arrival 4.0
        n.output("o", out);
        let t = analyze(&n);
        assert!((t.critical_delay - 5.5).abs() < 1e-12);
        // path should route through the XOR chain
        assert!(t.critical_path.contains(&x1) && t.critical_path.contains(&x2));
    }

    #[test]
    fn constants_have_zero_arrival() {
        let mut n = Netlist::new("c");
        let a = n.input("a");
        let one = n.const1();
        let x = n.and2(a, one);
        n.output("x", x);
        let t = analyze(&n);
        assert!((t.critical_delay - 1.5).abs() < 1e-12);
    }

    #[test]
    fn empty_output_set_reports_zero() {
        let mut n = Netlist::new("noout");
        let _ = n.input("a");
        let t = analyze(&n);
        assert_eq!(t.critical_delay, 0.0);
        assert_eq!(t.depth, 0);
    }
}

//! Switching-activity dynamic power model.
//!
//! Dynamic power in CMOS is `P ≈ α · C · V² · f` — at fixed technology,
//! voltage and clock the design-dependent term is the *switched
//! capacitance per cycle*: the sum over nets of (toggle probability ×
//! driven capacitance). We estimate toggle probabilities by simulating a
//! sequence of random input vectors (the same methodology as gate-level
//! power estimation with a VCD activity file) using the packed simulator:
//! within a 64-lane word, lanes are treated as 64 consecutive time steps,
//! so toggles are `popcount(v ^ (v >> 1))` plus the boundary bit against
//! the previous word.

use super::builder::Netlist;
use super::gate::GateKind;
use super::sim::PackedSim;
use crate::util::prng::Xoshiro256;

#[derive(Debug, Clone)]
pub struct PowerReport {
    /// Mean toggles per net per cycle (activity factor α), per signal.
    pub activity: Vec<f64>,
    /// Σ α_i · cap_i — switched capacitance per cycle, arbitrary units.
    pub switched_cap: f64,
    /// Number of simulated transitions.
    pub cycles: usize,
}

/// Estimate switching activity with `vectors` random input vectors
/// (rounded up to a multiple of 64) drawn uniformly.
pub fn estimate(netlist: &Netlist, vectors: usize, seed: u64) -> PowerReport {
    let words = vectors.div_ceil(64).max(1);
    let num_inputs = netlist.inputs().len();
    let mut rng = Xoshiro256::seeded(seed);
    let mut sim = PackedSim::new(netlist);
    let mut toggles = vec![0u64; netlist.len()];
    let mut prev_last_bit: Option<Vec<u8>> = None;

    for _ in 0..words {
        let inputs: Vec<u64> = (0..num_inputs).map(|_| rng.next_u64()).collect();
        let values = sim.run(netlist, &inputs);
        for (i, &v) in values.iter().enumerate() {
            // Toggles between consecutive lanes within the word. Bit k of
            // v^(v>>1) compares lane k with lane k+1; bit 63 would compare
            // lane 63 with a shifted-in zero — mask it off, the genuine
            // word-boundary transition is handled below via prev_last_bit.
            toggles[i] += ((v ^ (v >> 1)) & 0x7FFF_FFFF_FFFF_FFFF).count_ones() as u64;
            if let Some(prev) = &prev_last_bit {
                let first = (v & 1) as u8;
                if prev[i] != first {
                    toggles[i] += 1;
                }
            }
        }
        // record lane-63 value per signal for the next word's boundary
        let last: Vec<u8> = values.iter().map(|&v| ((v >> 63) & 1) as u8).collect();
        prev_last_bit = Some(last);
    }

    let cycles = words * 64 - 1;
    let mut activity = vec![0.0; netlist.len()];
    for (i, t) in toggles.iter().enumerate() {
        activity[i] = *t as f64 / cycles as f64;
    }
    let switched_cap = netlist
        .gates()
        .iter()
        .enumerate()
        .map(|(i, g)| activity[i] * g.kind.cap())
        .sum();
    PowerReport { activity, switched_cap, cycles }
}

/// Activity of input nets is ~0.5 toggles/cycle for uniform random vectors;
/// a constant net must have activity 0. Exposed for tests and calibration.
pub fn constant_nets(netlist: &Netlist) -> Vec<bool> {
    netlist
        .gates()
        .iter()
        .map(|g| matches!(g.kind, GateKind::Const0 | GateKind::Const1))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_do_not_toggle() {
        let mut n = Netlist::new("c");
        let a = n.input("a");
        let one = n.const1();
        let x = n.and2(a, one);
        n.output("x", x);
        let rep = estimate(&n, 4096, 42);
        let const_id = 1; // second gate pushed
        assert_eq!(rep.activity[const_id], 0.0);
    }

    #[test]
    fn activity_of_buffer_matches_input() {
        let mut n = Netlist::new("buf");
        let a = n.input("a");
        let b = n.buf(a);
        n.output("b", b);
        let rep = estimate(&n, 8192, 7);
        let (ia, ib) = (0usize, 1usize);
        assert!((rep.activity[ia] - rep.activity[ib]).abs() < 1e-12);
        // uniform random stream toggles with p≈0.5
        assert!((rep.activity[ia] - 0.5).abs() < 0.05, "activity {}", rep.activity[ia]);
    }

    #[test]
    fn and_gate_activity_below_input_activity() {
        // AND of independent uniform inputs is 1 with p=1/4 → toggle prob
        // 2·(1/4)·(3/4) = 0.375 < 0.5.
        let mut n = Netlist::new("and");
        let a = n.input("a");
        let b = n.input("b");
        let x = n.and2(a, b);
        n.output("x", x);
        let rep = estimate(&n, 16384, 11);
        let and_act = rep.activity[2];
        assert!((and_act - 0.375).abs() < 0.03, "activity {and_act}");
    }

    #[test]
    fn switched_cap_scales_with_size() {
        let build = |copies: usize| {
            let mut n = Netlist::new("x");
            let a = n.input("a");
            let b = n.input("b");
            let mut outs = Vec::new();
            for _ in 0..copies {
                outs.push(n.xor2(a, b));
            }
            for (i, o) in outs.iter().enumerate() {
                n.output(&format!("o{i}"), *o);
            }
            n
        };
        let small = estimate(&build(1), 4096, 3).switched_cap;
        let big = estimate(&build(10), 4096, 3).switched_cap;
        assert!(big > 5.0 * small, "10 copies should switch ≫ 1 copy");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut n = Netlist::new("d");
        let a = n.input("a");
        let b = n.input("b");
        let x = n.xor2(a, b);
        n.output("x", x);
        let r1 = estimate(&n, 1024, 99).switched_cap;
        let r2 = estimate(&n, 1024, 99).switched_cap;
        assert_eq!(r1, r2);
    }
}

//! Proposed *exact* sign-focused compressors (paper Fig. 3).
//!
//! `ExactAbc1` computes `A+B+C+1` exactly into (cout, carry, sum) — the
//! same function as the exact design of paper ref. [2], but implemented
//! with the factoring of Fig. 3(a). `ExactAbcd1` computes `A+B+C+D+1`
//! exactly into (cout, carry, sum); unlike ref. [2]'s design it reduces a
//! partial product (§3.1).
//!
//! Value encodings (including the constant `+1`):
//!
//! ```text
//! A+B+C+1   = 4·cout + 2·carry + sum,  sum = ~(A⊕B⊕C)
//! A+B+C+D+1 = 4·cout + 2·carry + sum,  sum = ~(A⊕B⊕C⊕D)
//! ```

use super::traits::{Abc1Compressor, Abcd1Compressor, OutBit};
use crate::netlist::{Netlist, SigId};

/// Exact `A+B+C+1` (Fig. 3(a)).
pub struct ExactAbc1;

impl Abc1Compressor for ExactAbc1 {
    fn name(&self) -> &'static str {
        "Exact SF [2]/Fig3a"
    }

    fn value(&self, a: bool, b: bool, c: bool) -> u8 {
        1 + a as u8 + b as u8 + c as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId) -> Vec<OutBit> {
        // value = 1 + a + b + c ∈ [1,4]
        //   sum   = ~(a⊕b⊕c)
        //   carry = (n==1 | n==2) = (a|b|c) & ~(a&b&c)
        //   cout  = a&b&c
        let sum = n.xnor3(a, b, c);
        let any = n.or3(a, b, c);
        let all = n.and3(a, b, c);
        let nall = n.not(all);
        let carry = n.and2(any, nall);
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: carry },
            OutBit { rel_weight: 2, sig: all },
        ]
    }
}

/// Exact `A+B+C+D+1` (Fig. 3(b)) — reduces one partial product relative to
/// the exact design of ref. [2].
pub struct ExactAbcd1;

impl Abcd1Compressor for ExactAbcd1 {
    fn name(&self) -> &'static str {
        "Exact SF Fig3b"
    }

    fn value(&self, a: bool, b: bool, c: bool, d: bool) -> u8 {
        1 + a as u8 + b as u8 + c as u8 + d as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId, d: SigId) -> Vec<OutBit> {
        // value = 1 + n, n = a+b+c+d ∈ [0,4] → value ∈ [1,5]
        //   sum   = ~parity(n)        (bit 0 of 1+n)
        //   carry = (n==1 | n==2)     (bit 1 of 1+n: 1+n ∈ {2,3})
        //   cout  = (n>=3)            (bit 2 of 1+n: 1+n ∈ {4,5})
        let p_ab = n.xor2(a, b);
        let p_cd = n.xor2(c, d);
        let parity = n.xor2(p_ab, p_cd);
        let sum = n.not(parity);
        // pair counts
        let ab = n.and2(a, b);
        let cd = n.and2(c, d);
        let any_ab = n.or2(a, b);
        let any_cd = n.or2(c, d);
        // n>=3: one pair full and the other non-empty, with at least one
        // of the cross terms: n>=3 ⇔ (ab & any_cd) | (cd & any_ab)
        let t1 = n.and2(ab, any_cd);
        let t2 = n.and2(cd, any_ab);
        let cout = n.or2(t1, t2);
        // n>=1
        let n_ge1 = n.or2(any_ab, any_cd);
        // carry = n∈{1,2} = n>=1 & ~(n>=3)
        let ncout = n.not(cout);
        let carry = n.and2(n_ge1, ncout);
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: carry },
            OutBit { rel_weight: 2, sig: cout },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::traits::{check_abc1, check_abcd1};

    #[test]
    fn exact_abc1_is_exact_and_netlist_matches() {
        assert!(ExactAbc1.is_exact());
        check_abc1(&ExactAbc1).unwrap();
    }

    #[test]
    fn exact_abcd1_is_exact_and_netlist_matches() {
        assert!(ExactAbcd1.is_exact());
        check_abcd1(&ExactAbcd1).unwrap();
    }

    #[test]
    fn exact_abcd1_covers_full_range() {
        // value must reach 1 (all zero) and 5 (all one)
        assert_eq!(ExactAbcd1.value(false, false, false, false), 1);
        assert_eq!(ExactAbcd1.value(true, true, true, true), 5);
    }
}

//! Compressor interfaces shared by the functional and netlist forms.

use crate::netlist::{Netlist, SigId};

/// One output bit of a compressor, tagged with its weight *relative to the
/// column the compressor sits in* (0 = same column, 1 = next column, ...).
///
/// Constant outputs (the "sign-focus trick" of keeping a carry at logic 1)
/// are represented as netlist constants by the builders and as part of the
/// functional `value()` by the models, so both forms stay comparable.
#[derive(Debug, Clone, Copy)]
pub struct OutBit {
    pub rel_weight: u8,
    pub sig: SigId,
}

/// An `A + B + C + 1` sign-focused compressor. `A` is the negative
/// (NAND-generated) partial product; `B`, `C` are positive. The implicit
/// `+1` is part of the compressor contract — `value()` includes it.
pub trait Abc1Compressor: Send + Sync {
    /// Short identifier used in tables ("AC1 [4]", "Proposed", ...).
    fn name(&self) -> &'static str;

    /// Column value encoded by the outputs for the given inputs,
    /// including the constant `+1`. Exact designs return `1+a+b+c`.
    fn value(&self, a: bool, b: bool, c: bool) -> u8;

    /// Whether the design is exact (`value == 1+a+b+c` for all inputs).
    fn is_exact(&self) -> bool {
        (0..8).all(|bits| {
            let (a, b, c) = (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0);
            self.value(a, b, c) == 1 + a as u8 + b as u8 + c as u8
        })
    }

    /// Emit the gate-level implementation. The returned bits must encode
    /// `value()`: `Σ 2^rel_weight · bit == value(a,b,c)` for all inputs
    /// (verified exhaustively by the test suite for every design).
    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId) -> Vec<OutBit>;
}

/// An `A + B + C + D + 1` sign-focused compressor. `A` is the negative
/// partial product; `B`, `C`, `D` are positive.
pub trait Abcd1Compressor: Send + Sync {
    fn name(&self) -> &'static str;

    /// Column value encoded by the outputs, including the constant `+1`.
    /// Exact designs return `1+a+b+c+d`.
    fn value(&self, a: bool, b: bool, c: bool, d: bool) -> u8;

    fn is_exact(&self) -> bool {
        (0..16).all(|bits| {
            let (a, b, c, d) =
                (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0, bits & 8 != 0);
            self.value(a, b, c, d) == 1 + a as u8 + b as u8 + c as u8 + d as u8
        })
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId, d: SigId) -> Vec<OutBit>;
}

/// Exhaustively verify that a built ABC1 netlist encodes the functional
/// model. Returns an error message on the first mismatch.
pub fn check_abc1(design: &dyn Abc1Compressor) -> Result<(), String> {
    let mut n = Netlist::new(design.name());
    let a = n.input("a");
    let b = n.input("b");
    let c = n.input("c");
    let outs = design.build(&mut n, a, b, c);
    for bits in 0..8u8 {
        let (va, vb, vc) = (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0);
        let values = crate::netlist::sim::eval_bool(&n, &[va, vb, vc]);
        let got: u8 = outs
            .iter()
            .map(|ob| (values[ob.sig as usize] as u8) << ob.rel_weight)
            .sum();
        let want = design.value(va, vb, vc);
        if got != want {
            return Err(format!(
                "{}: inputs a={va} b={vb} c={vc}: netlist encodes {got}, model says {want}",
                design.name()
            ));
        }
    }
    Ok(())
}

/// Exhaustively verify a built ABCD1 netlist against its functional model.
pub fn check_abcd1(design: &dyn Abcd1Compressor) -> Result<(), String> {
    let mut n = Netlist::new(design.name());
    let a = n.input("a");
    let b = n.input("b");
    let c = n.input("c");
    let d = n.input("d");
    let outs = design.build(&mut n, a, b, c, d);
    for bits in 0..16u8 {
        let (va, vb, vc, vd) =
            (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0, bits & 8 != 0);
        let values = crate::netlist::sim::eval_bool(&n, &[va, vb, vc, vd]);
        let got: u8 = outs
            .iter()
            .map(|ob| (values[ob.sig as usize] as u8) << ob.rel_weight)
            .sum();
        let want = design.value(va, vb, vc, vd);
        if got != want {
            return Err(format!(
                "{}: inputs a={va} b={vb} c={vc} d={vd}: netlist encodes {got}, model says {want}",
                design.name()
            ));
        }
    }
    Ok(())
}

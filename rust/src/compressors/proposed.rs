//! Proposed *approximate* sign-focused compressors (paper Fig. 4, Tables
//! 2 and 3) plus the ablation candidates discussed in DESIGN.md.
//!
//! ## `A+B+C+1` (Table 2, "Proposed" columns — fully legible in the paper)
//!
//! ```text
//! Carry = A | B | C
//! Sum   = ~A | B | C
//! value = 2·Carry + Sum
//! ```
//!
//! Errors: +1 at {A=0,B⊕C=1} (P = 3/64 each), −1 at {1,1,1} (P = 3/64);
//! `P_E = 9/64`, `E_mean = +3/64` under the Table-2 input distribution.
//! (The paper's printed P_E/E_mean summary row disagrees with its own Err
//! column; we reproduce the truth table, which is self-consistent.)
//!
//! ## `A+B+C+D+1` (Table 3 — reconstructed, see DESIGN.md §Reconstruction)
//!
//! The paper's design rule: introduce error in the *sum* output only, at
//! the input combinations with the lowest probability. Because `A` is
//! NAND-generated (`P(A=1)=3/4`), the low-probability rows are exactly the
//! `A=0` rows. The shipped design ("C5" of the DESIGN.md candidate sweep):
//!
//! ```text
//! Carry = maj(B,C,D)
//! Sum   = A & (B⊕C⊕D)
//! value = 2 + 2·Carry + Sum        (constant +2: the sign-focus carry
//!                                   kept at logic 1 one column up)
//! ```
//!
//! Every `A=1` row (probability 3/4 of the mass) is exact; errors are
//! confined to `A=0` rows, are always `+1` (never negative — no large
//! negative spikes at the CSP weights), and total `P_E = 36/256 ≈ 0.141`,
//! `E_mean = +36/256`. Among all candidates it gives the multiplier the
//! lowest MRED (the paper's headline Table-4 property); the alternatives
//! are retained for the ablation bench (`sfcmul ablate`).

use super::traits::{Abc1Compressor, Abcd1Compressor, OutBit};
use crate::netlist::{Netlist, SigId};

/// Proposed approximate `A+B+C+1` (paper Fig. 4(a), Table 2 last columns).
pub struct ProposedApproxAbc1;

impl Abc1Compressor for ProposedApproxAbc1 {
    fn name(&self) -> &'static str {
        "Proposed"
    }

    fn value(&self, a: bool, b: bool, c: bool) -> u8 {
        let carry = a | b | c;
        let sum = !a | b | c;
        2 * carry as u8 + sum as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId) -> Vec<OutBit> {
        let carry = n.or3(a, b, c);
        let na = n.not(a);
        let sum = n.or3(na, b, c);
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: carry },
        ]
    }
}

/// Proposed approximate `A+B+C+D+1` (paper Fig. 4(b), Table 3) —
/// reconstruction "C5" of DESIGN.md: `Carry = maj(B,C,D)`,
/// `Sum = A & (B⊕C⊕D)`, value offset +2. Exact on every `A=1` row;
/// all errors are `+1`.
pub struct ProposedApproxAbcd1;

/// Shared functional core so the multiplier fast models and the netlist
/// stay in lockstep.
pub fn proposed_abcd1_value(a: bool, b: bool, c: bool, d: bool) -> u8 {
    let carry = (b & c) | (b & d) | (c & d);
    let sum = a & (b ^ c ^ d);
    2 + 2 * carry as u8 + sum as u8
}

impl Abcd1Compressor for ProposedApproxAbcd1 {
    fn name(&self) -> &'static str {
        "Proposed"
    }

    fn value(&self, a: bool, b: bool, c: bool, d: bool) -> u8 {
        proposed_abcd1_value(a, b, c, d)
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId, d: SigId) -> Vec<OutBit> {
        let carry = n.maj3(b, c, d);
        let parity = n.xor3(b, c, d);
        let sum = n.and2(a, parity);
        let k1 = n.const1(); // the sign-focus constant carry (value offset +2)
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: carry },
            OutBit { rel_weight: 1, sig: k1 },
        ]
    }
}

/// Ablation candidate "C4": both outputs gated by A.
/// `Carry = A & maj(B,C,D)`, `Sum = A & (B⊕C⊕D)`, value offset +2.
/// Lowest compressor-level E_mean (+16/256) but errs −2 at `A=0,n=3`,
/// which costs multiplier-level MRED at the CSP weights.
pub struct AblationAbcd1Gated;

impl Abcd1Compressor for AblationAbcd1Gated {
    fn name(&self) -> &'static str {
        "Ablation-gated"
    }

    fn value(&self, a: bool, b: bool, c: bool, d: bool) -> u8 {
        let maj = (b & c) | (b & d) | (c & d);
        let carry = a & maj;
        let sum = a & (b ^ c ^ d);
        2 + 2 * carry as u8 + sum as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId, d: SigId) -> Vec<OutBit> {
        let maj = n.maj3(b, c, d);
        let parity = n.xor3(b, c, d);
        let carry = n.and2(a, maj);
        let sum = n.and2(a, parity);
        let k1 = n.const1();
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: carry },
            OutBit { rel_weight: 1, sig: k1 },
        ]
    }
}

/// Ablation candidate "C1": ungated parity sum.
/// `Carry = A & maj(B,C,D)`, `Sum = B⊕C⊕D`, value offset +2.
/// `P_E = 64/256`, `E_mean = +44/256 ≈ +0.17`.
pub struct AblationAbcd1Parity;

impl Abcd1Compressor for AblationAbcd1Parity {
    fn name(&self) -> &'static str {
        "Ablation-parity"
    }

    fn value(&self, a: bool, b: bool, c: bool, d: bool) -> u8 {
        let maj = (b & c) | (b & d) | (c & d);
        let carry = a & maj;
        let sum = b ^ c ^ d;
        2 + 2 * carry as u8 + sum as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId, d: SigId) -> Vec<OutBit> {
        let maj = n.maj3(b, c, d);
        let sum = n.xor3(b, c, d);
        let carry = n.and2(a, maj);
        let k1 = n.const1();
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: carry },
            OutBit { rel_weight: 1, sig: k1 },
        ]
    }
}

/// Ablation candidate "C3": XOR-free (cheapest).
/// `Carry = A & maj(B,C,D)`, `Sum = B|C|D`, value offset +2.
/// `P_E = 82/256`, `E_mean = +80/256 ≈ +0.31`.
pub struct AblationAbcd1OrSum;

impl Abcd1Compressor for AblationAbcd1OrSum {
    fn name(&self) -> &'static str {
        "Ablation-orsum"
    }

    fn value(&self, a: bool, b: bool, c: bool, d: bool) -> u8 {
        let maj = (b & c) | (b & d) | (c & d);
        let carry = a & maj;
        let sum = b | c | d;
        2 + 2 * carry as u8 + sum as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId, d: SigId) -> Vec<OutBit> {
        let maj = n.maj3(b, c, d);
        let sum = n.or3(b, c, d);
        let carry = n.and2(a, maj);
        let k1 = n.const1();
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: carry },
            OutBit { rel_weight: 1, sig: k1 },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::traits::{check_abc1, check_abcd1};

    /// Paper Table 2, "Proposed" columns: Carry, Sum, S_aprx per row
    /// (rows ordered A,B,C = P2,P1,P0 as printed).
    #[test]
    fn proposed_abc1_matches_paper_table2() {
        // (a, b, c) -> (carry, sum, value)
        let expect = [
            ((false, false, false), (0u8, 1u8, 1u8)),
            ((false, false, true), (1, 1, 3)),
            ((false, true, false), (1, 1, 3)),
            ((false, true, true), (1, 1, 3)),
            ((true, false, false), (1, 0, 2)),
            ((true, false, true), (1, 1, 3)),
            ((true, true, false), (1, 1, 3)),
            ((true, true, true), (1, 1, 3)),
        ];
        for ((a, b, c), (carry, sum, value)) in expect {
            let v = ProposedApproxAbc1.value(a, b, c);
            assert_eq!(v, value, "value at a={a} b={b} c={c}");
            assert_eq!(v >> 1, carry, "carry at a={a} b={b} c={c}");
            assert_eq!(v & 1, sum, "sum at a={a} b={b} c={c}");
        }
    }

    /// Err column of Table 2 for the proposed design: +1 at 001 and 010,
    /// -1 at 111, 0 elsewhere.
    #[test]
    fn proposed_abc1_error_pattern() {
        for bits in 0..8u8 {
            let (a, b, c) = (bits & 4 != 0, bits & 2 != 0, bits & 1 != 0);
            let exact = 1 + a as i8 + b as i8 + c as i8;
            let err = ProposedApproxAbc1.value(a, b, c) as i8 - exact;
            let expect = match (a, b, c) {
                (false, false, true) | (false, true, false) => 1,
                (true, true, true) => -1,
                _ => 0,
            };
            assert_eq!(err, expect, "a={a} b={b} c={c}");
        }
    }

    #[test]
    fn proposed_netlists_match_models() {
        check_abc1(&ProposedApproxAbc1).unwrap();
        check_abcd1(&ProposedApproxAbcd1).unwrap();
        check_abcd1(&AblationAbcd1Gated).unwrap();
        check_abcd1(&AblationAbcd1Parity).unwrap();
        check_abcd1(&AblationAbcd1OrSum).unwrap();
    }

    /// The shipped ABCD1 design must be exact on all A=1 rows — that is the
    /// design principle (A=1 has probability 3/4).
    #[test]
    fn proposed_abcd1_exact_on_a1_rows() {
        for bits in 0..8u8 {
            let (b, c, d) = (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0);
            let exact = 1 + 1 + b as u8 + c as u8 + d as u8;
            assert_eq!(proposed_abcd1_value(true, b, c, d), exact, "b={b} c={c} d={d}");
        }
    }

    /// Error pattern on A=0 rows: +1 at n∈{0,2}, 0 at n∈{1,3} — never
    /// negative (the property that keeps multiplier-level MRED low).
    #[test]
    fn proposed_abcd1_error_pattern_on_a0_rows() {
        for bits in 0..8u8 {
            let (b, c, d) = (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0);
            let n = b as i8 + c as i8 + d as i8;
            let exact = 1 + n;
            let err = proposed_abcd1_value(false, b, c, d) as i8 - exact;
            let expect = match n {
                0 | 2 => 1,
                1 | 3 => 0,
                _ => unreachable!(),
            };
            assert_eq!(err, expect, "n={n}");
            assert!(err >= 0, "never negative");
        }
    }

    #[test]
    fn approximate_designs_are_not_exact() {
        use crate::compressors::traits::{Abc1Compressor, Abcd1Compressor};
        assert!(!ProposedApproxAbc1.is_exact());
        assert!(!ProposedApproxAbcd1.is_exact());
        assert!(!AblationAbcd1Gated.is_exact());
        assert!(!AblationAbcd1Parity.is_exact());
        assert!(!AblationAbcd1OrSum.is_exact());
    }

    /// Area ordering: approximate < exact (the whole point of the design).
    #[test]
    fn approx_is_smaller_than_exact() {
        use crate::compressors::exact::{ExactAbc1, ExactAbcd1};
        let area = |f: &dyn Fn(&mut Netlist) -> ()| {
            let mut n = Netlist::new("t");
            f(&mut n);
            n.area()
        };
        let a_exact3 = area(&|n: &mut Netlist| {
            let a = n.input("a");
            let b = n.input("b");
            let c = n.input("c");
            ExactAbc1.build(n, a, b, c);
        });
        let a_prop3 = area(&|n: &mut Netlist| {
            let a = n.input("a");
            let b = n.input("b");
            let c = n.input("c");
            ProposedApproxAbc1.build(n, a, b, c);
        });
        assert!(a_prop3 < a_exact3, "approx ABC1 {a_prop3} !< exact {a_exact3}");

        let a_exact4 = area(&|n: &mut Netlist| {
            let a = n.input("a");
            let b = n.input("b");
            let c = n.input("c");
            let d = n.input("d");
            ExactAbcd1.build(n, a, b, c, d);
        });
        let a_prop4 = area(&|n: &mut Netlist| {
            let a = n.input("a");
            let b = n.input("b");
            let c = n.input("c");
            let d = n.input("d");
            ProposedApproxAbcd1.build(n, a, b, c, d);
        });
        assert!(a_prop4 < a_exact4, "approx ABCD1 {a_prop4} !< exact {a_exact4}");
    }
}

//! Probabilistic error statistics of sign-focused compressors — the
//! `P(Err)`, `P_E` and `E_mean` rows of paper Tables 2 and 3 (Eq. 4).
//!
//! Input model: `A` is a NAND-generated negative partial product of two
//! independent uniform bits, so `P(A=1) = 3/4`; `B`, `C`, `D` are
//! AND-generated, so `P(=1) = 1/4`. Row probability is the product.

use super::traits::{Abc1Compressor, Abcd1Compressor};

#[derive(Debug, Clone)]
pub struct CompressorStats {
    pub name: &'static str,
    /// Per-row: (inputs-as-bits, row probability, exact value, approx
    /// value, error). For ABC1 rows, bits = A<<2|B<<1|C (paper row order);
    /// for ABCD1, bits = A<<3|B<<2|C<<1|D.
    pub rows: Vec<(u8, f64, u8, u8, i8)>,
    /// Σ P(row) over rows with error ≠ 0  (paper Eq. 4, `P_E`).
    pub error_probability: f64,
    /// Σ P(row)·err  (paper Eq. 4, `E_mean`).
    pub mean_error: f64,
    /// Σ P(row)·|err| (mean error distance at compressor level).
    pub mean_abs_error: f64,
}

const P_A1: f64 = 0.75; // NAND output
const P_P1: f64 = 0.25; // AND output

fn p_bit(value: bool, p_one: f64) -> f64 {
    if value {
        p_one
    } else {
        1.0 - p_one
    }
}

/// Statistics of an `A+B+C+1` design under the Table-2 distribution.
pub fn abc1_stats(design: &dyn Abc1Compressor) -> CompressorStats {
    let mut rows = Vec::with_capacity(8);
    let (mut pe, mut me, mut mae) = (0.0, 0.0, 0.0);
    for bits in 0..8u8 {
        let a = bits & 4 != 0;
        let b = bits & 2 != 0;
        let c = bits & 1 != 0;
        let p = p_bit(a, P_A1) * p_bit(b, P_P1) * p_bit(c, P_P1);
        let exact = 1 + a as u8 + b as u8 + c as u8;
        let approx = design.value(a, b, c);
        let err = approx as i8 - exact as i8;
        if err != 0 {
            pe += p;
        }
        me += p * err as f64;
        mae += p * err.unsigned_abs() as f64;
        rows.push((bits, p, exact, approx, err));
    }
    CompressorStats {
        name: design.name(),
        rows,
        error_probability: pe,
        mean_error: me,
        mean_abs_error: mae,
    }
}

/// Statistics of an `A+B+C+D+1` design under the Table-3 distribution.
pub fn abcd1_stats(design: &dyn Abcd1Compressor) -> CompressorStats {
    let mut rows = Vec::with_capacity(16);
    let (mut pe, mut me, mut mae) = (0.0, 0.0, 0.0);
    for bits in 0..16u8 {
        let a = bits & 8 != 0;
        let b = bits & 4 != 0;
        let c = bits & 2 != 0;
        let d = bits & 1 != 0;
        let p = p_bit(a, P_A1) * p_bit(b, P_P1) * p_bit(c, P_P1) * p_bit(d, P_P1);
        let exact = 1 + a as u8 + b as u8 + c as u8 + d as u8;
        let approx = design.value(a, b, c, d);
        let err = approx as i8 - exact as i8;
        if err != 0 {
            pe += p;
        }
        me += p * err as f64;
        mae += p * err.unsigned_abs() as f64;
        rows.push((bits, p, exact, approx, err));
    }
    CompressorStats {
        name: design.name(),
        rows,
        error_probability: pe,
        mean_error: me,
        mean_abs_error: mae,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::baselines::*;
    use crate::compressors::exact::{ExactAbc1, ExactAbcd1};
    use crate::compressors::proposed::*;

    fn close(x: f64, y: f64) -> bool {
        (x - y).abs() < 1e-12
    }

    /// Row probabilities must match Table 2's P(Err) column:
    /// 000→9/64, 001→3/64, 010→3/64, 011→1/64, 100→27/64, 101→9/64,
    /// 110→9/64, 111→3/64.
    #[test]
    fn table2_row_probabilities() {
        let s = abc1_stats(&ExactAbc1);
        let expect = [9.0, 3.0, 3.0, 1.0, 27.0, 9.0, 9.0, 3.0];
        for (row, e) in s.rows.iter().zip(expect) {
            assert!(close(row.1, e / 64.0), "row {:03b}: {} vs {}", row.0, row.1, e / 64.0);
        }
        let total: f64 = s.rows.iter().map(|r| r.1).sum();
        assert!(close(total, 1.0));
    }

    /// Table 2 bottom rows. P_E values as printed (all consistent with the
    /// S_aprx columns): AC1 22/64, AC2 9/64, AC3 48/64, AC4 18/64,
    /// AC5 13/64, Proposed 9/64. E_mean magnitudes: 25/64, 12/64, 48/64,
    /// 18/64, 5/64, 3/64 (signs per our Err-column computation; the paper's
    /// summary-row signs are internally inconsistent — see EXPERIMENTS.md).
    #[test]
    fn table2_pe_and_emean() {
        let cases: Vec<(Box<dyn crate::compressors::traits::Abc1Compressor>, f64, f64)> = vec![
            (Box::new(ExactAbc1), 0.0, 0.0),
            (Box::new(Ac1Esposito4), 22.0 / 64.0, -25.0 / 64.0),
            (Box::new(Ac2Guo5), 9.0 / 64.0, -12.0 / 64.0),
            (Box::new(Ac3Strollo12), 48.0 / 64.0, -48.0 / 64.0),
            (Box::new(Ac4Du3), 18.0 / 64.0, 18.0 / 64.0),
            (Box::new(Ac5Du2), 13.0 / 64.0, 5.0 / 64.0),
            (Box::new(ProposedApproxAbc1), 9.0 / 64.0, 3.0 / 64.0),
        ];
        for (design, pe, me) in cases {
            let s = abc1_stats(design.as_ref());
            assert!(close(s.error_probability, pe), "{}: P_E {} vs {}", s.name, s.error_probability, pe);
            assert!(close(s.mean_error, me), "{}: E_mean {} vs {}", s.name, s.mean_error, me);
        }
    }

    /// The proposed ABC1 design must have the lowest P_E of all the
    /// approximate designs in Table 2 (tied or better), and the lowest
    /// |E_mean| — the paper's headline claim for this cell.
    #[test]
    fn proposed_abc1_dominates_table2() {
        let ours = abc1_stats(&ProposedApproxAbc1);
        for s in crate::compressors::all_abc1_designs()
            .iter()
            .map(|d| abc1_stats(d.as_ref()))
            .filter(|s| s.name != "Proposed" && s.error_probability > 0.0)
        {
            assert!(
                ours.error_probability <= s.error_probability + 1e-12,
                "P_E: ours {} vs {} {}",
                ours.error_probability,
                s.name,
                s.error_probability
            );
            assert!(
                ours.mean_error.abs() <= s.mean_error.abs() + 1e-12,
                "E_mean: ours {} vs {} {}",
                ours.mean_error,
                s.name,
                s.mean_error
            );
        }
    }

    /// Table 3 row probabilities: 0000 → 27/256 ... 1000 → 81/256 etc.
    #[test]
    fn table3_row_probabilities() {
        let s = abcd1_stats(&ExactAbcd1);
        // bits = A<<3|B<<2|C<<1|D
        let p_of = |bits: u8| s.rows[bits as usize].1;
        assert!(close(p_of(0b0000), 27.0 / 256.0));
        assert!(close(p_of(0b1000), 81.0 / 256.0));
        assert!(close(p_of(0b1001), 27.0 / 256.0));
        assert!(close(p_of(0b0111), 1.0 / 256.0));
        assert!(close(p_of(0b1111), 3.0 / 256.0));
        let total: f64 = s.rows.iter().map(|r| r.1).sum();
        assert!(close(total, 1.0));
    }

    /// Reconstructed proposed ABCD1 ("C5"): P_E = 36/256, E_mean = +36/256,
    /// and every error is exactly +1 (no negative spikes).
    #[test]
    fn proposed_abcd1_stats() {
        let s = abcd1_stats(&ProposedApproxAbcd1);
        assert!(close(s.error_probability, 36.0 / 256.0), "P_E = {}", s.error_probability);
        assert!(close(s.mean_error, 36.0 / 256.0), "E_mean = {}", s.mean_error);
        for row in &s.rows {
            assert!(row.4 == 0 || row.4 == 1, "row {:04b}: err {}", row.0, row.4);
        }
    }

    /// The shipped ABCD1 has the lowest P_E of the candidates and is the
    /// only one whose errors never go negative — the property that wins
    /// multiplier-level MRED (see `sfcmul ablate`).
    #[test]
    fn proposed_abcd1_beats_ablations() {
        let ours = abcd1_stats(&ProposedApproxAbcd1);
        for alt in [
            abcd1_stats(&AblationAbcd1Gated),
            abcd1_stats(&AblationAbcd1Parity),
            abcd1_stats(&AblationAbcd1OrSum),
        ] {
            assert!(
                ours.error_probability <= alt.error_probability + 1e-12,
                "P_E vs {}", alt.name
            );
            let alt_has_negative = alt.rows.iter().any(|r| r.4 < 0)
                || alt.mean_error.abs() > ours.mean_error.abs();
            assert!(alt_has_negative, "{} should be dominated somewhere", alt.name);
        }
    }

    #[test]
    fn exact_designs_have_zero_stats() {
        for s in [abcd1_stats(&ExactAbcd1), abcd1_stats(&DualQuality1Abcd1)] {
            assert_eq!(s.error_probability, 0.0, "{}", s.name);
            assert_eq!(s.mean_error, 0.0, "{}", s.name);
        }
    }
}

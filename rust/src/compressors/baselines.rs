//! Baseline compressors the paper compares against (Table 2 columns
//! AC1..AC5 and the 4:2 designs of refs. [1] and [7]).
//!
//! Functional behaviour is taken verbatim from the paper's Table 2
//! `S_aprx` columns (which are fully legible); the circuits are minimal
//! two-level realisations of those truth tables, matching the published
//! schematics of Fig. 2 where those are known:
//!
//! | design | S_aprx over (A,B,C)=000..111 | realisation |
//! |---|---|---|
//! | AC1 [4]  | 1,2,2,2,2,2,2,2 | Carry=A|B|C, Sum=NOR(A,B,C) |
//! | AC2 [5]  | 1,1,1,3,2,3,3,2 | Carry=A·(B|C)... see below |
//! | AC3 [12] | 1,2,2,3,1,2,2,3 | stacking: ignores A |
//! | AC4 [3]  | 3,3,3,3,2,3,3,2 | Carry≡1, Sum=NAND(A,XNOR(B,C)) |
//! | AC5 [2]  | 2,2,2,2,2,3,3,3 | Carry≡1, Sum=A·(B|C) |
//!
//! Probabilities of the table rows follow P(A)=3/4, P(B)=P(C)=1/4.

use super::traits::{Abc1Compressor, Abcd1Compressor, OutBit};
use crate::netlist::{Netlist, SigId};

/// AC1 — Esposito et al., TCAS-I 2018 (paper ref. [4]).
/// `S_aprx = 1,2,2,2,2,2,2,2`: Carry = A|B|C, Sum = NOR(A,B,C).
pub struct Ac1Esposito4;

impl Abc1Compressor for Ac1Esposito4 {
    fn name(&self) -> &'static str {
        "AC1 [4]"
    }

    fn value(&self, a: bool, b: bool, c: bool) -> u8 {
        let carry = a | b | c;
        let sum = !(a | b | c);
        2 * carry as u8 + sum as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId) -> Vec<OutBit> {
        let carry = n.or3(a, b, c);
        let sum = n.nor3(a, b, c);
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: carry },
        ]
    }
}

/// AC2 — Guo, Sun, Kimura, SOCC 2019 (paper ref. [5]).
/// `S_aprx = 1,1,1,3,2,3,3,2`:
/// Carry = A | (B & C), Sum = NAND(A, XNOR(B,C)).
pub struct Ac2Guo5;

impl Abc1Compressor for Ac2Guo5 {
    fn name(&self) -> &'static str {
        "AC2 [5]"
    }

    fn value(&self, a: bool, b: bool, c: bool) -> u8 {
        let carry = a | (b & c);
        let sum = !(a & !(b ^ c));
        2 * carry as u8 + sum as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId) -> Vec<OutBit> {
        let bc = n.and2(b, c);
        let carry = n.or2(a, bc);
        let x = n.xnor2(b, c);
        let sum = n.nand2(a, x);
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: carry },
        ]
    }
}

/// AC3 — Strollo et al., TCAS-I 2020 (paper ref. [12]), the stacking-logic
/// design: drops the negative input entirely.
/// `S_aprx = 1,2,2,3,1,2,2,3`: Carry = B|C, Sum = XNOR(B,C).
pub struct Ac3Strollo12;

impl Abc1Compressor for Ac3Strollo12 {
    fn name(&self) -> &'static str {
        "AC3 [12]"
    }

    fn value(&self, _a: bool, b: bool, c: bool) -> u8 {
        let carry = b | c;
        let sum = !(b ^ c);
        2 * carry as u8 + sum as u8
    }

    fn build(&self, n: &mut Netlist, _a: SigId, b: SigId, c: SigId) -> Vec<OutBit> {
        let carry = n.or2(b, c);
        let sum = n.xnor2(b, c);
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: carry },
        ]
    }
}

/// AC4 — Du et al., OJCAS 2024 (paper ref. [3]): Carry kept constant 1,
/// error pushed into Sum. `S_aprx = 3,3,3,3,2,3,3,2`:
/// Sum = NAND(A, XNOR(B,C)).
pub struct Ac4Du3;

impl Abc1Compressor for Ac4Du3 {
    fn name(&self) -> &'static str {
        "AC4 [3]"
    }

    fn value(&self, a: bool, b: bool, c: bool) -> u8 {
        let sum = !(a & !(b ^ c));
        2 + sum as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId) -> Vec<OutBit> {
        let x = n.xnor2(b, c);
        let sum = n.nand2(a, x);
        let k1 = n.const1();
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: k1 },
        ]
    }
}

/// AC5 — Du et al., APCCAS 2022 (paper ref. [2]): Carry constant 1,
/// `S_aprx = 2,2,2,2,2,3,3,3`: Sum = A & (B|C).
pub struct Ac5Du2;

impl Abc1Compressor for Ac5Du2 {
    fn name(&self) -> &'static str {
        "AC5 [2]"
    }

    fn value(&self, a: bool, b: bool, c: bool) -> u8 {
        let sum = a & (b | c);
        2 + sum as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId) -> Vec<OutBit> {
        let bc = n.or2(b, c);
        let sum = n.and2(a, bc);
        let k1 = n.const1();
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: k1 },
        ]
    }
}

/// Ref. [1] — Akbari et al., TVLSI 2017: dual-quality 4:2 compressor,
/// operated in its *accurate* mode for the CSP (the configuration the
/// paper's Table 4 row implies: lowest ER of the baselines). Exact
/// `A+B+C+D+1` function at full 4:2 cost plus the mode mux overhead.
pub struct DualQuality1Abcd1;

impl Abcd1Compressor for DualQuality1Abcd1 {
    fn name(&self) -> &'static str {
        "DQ4:2 [1]"
    }

    fn value(&self, a: bool, b: bool, c: bool, d: bool) -> u8 {
        1 + a as u8 + b as u8 + c as u8 + d as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId, d: SigId) -> Vec<OutBit> {
        // exact core (same function as ExactAbcd1) plus the dual-quality
        // bypass muxes that make the cell switchable at runtime — the area
        // overhead the paper's Table 5 row reflects.
        let outs = super::exact::ExactAbcd1.build(n, a, b, c, d);
        let approx_sum = n.or2(a, b); // the "low-quality" path exists in cell
        let mode = n.const1(); // accurate mode selected
        let sum = n.mux2(mode, approx_sum, outs[0].sig);
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 1, sig: outs[1].sig },
            OutBit { rel_weight: 2, sig: outs[2].sig },
        ]
    }
}

/// Ref. [1]'s dual-quality cell switched to its *approximate* part (the
/// configuration the paper's Table-4 row errs with): both halves collapse
/// to OR terms — `Sum = A|B`, `Carry = C|D`, constant `+1`. Errors are
/// `−(A&B) − 2·(C&D)`, i.e. only when a pair is doubly set.
pub struct DualQualityApprox1Abcd1;

impl Abcd1Compressor for DualQualityApprox1Abcd1 {
    fn name(&self) -> &'static str {
        "DQ4:2lq [1]"
    }

    fn value(&self, a: bool, b: bool, c: bool, d: bool) -> u8 {
        1 + (a | b) as u8 + 2 * (c | d) as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId, d: SigId) -> Vec<OutBit> {
        let sum = n.or2(a, b);
        let carry = n.or2(c, d);
        let k1 = n.const1();
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 0, sig: k1 },
            OutBit { rel_weight: 1, sig: carry },
        ]
    }
}

/// Ref. [7] — Krishna et al., ESL 2024: probability-based approximate 4:2
/// compressor. Sum is the exact parity; Carry keeps only the in-pair AND
/// terms, erring by −2 exactly when both pairs are half-full. Fitted into
/// the sign-focused slot the constant `+1` rides along unchanged.
pub struct ProbBased7Abcd1;

impl Abcd1Compressor for ProbBased7Abcd1 {
    fn name(&self) -> &'static str {
        "PB4:2 [7]"
    }

    fn value(&self, a: bool, b: bool, c: bool, d: bool) -> u8 {
        let sum = a ^ b ^ c ^ d;
        // in-pair AND terms only: misses the cross-pair case (n=2 with one
        // bit in each pair) — the design's four error combinations
        let carry = (a & b) | (c & d);
        let cout = a & b & c & d;
        1 + 2 * carry as u8 + sum as u8 + 2 * cout as u8
    }

    fn build(&self, n: &mut Netlist, a: SigId, b: SigId, c: SigId, d: SigId) -> Vec<OutBit> {
        let p_ab = n.xor2(a, b);
        let p_cd = n.xor2(c, d);
        let sum = n.xor2(p_ab, p_cd);
        let ab = n.and2(a, b);
        let cd = n.and2(c, d);
        let carry = n.or2(ab, cd);
        let cout = n.and2(ab, cd);
        let k1 = n.const1();
        vec![
            OutBit { rel_weight: 0, sig: sum },
            OutBit { rel_weight: 0, sig: k1 },
            OutBit { rel_weight: 1, sig: carry },
            OutBit { rel_weight: 1, sig: cout },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::traits::{check_abc1, check_abcd1};

    /// Paper Table 2 `S_aprx` columns, rows (A,B,C) = 000..111 in printed
    /// order (Const=1 throughout).
    #[test]
    fn table2_saprx_columns_match_paper() {
        let rows: [(bool, bool, bool); 8] = [
            (false, false, false),
            (false, false, true),
            (false, true, false),
            (false, true, true),
            (true, false, false),
            (true, false, true),
            (true, true, false),
            (true, true, true),
        ];
        let ac1 = [1, 2, 2, 2, 2, 2, 2, 2];
        let ac2 = [1, 1, 1, 3, 2, 3, 3, 2];
        let ac3 = [1, 2, 2, 3, 1, 2, 2, 3];
        let ac4 = [3, 3, 3, 3, 2, 3, 3, 2];
        let ac5 = [2, 2, 2, 2, 2, 3, 3, 3];
        for (i, &(a, b, c)) in rows.iter().enumerate() {
            assert_eq!(Ac1Esposito4.value(a, b, c), ac1[i], "AC1 row {i}");
            assert_eq!(Ac2Guo5.value(a, b, c), ac2[i], "AC2 row {i}");
            assert_eq!(Ac3Strollo12.value(a, b, c), ac3[i], "AC3 row {i}");
            assert_eq!(Ac4Du3.value(a, b, c), ac4[i], "AC4 row {i}");
            assert_eq!(Ac5Du2.value(a, b, c), ac5[i], "AC5 row {i}");
        }
    }

    #[test]
    fn all_baseline_netlists_match_models() {
        check_abc1(&Ac1Esposito4).unwrap();
        check_abc1(&Ac2Guo5).unwrap();
        check_abc1(&Ac3Strollo12).unwrap();
        check_abc1(&Ac4Du3).unwrap();
        check_abc1(&Ac5Du2).unwrap();
        check_abcd1(&DualQuality1Abcd1).unwrap();
        check_abcd1(&ProbBased7Abcd1).unwrap();
    }

    #[test]
    fn dual_quality_accurate_mode_is_exact() {
        use crate::compressors::traits::Abcd1Compressor;
        assert!(DualQuality1Abcd1.is_exact());
    }

    #[test]
    fn prob_based_errs_only_on_cross_pairs() {
        // err = value - (1+n); nonzero exactly when both pairs half-full
        for bits in 0..16u8 {
            let (a, b, c, d) =
                (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0, bits & 8 != 0);
            let exact = 1 + a as i8 + b as i8 + c as i8 + d as i8;
            let err = ProbBased7Abcd1.value(a, b, c, d) as i8 - exact;
            let cross = (a ^ b) & (c ^ d);
            if cross {
                assert_eq!(err, -2, "bits {bits:04b}");
            } else {
                assert_eq!(err, 0, "bits {bits:04b}");
            }
        }
    }
}

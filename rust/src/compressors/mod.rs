//! Sign-focused compressor library (paper §2.1, §3.1 — Tables 2 and 3).
//!
//! A *sign-focused* compressor sums a negative (NAND-generated) partial
//! product `A`, positive (AND-generated) partial products `B, C(, D)`, and
//! the constant logic `1` that the Baugh-Wooley matrix places in the CSP
//! columns: `A+B+C+1` (3:2-shaped) or `A+B+C+D+1` (4:2-shaped).
//!
//! Each design exists in two coupled forms that are cross-checked
//! exhaustively in tests:
//!
//! * a **functional model** (`value(..) -> u8`, the column value the
//!   compressor's outputs encode) used by the fast multiplier models and
//!   the error harness, and
//! * a **netlist builder** used by the hardware (area/power/delay) model.
//!
//! Input probability model (paper Table 2): `A` is produced by a NAND gate
//! of two independent uniform bits, so `P(A=1)=3/4`; `B,C,D` by AND gates,
//! so `P(=1)=1/4`. [`stats`] computes the error probability `P_E` and mean
//! error `E_mean` of every design under this distribution — reproducing the
//! bottom rows of Table 2 and the Table 3 analysis.

pub mod traits;
pub mod exact;
pub mod proposed;
pub mod baselines;
pub mod stats;

pub use stats::{abc1_stats, abcd1_stats, CompressorStats};
pub use traits::{Abc1Compressor, Abcd1Compressor, OutBit};

use std::sync::Arc;

/// Every `A+B+C+1` design of paper Table 2, in table order.
pub fn all_abc1_designs() -> Vec<Arc<dyn Abc1Compressor>> {
    vec![
        Arc::new(exact::ExactAbc1),
        Arc::new(baselines::Ac1Esposito4),
        Arc::new(baselines::Ac2Guo5),
        Arc::new(baselines::Ac3Strollo12),
        Arc::new(baselines::Ac4Du3),
        Arc::new(baselines::Ac5Du2),
        Arc::new(proposed::ProposedApproxAbc1),
    ]
}

/// Every `A+B+C+D+1` design (proposed exact/approx, ablation candidates,
/// and the 4:2-derived baselines of paper refs. [1] and [7]).
pub fn all_abcd1_designs() -> Vec<Arc<dyn Abcd1Compressor>> {
    vec![
        Arc::new(exact::ExactAbcd1),
        Arc::new(proposed::ProposedApproxAbcd1),
        Arc::new(proposed::AblationAbcd1Gated),
        Arc::new(proposed::AblationAbcd1Parity),
        Arc::new(proposed::AblationAbcd1OrSum),
        Arc::new(baselines::DualQuality1Abcd1),
        Arc::new(baselines::ProbBased7Abcd1),
    ]
}

//! The `SFC/1` wire protocol: one ASCII header line per frame, then a
//! binary payload whose length is implied by the header dimensions.
//!
//! A client holds one TCP connection and streams frames — the video
//! story: repeated edge/infer jobs over a single connection, with the
//! server reusing its receive buffers between frames. The same listener
//! also answers plain HTTP/1.1 (`GET /metrics`); the dispatcher sniffs
//! the first header token (see [`crate::server::http::is_http`]).
//!
//! Request grammar (tokens are space-separated, line ends with `\n`):
//!
//! ```text
//! EDGE w=W h=H [engine=NAME] [op=OP]\n   + W*H bytes   (u8 pixels, row-major)
//! GEMM m=M k=K n=N [engine=NAME]\n       + M*K + K*N bytes (i8 A then i8 B, row-major)
//! METRICS\n
//! TRACE\n
//! PING\n
//! QUIT\n
//! ```
//!
//! Responses:
//!
//! ```text
//! OK w=W h=H latency_us=L\n   + W*H bytes            (EDGE)
//! OK m=M n=N latency_us=L\n   + M*N*4 bytes i32 LE   (GEMM)
//! OK bytes=B\n                + B bytes of text      (METRICS / TRACE)
//! OK pong\n                                          (PING)
//! OK bye\n                                           (QUIT; server closes)
//! ERR <code> <message>\n                             (any request)
//! ```
//!
//! Error codes ([`ErrCode`]): `bad-request`, `unknown-engine`,
//! `unsupported`, `busy` (in-flight bound reached — the 429 analogue),
//! `quota` (per-client token bucket empty), `engine-failed` (the serving
//! engine panicked or its circuit breaker is open — transient, worth a
//! retry), `deadline` (the job exceeded the server's per-job deadline),
//! `shutting-down`, `internal`. A denied job frame consumes its payload
//! first, and a job that fails *after* admission sends a bare `ERR` line
//! in place of its `OK` + payload — either way the connection stays
//! framed and usable: clients get a clean error line, never a hang or a
//! desync.

use crate::image::ops::Operator;
use std::io::Read;
use std::time::{Duration, Instant};

/// Longest accepted header line (bytes, excluding the terminator).
pub const MAX_HEADER_BYTES: usize = 4096;
/// Largest accepted edge frame (pixels) — 16 Mpix bounds a single
/// frame's allocation at 16 MiB.
pub const MAX_EDGE_PIXELS: usize = 1 << 24;
/// Largest accepted GEMM dimension.
pub const MAX_GEMM_DIM: usize = 1 << 15;
/// Largest accepted combined GEMM operand payload (bytes).
pub const MAX_GEMM_PAYLOAD: usize = 1 << 26;

/// One parsed request frame header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    Ping,
    Quit,
    Metrics,
    /// Dump the coordinator's trace ring as Chrome trace-event JSON
    /// (empty document when tracing is disabled).
    Trace,
    Edge { w: usize, h: usize, engine: Option<String>, op: Operator },
    Gemm { m: usize, k: usize, n: usize, engine: Option<String> },
}

impl Request {
    /// Payload bytes that follow this header on the wire.
    pub fn payload_len(&self) -> usize {
        match self {
            Request::Edge { w, h, .. } => w * h,
            Request::Gemm { m, k, n, .. } => m * k + k * n,
            _ => 0,
        }
    }
}

/// Machine-readable error class carried on `ERR` lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    BadRequest,
    UnknownEngine,
    Unsupported,
    Busy,
    Quota,
    /// The serving engine failed the job (panic caught by the worker, or
    /// an open circuit breaker with no usable fallback). Transient from
    /// the client's point of view: a retry may land on a healthy engine
    /// or a recovered breaker.
    EngineFailed,
    /// The job exceeded the server-side per-job deadline.
    Deadline,
    ShuttingDown,
    Internal,
}

impl ErrCode {
    pub fn key(self) -> &'static str {
        match self {
            ErrCode::BadRequest => "bad-request",
            ErrCode::UnknownEngine => "unknown-engine",
            ErrCode::Unsupported => "unsupported",
            ErrCode::Busy => "busy",
            ErrCode::Quota => "quota",
            ErrCode::EngineFailed => "engine-failed",
            ErrCode::Deadline => "deadline",
            ErrCode::ShuttingDown => "shutting-down",
            ErrCode::Internal => "internal",
        }
    }
}

impl std::fmt::Display for ErrCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key())
    }
}

/// Parse one request header line. The error string is the human-readable
/// message the server sends back as `ERR bad-request <message>`.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let mut toks = line.split_ascii_whitespace();
    let verb = toks.next().ok_or("empty request line")?;
    let mut kv: Vec<(&str, &str)> = Vec::new();
    for t in toks {
        let (k, v) = t
            .split_once('=')
            .ok_or_else(|| format!("malformed token {t:?} (expected key=value)"))?;
        kv.push((k, v));
    }
    let get = |key: &str| kv.iter().find(|(k, _)| *k == key).map(|(_, v)| *v);
    let dim = |key: &str| -> Result<usize, String> {
        get(key)
            .ok_or_else(|| format!("{verb} needs {key}="))?
            .parse::<usize>()
            .map_err(|e| format!("bad {key}=: {e}"))
    };
    match verb {
        "PING" => Ok(Request::Ping),
        "QUIT" => Ok(Request::Quit),
        "METRICS" => Ok(Request::Metrics),
        "TRACE" => Ok(Request::Trace),
        "EDGE" => {
            let (w, h) = (dim("w")?, dim("h")?);
            if w == 0 || h == 0 {
                return Err("EDGE needs w>0 and h>0".into());
            }
            if w.saturating_mul(h) > MAX_EDGE_PIXELS {
                return Err(format!("EDGE frame {w}x{h} exceeds {MAX_EDGE_PIXELS} pixels"));
            }
            let op = match get("op") {
                None => Operator::Laplacian,
                Some(s) => s.parse::<Operator>().map_err(|e| format!("bad op=: {e}"))?,
            };
            Ok(Request::Edge { w, h, engine: get("engine").map(String::from), op })
        }
        "GEMM" => {
            let (m, k, n) = (dim("m")?, dim("k")?, dim("n")?);
            if m.max(k).max(n) > MAX_GEMM_DIM {
                return Err(format!("GEMM dims {m}x{k}x{n} exceed {MAX_GEMM_DIM}"));
            }
            if m * k + k * n > MAX_GEMM_PAYLOAD {
                return Err(format!(
                    "GEMM operand payload {} exceeds {MAX_GEMM_PAYLOAD} bytes",
                    m * k + k * n
                ));
            }
            Ok(Request::Gemm { m, k, n, engine: get("engine").map(String::from) })
        }
        other => Err(format!("unknown verb {other:?}")),
    }
}

/// Header-line builders — shared by [`crate::server::client::Client`]
/// and the tests, so both sides of the wire agree on the grammar.
pub fn edge_header(w: usize, h: usize, engine: Option<&str>, op: Operator) -> String {
    let engine = engine.map(|e| format!(" engine={e}")).unwrap_or_default();
    format!("EDGE w={w} h={h} op={}{engine}\n", op.key())
}

pub fn gemm_header(m: usize, k: usize, n: usize, engine: Option<&str>) -> String {
    let engine = engine.map(|e| format!(" engine={e}")).unwrap_or_default();
    format!("GEMM m={m} k={k} n={n}{engine}\n")
}

/// Outcome of one non-blocking line poll (see [`FrameReader::poll_line`]).
#[derive(Debug)]
pub enum LineRead {
    /// A complete header line (terminator stripped, `\r\n` tolerated).
    Line(String),
    /// Clean end of stream at a frame boundary.
    Eof,
    /// The read timed out. `partial` is true when header bytes are
    /// already buffered (a client mid-send) — the caller should keep
    /// waiting; with `partial == false` the connection is idle and may
    /// be closed for drain.
    Idle { partial: bool },
}

/// Buffered frame reader over a byte stream. Owns the receive buffer,
/// which is reused across frames on a long-lived streaming connection —
/// the server-side buffer-reuse half of the video story.
///
/// Timeout-aware: when the underlying socket carries a read timeout,
/// [`FrameReader::poll_line`] surfaces idleness instead of failing, so
/// the connection handler can poll its shutdown flag between frames.
pub struct FrameReader {
    buf: Vec<u8>,
    start: usize,
}

impl FrameReader {
    pub fn new() -> Self {
        Self { buf: Vec::with_capacity(1024), start: 0 }
    }

    fn compact(&mut self) {
        if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }

    fn pending(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Poll for the next header line. Returns [`LineRead::Idle`] on a
    /// read timeout (socket `WouldBlock`/`TimedOut`), so a blocking
    /// socket without a timeout never observes it.
    pub fn poll_line(&mut self, r: &mut impl Read) -> std::io::Result<LineRead> {
        loop {
            if let Some(pos) = self.buf[self.start..].iter().position(|&b| b == b'\n') {
                let end = self.start + pos;
                let mut line_bytes = &self.buf[self.start..end];
                if line_bytes.last() == Some(&b'\r') {
                    line_bytes = &line_bytes[..line_bytes.len() - 1];
                }
                let line = std::str::from_utf8(line_bytes)
                    .map_err(|_| {
                        std::io::Error::new(
                            std::io::ErrorKind::InvalidData,
                            "non-UTF-8 header line",
                        )
                    })?
                    .to_string();
                self.start = end + 1;
                return Ok(LineRead::Line(line));
            }
            if self.pending() > MAX_HEADER_BYTES {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "header line too long",
                ));
            }
            self.compact();
            let mut tmp = [0u8; 4096];
            match r.read(&mut tmp) {
                Ok(0) => {
                    return if self.buf.is_empty() {
                        Ok(LineRead::Eof)
                    } else {
                        Err(std::io::Error::new(
                            std::io::ErrorKind::UnexpectedEof,
                            "connection closed mid-header",
                        ))
                    };
                }
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Ok(LineRead::Idle { partial: !self.buf.is_empty() });
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
    }

    /// Read exactly `out.len()` payload bytes, draining any bytes already
    /// buffered behind the header first. Once a header has arrived the
    /// frame is always finished (drain semantics), but a peer that goes
    /// silent mid-payload for longer than `max_idle` (consecutively)
    /// errors out instead of pinning the handler forever.
    pub fn read_exact_payload(
        &mut self,
        r: &mut impl Read,
        out: &mut [u8],
        max_idle: Duration,
    ) -> std::io::Result<()> {
        let take = self.pending().min(out.len());
        if take > 0 {
            out[..take].copy_from_slice(&self.buf[self.start..self.start + take]);
            self.start += take;
            if self.start == self.buf.len() {
                self.buf.clear();
                self.start = 0;
            }
        }
        let mut filled = take;
        let mut idle_since: Option<Instant> = None;
        while filled < out.len() {
            match r.read(&mut out[filled..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "connection closed mid-payload",
                    ));
                }
                Ok(n) => {
                    filled += n;
                    idle_since = None;
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    let t = *idle_since.get_or_insert_with(Instant::now);
                    if t.elapsed() > max_idle {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            "peer stalled mid-payload",
                        ));
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }
}

impl Default for FrameReader {
    fn default() -> Self {
        Self::new()
    }
}

/// Parse the `k=v` tokens of an `OK` response line (client side).
pub fn parse_ok_fields(line: &str) -> Vec<(String, String)> {
    line.split_ascii_whitespace()
        .skip(1) // "OK"
        .filter_map(|t| t.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_verb() {
        assert_eq!(parse_request("PING").unwrap(), Request::Ping);
        assert_eq!(parse_request("QUIT").unwrap(), Request::Quit);
        assert_eq!(parse_request("METRICS").unwrap(), Request::Metrics);
        assert_eq!(parse_request("TRACE").unwrap(), Request::Trace);
        assert_eq!(Request::Trace.payload_len(), 0);
        let e = parse_request("EDGE w=64 h=48 engine=proposed@8 op=sobel").unwrap();
        assert_eq!(
            e,
            Request::Edge {
                w: 64,
                h: 48,
                engine: Some("proposed@8".into()),
                op: Operator::Sobel
            }
        );
        assert_eq!(e.payload_len(), 64 * 48);
        let g = parse_request("GEMM m=3 k=5 n=7").unwrap();
        assert_eq!(g, Request::Gemm { m: 3, k: 5, n: 7, engine: None });
        assert_eq!(g.payload_len(), 3 * 5 + 5 * 7);
    }

    #[test]
    fn header_builders_roundtrip_through_parse() {
        let h = edge_header(10, 20, Some("exact@8"), Operator::Roberts);
        assert_eq!(
            parse_request(h.trim_end()).unwrap(),
            Request::Edge { w: 10, h: 20, engine: Some("exact@8".into()), op: Operator::Roberts }
        );
        let h = gemm_header(4, 6, 8, None);
        assert_eq!(
            parse_request(h.trim_end()).unwrap(),
            Request::Gemm { m: 4, k: 6, n: 8, engine: None }
        );
    }

    #[test]
    fn rejects_malformed_and_oversized() {
        assert!(parse_request("").is_err());
        assert!(parse_request("NOPE").is_err());
        assert!(parse_request("EDGE w=4").is_err(), "missing h=");
        assert!(parse_request("EDGE w=0 h=4").is_err(), "zero dim");
        assert!(parse_request("EDGE w=99999999 h=99999999").is_err(), "pixel bound");
        assert!(parse_request("EDGE w=4 h=4 op=nope").is_err(), "unknown operator");
        assert!(parse_request("EDGE w=4 h=4 junk").is_err(), "non-k=v token");
        assert!(parse_request("GEMM m=4 k=5").is_err(), "missing n=");
        assert!(parse_request("GEMM m=40000 k=2 n=2").is_err(), "dim bound");
    }

    #[test]
    fn frame_reader_splits_lines_and_payload() {
        let wire = b"EDGE w=2 h=2\nABCDPING\r\n".to_vec();
        let mut cur = std::io::Cursor::new(wire);
        let mut fr = FrameReader::new();
        match fr.poll_line(&mut cur).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "EDGE w=2 h=2"),
            other => panic!("{other:?}"),
        }
        let mut payload = [0u8; 4];
        fr.read_exact_payload(&mut cur, &mut payload, Duration::from_secs(1)).unwrap();
        assert_eq!(&payload, b"ABCD");
        match fr.poll_line(&mut cur).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "PING", "CRLF stripped"),
            other => panic!("{other:?}"),
        }
        assert!(matches!(fr.poll_line(&mut cur).unwrap(), LineRead::Eof));
    }

    #[test]
    fn frame_reader_rejects_unterminated_monster_header() {
        let wire = vec![b'x'; MAX_HEADER_BYTES + 10];
        let mut cur = std::io::Cursor::new(wire);
        let mut fr = FrameReader::new();
        assert!(fr.poll_line(&mut cur).is_err());
    }

    #[test]
    fn eof_mid_header_is_an_error_not_a_clean_close() {
        let mut cur = std::io::Cursor::new(b"EDGE w=2".to_vec());
        let mut fr = FrameReader::new();
        assert!(fr.poll_line(&mut cur).is_err());
    }

    #[test]
    fn ok_field_parse() {
        let f = parse_ok_fields("OK w=3 h=4 latency_us=120");
        assert_eq!(f[0], ("w".into(), "3".into()));
        assert_eq!(f[2], ("latency_us".into(), "120".into()));
    }
}

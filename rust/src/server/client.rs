//! Blocking client for the `SFC/1` job protocol — used by the
//! `load_gen` example, the socket tests, and anyone scripting the
//! server without speaking raw frames.
//!
//! A [`Client`] wraps one TCP connection and streams frames over it
//! (the connection-reuse half of the streaming story). All calls are
//! synchronous: submit one frame, block for its reply. The socket
//! carries no read timeout, so [`super::protocol::LineRead::Idle`] is
//! never observed here.

use crate::image::ops::Operator;
use crate::image::Image;
use crate::nn::{MatI32, MatI8};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::protocol::{self, FrameReader, LineRead};

/// A stall bound for payload reads; effectively "wait for the server".
const CLIENT_PAYLOAD_IDLE: Duration = Duration::from_secs(3600);

/// Errors a client call can surface.
#[derive(Debug)]
pub enum ClientError {
    /// Transport-level failure.
    Io(std::io::Error),
    /// The server answered with an `ERR` line; `code` is the
    /// machine-readable class (`busy`, `quota`, `unknown-engine`, ...).
    Server { code: String, message: String },
    /// The server's reply did not follow the protocol.
    Protocol(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Server { code, message } => write!(f, "server error [{code}]: {message}"),
            ClientError::Protocol(m) => write!(f, "protocol violation: {m}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

/// The result of one served edge frame.
pub struct EdgeReply {
    pub edges: Image,
    /// Server-side job latency (queue + compute), as reported on the
    /// `OK` line.
    pub latency_us: u64,
}

/// The result of one served GEMM frame.
pub struct GemmReply {
    pub out: MatI32,
    pub latency_us: u64,
}

/// One streaming connection to a serving front-end.
pub struct Client {
    sock: TcpStream,
    reader: FrameReader,
}

impl Client {
    /// Connect to a server address (anything [`ToSocketAddrs`], e.g. a
    /// [`SocketAddr`] or `"127.0.0.1:7878"`).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self, ClientError> {
        let sock = TcpStream::connect(addr)?;
        sock.set_nodelay(true)?;
        Ok(Self { sock, reader: FrameReader::new() })
    }

    fn read_line(&mut self) -> Result<String, ClientError> {
        loop {
            match self.reader.poll_line(&mut self.sock)? {
                LineRead::Line(l) => return Ok(l),
                LineRead::Eof => {
                    return Err(ClientError::Protocol("server closed the connection".into()))
                }
                LineRead::Idle { .. } => continue, // no read timeout set; defensive
            }
        }
    }

    /// Read a reply line, splitting `ERR` answers into [`ClientError::Server`].
    fn read_ok(&mut self) -> Result<String, ClientError> {
        let line = self.read_line()?;
        if let Some(rest) = line.strip_prefix("ERR ") {
            let (code, message) = rest.split_once(' ').unwrap_or((rest, ""));
            return Err(ClientError::Server { code: code.into(), message: message.into() });
        }
        if line == "OK" || line.starts_with("OK ") {
            Ok(line)
        } else {
            Err(ClientError::Protocol(format!("expected OK/ERR, got {line:?}")))
        }
    }

    fn field(line: &str, key: &str) -> Result<u64, ClientError> {
        protocol::parse_ok_fields(line)
            .into_iter()
            .find(|(k, _)| k == key)
            .ok_or_else(|| ClientError::Protocol(format!("missing {key}= in {line:?}")))?
            .1
            .parse::<u64>()
            .map_err(|e| ClientError::Protocol(format!("bad {key}= in {line:?}: {e}")))
    }

    fn read_payload(&mut self, len: usize) -> Result<Vec<u8>, ClientError> {
        let mut buf = vec![0u8; len];
        self.reader.read_exact_payload(&mut self.sock, &mut buf, CLIENT_PAYLOAD_IDLE)?;
        Ok(buf)
    }

    /// Liveness round trip.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        self.sock.write_all(b"PING\n")?;
        self.read_ok().map(|_| ())
    }

    /// Submit one edge-detection frame and block for the result.
    pub fn edge(
        &mut self,
        img: &Image,
        engine: Option<&str>,
        op: Operator,
    ) -> Result<EdgeReply, ClientError> {
        let header = protocol::edge_header(img.width, img.height, engine, op);
        self.sock.write_all(header.as_bytes())?;
        self.sock.write_all(&img.data)?;
        let line = self.read_ok()?;
        let (w, h) = (Self::field(&line, "w")? as usize, Self::field(&line, "h")? as usize);
        let latency_us = Self::field(&line, "latency_us")?;
        let data = self.read_payload(w * h)?;
        Ok(EdgeReply { edges: Image { width: w, height: h, data }, latency_us })
    }

    /// Submit one quantized GEMM (`C = A × B`) and block for the result.
    pub fn gemm(
        &mut self,
        a: &MatI8,
        b: &MatI8,
        engine: Option<&str>,
    ) -> Result<GemmReply, ClientError> {
        let header = protocol::gemm_header(a.rows, a.cols, b.cols, engine);
        self.sock.write_all(header.as_bytes())?;
        let mut payload = Vec::with_capacity(a.data.len() + b.data.len());
        payload.extend(a.data.iter().map(|&v| v as u8));
        payload.extend(b.data.iter().map(|&v| v as u8));
        self.sock.write_all(&payload)?;
        let line = self.read_ok()?;
        let (m, n) = (Self::field(&line, "m")? as usize, Self::field(&line, "n")? as usize);
        let latency_us = Self::field(&line, "latency_us")?;
        let bytes = self.read_payload(m * n * 4)?;
        let mut out = MatI32::new(m, n);
        for (dst, chunk) in out.data.iter_mut().zip(bytes.chunks_exact(4)) {
            *dst = i32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(GemmReply { out, latency_us })
    }

    /// Fetch the metrics text over the job protocol (`METRICS` frame).
    pub fn metrics_text(&mut self) -> Result<String, ClientError> {
        self.sock.write_all(b"METRICS\n")?;
        let line = self.read_ok()?;
        let len = Self::field(&line, "bytes")? as usize;
        let bytes = self.read_payload(len)?;
        String::from_utf8(bytes)
            .map_err(|_| ClientError::Protocol("metrics text is not UTF-8".into()))
    }

    /// Polite goodbye; the server closes the connection after replying.
    pub fn quit(mut self) -> Result<(), ClientError> {
        self.sock.write_all(b"QUIT\n")?;
        self.read_ok().map(|_| ())
    }
}

/// One-shot HTTP GET against the same listener (e.g. `/metrics`,
/// `/healthz`). Returns (status code, body).
pub fn http_get(addr: SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    let mut sock = TcpStream::connect(addr)?;
    sock.write_all(format!("GET {path} HTTP/1.1\r\nHost: sfcmul\r\n\r\n").as_bytes())?;
    let mut raw = Vec::new();
    sock.read_to_end(&mut raw)?; // server sends Connection: close
    let text = String::from_utf8_lossy(&raw);
    let mut lines = text.splitn(2, "\r\n\r\n");
    let head = lines.next().unwrap_or("");
    let body = lines.next().unwrap_or("").to_string();
    let status = head
        .split_ascii_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "malformed status line")
        })?;
    Ok((status, body))
}

//! Blocking client for the `SFC/1` job protocol — used by the
//! `load_gen` example, the socket tests, and anyone scripting the
//! server without speaking raw frames.
//!
//! A [`Client`] wraps one TCP connection and streams frames over it
//! (the connection-reuse half of the streaming story). All calls are
//! synchronous: submit one frame, block for its reply. The socket
//! carries no read timeout, so [`super::protocol::LineRead::Idle`] is
//! never observed here.

use crate::image::ops::Operator;
use crate::image::Image;
use crate::nn::{MatI32, MatI8};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::protocol::{self, FrameReader, LineRead};

/// A stall bound for payload reads; effectively "wait for the server".
const CLIENT_PAYLOAD_IDLE: Duration = Duration::from_secs(3600);

/// Errors a client call can surface.
#[derive(Debug)]
pub enum ClientError {
    /// Transport-level failure.
    Io(std::io::Error),
    /// The server answered with an `ERR` line; `code` is the
    /// machine-readable class (`busy`, `quota`, `unknown-engine`, ...).
    Server { code: String, message: String },
    /// The server's reply did not follow the protocol.
    Protocol(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Server { code, message } => write!(f, "server error [{code}]: {message}"),
            ClientError::Protocol(m) => write!(f, "protocol violation: {m}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl ClientError {
    /// Is this failure worth retrying? `ERR` codes that reflect a
    /// momentary server condition — load shedding (`busy`, `quota`) or a
    /// fault-tolerance outcome (`engine-failed`: a caught panic or open
    /// breaker; `deadline`: a watchdog miss) — can succeed on a later
    /// attempt against the same healthy protocol stream. Validation
    /// errors, shutdown, transport and protocol failures are not
    /// retried.
    pub fn is_transient(&self) -> bool {
        match self {
            ClientError::Server { code, .. } => {
                matches!(code.as_str(), "busy" | "quota" | "engine-failed" | "deadline")
            }
            _ => false,
        }
    }
}

/// Deterministic exponential backoff for transient server errors: the
/// delay before attempt `i` (of `attempts` total) is `base << (i - 1)`,
/// capped at `max` — no jitter, so tests and soak runs are exactly
/// reproducible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts, including the first one (0 behaves like 1).
    pub attempts: u32,
    /// Delay before the first retry.
    pub base: Duration,
    /// Upper bound on any single delay.
    pub max: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { attempts: 4, base: Duration::from_millis(10), max: Duration::from_millis(200) }
    }
}

impl RetryPolicy {
    /// The backoff delay before retry number `retry` (1-based).
    pub fn delay(&self, retry: u32) -> Duration {
        let shift = retry.saturating_sub(1).min(20);
        let d = self.base.saturating_mul(1u32 << shift);
        d.min(self.max)
    }

    /// Run `op` under this policy: retry on
    /// [transient](ClientError::is_transient) errors with backoff,
    /// return the first success or the last error.
    pub fn run<T>(
        &self,
        mut op: impl FnMut() -> Result<T, ClientError>,
    ) -> Result<T, ClientError> {
        let attempts = self.attempts.max(1);
        let mut last_err = None;
        for attempt in 1..=attempts {
            match op() {
                Ok(v) => return Ok(v),
                Err(e) if e.is_transient() && attempt < attempts => {
                    std::thread::sleep(self.delay(attempt));
                    last_err = Some(e);
                }
                Err(e) => return Err(e),
            }
        }
        // Unreachable in practice (the loop always returns), but keep a
        // sane value rather than a panic.
        Err(last_err
            .unwrap_or_else(|| ClientError::Protocol("retry loop made no attempt".into())))
    }
}

/// The result of one served edge frame.
pub struct EdgeReply {
    pub edges: Image,
    /// Server-side job latency (queue + compute), as reported on the
    /// `OK` line.
    pub latency_us: u64,
}

/// The result of one served GEMM frame.
pub struct GemmReply {
    pub out: MatI32,
    pub latency_us: u64,
}

/// One streaming connection to a serving front-end.
pub struct Client {
    sock: TcpStream,
    reader: FrameReader,
}

impl Client {
    /// Connect to a server address (anything [`ToSocketAddrs`], e.g. a
    /// [`SocketAddr`] or `"127.0.0.1:7878"`).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self, ClientError> {
        let sock = TcpStream::connect(addr)?;
        sock.set_nodelay(true)?;
        Ok(Self { sock, reader: FrameReader::new() })
    }

    fn read_line(&mut self) -> Result<String, ClientError> {
        loop {
            match self.reader.poll_line(&mut self.sock)? {
                LineRead::Line(l) => return Ok(l),
                LineRead::Eof => {
                    return Err(ClientError::Protocol("server closed the connection".into()))
                }
                LineRead::Idle { .. } => continue, // no read timeout set; defensive
            }
        }
    }

    /// Read a reply line, splitting `ERR` answers into [`ClientError::Server`].
    fn read_ok(&mut self) -> Result<String, ClientError> {
        let line = self.read_line()?;
        if let Some(rest) = line.strip_prefix("ERR ") {
            let (code, message) = rest.split_once(' ').unwrap_or((rest, ""));
            return Err(ClientError::Server { code: code.into(), message: message.into() });
        }
        if line == "OK" || line.starts_with("OK ") {
            Ok(line)
        } else {
            Err(ClientError::Protocol(format!("expected OK/ERR, got {line:?}")))
        }
    }

    fn field(line: &str, key: &str) -> Result<u64, ClientError> {
        protocol::parse_ok_fields(line)
            .into_iter()
            .find(|(k, _)| k == key)
            .ok_or_else(|| ClientError::Protocol(format!("missing {key}= in {line:?}")))?
            .1
            .parse::<u64>()
            .map_err(|e| ClientError::Protocol(format!("bad {key}= in {line:?}: {e}")))
    }

    fn read_payload(&mut self, len: usize) -> Result<Vec<u8>, ClientError> {
        let mut buf = vec![0u8; len];
        self.reader.read_exact_payload(&mut self.sock, &mut buf, CLIENT_PAYLOAD_IDLE)?;
        Ok(buf)
    }

    /// Liveness round trip.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        self.sock.write_all(b"PING\n")?;
        self.read_ok().map(|_| ())
    }

    /// Submit one edge-detection frame and block for the result.
    pub fn edge(
        &mut self,
        img: &Image,
        engine: Option<&str>,
        op: Operator,
    ) -> Result<EdgeReply, ClientError> {
        let header = protocol::edge_header(img.width, img.height, engine, op);
        self.sock.write_all(header.as_bytes())?;
        self.sock.write_all(&img.data)?;
        let line = self.read_ok()?;
        let (w, h) = (Self::field(&line, "w")? as usize, Self::field(&line, "h")? as usize);
        let latency_us = Self::field(&line, "latency_us")?;
        let data = self.read_payload(w * h)?;
        Ok(EdgeReply { edges: Image { width: w, height: h, data }, latency_us })
    }

    /// Submit one quantized GEMM (`C = A × B`) and block for the result.
    pub fn gemm(
        &mut self,
        a: &MatI8,
        b: &MatI8,
        engine: Option<&str>,
    ) -> Result<GemmReply, ClientError> {
        let header = protocol::gemm_header(a.rows, a.cols, b.cols, engine);
        self.sock.write_all(header.as_bytes())?;
        let mut payload = Vec::with_capacity(a.data.len() + b.data.len());
        payload.extend(a.data.iter().map(|&v| v as u8));
        payload.extend(b.data.iter().map(|&v| v as u8));
        self.sock.write_all(&payload)?;
        let line = self.read_ok()?;
        let (m, n) = (Self::field(&line, "m")? as usize, Self::field(&line, "n")? as usize);
        let latency_us = Self::field(&line, "latency_us")?;
        let bytes = self.read_payload(m * n * 4)?;
        let mut out = MatI32::new(m, n);
        for (dst, chunk) in out.data.iter_mut().zip(bytes.chunks_exact(4)) {
            *dst = i32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(GemmReply { out, latency_us })
    }

    /// [`edge`](Self::edge) under a [`RetryPolicy`]: transient `ERR`
    /// replies (`busy`, `quota`, `engine-failed`, `deadline`) are
    /// retried with backoff on the same connection — the protocol
    /// guarantees an `ERR` frame never desyncs the stream, so the next
    /// attempt reuses it safely.
    pub fn edge_with_retry(
        &mut self,
        img: &Image,
        engine: Option<&str>,
        op: Operator,
        policy: RetryPolicy,
    ) -> Result<EdgeReply, ClientError> {
        policy.run(|| self.edge(img, engine, op))
    }

    /// [`gemm`](Self::gemm) under a [`RetryPolicy`] (see
    /// [`edge_with_retry`](Self::edge_with_retry)).
    pub fn gemm_with_retry(
        &mut self,
        a: &MatI8,
        b: &MatI8,
        engine: Option<&str>,
        policy: RetryPolicy,
    ) -> Result<GemmReply, ClientError> {
        policy.run(|| self.gemm(a, b, engine))
    }

    /// Fetch the metrics text over the job protocol (`METRICS` frame).
    pub fn metrics_text(&mut self) -> Result<String, ClientError> {
        self.sock.write_all(b"METRICS\n")?;
        let line = self.read_ok()?;
        let len = Self::field(&line, "bytes")? as usize;
        let bytes = self.read_payload(len)?;
        String::from_utf8(bytes)
            .map_err(|_| ClientError::Protocol("metrics text is not UTF-8".into()))
    }

    /// Fetch the coordinator's Chrome trace-event JSON over the job
    /// protocol (`TRACE` frame). An empty (but valid) document comes
    /// back when tracing is disabled server-side.
    pub fn trace_text(&mut self) -> Result<String, ClientError> {
        self.sock.write_all(b"TRACE\n")?;
        let line = self.read_ok()?;
        let len = Self::field(&line, "bytes")? as usize;
        let bytes = self.read_payload(len)?;
        String::from_utf8(bytes)
            .map_err(|_| ClientError::Protocol("trace text is not UTF-8".into()))
    }

    /// Polite goodbye; the server closes the connection after replying.
    pub fn quit(mut self) -> Result<(), ClientError> {
        self.sock.write_all(b"QUIT\n")?;
        self.read_ok().map(|_| ())
    }
}

/// One-shot HTTP GET against the same listener (e.g. `/metrics`,
/// `/healthz`). Returns (status code, body).
pub fn http_get(addr: SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    let mut sock = TcpStream::connect(addr)?;
    sock.write_all(format!("GET {path} HTTP/1.1\r\nHost: sfcmul\r\n\r\n").as_bytes())?;
    let mut raw = Vec::new();
    sock.read_to_end(&mut raw)?; // server sends Connection: close
    let text = String::from_utf8_lossy(&raw);
    let mut lines = text.splitn(2, "\r\n\r\n");
    let head = lines.next().unwrap_or("");
    let body = lines.next().unwrap_or("").to_string();
    let status = head
        .split_ascii_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "malformed status line")
        })?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server_err(code: &str) -> ClientError {
        ClientError::Server { code: code.into(), message: "m".into() }
    }

    #[test]
    fn transient_codes_are_exactly_the_retryable_ones() {
        for code in ["busy", "quota", "engine-failed", "deadline"] {
            assert!(server_err(code).is_transient(), "{code}");
        }
        for code in ["bad-request", "unknown-engine", "unsupported", "shutting-down", "internal"]
        {
            assert!(!server_err(code).is_transient(), "{code}");
        }
        assert!(!ClientError::Protocol("x".into()).is_transient());
        assert!(!ClientError::Io(std::io::Error::new(std::io::ErrorKind::Other, "x"))
            .is_transient());
    }

    #[test]
    fn backoff_doubles_and_caps_deterministically() {
        let p = RetryPolicy {
            attempts: 5,
            base: Duration::from_millis(10),
            max: Duration::from_millis(35),
        };
        assert_eq!(p.delay(1), Duration::from_millis(10));
        assert_eq!(p.delay(2), Duration::from_millis(20));
        assert_eq!(p.delay(3), Duration::from_millis(35), "capped");
        assert_eq!(p.delay(4), Duration::from_millis(35));
    }

    #[test]
    fn run_retries_transient_until_success() {
        let p = RetryPolicy {
            attempts: 4,
            base: Duration::from_millis(1),
            max: Duration::from_millis(1),
        };
        let mut calls = 0u32;
        let r = p.run(|| {
            calls += 1;
            if calls < 3 {
                Err(server_err("engine-failed"))
            } else {
                Ok(7)
            }
        });
        assert_eq!(r.unwrap(), 7);
        assert_eq!(calls, 3);
    }

    #[test]
    fn run_fails_fast_on_fatal_and_gives_up_after_attempts() {
        let p = RetryPolicy {
            attempts: 4,
            base: Duration::from_millis(1),
            max: Duration::from_millis(1),
        };
        let mut calls = 0u32;
        let r: Result<(), _> = p.run(|| {
            calls += 1;
            Err(server_err("unknown-engine"))
        });
        assert!(matches!(r, Err(ClientError::Server { ref code, .. }) if code == "unknown-engine"));
        assert_eq!(calls, 1, "fatal errors are not retried");

        let mut calls = 0u32;
        let r: Result<(), _> = p.run(|| {
            calls += 1;
            Err(server_err("busy"))
        });
        assert!(matches!(r, Err(ClientError::Server { ref code, .. }) if code == "busy"));
        assert_eq!(calls, 4, "transient errors exhaust the attempt budget");
    }
}

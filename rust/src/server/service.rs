//! The serving front-end: a `std::net` TCP listener translating wire
//! frames ([`super::protocol`]) into coordinator submissions.
//!
//! Shape: one non-blocking accept loop feeds accepted sockets into a
//! bounded connection queue drained by a fixed pool of handler threads
//! (connection-per-worker — a handler owns its connection for the
//! connection's whole life, so a streaming client gets stable
//! server-side buffers). When every handler is busy and the pending
//! queue is full, new connections are refused with `ERR busy` instead
//! of queueing unboundedly — admission control starts at accept time.
//!
//! Per frame, the handler: reads the header line (poll-style, so the
//! stop flag is observed between frames), sniffs HTTP, reads the
//! payload *before* the admission check (a denied frame must not desync
//! the stream), consults [`super::limits::Admission`], submits to the
//! coordinator, waits, replies. Job results are returned on the same
//! connection in submission order.
//!
//! Shutdown ([`Server::stop`]) is drain-first: the accept loop closes,
//! handlers finish the frame in flight (in-flight jobs complete against
//! the still-running coordinator), idle streaming connections are
//! closed politely, then the threads join. Stopping the server never
//! stops the coordinator — that stays with the owner, so the CLI can
//! print a final fleet snapshot after the listener is gone.

use crate::coordinator::{Coordinator, JobError};
use crate::image::Image;
use crate::nn::MatI8;
use crate::util::pool::{bounded, Receiver, Sender, TrySendError};
use std::io::Write;
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::http;
use super::limits::{Admission, AdmissionConfig, Deny};
use super::protocol::{self, ErrCode, FrameReader, LineRead, Request};

/// Tuning for [`Server::start`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address, e.g. `"127.0.0.1:7878"`; port 0 picks a free one
    /// (read it back via [`Server::local_addr`]).
    pub addr: String,
    /// Handler threads — the maximum number of concurrently served
    /// connections.
    pub conn_workers: usize,
    /// Accepted-but-unhandled connections allowed to wait for a free
    /// handler before new arrivals are refused.
    pub pending_conns: usize,
    /// Global in-flight job bound (see [`AdmissionConfig`]); 0 = off.
    pub max_inflight: usize,
    /// Per-client sustained job rate; <= 0 disables quotas.
    pub quota_rps: f64,
    /// Per-client burst allowance above the sustained rate.
    pub quota_burst: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            conn_workers: 8,
            pending_conns: 32,
            max_inflight: 64,
            quota_rps: 0.0,
            quota_burst: 8.0,
        }
    }
}

/// Live server counters (all monotonic except `connections_open`).
#[derive(Default)]
struct ServerStats {
    connections_total: AtomicU64,
    connections_open: AtomicUsize,
    requests_ok: AtomicU64,
    rejected_busy: AtomicU64,
    rejected_quota: AtomicU64,
    protocol_errors: AtomicU64,
    http_requests: AtomicU64,
}

/// Point-in-time copy of the server gauges, rendered by `/metrics` and
/// the `serve` stdout report.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ServerStatsSnapshot {
    pub connections_total: u64,
    pub connections_open: usize,
    pub requests_ok: u64,
    /// Frames denied by the in-flight bound, plus connections refused at
    /// accept time with a full pending queue.
    pub rejected_busy: u64,
    pub rejected_quota: u64,
    pub protocol_errors: u64,
    pub http_requests: u64,
}

impl ServerStats {
    fn snapshot(&self) -> ServerStatsSnapshot {
        ServerStatsSnapshot {
            connections_total: self.connections_total.load(Ordering::Relaxed),
            connections_open: self.connections_open.load(Ordering::Relaxed),
            requests_ok: self.requests_ok.load(Ordering::Relaxed),
            rejected_busy: self.rejected_busy.load(Ordering::Relaxed),
            rejected_quota: self.rejected_quota.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            http_requests: self.http_requests.load(Ordering::Relaxed),
        }
    }
}

/// State shared by the accept loop and every handler thread.
struct ServerShared {
    coord: Arc<Coordinator>,
    admission: Admission,
    stats: ServerStats,
    /// Server start time — the `/healthz` uptime reference.
    started: Instant,
    /// Per-instance stop flag (NOT the process-global
    /// [`super::shutdown`] flag — parallel tests each run their own
    /// server and must not observe each other's shutdowns).
    stop: AtomicBool,
}

/// A running serving front-end. Stop it with [`Server::stop`] (drains
/// and joins) or just drop it (same drain path).
pub struct Server {
    shared: Arc<ServerShared>,
    local_addr: SocketAddr,
    accept_thread: Option<JoinHandle<()>>,
    handler_threads: Vec<JoinHandle<()>>,
}

/// Socket read timeout on handler connections: the poll tick at which
/// idle streaming connections observe the stop flag.
const READ_TICK: Duration = Duration::from_millis(100);
/// Longest a client may stall mid-payload before the frame errors out.
const PAYLOAD_IDLE_LIMIT: Duration = Duration::from_secs(60);
/// Accept-loop sleep when no connection is pending.
const ACCEPT_IDLE: Duration = Duration::from_millis(10);
/// Socket write deadline on handler connections: a peer that stops
/// reading while a reply payload is in flight errors the connection out
/// instead of pinning the handler thread forever.
const WRITE_LIMIT: Duration = Duration::from_secs(30);

impl Server {
    /// Bind `cfg.addr` and start the accept loop plus handler pool. The
    /// server borrows the coordinator via `Arc` and never shuts it down.
    pub fn start(coord: Arc<Coordinator>, cfg: ServerConfig) -> crate::Result<Self> {
        assert!(cfg.conn_workers >= 1 && cfg.pending_conns >= 1);
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| crate::util::error::Error::msg(format!("bind {}: {e}", cfg.addr)))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| crate::util::error::Error::msg(format!("local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| crate::util::error::Error::msg(format!("set_nonblocking: {e}")))?;
        let shared = Arc::new(ServerShared {
            coord,
            admission: Admission::new(AdmissionConfig {
                max_inflight: cfg.max_inflight,
                quota_rps: cfg.quota_rps,
                quota_burst: cfg.quota_burst,
            }),
            stats: ServerStats::default(),
            started: Instant::now(),
            stop: AtomicBool::new(false),
        });
        let (conn_tx, conn_rx) = bounded::<TcpStream>(cfg.pending_conns);
        let handler_threads = (0..cfg.conn_workers)
            .map(|i| {
                let rx = conn_rx.clone();
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("sfcmul-conn-{i}"))
                    .spawn(move || handler_loop(rx, shared))
                    .unwrap_or_else(|e| panic!("spawn connection handler: {e}"))
            })
            .collect();
        let accept_thread = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("sfcmul-accept".into())
                .spawn(move || accept_loop(listener, conn_tx, shared))
                .unwrap_or_else(|e| panic!("spawn accept loop: {e}"))
        };
        Ok(Self { shared, local_addr, accept_thread: Some(accept_thread), handler_threads })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Point-in-time server gauges.
    pub fn stats(&self) -> ServerStatsSnapshot {
        self.shared.stats.snapshot()
    }

    /// Raise the stop flag without blocking (the drain happens in
    /// [`Server::stop`] / drop).
    pub fn request_stop(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
    }

    /// Graceful shutdown: stop accepting, let handlers finish their
    /// in-flight frames, join all threads. Returns the final gauges.
    pub fn stop(mut self) -> ServerStatsSnapshot {
        self.stop_inner();
        self.shared.stats.snapshot()
    }

    fn stop_inner(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            // Joining the accept thread drops the connection sender,
            // which closes the queue once handlers drain it.
            let _ = t.join();
        }
        for t in self.handler_threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn accept_loop(listener: TcpListener, conn_tx: Sender<TcpStream>, shared: Arc<ServerShared>) {
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((sock, _peer)) => {
                let _ = sock.set_nodelay(true);
                let _ = sock.set_read_timeout(Some(READ_TICK));
                let _ = sock.set_write_timeout(Some(WRITE_LIMIT));
                match conn_tx.try_send(sock) {
                    Ok(()) => {}
                    Err(TrySendError::Full(mut sock)) => {
                        // Every handler busy and the pending queue full:
                        // refuse at the door rather than queue unboundedly.
                        shared.stats.rejected_busy.fetch_add(1, Ordering::Relaxed);
                        let _ = sock.write_all(
                            format!("ERR {} server at connection capacity\n", ErrCode::Busy)
                                .as_bytes(),
                        );
                    }
                    Err(TrySendError::Closed(_)) => return,
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_IDLE);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => std::thread::sleep(ACCEPT_IDLE),
        }
    }
    // conn_tx drops here; handlers drain the pending queue then exit.
}

fn handler_loop(rx: Receiver<TcpStream>, shared: Arc<ServerShared>) {
    while let Some(sock) = rx.recv() {
        shared.stats.connections_total.fetch_add(1, Ordering::Relaxed);
        shared.stats.connections_open.fetch_add(1, Ordering::Relaxed);
        handle_conn(sock, &shared);
        shared.stats.connections_open.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Why the connection ended — purely informational; errors writing the
/// goodbye are ignored (the peer may already be gone).
fn handle_conn(mut sock: TcpStream, shared: &ServerShared) {
    let peer_ip =
        sock.peer_addr().map(|a| a.ip()).unwrap_or(IpAddr::V4(Ipv4Addr::LOCALHOST));
    let mut reader = FrameReader::new();
    // Receive buffer reused across every frame of this connection (the
    // streaming/video story: per-frame allocation is one payload clone,
    // not a fresh read buffer).
    let mut payload: Vec<u8> = Vec::new();
    loop {
        let line = match reader.poll_line(&mut sock) {
            Ok(LineRead::Line(l)) => l,
            Ok(LineRead::Eof) => return,
            Ok(LineRead::Idle { partial }) => {
                if shared.stop.load(Ordering::SeqCst) && !partial {
                    // Idle streaming connection during drain: close
                    // politely at a frame boundary.
                    let _ = sock.write_all(
                        format!("ERR {} server draining\n", ErrCode::ShuttingDown).as_bytes(),
                    );
                    return;
                }
                continue;
            }
            Err(_) => {
                shared.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                return;
            }
        };
        if line.is_empty() {
            continue; // stray blank line between frames
        }
        if http::is_http(&line) {
            shared.stats.http_requests.fetch_add(1, Ordering::Relaxed);
            serve_http(&mut sock, &mut reader, &line, shared);
            return; // HTTP exchanges are one-shot (Connection: close)
        }
        let req = match protocol::parse_request(&line) {
            Ok(r) => r,
            Err(msg) => {
                shared.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                if write_err(&mut sock, ErrCode::BadRequest, &msg).is_err() {
                    return;
                }
                continue;
            }
        };
        // Read the payload BEFORE any admission decision: a denied frame
        // must consume its bytes or the stream desyncs.
        let need = req.payload_len();
        payload.clear();
        payload.resize(need, 0);
        if need > 0
            && reader
                .read_exact_payload(&mut sock, &mut payload, PAYLOAD_IDLE_LIMIT)
                .is_err()
        {
            shared.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let keep_going = match req {
            Request::Ping => sock.write_all(b"OK pong\n").is_ok(),
            Request::Quit => {
                let _ = sock.write_all(b"OK bye\n");
                false
            }
            Request::Metrics => {
                let text = http::render_metrics(
                    &shared.coord.metrics(),
                    &shared.stats.snapshot(),
                );
                sock.write_all(format!("OK bytes={}\n", text.len()).as_bytes()).is_ok()
                    && sock.write_all(text.as_bytes()).is_ok()
            }
            Request::Trace => {
                let text =
                    shared.coord.tracer().chrome_trace_json(shared.coord.engine_names());
                sock.write_all(format!("OK bytes={}\n", text.len()).as_bytes()).is_ok()
                    && sock.write_all(text.as_bytes()).is_ok()
            }
            Request::Edge { w, h, ref engine, op } => {
                serve_edge(&mut sock, shared, peer_ip, w, h, engine.as_deref(), op, &payload)
            }
            Request::Gemm { m, k, n, ref engine } => {
                serve_gemm(&mut sock, shared, peer_ip, m, k, n, engine.as_deref(), &payload)
            }
        };
        if !keep_going {
            return;
        }
    }
}

/// Run one job frame's admission check; on denial, answer the client
/// and report `None`. `Some(guard)` holds the in-flight slot.
fn admit<'a>(
    sock: &mut TcpStream,
    shared: &'a ServerShared,
    peer_ip: IpAddr,
) -> Option<Result<super::limits::InflightGuard<'a>, ()>> {
    if shared.stop.load(Ordering::SeqCst) {
        let ok = write_err(sock, ErrCode::ShuttingDown, "server draining").is_ok();
        return if ok { Some(Err(())) } else { None };
    }
    match shared.admission.try_admit(peer_ip) {
        Ok(guard) => Some(Ok(guard)),
        Err(Deny::Busy { inflight, bound }) => {
            shared.stats.rejected_busy.fetch_add(1, Ordering::Relaxed);
            let ok = write_err(
                sock,
                ErrCode::Busy,
                &format!("{inflight}/{bound} jobs in flight, retry later"),
            )
            .is_ok();
            if ok {
                Some(Err(()))
            } else {
                None
            }
        }
        Err(Deny::Quota) => {
            shared.stats.rejected_quota.fetch_add(1, Ordering::Relaxed);
            let ok = write_err(sock, ErrCode::Quota, "client rate quota exhausted").is_ok();
            if ok {
                Some(Err(()))
            } else {
                None
            }
        }
    }
}

/// Returns false when the connection should close.
#[allow(clippy::too_many_arguments)]
fn serve_edge(
    sock: &mut TcpStream,
    shared: &ServerShared,
    peer_ip: IpAddr,
    w: usize,
    h: usize,
    engine: Option<&str>,
    op: crate::image::ops::Operator,
    payload: &[u8],
) -> bool {
    let guard = match admit(sock, shared, peer_ip) {
        None => return false,
        Some(Err(())) => return true, // denied but answered; stream continues
        Some(Ok(g)) => g,
    };
    let img = Image { width: w, height: h, data: payload.to_vec() };
    // A failure *after* admission (engine panic, open breaker, deadline)
    // answers with a bare ERR line in place of the OK + payload — the
    // stream stays framed, and the client can retry on the same
    // connection.
    let res = match shared.coord.submit_to(img, engine, op) {
        Ok(handle) => handle.wait(),
        Err(e) => Err(e),
    };
    drop(guard); // job settled: release the in-flight slot before I/O
    let res = match res {
        Ok(r) => r,
        Err(e) => return write_err(sock, classify(&e), &format!("{e}")).is_ok(),
    };
    shared.stats.requests_ok.fetch_add(1, Ordering::Relaxed);
    let header = format!(
        "OK w={} h={} latency_us={}\n",
        res.edges.width,
        res.edges.height,
        res.latency.as_micros()
    );
    sock.write_all(header.as_bytes()).is_ok() && sock.write_all(&res.edges.data).is_ok()
}

/// Returns false when the connection should close.
#[allow(clippy::too_many_arguments)]
fn serve_gemm(
    sock: &mut TcpStream,
    shared: &ServerShared,
    peer_ip: IpAddr,
    m: usize,
    k: usize,
    n: usize,
    engine: Option<&str>,
    payload: &[u8],
) -> bool {
    let guard = match admit(sock, shared, peer_ip) {
        None => return false,
        Some(Err(())) => return true,
        Some(Ok(g)) => g,
    };
    let mut a = MatI8::new(m, k);
    let mut b = MatI8::new(k, n);
    for (dst, src) in a.data.iter_mut().zip(&payload[..m * k]) {
        *dst = *src as i8;
    }
    for (dst, src) in b.data.iter_mut().zip(&payload[m * k..]) {
        *dst = *src as i8;
    }
    let res = match shared.coord.submit_gemm(a, b, engine) {
        Ok(handle) => handle.wait(),
        Err(e) => Err(e),
    };
    drop(guard);
    let res = match res {
        Ok(r) => r,
        Err(e) => return write_err(sock, classify(&e), &format!("{e}")).is_ok(),
    };
    shared.stats.requests_ok.fetch_add(1, Ordering::Relaxed);
    let header = format!(
        "OK m={} n={} latency_us={}\n",
        res.out.rows,
        res.out.cols,
        res.latency.as_micros()
    );
    if sock.write_all(header.as_bytes()).is_err() {
        return false;
    }
    let mut bytes = Vec::with_capacity(res.out.data.len() * 4);
    for v in &res.out.data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    sock.write_all(&bytes).is_ok()
}

/// Map a coordinator job error to its wire code.
fn classify(e: &JobError) -> ErrCode {
    match e {
        JobError::Invalid(msg) => {
            if msg.contains("unknown engine") {
                ErrCode::UnknownEngine
            } else if msg.contains("does not support") || msg.contains("does not serve") {
                ErrCode::Unsupported
            } else {
                ErrCode::BadRequest
            }
        }
        JobError::EngineFailed { .. } => ErrCode::EngineFailed,
        JobError::Deadline { .. } => ErrCode::Deadline,
        JobError::Shutdown => ErrCode::ShuttingDown,
        // A vanished reply channel is a server-side invariant breach,
        // not something the client can fix.
        JobError::QueueClosed => ErrCode::Internal,
    }
}

fn write_err(sock: &mut TcpStream, code: ErrCode, msg: &str) -> std::io::Result<()> {
    // Keep the message single-line: the protocol is line-framed.
    let msg = msg.replace('\n', " ");
    sock.write_all(format!("ERR {code} {msg}\n").as_bytes())
}

/// Serve one HTTP exchange on a connection whose request line was
/// already read. Remaining request headers are drained (until the blank
/// line or idle) purely to be polite to the peer's write path.
fn serve_http(sock: &mut TcpStream, reader: &mut FrameReader, request_line: &str, shared: &ServerShared) {
    loop {
        match reader.poll_line(sock) {
            Ok(LineRead::Line(l)) if l.is_empty() => break,
            Ok(LineRead::Line(_)) => continue,
            _ => break, // EOF/idle/garbage: answer with what we have
        }
    }
    let resp = match http::parse_request_line(request_line) {
        Some((method, path)) => {
            let degraded = shared.coord.degraded();
            http::route(
                method,
                path,
                degraded,
                || http::render_metrics(&shared.coord.metrics(), &shared.stats.snapshot()),
                || {
                    http::render_healthz(
                        degraded,
                        shared.started.elapsed().as_secs(),
                        &shared.coord.metrics(),
                    )
                },
            )
        }
        None => http::response(400, "Bad Request", "text/plain", "bad request line\n"),
    };
    let _ = sock.write_all(resp.as_bytes());
}

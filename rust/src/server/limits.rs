//! Admission control: a global in-flight bound plus per-client
//! token-bucket quotas.
//!
//! Both knobs protect the coordinator's bounded tile queue from
//! unbounded fan-in. The in-flight bound caps *concurrent* work (jobs
//! admitted but not yet completed) across all connections; the token
//! bucket caps *rate* per client IP. A denied frame costs the client
//! one round trip (`ERR busy` / `ERR quota`), never a hang — payload
//! bytes are consumed before the admission check so the stream stays
//! framed.

use std::collections::HashMap;
use std::net::IpAddr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Tuning for [`Admission`].
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Maximum jobs admitted but not yet completed, across all
    /// connections. `0` disables the bound.
    pub max_inflight: usize,
    /// Sustained per-client job rate (jobs/second). `<= 0.0` disables
    /// quotas entirely.
    pub quota_rps: f64,
    /// Bucket capacity: how many jobs a client may burst above the
    /// sustained rate.
    pub quota_burst: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self { max_inflight: 64, quota_rps: 0.0, quota_burst: 8.0 }
    }
}

/// Why a frame was denied admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Deny {
    /// The global in-flight bound is saturated (the 429 analogue).
    Busy { inflight: usize, bound: usize },
    /// This client's token bucket is empty.
    Quota,
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Shared admission state. One instance per server; cheap to consult
/// per frame (an atomic bump plus, when quotas are on, one short
/// mutex-guarded map probe).
pub struct Admission {
    cfg: AdmissionConfig,
    inflight: AtomicUsize,
    buckets: Mutex<HashMap<IpAddr, Bucket>>,
}

/// Soft cap on tracked client IPs; beyond it, stale buckets (idle
/// > 60 s) are evicted before inserting a new one.
const MAX_TRACKED_CLIENTS: usize = 4096;

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Self {
        Self { cfg, inflight: AtomicUsize::new(0), buckets: Mutex::new(HashMap::new()) }
    }

    /// Jobs currently admitted but not completed.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Try to admit one job from `client`. On success the returned
    /// guard holds the in-flight slot until dropped (job completion).
    ///
    /// Quota is charged before the in-flight probe: a rate-abusive
    /// client burns its own bucket, not a global slot.
    pub fn try_admit(&self, client: IpAddr) -> Result<InflightGuard<'_>, Deny> {
        if self.cfg.quota_rps > 0.0 && !self.take_token(client) {
            return Err(Deny::Quota);
        }
        if self.cfg.max_inflight > 0 {
            let bound = self.cfg.max_inflight;
            let res = self.inflight.fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                if n < bound {
                    Some(n + 1)
                } else {
                    None
                }
            });
            if let Err(n) = res {
                return Err(Deny::Busy { inflight: n, bound });
            }
        } else {
            self.inflight.fetch_add(1, Ordering::AcqRel);
        }
        Ok(InflightGuard { adm: self })
    }

    fn take_token(&self, client: IpAddr) -> bool {
        let now = Instant::now();
        let mut map = crate::util::sync::lock(&self.buckets);
        if !map.contains_key(&client) && map.len() >= MAX_TRACKED_CLIENTS {
            map.retain(|_, b| now.duration_since(b.last).as_secs() < 60);
        }
        let bucket = map
            .entry(client)
            .or_insert_with(|| Bucket { tokens: self.cfg.quota_burst, last: now });
        let dt = now.duration_since(bucket.last).as_secs_f64();
        bucket.last = now;
        bucket.tokens = (bucket.tokens + dt * self.cfg.quota_rps).min(self.cfg.quota_burst);
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// RAII in-flight slot; dropping it (job done or errored) releases the
/// slot back to the global bound.
pub struct InflightGuard<'a> {
    adm: &'a Admission,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.adm.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::Ipv4Addr;

    fn ip(last: u8) -> IpAddr {
        IpAddr::V4(Ipv4Addr::new(10, 0, 0, last))
    }

    #[test]
    fn inflight_bound_enforced_and_released_by_guard() {
        let adm =
            Admission::new(AdmissionConfig { max_inflight: 2, quota_rps: 0.0, quota_burst: 0.0 });
        let g1 = adm.try_admit(ip(1)).unwrap();
        let g2 = adm.try_admit(ip(1)).unwrap();
        assert_eq!(adm.inflight(), 2);
        match adm.try_admit(ip(1)) {
            Err(Deny::Busy { inflight: 2, bound: 2 }) => {}
            other => panic!("expected Busy, got {other:?}"),
        }
        drop(g1);
        let g3 = adm.try_admit(ip(1)).expect("slot freed by guard drop");
        drop(g2);
        drop(g3);
        assert_eq!(adm.inflight(), 0);
    }

    #[test]
    fn zero_bound_means_unlimited() {
        let adm =
            Admission::new(AdmissionConfig { max_inflight: 0, quota_rps: 0.0, quota_burst: 0.0 });
        let guards: Vec<_> = (0..100).map(|_| adm.try_admit(ip(1)).unwrap()).collect();
        assert_eq!(adm.inflight(), 100);
        drop(guards);
        assert_eq!(adm.inflight(), 0);
    }

    #[test]
    fn token_bucket_denies_after_burst_and_is_per_client() {
        // Negligible refill rate: only the burst allowance matters.
        let adm = Admission::new(AdmissionConfig {
            max_inflight: 0,
            quota_rps: 1e-9,
            quota_burst: 2.0,
        });
        let _a1 = adm.try_admit(ip(1)).unwrap();
        let _a2 = adm.try_admit(ip(1)).unwrap();
        assert_eq!(adm.try_admit(ip(1)).err(), Some(Deny::Quota));
        // A different client has its own bucket.
        let _b1 = adm.try_admit(ip(2)).unwrap();
    }

    #[test]
    fn quota_denial_does_not_leak_inflight_slots() {
        let adm = Admission::new(AdmissionConfig {
            max_inflight: 8,
            quota_rps: 1e-9,
            quota_burst: 1.0,
        });
        let g = adm.try_admit(ip(1)).unwrap();
        assert_eq!(adm.try_admit(ip(1)).err(), Some(Deny::Quota));
        assert_eq!(adm.inflight(), 1);
        drop(g);
        assert_eq!(adm.inflight(), 0);
    }
}

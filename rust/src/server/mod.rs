//! L4 — the network serving front-end.
//!
//! PRs 1–5 built the compute stack: multiplier designs, the convolution
//! cores, the im2col+GEMM nn layer and the L3 coordinator fleet. This
//! module turns that fleet into a *service*: a `std::net`-only TCP
//! listener speaking a line-delimited streaming job protocol
//! ([`protocol`], the `SFC/1` grammar) with a minimal HTTP/1.1 surface
//! on the same port ([`http`]: `GET /metrics`, `GET /healthz`).
//!
//! The production concerns live in their own submodules:
//!
//! * [`limits`] — admission control: a global in-flight job bound
//!   (reject with `ERR busy` when saturated) plus per-client
//!   token-bucket rate quotas (`ERR quota`).
//! * [`shutdown`] — the SIGINT/SIGTERM flag the `serve` CLI polls to
//!   drain in-flight work instead of aborting mid-batch.
//! * [`service`] — the listener: bounded connection queue, fixed
//!   handler pool (connection-per-worker), graceful drain-first stop.
//! * [`client`] — the blocking client used by `load_gen`, the socket
//!   tests, and scripts.
//!
//! Everything is hand-rolled on `std` — no tokio, hyper, or signal
//! crates — matching the crate's offline, auditable-substrate rule
//! (see [`crate::util`]).
//!
//! Fault tolerance rides the same wire: job failures surface as `ERR
//! engine-failed` / `ERR deadline` lines that never desync the stream,
//! `/healthz` turns `503` (with a JSON body naming the open breakers)
//! while any engine's circuit breaker is open, and
//! [`client::RetryPolicy`] gives callers deterministic bounded retry
//! with backoff on exactly the transient codes.
//!
//! Observability rides it too: the `TRACE` frame dumps the
//! coordinator's span ring as Chrome trace-event JSON
//! ([`crate::obs::trace`]), and `/metrics` carries the per-stage
//! latency histograms and live quality gauges next to the counters.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod client;
pub mod http;
pub mod limits;
pub mod protocol;
pub mod service;
pub mod shutdown;

pub use client::{http_get, Client, ClientError, EdgeReply, GemmReply, RetryPolicy};
pub use limits::{Admission, AdmissionConfig, Deny};
pub use protocol::{ErrCode, Request};
pub use service::{Server, ServerConfig, ServerStatsSnapshot};

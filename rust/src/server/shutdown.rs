//! SIGINT/SIGTERM-safe shutdown flag.
//!
//! `std` has no signal API, and the crate is dependency-free, so the
//! handler is registered through the one C function the POSIX standard
//! guarantees: `signal(2)`. The handler body only stores an
//! `AtomicBool` — the sole thing that is async-signal-safe in Rust —
//! and the serve loop polls [`signalled`] between batches to drain
//! in-flight work instead of aborting mid-batch.
//!
//! The flag is process-global and write-once by design: it belongs to
//! the binary's main loop. Library users ([`super::Server`]) carry
//! their own per-instance stop flag so parallel tests never observe
//! each other's shutdowns.

use std::sync::atomic::{AtomicBool, Ordering};

static SIGNALLED: AtomicBool = AtomicBool::new(false);

/// Has SIGINT/SIGTERM been received (or [`trigger`] called)?
pub fn signalled() -> bool {
    SIGNALLED.load(Ordering::SeqCst)
}

/// Raise the flag programmatically — used by tests and by the CLI to
/// share one drain path between signal- and self-initiated shutdown.
pub fn trigger() {
    SIGNALLED.store(true, Ordering::SeqCst);
}

/// Install the SIGINT + SIGTERM handlers. Idempotent; no-op off Unix.
#[cfg(unix)]
pub fn install() {
    extern "C" fn on_signal(_signum: i32) {
        SIGNALLED.store(true, Ordering::SeqCst);
    }
    // SAFETY: `signal` is the POSIX libc entry point; the handler only
    // performs an atomic store, which is async-signal-safe.
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

/// Install the SIGINT + SIGTERM handlers. Idempotent; no-op off Unix.
#[cfg(not(unix))]
pub fn install() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trigger_raises_the_flag() {
        // `install` must at minimum not crash; the flag path is what the
        // serve loop consumes.
        install();
        trigger();
        assert!(signalled());
    }
}

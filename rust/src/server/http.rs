//! Minimal HTTP/1.1 surface sharing the job-protocol listener.
//!
//! The dispatcher sniffs each header line: if it starts with an HTTP
//! method token the connection is treated as a one-shot HTTP exchange
//! (`Connection: close`), otherwise it stays on the streaming job
//! protocol. Supported routes:
//!
//! * `GET /metrics` — Prometheus text exposition of the coordinator
//!   [`MetricsSnapshot`] plus server gauges: every series carries
//!   `# HELP`/`# TYPE` headers, label values are escaped per the
//!   exposition format, counters end in `_total`, and the per-(engine,
//!   stage) log₂ latency histograms ([`crate::obs::hist`]) and live
//!   approximation-quality gauges ([`crate::obs::quality`]) ride along.
//! * `GET /healthz` — health probe: a small JSON document (`status`,
//!   `uptime_s`, `queue_depth`, per-engine breaker states) served with
//!   `200` while every engine's circuit breaker is closed and `503`
//!   otherwise — load balancers key on the status code as before, while
//!   humans and scripts get the *why* in the body.
//!
//! Everything else is `404`; non-GET/HEAD methods are `405`. This is
//! deliberately not a general HTTP server — no keep-alive, chunking, or
//! header interpretation beyond the request line.

use crate::coordinator::MetricsSnapshot;
use crate::obs::hist::{bucket_le_us, Stage, BUCKETS};
use crate::util::json::Json;

use super::service::ServerStatsSnapshot;

/// Does this job-protocol header line actually open an HTTP request?
pub fn is_http(line: &str) -> bool {
    ["GET ", "HEAD ", "POST ", "PUT ", "DELETE "].iter().any(|m| line.starts_with(m))
}

/// Parse an HTTP request line into (method, path). Returns `None` when
/// the line is not a well-formed request line.
pub fn parse_request_line(line: &str) -> Option<(&str, &str)> {
    let mut parts = line.split_ascii_whitespace();
    let method = parts.next()?;
    let path = parts.next()?;
    let version = parts.next()?;
    if !version.starts_with("HTTP/") {
        return None;
    }
    Some((method, path))
}

/// Build a full HTTP/1.1 response with `Connection: close`.
pub fn response(status: u16, reason: &str, content_type: &str, body: &str) -> String {
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

/// Route one HTTP request to its response text. `degraded` is the
/// coordinator's circuit-breaker signal: it selects the `/healthz`
/// status code (`503` when any breaker is open) without touching any
/// other route; `health` renders the probe's JSON body either way.
pub fn route(
    method: &str,
    path: &str,
    degraded: bool,
    metrics: impl FnOnce() -> String,
    health: impl FnOnce() -> String,
) -> String {
    if method != "GET" && method != "HEAD" {
        return response(405, "Method Not Allowed", "text/plain", "method not allowed\n");
    }
    match path {
        "/metrics" => response(200, "OK", "text/plain; version=0.0.4", &metrics()),
        "/healthz" if degraded => {
            response(503, "Service Unavailable", "application/json", &health())
        }
        "/healthz" => response(200, "OK", "application/json", &health()),
        _ => response(404, "Not Found", "text/plain", "not found\n"),
    }
}

/// Render the `/healthz` body: machine-readable health context for the
/// probe. The word `degraded` appears as the `status` value exactly when
/// the instance serves `503`, so greps against the old plain-text body
/// keep working.
pub fn render_healthz(degraded: bool, uptime_s: u64, m: &MetricsSnapshot) -> String {
    let engines: Vec<Json> = m
        .per_engine
        .iter()
        .map(|e| {
            Json::obj()
                .set("name", e.name.as_str())
                .set("breaker", e.breaker.to_string())
        })
        .collect();
    let doc = Json::obj()
        .set("status", if degraded { "degraded" } else { "ok" })
        .set("uptime_s", uptime_s as i64)
        .set("queue_depth", m.queue_depth)
        .set("engines", Json::Arr(engines));
    format!("{doc}\n")
}

/// Escape a label value per the Prometheus text exposition format:
/// backslash, double quote, and newline must be backslash-escaped.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Emit the `# HELP` / `# TYPE` preamble for one metric family.
fn family(out: &mut String, name: &str, kind: &str, help: &str) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn quantile_lines(out: &mut String, name: &str, labels: &str, p50: f64, p90: f64, p99: f64) {
    use std::fmt::Write;
    let sep = if labels.is_empty() { "" } else { "," };
    for (q, v) in [("0.5", p50), ("0.9", p90), ("0.99", p99)] {
        let _ = writeln!(out, "{name}{{{labels}{sep}quantile=\"{q}\"}} {v:.6}");
    }
}

/// Render the coordinator snapshot plus server gauges in the Prometheus
/// text exposition format. Every family gets `# HELP`/`# TYPE` headers
/// (emitted once, before all of the family's samples), label values are
/// escaped, and cumulative series end in `_total`.
pub fn render_metrics(m: &MetricsSnapshot, s: &ServerStatsSnapshot) -> String {
    use std::fmt::Write;
    let mut out = String::with_capacity(8192);
    let w = &mut out;

    // Fleet-wide coordinator counters.
    for (name, help, v) in [
        ("sfcmul_jobs_accepted_total", "Jobs admitted at submit time.", m.jobs_accepted),
        ("sfcmul_jobs_rejected_total", "Submissions rejected at validation time.", m.jobs_rejected),
        ("sfcmul_jobs_completed_total", "Jobs finished successfully.", m.jobs_completed),
        ("sfcmul_jobs_failed_total", "Jobs failed (panic, deadline, or error).", m.jobs_failed),
        ("sfcmul_tiles_processed_total", "Work units (tiles / GEMM blocks) processed.", m.tiles_processed),
        ("sfcmul_batches_total", "Worker batches executed.", m.batches),
    ] {
        family(w, name, "counter", help);
        let _ = writeln!(w, "{name} {v}");
    }
    family(w, "sfcmul_queue_depth", "gauge", "Work items waiting in the shared queue.");
    let _ = writeln!(w, "sfcmul_queue_depth {}", m.queue_depth);
    family(w, "sfcmul_job_latency_ms", "summary", "End-to-end job latency quantiles (reservoir-sampled), in milliseconds.");
    quantile_lines(w, "sfcmul_job_latency_ms", "", m.latency_p50_ms, m.latency_p90_ms, m.latency_p99_ms);

    // Per-engine rows: one family header, then one sample per engine.
    type EngineVal = fn(&crate::coordinator::EngineMetricsSnapshot) -> u64;
    let engine_counters: [(&str, &str, EngineVal); 6] = [
        ("sfcmul_engine_jobs_completed_total", "Jobs finished by this engine.", |e| e.jobs_completed),
        ("sfcmul_engine_jobs_failed_total", "Jobs failed while assigned to this engine.", |e| e.jobs_failed),
        ("sfcmul_engine_panics_caught_total", "Engine panics caught by the worker's isolation boundary.", |e| e.panics_caught),
        ("sfcmul_engine_deadline_misses_total", "Jobs failed by the watchdog for exceeding their deadline.", |e| e.deadline_misses),
        ("sfcmul_engine_tiles_processed_total", "Work units processed by this engine.", |e| e.tiles_processed),
        ("sfcmul_engine_batches_total", "Batches executed by this engine.", |e| e.batches),
    ];
    for (name, help, get) in engine_counters {
        family(w, name, "counter", help);
        for e in &m.per_engine {
            let _ = writeln!(w, "{name}{{engine=\"{}\"}} {}", escape_label(&e.name), get(e));
        }
    }
    family(w, "sfcmul_engine_breaker_state", "gauge", "Circuit-breaker state: 0 = closed, 1 = half-open, 2 = open.");
    for e in &m.per_engine {
        let _ = writeln!(w, "sfcmul_engine_breaker_state{{engine=\"{}\"}} {}", escape_label(&e.name), e.breaker.code());
    }
    family(w, "sfcmul_engine_busy_seconds", "gauge", "Cumulative engine compute time.");
    for e in &m.per_engine {
        let _ = writeln!(w, "sfcmul_engine_busy_seconds{{engine=\"{}\"}} {:.6}", escape_label(&e.name), e.engine_busy.as_secs_f64());
    }
    family(w, "sfcmul_engine_job_latency_ms", "summary", "Per-engine job latency quantiles (reservoir-sampled), in milliseconds.");
    for e in &m.per_engine {
        let labels = format!("engine=\"{}\"", escape_label(&e.name));
        quantile_lines(w, "sfcmul_engine_job_latency_ms", &labels, e.latency_p50_ms, e.latency_p90_ms, e.latency_p99_ms);
    }

    // Per-(engine, stage) log2 latency histograms (the obs layer).
    family(
        w,
        "sfcmul_stage_latency_seconds",
        "histogram",
        "Per-stage latency (queue_wait = enqueue to drain, compute = batch execution, e2e = submit to completion) in log2 buckets.",
    );
    for e in &m.per_engine {
        let engine = escape_label(&e.name);
        for stage in Stage::ALL {
            let h = &e.stages[stage as usize];
            let labels = format!("engine=\"{engine}\",stage=\"{}\"", stage.label());
            for i in 0..BUCKETS {
                let le = match bucket_le_us(i) {
                    Some(us) => format!("{}", us as f64 / 1e6),
                    None => "+Inf".to_string(),
                };
                let _ = writeln!(
                    w,
                    "sfcmul_stage_latency_seconds_bucket{{{labels},le=\"{le}\"}} {}",
                    h.cumulative(i)
                );
            }
            let _ = writeln!(w, "sfcmul_stage_latency_seconds_sum{{{labels}}} {:.9}", h.sum_seconds);
            let _ = writeln!(w, "sfcmul_stage_latency_seconds_count{{{labels}}} {}", h.count);
        }
    }

    // Live approximation-quality telemetry (shadow-recomputed samples).
    for (name, help) in [
        ("sfcmul_quality_sampled_units_total", "Work units (conv tiles / GEMM blocks) shadow-recomputed by the quality sampler."),
        ("sfcmul_quality_sampled_pairs_total", "Operand pairs compared against the exact product by the quality sampler."),
        ("sfcmul_quality_mismatches_total", "Sampled operand pairs whose approximate product differed from exact."),
    ] {
        family(w, name, "counter", help);
        for e in &m.per_engine {
            let v = match name {
                "sfcmul_quality_sampled_units_total" => e.quality.units,
                "sfcmul_quality_sampled_pairs_total" => e.quality.pairs,
                _ => e.quality.mismatches,
            };
            let _ = writeln!(w, "{name}{{engine=\"{}\"}} {v}", escape_label(&e.name));
        }
    }
    family(w, "sfcmul_quality_mismatch_rate", "gauge", "Live error rate over sampled pairs (0 when nothing sampled).");
    for e in &m.per_engine {
        let _ = writeln!(w, "sfcmul_quality_mismatch_rate{{engine=\"{}\"}} {}", escape_label(&e.name), e.quality.mismatch_rate());
    }
    family(w, "sfcmul_quality_med", "gauge", "Live mean |error distance| over sampled pairs.");
    for e in &m.per_engine {
        let _ = writeln!(w, "sfcmul_quality_med{{engine=\"{}\"}} {}", escape_label(&e.name), e.quality.med());
    }
    family(w, "sfcmul_quality_nmed", "gauge", "Live NMED (MED / 2^14) over sampled pairs.");
    for e in &m.per_engine {
        let _ = writeln!(w, "sfcmul_quality_nmed{{engine=\"{}\"}} {}", escape_label(&e.name), e.quality.nmed());
    }
    family(w, "sfcmul_quality_max_ed", "gauge", "Largest |error distance| observed by the quality sampler.");
    for e in &m.per_engine {
        let _ = writeln!(w, "sfcmul_quality_max_ed{{engine=\"{}\"}} {}", escape_label(&e.name), e.quality.max_ed);
    }

    // Server front-end gauges.
    family(w, "sfcmul_server_connections_open", "gauge", "Connections currently held by handler threads.");
    let _ = writeln!(w, "sfcmul_server_connections_open {}", s.connections_open);
    family(w, "sfcmul_server_connections_total", "counter", "Connections accepted since start.");
    let _ = writeln!(w, "sfcmul_server_connections_total {}", s.connections_total);
    family(w, "sfcmul_server_requests_ok_total", "counter", "Frames answered with OK.");
    let _ = writeln!(w, "sfcmul_server_requests_ok_total {}", s.requests_ok);
    family(w, "sfcmul_server_rejected_total", "counter", "Frames or connections refused by admission control.");
    let _ = writeln!(w, "sfcmul_server_rejected_total{{reason=\"busy\"}} {}", s.rejected_busy);
    let _ = writeln!(w, "sfcmul_server_rejected_total{{reason=\"quota\"}} {}", s.rejected_quota);
    family(w, "sfcmul_server_protocol_errors_total", "counter", "Connections dropped for malformed frames.");
    let _ = writeln!(w, "sfcmul_server_protocol_errors_total {}", s.protocol_errors);
    family(w, "sfcmul_server_http_requests_total", "counter", "HTTP exchanges served on the shared listener.");
    let _ = writeln!(w, "sfcmul_server_http_requests_total {}", s.http_requests);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use std::collections::HashSet;
    use std::time::Duration;

    #[test]
    fn http_sniff_only_matches_methods() {
        assert!(is_http("GET /metrics HTTP/1.1"));
        assert!(is_http("HEAD /healthz HTTP/1.1"));
        assert!(!is_http("EDGE w=4 h=4"));
        assert!(!is_http("GEMM m=1 k=1 n=1"));
        assert!(!is_http("GETX /"), "prefix requires the trailing space");
    }

    #[test]
    fn request_line_parse() {
        assert_eq!(parse_request_line("GET /metrics HTTP/1.1"), Some(("GET", "/metrics")));
        assert_eq!(parse_request_line("GET /metrics"), None, "missing version");
    }

    #[test]
    fn routes_and_statuses() {
        let health = || "{\"status\":\"ok\"}\n".to_string();
        let r = route("GET", "/healthz", false, String::new, health);
        assert!(r.starts_with("HTTP/1.1 200 OK"));
        assert!(r.contains("Content-Type: application/json"));
        assert!(r.ends_with("{\"status\":\"ok\"}\n"));
        assert!(route("GET", "/nope", false, String::new, health).starts_with("HTTP/1.1 404"));
        assert!(route("POST", "/metrics", false, String::new, health).starts_with("HTTP/1.1 405"));
        let r = route("GET", "/metrics", false, || "x 1\n".to_string(), health);
        assert!(r.contains("Content-Length: 4"));
        assert!(r.ends_with("x 1\n"));
    }

    /// An open circuit breaker flips only `/healthz` — to `503` with a
    /// `degraded` status body — while `/metrics` keeps answering `200`
    /// (operators need the counters most exactly when the instance is
    /// degraded).
    #[test]
    fn healthz_reports_degraded_when_breaker_open() {
        let health = || "{\"status\":\"degraded\"}\n".to_string();
        let r = route("GET", "/healthz", true, String::new, health);
        assert!(r.starts_with("HTTP/1.1 503 Service Unavailable"));
        assert!(r.contains("degraded"));
        assert!(route("GET", "/metrics", true, || "x 1\n".into(), health)
            .starts_with("HTTP/1.1 200"));
        assert!(route("GET", "/nope", true, String::new, health).starts_with("HTTP/1.1 404"));
    }

    /// The healthz body is a parseable JSON document carrying uptime,
    /// queue depth, and the per-engine breaker states.
    #[test]
    fn healthz_body_is_structured_json() {
        let metrics = Metrics::new(vec!["proposed@8".into(), "exact@8".into()]);
        let m = metrics.snapshot();
        let body = render_healthz(false, 42, &m);
        let doc = crate::util::json::Json::parse(body.trim_end()).expect("healthz JSON parses");
        assert_eq!(doc.get("status").and_then(|v| v.as_str()), Some("ok"));
        assert_eq!(doc.get("uptime_s").and_then(|v| v.as_i64()), Some(42));
        assert_eq!(doc.get("queue_depth").and_then(|v| v.as_i64()), Some(0));
        let engines = doc.get("engines").and_then(|v| v.as_arr()).expect("engines array");
        assert_eq!(engines.len(), 2);
        assert_eq!(engines[0].get("name").and_then(|v| v.as_str()), Some("proposed@8"));
        assert_eq!(engines[0].get("breaker").and_then(|v| v.as_str()), Some("closed"));
        let degraded = render_healthz(true, 7, &m);
        assert!(degraded.contains("\"status\":\"degraded\""));
    }

    #[test]
    fn label_escaping_covers_the_exposition_specials() {
        assert_eq!(escape_label("plain@8"), "plain@8");
        assert_eq!(escape_label("a\"b"), "a\\\"b");
        assert_eq!(escape_label("a\\b"), "a\\\\b");
        assert_eq!(escape_label("a\nb"), "a\\nb");
    }

    #[test]
    fn metrics_render_has_engine_quantiles_and_server_gauges() {
        let metrics = Metrics::new(vec!["proposed@8".into(), "exact@8".into()]);
        metrics.record_job(0, Duration::from_millis(7));
        metrics.record_batch(0, 3, Duration::from_millis(2));
        metrics.record_accept();
        let m = metrics.snapshot();
        let s = ServerStatsSnapshot {
            connections_total: 5,
            connections_open: 2,
            requests_ok: 40,
            rejected_busy: 1,
            rejected_quota: 2,
            protocol_errors: 3,
            http_requests: 4,
        };
        let text = render_metrics(&m, &s);
        assert!(text.contains("sfcmul_jobs_accepted_total 1"));
        assert!(text.contains("sfcmul_jobs_failed_total 0"));
        assert!(text.contains("sfcmul_engine_jobs_failed_total{engine=\"proposed@8\"} 0"));
        assert!(text.contains("sfcmul_engine_panics_caught_total{engine=\"proposed@8\"} 0"));
        assert!(text.contains("sfcmul_engine_deadline_misses_total{engine=\"exact@8\"} 0"));
        assert!(text.contains("sfcmul_engine_breaker_state{engine=\"proposed@8\"} 0"));
        assert!(text.contains("sfcmul_engine_job_latency_ms{engine=\"proposed@8\",quantile=\"0.5\"}"));
        assert!(text.contains("sfcmul_engine_job_latency_ms{engine=\"exact@8\",quantile=\"0.99\"}"));
        assert!(text.contains("sfcmul_server_rejected_total{reason=\"quota\"} 2"));
        assert!(text.contains("sfcmul_server_connections_open 2"));
        // The compute-stage histogram saw the recorded batch.
        assert!(text.contains(
            "sfcmul_stage_latency_seconds_count{engine=\"proposed@8\",stage=\"compute\"} 1"
        ));
        assert!(text.contains(
            "sfcmul_stage_latency_seconds_bucket{engine=\"proposed@8\",stage=\"compute\",le=\"+Inf\"} 1"
        ));
        // Quality gauges exist even before anything is sampled.
        assert!(text.contains("sfcmul_quality_nmed{engine=\"proposed@8\"} 0"));
        assert!(text.contains("sfcmul_quality_sampled_pairs_total{engine=\"exact@8\"} 0"));
        // Every non-comment line is `name{...} value` with a parseable value.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, val) = line.rsplit_once(' ').expect("name value");
            val.parse::<f64>().unwrap_or_else(|_| panic!("unparseable value in {line:?}"));
        }
    }

    /// Exposition-format lint: every sample family carries `# HELP` and
    /// `# TYPE` headers emitted before its first sample, histogram
    /// children map back to their declared family, label sections parse
    /// with balanced quotes under escaping, and counter families end in
    /// `_total`.
    #[test]
    fn exposition_format_is_well_formed() {
        // An engine name exercising the escaping rules end to end.
        let metrics = Metrics::new(vec!["odd\"na\\me".into(), "exact@8".into()]);
        metrics.record_job(0, Duration::from_millis(3));
        metrics.record_batch(1, 2, Duration::from_millis(1));
        let m = metrics.snapshot();
        let s = ServerStatsSnapshot::default();
        let text = render_metrics(&m, &s);
        assert!(text.contains("engine=\"odd\\\"na\\\\me\""), "label value escaped");

        let mut helped: HashSet<String> = HashSet::new();
        let mut typed: HashSet<String> = HashSet::new();
        let mut types: Vec<(String, String)> = Vec::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let name = rest.split(' ').next().unwrap_or("");
                assert!(!name.is_empty(), "HELP without a name: {line:?}");
                helped.insert(name.to_string());
                continue;
            }
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split(' ');
                let name = it.next().unwrap_or("").to_string();
                let kind = it.next().unwrap_or("").to_string();
                assert!(
                    ["counter", "gauge", "histogram", "summary"].contains(&kind.as_str()),
                    "bad TYPE in {line:?}"
                );
                if kind == "counter" {
                    assert!(name.ends_with("_total"), "counter {name} must end in _total");
                }
                typed.insert(name.clone());
                types.push((name, kind));
                continue;
            }
            assert!(!line.starts_with('#'), "stray comment line {line:?}");
            // Sample line: name[{labels}] value.
            let (series, value) = line.rsplit_once(' ').expect("sample has a value");
            assert!(value.parse::<f64>().is_ok(), "value in {line:?}");
            let name = match series.find('{') {
                Some(br) => {
                    let labels = &series[br..];
                    assert!(labels.ends_with('}'), "unterminated labels in {line:?}");
                    // Balanced quotes outside escapes.
                    let mut quotes = 0usize;
                    let mut esc = false;
                    for c in labels.chars() {
                        if esc {
                            esc = false;
                        } else if c == '\\' {
                            esc = true;
                        } else if c == '"' {
                            quotes += 1;
                        }
                    }
                    assert_eq!(quotes % 2, 0, "unbalanced quotes in {line:?}");
                    &series[..br]
                }
                None => series,
            };
            // Histogram children resolve to their declared family name;
            // `_sum`/`_count` only alias a family when one exists (so
            // `..._total` names are never mis-stripped).
            let base = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_sum").filter(|b| typed.contains(*b)))
                .or_else(|| name.strip_suffix("_count").filter(|b| typed.contains(*b)))
                .unwrap_or(name);
            assert!(helped.contains(base), "sample {name} missing # HELP {base}");
            assert!(typed.contains(base), "sample {name} missing # TYPE {base}");
        }
        // Histogram families expose _bucket, _sum, and _count children,
        // including the mandatory +Inf bucket.
        for (name, kind) in &types {
            if kind == "histogram" {
                for suffix in ["_bucket{", "_sum{", "_count{"] {
                    assert!(
                        text.contains(&format!("{name}{suffix}")),
                        "histogram {name} missing {suffix} samples"
                    );
                }
                assert!(
                    text.contains(&format!(
                        "{name}_bucket{{engine=\"exact@8\",stage=\"compute\",le=\"+Inf\"}}"
                    )),
                    "histogram {name} missing the +Inf bucket"
                );
            }
        }
    }
}

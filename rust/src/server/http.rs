//! Minimal HTTP/1.1 surface sharing the job-protocol listener.
//!
//! The dispatcher sniffs each header line: if it starts with an HTTP
//! method token the connection is treated as a one-shot HTTP exchange
//! (`Connection: close`), otherwise it stays on the streaming job
//! protocol. Supported routes:
//!
//! * `GET /metrics` — Prometheus-style text exposition of the
//!   coordinator [`MetricsSnapshot`] plus server gauges (including
//!   per-engine failure counters and circuit-breaker state).
//! * `GET /healthz` — health probe: `200 ok` while every engine's
//!   circuit breaker is closed, `503 degraded` otherwise — load
//!   balancers can steer traffic away from a degraded instance while
//!   its fallback routing keeps in-flight clients served.
//!
//! Everything else is `404`; non-GET/HEAD methods are `405`. This is
//! deliberately not a general HTTP server — no keep-alive, chunking, or
//! header interpretation beyond the request line.

use crate::coordinator::MetricsSnapshot;

use super::service::ServerStatsSnapshot;

/// Does this job-protocol header line actually open an HTTP request?
pub fn is_http(line: &str) -> bool {
    ["GET ", "HEAD ", "POST ", "PUT ", "DELETE "].iter().any(|m| line.starts_with(m))
}

/// Parse an HTTP request line into (method, path). Returns `None` when
/// the line is not a well-formed request line.
pub fn parse_request_line(line: &str) -> Option<(&str, &str)> {
    let mut parts = line.split_ascii_whitespace();
    let method = parts.next()?;
    let path = parts.next()?;
    let version = parts.next()?;
    if !version.starts_with("HTTP/") {
        return None;
    }
    Some((method, path))
}

/// Build a full HTTP/1.1 response with `Connection: close`.
pub fn response(status: u16, reason: &str, content_type: &str, body: &str) -> String {
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

/// Route one HTTP request to its response text. `degraded` is the
/// coordinator's circuit-breaker signal: it turns the `/healthz` probe
/// into `503 degraded` without touching any other route.
pub fn route(method: &str, path: &str, degraded: bool, metrics: impl FnOnce() -> String) -> String {
    if method != "GET" && method != "HEAD" {
        return response(405, "Method Not Allowed", "text/plain", "method not allowed\n");
    }
    match path {
        "/metrics" => response(200, "OK", "text/plain; version=0.0.4", &metrics()),
        "/healthz" if degraded => {
            response(503, "Service Unavailable", "text/plain", "degraded\n")
        }
        "/healthz" => response(200, "OK", "text/plain", "ok\n"),
        _ => response(404, "Not Found", "text/plain", "not found\n"),
    }
}

fn quantile_lines(out: &mut String, name: &str, labels: &str, p50: f64, p90: f64, p99: f64) {
    use std::fmt::Write;
    let sep = if labels.is_empty() { "" } else { "," };
    for (q, v) in [("0.5", p50), ("0.9", p90), ("0.99", p99)] {
        let _ = writeln!(out, "{name}{{{labels}{sep}quantile=\"{q}\"}} {v:.6}");
    }
}

/// Render the coordinator snapshot plus server gauges in the Prometheus
/// text exposition format (one `name{labels} value` line per sample).
pub fn render_metrics(m: &MetricsSnapshot, s: &ServerStatsSnapshot) -> String {
    use std::fmt::Write;
    let mut out = String::with_capacity(2048);
    let w = &mut out;
    let _ = writeln!(w, "# Fleet-wide coordinator counters.");
    let _ = writeln!(w, "sfcmul_jobs_accepted_total {}", m.jobs_accepted);
    let _ = writeln!(w, "sfcmul_jobs_rejected_total {}", m.jobs_rejected);
    let _ = writeln!(w, "sfcmul_jobs_completed_total {}", m.jobs_completed);
    let _ = writeln!(w, "sfcmul_jobs_failed_total {}", m.jobs_failed);
    let _ = writeln!(w, "sfcmul_tiles_processed_total {}", m.tiles_processed);
    let _ = writeln!(w, "sfcmul_batches_total {}", m.batches);
    let _ = writeln!(w, "sfcmul_queue_depth {}", m.queue_depth);
    quantile_lines(w, "sfcmul_job_latency_ms", "", m.latency_p50_ms, m.latency_p90_ms, m.latency_p99_ms);
    let _ = writeln!(w, "# Per-engine rows.");
    for e in &m.per_engine {
        let labels = format!("engine=\"{}\"", e.name);
        let _ = writeln!(w, "sfcmul_engine_jobs_completed_total{{{labels}}} {}", e.jobs_completed);
        let _ = writeln!(w, "sfcmul_engine_jobs_failed_total{{{labels}}} {}", e.jobs_failed);
        let _ = writeln!(w, "sfcmul_engine_panics_caught_total{{{labels}}} {}", e.panics_caught);
        let _ = writeln!(w, "sfcmul_engine_deadline_misses_total{{{labels}}} {}", e.deadline_misses);
        // Breaker state as a gauge: 0 = closed, 1 = half-open, 2 = open.
        let _ = writeln!(w, "sfcmul_engine_breaker_state{{{labels}}} {}", e.breaker.code());
        let _ = writeln!(w, "sfcmul_engine_tiles_processed_total{{{labels}}} {}", e.tiles_processed);
        let _ = writeln!(w, "sfcmul_engine_batches_total{{{labels}}} {}", e.batches);
        let _ = writeln!(w, "sfcmul_engine_busy_seconds{{{labels}}} {:.6}", e.engine_busy.as_secs_f64());
        quantile_lines(
            w,
            "sfcmul_engine_job_latency_ms",
            &labels,
            e.latency_p50_ms,
            e.latency_p90_ms,
            e.latency_p99_ms,
        );
    }
    let _ = writeln!(w, "# Server front-end gauges.");
    let _ = writeln!(w, "sfcmul_server_connections_open {}", s.connections_open);
    let _ = writeln!(w, "sfcmul_server_connections_total {}", s.connections_total);
    let _ = writeln!(w, "sfcmul_server_requests_ok_total {}", s.requests_ok);
    let _ = writeln!(w, "sfcmul_server_rejected_total{{reason=\"busy\"}} {}", s.rejected_busy);
    let _ = writeln!(w, "sfcmul_server_rejected_total{{reason=\"quota\"}} {}", s.rejected_quota);
    let _ = writeln!(w, "sfcmul_server_protocol_errors_total {}", s.protocol_errors);
    let _ = writeln!(w, "sfcmul_server_http_requests_total {}", s.http_requests);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use std::time::Duration;

    #[test]
    fn http_sniff_only_matches_methods() {
        assert!(is_http("GET /metrics HTTP/1.1"));
        assert!(is_http("HEAD /healthz HTTP/1.1"));
        assert!(!is_http("EDGE w=4 h=4"));
        assert!(!is_http("GEMM m=1 k=1 n=1"));
        assert!(!is_http("GETX /"), "prefix requires the trailing space");
    }

    #[test]
    fn request_line_parse() {
        assert_eq!(parse_request_line("GET /metrics HTTP/1.1"), Some(("GET", "/metrics")));
        assert_eq!(parse_request_line("GET /metrics"), None, "missing version");
    }

    #[test]
    fn routes_and_statuses() {
        let r = route("GET", "/healthz", false, String::new);
        assert!(r.starts_with("HTTP/1.1 200 OK"));
        assert!(r.ends_with("ok\n"));
        assert!(route("GET", "/nope", false, String::new).starts_with("HTTP/1.1 404"));
        assert!(route("POST", "/metrics", false, String::new).starts_with("HTTP/1.1 405"));
        let r = route("GET", "/metrics", false, || "x 1\n".to_string());
        assert!(r.contains("Content-Length: 4"));
        assert!(r.ends_with("x 1\n"));
    }

    /// An open circuit breaker flips only `/healthz` — to `503 degraded`
    /// — while `/metrics` keeps answering `200` (operators need the
    /// counters most exactly when the instance is degraded).
    #[test]
    fn healthz_reports_degraded_when_breaker_open() {
        let r = route("GET", "/healthz", true, String::new);
        assert!(r.starts_with("HTTP/1.1 503 Service Unavailable"));
        assert!(r.ends_with("degraded\n"));
        assert!(route("GET", "/metrics", true, || "x 1\n".into()).starts_with("HTTP/1.1 200"));
        assert!(route("GET", "/nope", true, String::new).starts_with("HTTP/1.1 404"));
    }

    #[test]
    fn metrics_render_has_engine_quantiles_and_server_gauges() {
        let metrics = Metrics::new(vec!["proposed@8".into(), "exact@8".into()]);
        metrics.record_job(0, Duration::from_millis(7));
        metrics.record_batch(0, 3, Duration::from_millis(2));
        metrics.record_accept();
        let m = metrics.snapshot();
        let s = ServerStatsSnapshot {
            connections_total: 5,
            connections_open: 2,
            requests_ok: 40,
            rejected_busy: 1,
            rejected_quota: 2,
            protocol_errors: 3,
            http_requests: 4,
        };
        let text = render_metrics(&m, &s);
        assert!(text.contains("sfcmul_jobs_accepted_total 1"));
        assert!(text.contains("sfcmul_jobs_failed_total 0"));
        assert!(text.contains("sfcmul_engine_jobs_failed_total{engine=\"proposed@8\"} 0"));
        assert!(text.contains("sfcmul_engine_panics_caught_total{engine=\"proposed@8\"} 0"));
        assert!(text.contains("sfcmul_engine_deadline_misses_total{engine=\"exact@8\"} 0"));
        assert!(text.contains("sfcmul_engine_breaker_state{engine=\"proposed@8\"} 0"));
        assert!(text.contains("sfcmul_engine_job_latency_ms{engine=\"proposed@8\",quantile=\"0.5\"}"));
        assert!(text.contains("sfcmul_engine_job_latency_ms{engine=\"exact@8\",quantile=\"0.99\"}"));
        assert!(text.contains("sfcmul_server_rejected_total{reason=\"quota\"} 2"));
        assert!(text.contains("sfcmul_server_connections_open 2"));
        // Every non-comment line is `name{...} value` with a parseable value.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, val) = line.rsplit_once(' ').expect("name value");
            val.parse::<f64>().unwrap_or_else(|_| panic!("unparseable value in {line:?}"));
        }
    }
}

//! Image substrate for the edge-detection application (paper §4, Fig. 9).

pub mod pgm;
pub mod synth;
pub mod colsum;
pub mod conv;
pub mod ops;
pub mod psnr;

pub use colsum::ColSumKernel;
pub use conv::{conv3x3, conv3x3_lut, conv3x3_lut_9tap, conv3x3_rowbuf, edge_detect, LAPLACIAN};
pub use ops::{apply_operator, apply_operator_lut, OpProgram, Operator, Post};
pub use pgm::Image;
pub use psnr::psnr;
pub use synth::synthetic_scene;

//! 3×3 spatial convolution with a pluggable multiplier (paper §4).
//!
//! The multiply in the MAC is the 8-bit *signed* multiplier under test.
//! Fixed-point operand conditioning (the "custom convolution layer" of
//! §4): image pixels are 0..255, which does not fit a signed 8-bit
//! operand, so pixels enter the datapath pre-scaled by one right-shift
//! (0..127); kernel coefficients are pre-scaled by `KERNEL_PRESCALE` (×8)
//! so the products are MSB-aligned to the datapath — with the raw
//! Laplacian coefficients (−1, 8) every product would live almost
//! entirely inside the truncated LSP columns and any truncating design
//! would destroy it. MSB-aligning the operands is exactly how a
//! fixed-point designer integrates a truncated multiplier. The output
//! rule is per operator (a [`Post`]: magnitude vs. saturate plus the
//! operator's display shift — the Laplacian's is `|acc| >> 5` clamped to
//! 0..255). Every design, including the exact reference that PSNR is
//! computed against, goes through the identical path, so comparisons are
//! unaffected. The operator registry (kernels + post rules) lives in
//! [`super::ops`]; these functions are the single-pass cores it runs.
//!
//! Three hardware-faithful implementations are provided and tested equal:
//!
//! * [`conv3x3`] — direct zero-padded convolution (the Python reference
//!   path of §4);
//! * [`conv3x3_lut`] — the table-backed fast path: for uniform-ring
//!   kernels (the Laplacian) it runs the sliding column-sum core of
//!   [`super::colsum`]; other kernels fall back to the folded-tap
//!   9-lookup kernel [`conv3x3_lut_9tap`], which is also retained as the
//!   pre-colsum perf baseline (`BENCH_conv.json`);
//! * [`conv3x3_rowbuf`] — the streaming row-buffer datapath of Fig. 8:
//!   two line buffers + a 3×3 window register file, one output per cycle.

use super::colsum::ColSumKernel;
use super::ops::{Operator, Post};
use super::pgm::Image;
use crate::multipliers::MultiplierModel;

/// The Laplacian kernel of Eq. (6).
pub const LAPLACIAN: [[i64; 3]; 3] = [[-1, -1, -1], [-1, 8, -1], [-1, -1, -1]];

/// Pixel pre-shift to fit the signed 8-bit operand range.
pub const PIXEL_SHIFT: u32 = 1;

/// Kernel coefficients are fed to the multiplier as `k << 3` (−8 / +64),
/// MSB-aligning the products to the significant columns.
pub const KERNEL_PRESCALE_SHIFT: u32 = 3;

#[inline]
fn prescale_kernel(k: i64) -> i64 {
    k << KERNEL_PRESCALE_SHIFT
}

/// Output normalisation: the Laplacian response `Σ k·px` spans ±2040 and
/// is conventionally displayed as `|response| / 8` (the centre weight), so
/// the full response range maps exactly onto 0..255.
pub const OUTPUT_NORM_SHIFT: u32 = 3;
// Output post-processing is per operator: each convolution pass carries a
// `super::ops::Post` (magnitude vs. saturate + display shift);
// `Post::LAPLACIAN` is the historical rule (acc = Σ (k<<3)·(px>>1) =
// 4·Σ k·px; display |Σ k·px| >> 3).

/// Direct zero-padded 3×3 convolution using `model` for every multiply,
/// collapsing each accumulator through `post`.
pub fn conv3x3(
    img: &Image,
    kernel: &[[i64; 3]; 3],
    model: &dyn MultiplierModel,
    post: Post,
) -> Image {
    let mut out = Image::new(img.width, img.height);
    for (i, &acc) in conv3x3_acc(img, kernel, model).iter().enumerate() {
        out.data[i] = post.apply(acc);
    }
    out
}

/// The raw per-pixel accumulators of the direct convolution (row-major),
/// before any post-processing — the pre-clamp view the property tests
/// check linearity and gradient antisymmetry on.
pub fn conv3x3_acc(img: &Image, kernel: &[[i64; 3]; 3], model: &dyn MultiplierModel) -> Vec<i64> {
    let mut accs = vec![0i64; img.width * img.height];
    for y in 0..img.height as isize {
        for x in 0..img.width as isize {
            let mut acc = 0i64;
            for ky in -1..=1isize {
                for kx in -1..=1isize {
                    let px = (img.get_padded(x + kx, y + ky) >> PIXEL_SHIFT) as i64;
                    let k = prescale_kernel(kernel[(ky + 1) as usize][(kx + 1) as usize]);
                    acc += model.multiply(px, k); // pixel = operand A (varying bits)
                }
            }
            accs[y as usize * img.width + x as usize] = acc;
        }
    }
    accs
}

/// Direct convolution through a 256×256 product table (index =
/// `(a_byte << 8) | b_byte`) — the fast path used by the coordinator and
/// mirrored by the Pallas kernel.
///
/// Perf (EXPERIMENTS.md §Perf, iteration L3-4): uniform-ring kernels (the
/// Laplacian) run the sliding column-sum core ([`super::colsum`]) over a
/// zero-padded copy of the image — ≈2 lookups + 5 adds per pixel with
/// L1-resident `i32` tap tables, no border special-casing. Kernels with
/// distinct ring coefficients fall back to [`conv3x3_lut_9tap`].
pub fn conv3x3_lut(img: &Image, kernel: &[[i64; 3]; 3], lut: &[i32], post: Post) -> Image {
    assert_eq!(lut.len(), 65536);
    if let Some(k) = ColSumKernel::for_kernel(kernel, lut, post) {
        let (w, h) = (img.width, img.height);
        let mut out = Image::new(w, h);
        if w == 0 || h == 0 {
            return out;
        }
        let padded = padded_copy(img);
        k.run(&padded, w + 2, &mut out.data, w, w, h);
        return out;
    }
    conv3x3_lut_9tap(img, kernel, lut, post)
}

/// Zero-padded `(h+2) × (w+2)` copy of an image — the explicit form of
/// the padding [`Image::get_padded`] synthesises, so the column-sum core
/// can run border rows through the same branch-free inner loop (shared
/// with the operator programs of [`super::ops`]).
pub(crate) fn padded_copy(img: &Image) -> Vec<u8> {
    let (w, h) = (img.width, img.height);
    let mut p = vec![0u8; (w + 2) * (h + 2)];
    for y in 0..h {
        let base = (y + 1) * (w + 2) + 1;
        p[base..base + w].copy_from_slice(&img.data[y * w..(y + 1) * w]);
    }
    p
}

/// The pre-colsum folded-tap kernel: 9 table loads + 8 adds per output
/// pixel on raw row slices, borders through the padded path. Retained
/// verbatim (i) as the fallback for kernels the column-sum identity does
/// not cover and (ii) as the measured baseline the `bench_conv` speedup
/// and the committed `BENCH_conv.json` trajectory compare against.
pub fn conv3x3_lut_9tap(img: &Image, kernel: &[[i64; 3]; 3], lut: &[i32], post: Post) -> Image {
    assert_eq!(lut.len(), 65536);
    // fold per-tap tables
    let mut taps = [[0i32; 256]; 9];
    for (t, tap) in taps.iter_mut().enumerate() {
        let k = prescale_kernel(kernel[t / 3][t % 3]) as i8 as u8 as usize;
        for px in 0..256usize {
            tap[px] = lut[((px >> PIXEL_SHIFT) << 8) | k];
        }
    }
    let (w, h) = (img.width, img.height);
    let mut out = Image::new(w, h);
    // border via the padded path
    let mut border = |x: isize, y: isize, out: &mut Image| {
        let mut acc = 0i64;
        for ky in -1..=1isize {
            for kx in -1..=1isize {
                let px = img.get_padded(x + kx, y + ky) as usize;
                acc += taps[((ky + 1) * 3 + kx + 1) as usize][px] as i64;
            }
        }
        out.set(x as usize, y as usize, post.apply(acc));
    };
    for x in 0..w as isize {
        border(x, 0, &mut out);
        if h > 1 {
            border(x, h as isize - 1, &mut out);
        }
    }
    for y in 1..h.saturating_sub(1) as isize {
        border(0, y, &mut out);
        if w > 1 {
            border(w as isize - 1, y, &mut out);
        }
    }
    // interior on raw slices
    if w >= 3 && h >= 3 {
        for y in 1..h - 1 {
            let r0 = &img.data[(y - 1) * w..(y - 1) * w + w];
            let r1 = &img.data[y * w..y * w + w];
            let r2 = &img.data[(y + 1) * w..(y + 1) * w + w];
            let out_row = &mut out.data[y * w + 1..y * w + w - 1];
            for (i, out_px) in out_row.iter_mut().enumerate() {
                let acc = taps[0][r0[i] as usize] as i64
                    + taps[1][r0[i + 1] as usize] as i64
                    + taps[2][r0[i + 2] as usize] as i64
                    + taps[3][r1[i] as usize] as i64
                    + taps[4][r1[i + 1] as usize] as i64
                    + taps[5][r1[i + 2] as usize] as i64
                    + taps[6][r2[i] as usize] as i64
                    + taps[7][r2[i + 1] as usize] as i64
                    + taps[8][r2[i + 2] as usize] as i64;
                *out_px = post.apply(acc);
            }
        }
    }
    out
}

/// Streaming row-buffer convolution (paper Fig. 8).
///
/// Pixels arrive in raster order; two line buffers hold the previous two
/// scanlines and a 3-wide window register file slides across. Output
/// pixel (x, y) is emitted when input pixel (x+1, y+1) arrives (one-pixel
/// latency plus one line), with zero padding synthesised at the borders.
pub fn conv3x3_rowbuf(
    img: &Image,
    kernel: &[[i64; 3]; 3],
    model: &dyn MultiplierModel,
    post: Post,
) -> Image {
    let (w, h) = (img.width, img.height);
    let mut out = Image::new(w, h);
    // line buffers: rows y-1 and y-2 relative to the arriving pixel
    // (pre-shifted samples, the form they'd be stored in on-chip)
    let mut line1: Vec<u8> = vec![0; w]; // previous row
    let mut line2: Vec<u8> = vec![0; w]; // row before that
    for y in 0..h + 1 {
        // one extra row to flush the last output line
        let mut win = [[0u8; 3]; 3]; // window registers [row][col]
        for x in 0..w + 1 {
            // shift window left
            for row in win.iter_mut() {
                row[0] = row[1];
                row[1] = row[2];
            }
            // load new column: rows y-2, y-1 from line buffers, y from input
            let (c2, c1, c0) = if x < w {
                let fresh = if y < h { img.get(x, y) >> PIXEL_SHIFT } else { 0 };
                let col = (line2[x], line1[x], fresh);
                // rotate line buffers for this column
                line2[x] = line1[x];
                line1[x] = fresh;
                col
            } else {
                (0, 0, 0) // right border flush
            };
            win[0][2] = c2;
            win[1][2] = c1;
            win[2][2] = c0;
            // the window is centred on (x-1, y-1)
            if y >= 1 && x >= 1 {
                let (ox, oy) = (x - 1, y - 1);
                if ox < w && oy < h {
                    let mut acc = 0i64;
                    for (ky, row) in win.iter().enumerate() {
                        for (kx, &px) in row.iter().enumerate() {
                            acc += model.multiply(px as i64, prescale_kernel(kernel[ky][kx]));
                        }
                    }
                    out.set(ox, oy, post.apply(acc));
                }
            }
        }
    }
    out
}

/// Edge detection (paper §4): the Laplacian operator of the registry —
/// one definition of the kernel and clamp rule, shared with every other
/// caller (see [`super::ops`]).
pub fn edge_detect(img: &Image, model: &dyn MultiplierModel) -> Image {
    super::ops::apply_operator(img, Operator::Laplacian, model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::synthetic_scene;
    use crate::multipliers::{build_design, DesignId};

    #[test]
    fn flat_image_has_no_edges() {
        let mut img = Image::new(16, 16);
        img.data.fill(100);
        let exact = build_design(DesignId::Exact, 8);
        let edges = edge_detect(&img, exact.as_ref());
        // interior must be exactly zero (Laplacian of constant)
        for y in 1..15 {
            for x in 1..15 {
                assert_eq!(edges.get(x, y), 0, "({x},{y})");
            }
        }
        // borders see zero padding → strong response
        assert!(edges.get(0, 0) > 0);
    }

    #[test]
    fn step_edge_is_detected() {
        let mut img = Image::new(16, 16);
        for y in 0..16 {
            for x in 0..16 {
                img.set(x, y, if x < 8 { 20 } else { 220 });
            }
        }
        let exact = build_design(DesignId::Exact, 8);
        let edges = edge_detect(&img, exact.as_ref());
        // the step column responds, flat interior does not
        assert!(edges.get(7, 8) > 50, "edge response {}", edges.get(7, 8));
        assert_eq!(edges.get(3, 8), 0);
        assert_eq!(edges.get(12, 8), 0);
    }

    #[test]
    fn rowbuf_equals_direct_exact() {
        let img = synthetic_scene(33, 21, 3);
        let exact = build_design(DesignId::Exact, 8);
        let a = conv3x3(&img, &LAPLACIAN, exact.as_ref(), Post::LAPLACIAN);
        let b = conv3x3_rowbuf(&img, &LAPLACIAN, exact.as_ref(), Post::LAPLACIAN);
        assert_eq!(a, b);
    }

    #[test]
    fn rowbuf_equals_direct_approximate() {
        let img = synthetic_scene(40, 27, 9);
        let m = build_design(DesignId::Proposed, 8);
        let a = conv3x3(&img, &LAPLACIAN, m.as_ref(), Post::LAPLACIAN);
        let b = conv3x3_rowbuf(&img, &LAPLACIAN, m.as_ref(), Post::LAPLACIAN);
        assert_eq!(a, b);
    }

    #[test]
    fn lut_equals_model_conv() {
        let img = synthetic_scene(32, 32, 5);
        let m = build_design(DesignId::Proposed, 8);
        let lut = crate::multipliers::lut::product_table(m.as_ref());
        let a = conv3x3(&img, &LAPLACIAN, m.as_ref(), Post::LAPLACIAN);
        let b = conv3x3_lut(&img, &LAPLACIAN, &lut, Post::LAPLACIAN);
        assert_eq!(a, b);
    }

    /// The column-sum fast path and the retained 9-lookup kernel are one
    /// function to callers — bit-exact on ragged shapes including the
    /// degenerate 1×1 / 1×N / N×1 windows (full sweep over every
    /// registered design lives in `tests/colsum_equiv.rs`).
    #[test]
    fn lut_colsum_equals_9tap_ragged() {
        let m = build_design(DesignId::Proposed, 8);
        let lut = crate::multipliers::lut::product_table(m.as_ref());
        for &(w, h) in &[(1usize, 1usize), (1, 9), (9, 1), (5, 4), (65, 63)] {
            let img = synthetic_scene(w, h, 3);
            let a = conv3x3_lut(&img, &LAPLACIAN, &lut, Post::LAPLACIAN);
            let b = conv3x3_lut_9tap(&img, &LAPLACIAN, &lut, Post::LAPLACIAN);
            assert_eq!(a, b, "{w}x{h}");
        }
    }

    /// Non-uniform-ring kernels route through the generic 9-lookup path
    /// and still match the model convolution.
    #[test]
    fn non_uniform_kernel_falls_back_correctly() {
        let kernel = [[-1i64, 0, 1], [-2, 0, 2], [-1, 0, 1]]; // Sobel-x
        let img = synthetic_scene(24, 17, 6);
        let exact = build_design(DesignId::Exact, 8);
        let lut = crate::multipliers::lut::product_table(exact.as_ref());
        let post = Post::magnitude(3);
        let a = conv3x3(&img, &kernel, exact.as_ref(), post);
        let b = conv3x3_lut(&img, &kernel, &lut, post);
        assert_eq!(a, b);
    }

    #[test]
    fn approximate_edges_resemble_exact() {
        let img = synthetic_scene(64, 64, 11);
        let exact = build_design(DesignId::Exact, 8);
        let prop = build_design(DesignId::Proposed, 8);
        let e = edge_detect(&img, exact.as_ref());
        let p = edge_detect(&img, prop.as_ref());
        let psnr = crate::image::psnr::psnr(&e, &p);
        assert!(psnr > 12.0, "PSNR {psnr} too low — edge structure lost");
    }
}

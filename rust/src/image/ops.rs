//! Operator registry — every edge-detection / filtering operator the
//! system serves, with its 3×3 kernel(s), output post-processing rule,
//! and the folded-tap execution program the table-backed paths run.
//!
//! The paper evaluates one operator (the uniform-ring Laplacian of
//! Eq. (6)); approximate-multiplier surveys evaluate across *several*
//! image kernels because error behaviour is operator-dependent — signed
//! gradient operators (Sobel/Prewitt/Scharr/Roberts) exercise the
//! negative-partial-product path of the sign-focused compressors far
//! harder than the Laplacian does. This module opens that workload:
//!
//! * [`Operator`] — the closed registry of served operators. Single-pass
//!   operators (`laplacian`, `sharpen`, `gaussian3`) run one kernel;
//!   directional operators (`sobel`, `prewitt`, `scharr`, `roberts`) run
//!   a Gx and a Gy pass and combine them into the classic integer
//!   gradient magnitude `min(255, |Gx| + |Gy|)` (saturating u8 add — the
//!   per-component clamp commutes with the final clamp, so clamping each
//!   pass first is exact).
//! * [`Post`] — the per-operator output rule: gradient/magnitude
//!   operators display `|acc| >> s` ([`PostMode::Magnitude`]), filters
//!   display `acc >> s` clamped at 0 ([`PostMode::Saturate`]); `s` folds
//!   the operand-conditioning shifts with the operator's display
//!   normalisation ([`Post::apply`]).
//! * [`OpProgram`] — an operator compiled against one design's products:
//!   per-pass folded tap tables (pixel pre-shift and kernel pre-scale
//!   baked in, exactly like the historical Laplacian fold). Uniform-ring
//!   kernels run the sliding column-sum core of [`super::colsum`]
//!   (≈2 lookups + 5 adds/pixel); other kernels run the generic per-tap
//!   path with **identically-zero tap tables elided** — elision is keyed
//!   on folded table *content*, not on the coefficient, because an
//!   approximate design may return nonzero products for a zero
//!   coefficient (compensation constants). Roberts drops from 9 to
//!   2 lookups per pass this way, the Gx/Gy family from 9 to 6.
//!
//! Operand conditioning is shared with the Laplacian path (see
//! [`super::conv`]): pixels enter pre-shifted by [`PIXEL_SHIFT`], kernel
//! coefficients pre-scaled by [`KERNEL_PRESCALE_SHIFT`] — every
//! coefficient here keeps `|k| ≤ 15` so the pre-scaled operand fits the
//! signed 8-bit multiplier port.

use super::colsum::ColSumKernel;
use super::conv::{conv3x3, padded_copy, KERNEL_PRESCALE_SHIFT, OUTPUT_NORM_SHIFT, PIXEL_SHIFT};
use super::pgm::Image;
use crate::multipliers::MultiplierModel;
use crate::util::error::Error;
use std::fmt;
use std::str::FromStr;

/// How an accumulated response becomes an output pixel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PostMode {
    /// Edge magnitude: `|acc| >> s`, clamped to 0..255 (the Laplacian and
    /// every gradient component).
    Magnitude,
    /// Filter output: `acc >> s` (arithmetic), clamped to 0..255
    /// (sharpen, gaussian smoothing — negative responses floor at black).
    Saturate,
}

/// Per-operator output post-processing: mode + display normalisation.
///
/// The accumulator holds `Σ (k << KERNEL_PRESCALE_SHIFT) · (px >>
/// PIXEL_SHIFT)`, i.e. the operator response on the half-intensity image
/// scaled by 2^(KERNEL_PRESCALE_SHIFT−PIXEL_SHIFT); `apply` folds that
/// conditioning factor with the operator's own `norm_shift` (e.g. the
/// Laplacian's conventional ÷8) into a single shift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Post {
    pub mode: PostMode,
    /// Operator display normalisation (power of two).
    pub norm_shift: u32,
}

impl Post {
    pub const fn magnitude(norm_shift: u32) -> Self {
        Self { mode: PostMode::Magnitude, norm_shift }
    }

    pub const fn saturate(norm_shift: u32) -> Self {
        Self { mode: PostMode::Saturate, norm_shift }
    }

    /// The historical Laplacian rule (`|acc| >> 5`, clamp) — the one rule
    /// every pre-operator-pipeline path hardcoded.
    pub const LAPLACIAN: Post = Post::magnitude(OUTPUT_NORM_SHIFT);

    /// Collapse an accumulated response to an output pixel.
    #[inline]
    pub fn apply(self, acc: i64) -> u8 {
        let s = KERNEL_PRESCALE_SHIFT - PIXEL_SHIFT + self.norm_shift;
        let v = match self.mode {
            PostMode::Magnitude => acc.abs() >> s,
            PostMode::Saturate => acc >> s,
        };
        v.clamp(0, 255) as u8
    }

    /// Row form of [`Post::apply`] for i32 accumulator rows: the mode
    /// branch is hoisted out of the per-pixel loop so the shift/clamp
    /// body is a straight-line loop the compiler can vectorize.
    /// Bit-exact with `apply(acc as i64)` for every i32 (the colsum
    /// path's [`crate::image::colsum::MAX_TAP_ABS`] bound keeps
    /// accumulators far from `i32::MIN`, so `abs` cannot overflow).
    pub fn apply_row(self, acc: &[i32], out: &mut [u8]) {
        assert_eq!(acc.len(), out.len());
        let s = KERNEL_PRESCALE_SHIFT - PIXEL_SHIFT + self.norm_shift;
        match self.mode {
            PostMode::Magnitude => {
                for (o, &a) in out.iter_mut().zip(acc) {
                    *o = (a.abs() >> s).min(255) as u8;
                }
            }
            PostMode::Saturate => {
                for (o, &a) in out.iter_mut().zip(acc) {
                    *o = (a >> s).clamp(0, 255) as u8;
                }
            }
        }
    }
}

/// One convolution pass of an operator: a 3×3 kernel and its output rule.
#[derive(Debug, Clone, Copy)]
pub struct Pass {
    /// Pass label for listings/diagnostics (`laplacian`, `gx`, `gy`, ...).
    pub label: &'static str,
    pub kernel: [[i64; 3]; 3],
    pub post: Post,
}

const fn pass(label: &'static str, kernel: [[i64; 3]; 3], post: Post) -> Pass {
    Pass { label, kernel, post }
}

// Directional kernels. Roberts' classic 2×2 cross pair is embedded in the
// lower-right 2×2 of the 3×3 window (output (x,y) differences pixel
// (x,y) against (x+1,y+1) and (x,y+1) against (x+1,y)), so it rides the
// same 3×3 datapath as everything else.
const SOBEL_GX: [[i64; 3]; 3] = [[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]];
const SOBEL_GY: [[i64; 3]; 3] = [[-1, -2, -1], [0, 0, 0], [1, 2, 1]];
const PREWITT_GX: [[i64; 3]; 3] = [[-1, 0, 1], [-1, 0, 1], [-1, 0, 1]];
const PREWITT_GY: [[i64; 3]; 3] = [[-1, -1, -1], [0, 0, 0], [1, 1, 1]];
const SCHARR_GX: [[i64; 3]; 3] = [[-3, 0, 3], [-10, 0, 10], [-3, 0, 3]];
const SCHARR_GY: [[i64; 3]; 3] = [[-3, -10, -3], [0, 0, 0], [3, 10, 3]];
const ROBERTS_GX: [[i64; 3]; 3] = [[0, 0, 0], [0, 1, 0], [0, 0, -1]];
const ROBERTS_GY: [[i64; 3]; 3] = [[0, 0, 0], [0, 0, 1], [0, -1, 0]];
const SHARPEN_K: [[i64; 3]; 3] = [[0, -1, 0], [-1, 5, -1], [0, -1, 0]];
const GAUSSIAN3_K: [[i64; 3]; 3] = [[1, 2, 1], [2, 4, 2], [1, 2, 1]];

// Per-operator pass tables. Gradient norm shifts are chosen so each
// component spans ≈0..255 before the magnitude sum (Σ|k| per direction:
// sobel 8 → ÷8, prewitt 6 → ÷8, scharr 32 → ÷32, roberts 2 → ÷2);
// saturate shifts map the filter's DC gain back to unity (sharpen Σk=1,
// gaussian Σk=16).
const PASSES_LAPLACIAN: [Pass; 1] =
    [pass("laplacian", super::conv::LAPLACIAN, Post::LAPLACIAN)];
const PASSES_SOBEL: [Pass; 2] = [
    pass("gx", SOBEL_GX, Post::magnitude(3)),
    pass("gy", SOBEL_GY, Post::magnitude(3)),
];
const PASSES_PREWITT: [Pass; 2] = [
    pass("gx", PREWITT_GX, Post::magnitude(3)),
    pass("gy", PREWITT_GY, Post::magnitude(3)),
];
const PASSES_SCHARR: [Pass; 2] = [
    pass("gx", SCHARR_GX, Post::magnitude(5)),
    pass("gy", SCHARR_GY, Post::magnitude(5)),
];
const PASSES_ROBERTS: [Pass; 2] = [
    pass("gx", ROBERTS_GX, Post::magnitude(1)),
    pass("gy", ROBERTS_GY, Post::magnitude(1)),
];
const PASSES_SHARPEN: [Pass; 1] = [pass("sharpen", SHARPEN_K, Post::saturate(0))];
const PASSES_GAUSSIAN3: [Pass; 1] = [pass("gaussian3", GAUSSIAN3_K, Post::saturate(4))];

/// Number of registered operators ([`Operator::all`]).
pub const OPERATOR_COUNT: usize = 7;

/// The served operator set. Discriminants are the wire ids carried by
/// coordinator tiles ([`Operator::id`] / [`Operator::from_id`]); the
/// Laplacian is id 0, the historical default.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Operator {
    /// Uniform-ring Laplacian of paper Eq. (6) — the original workload.
    Laplacian,
    /// Sobel gradient magnitude |Gx|+|Gy|.
    Sobel,
    /// Prewitt gradient magnitude.
    Prewitt,
    /// Scharr gradient magnitude (rotation-optimised 3×3 derivative).
    Scharr,
    /// Roberts cross gradient magnitude (2×2 pair on the 3×3 datapath).
    Roberts,
    /// Identity + Laplacian sharpening filter.
    Sharpen,
    /// 3×3 binomial Gaussian smoothing.
    Gaussian3,
}

impl Operator {
    /// Every registered operator, id order.
    pub const fn all() -> [Operator; OPERATOR_COUNT] {
        [
            Operator::Laplacian,
            Operator::Sobel,
            Operator::Prewitt,
            Operator::Scharr,
            Operator::Roberts,
            Operator::Sharpen,
            Operator::Gaussian3,
        ]
    }

    /// Stable wire id (the `Tile::op` routing byte).
    pub const fn id(self) -> u8 {
        self as u8
    }

    /// Inverse of [`Operator::id`].
    pub fn from_id(id: u8) -> Option<Operator> {
        Operator::all().get(id as usize).copied()
    }

    /// Canonical CLI/spec key.
    pub const fn key(self) -> &'static str {
        match self {
            Operator::Laplacian => "laplacian",
            Operator::Sobel => "sobel",
            Operator::Prewitt => "prewitt",
            Operator::Scharr => "scharr",
            Operator::Roberts => "roberts",
            Operator::Sharpen => "sharpen",
            Operator::Gaussian3 => "gaussian3",
        }
    }

    /// The convolution passes this operator runs (1 for plain filters,
    /// 2 — Gx then Gy — for gradient-magnitude operators).
    pub fn passes(self) -> &'static [Pass] {
        match self {
            Operator::Laplacian => &PASSES_LAPLACIAN,
            Operator::Sobel => &PASSES_SOBEL,
            Operator::Prewitt => &PASSES_PREWITT,
            Operator::Scharr => &PASSES_SCHARR,
            Operator::Roberts => &PASSES_ROBERTS,
            Operator::Sharpen => &PASSES_SHARPEN,
            Operator::Gaussian3 => &PASSES_GAUSSIAN3,
        }
    }

    /// True for the two-pass |Gx|+|Gy| operators.
    pub fn is_gradient_pair(self) -> bool {
        self.passes().len() == 2
    }

    /// One-line description for the `ops` listing.
    pub const fn describe(self) -> &'static str {
        match self {
            Operator::Laplacian => "uniform-ring Laplacian edge magnitude (paper Eq. 6)",
            Operator::Sobel => "Sobel |Gx|+|Gy| gradient magnitude",
            Operator::Prewitt => "Prewitt |Gx|+|Gy| gradient magnitude",
            Operator::Scharr => "Scharr |Gx|+|Gy| gradient magnitude",
            Operator::Roberts => "Roberts cross |Gx|+|Gy| gradient magnitude",
            Operator::Sharpen => "identity + Laplacian sharpening filter",
            Operator::Gaussian3 => "3x3 binomial Gaussian smoothing",
        }
    }
}

impl fmt::Display for Operator {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.key())
    }
}

impl FromStr for Operator {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self, Error> {
        let lower = s.trim().to_lowercase();
        Operator::all()
            .into_iter()
            .find(|op| op.key() == lower)
            .ok_or_else(|| {
                let keys: Vec<&str> = Operator::all().iter().map(|o| o.key()).collect();
                Error::msg(format!("unknown operator {s:?} ({})", keys.join(" | ")))
            })
    }
}

/// Saturating per-pixel magnitude combine: `a[i] = min(255, a[i]+b[i])`.
pub fn combine_magnitude(a: &mut [u8], b: &[u8]) {
    for (x, &y) in a.iter_mut().zip(b) {
        *x = x.saturating_add(y);
    }
}

/// How one compiled pass executes — exposed for the `ops` listing and the
/// fast-path tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassKind {
    /// Uniform-ring sliding column-sum core (≈2 lookups + 5 adds/pixel).
    ColSum,
    /// Generic folded-tap path with this many active (non-zero-table)
    /// taps, i32 tables.
    Taps(usize),
    /// Generic path with i64 tables (wide designs whose products exceed
    /// the i32-safe bound).
    WideTaps(usize),
}

impl fmt::Display for PassKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PassKind::ColSum => write!(f, "colsum"),
            PassKind::Taps(n) => write!(f, "taps({n})"),
            PassKind::WideTaps(n) => write!(f, "taps-wide({n})"),
        }
    }
}

/// One active tap of the generic folded path: its window offset
/// (precomputed at fold time — nothing per-call to derive) and table.
struct Tap<T> {
    dy: usize,
    dx: usize,
    table: Box<[T; 256]>,
}

enum PassKernel {
    ColSum(ColSumKernel),
    Taps { taps: Vec<Tap<i32>>, post: Post },
    WideTaps { taps: Vec<Tap<i64>>, post: Post },
}

impl PassKernel {
    /// Fold one pass against a product source. `prod(a, b)` is the
    /// design's product for the *conditioned* operands: `a` the
    /// pre-shifted pixel (0..=127 at the current [`PIXEL_SHIFT`]), `b`
    /// the pre-scaled kernel coefficient.
    fn build(p: &Pass, prod: &dyn Fn(u8, i8) -> i64) -> Self {
        let fold = |k: i64| -> Box<[i64; 256]> {
            let scaled = k << KERNEL_PRESCALE_SHIFT;
            debug_assert_eq!(scaled as i8 as i64, scaled, "coefficient {k} overflows the operand");
            let kb = scaled as i8;
            let mut t = Box::new([0i64; 256]);
            for (px, slot) in t.iter_mut().enumerate() {
                *slot = prod((px as u8) >> PIXEL_SHIFT, kb);
            }
            t
        };
        // Uniform-ring kernels take the sliding column-sum core when the
        // folded taps fit its i32-safe bound (eligibility shared with the
        // direct path: `colsum::uniform_ring`).
        if let Some((center, ring)) = crate::image::colsum::uniform_ring(&p.kernel) {
            let tap_center = fold(center);
            let tap_ring = fold(ring);
            if let Some(k) = ColSumKernel::try_from_taps(&tap_center, &tap_ring, p.post) {
                return PassKernel::ColSum(k);
            }
            return Self::from_tables(
                (0..9u8).map(|t| if t == 4 { tap_center.clone() } else { tap_ring.clone() }),
                p.post,
            );
        }
        Self::from_tables((0..9u8).map(|t| fold(p.kernel[t as usize / 3][t as usize % 3])), p.post)
    }

    /// Classify folded tables: elide identically-zero ones (exact for any
    /// input — the table *is* the tap's entire contribution), use i32
    /// tables when every value fits (L1-friendly), i64 otherwise.
    fn from_tables(tables: impl Iterator<Item = Box<[i64; 256]>>, post: Post) -> Self {
        let active: Vec<(usize, Box<[i64; 256]>)> = tables
            .enumerate()
            .filter(|(_, t)| t.iter().any(|&v| v != 0))
            .collect();
        let fits_i32 = active
            .iter()
            .all(|(_, t)| t.iter().all(|&v| i32::try_from(v).is_ok()));
        if fits_i32 {
            let taps = active
                .into_iter()
                .map(|(i, t)| {
                    let mut n = Box::new([0i32; 256]);
                    for (d, &s) in n.iter_mut().zip(t.iter()) {
                        *d = s as i32;
                    }
                    Tap { dy: i / 3, dx: i % 3, table: n }
                })
                .collect();
            PassKernel::Taps { taps, post }
        } else {
            let taps = active
                .into_iter()
                .map(|(i, t)| Tap { dy: i / 3, dx: i % 3, table: t })
                .collect();
            PassKernel::WideTaps { taps, post }
        }
    }

    fn kind(&self) -> PassKind {
        match self {
            PassKernel::ColSum(_) => PassKind::ColSum,
            PassKernel::Taps { taps, .. } => PassKind::Taps(taps.len()),
            PassKernel::WideTaps { taps, .. } => PassKind::WideTaps(taps.len()),
        }
    }

    /// Run over a zero-padding-included window (same contract as
    /// [`ColSumKernel::run`]): the `(out_h+2) × (out_w+2)` source window
    /// starting at `src[0]` with rows `src_stride` apart.
    fn run(
        &self,
        src: &[u8],
        src_stride: usize,
        out: &mut [u8],
        out_stride: usize,
        out_w: usize,
        out_h: usize,
    ) {
        match self {
            PassKernel::ColSum(k) => k.run(src, src_stride, out, out_stride, out_w, out_h),
            PassKernel::Taps { taps, post } => {
                run_taps(taps, *post, src, src_stride, out, out_stride, out_w, out_h)
            }
            PassKernel::WideTaps { taps, post } => {
                run_taps(taps, *post, src, src_stride, out, out_stride, out_w, out_h)
            }
        }
    }
}

fn run_taps<T: Copy + Into<i64>>(
    taps: &[Tap<T>],
    post: Post,
    src: &[u8],
    src_stride: usize,
    out: &mut [u8],
    out_stride: usize,
    out_w: usize,
    out_h: usize,
) {
    assert!(out_w >= 1 && out_h >= 1, "empty output window");
    assert!(src_stride >= out_w + 2, "src rows narrower than the window");
    assert!(out_stride >= out_w, "out rows narrower than the output");
    assert!(src.len() >= (out_h + 1) * src_stride + out_w + 2, "src window out of bounds");
    assert!(out.len() >= (out_h - 1) * out_stride + out_w, "out buffer too small");
    for oy in 0..out_h {
        let out_row = &mut out[oy * out_stride..oy * out_stride + out_w];
        for (ox, out_px) in out_row.iter_mut().enumerate() {
            let mut acc = 0i64;
            for t in taps {
                acc += t.table[src[(oy + t.dy) * src_stride + ox + t.dx] as usize].into();
            }
            *out_px = post.apply(acc);
        }
    }
}

/// An operator compiled against one design's product source: the folded
/// per-pass execution programs every table-backed path runs (the direct
/// [`apply_operator_lut`] convolution and the coordinator tile engines).
pub struct OpProgram {
    op: Operator,
    passes: Vec<PassKernel>,
}

impl OpProgram {
    /// Compile `op` against an arbitrary product source (`prod(a, b)` =
    /// the design's product of pre-shifted pixel `a` and pre-scaled
    /// coefficient `b`). The LUT engines pass a table lookup; the bitsim
    /// engine passes netlist-swept products.
    pub fn build(op: Operator, prod: &dyn Fn(u8, i8) -> i64) -> Self {
        Self { op, passes: op.passes().iter().map(|p| PassKernel::build(p, prod)).collect() }
    }

    /// Compile against a 256×256 product table (index
    /// `(a_byte << 8) | b_byte`).
    pub fn from_lut(op: Operator, lut: &[i32]) -> Self {
        assert_eq!(lut.len(), 65536);
        Self::build(op, &|a, b| lut[((a as usize) << 8) | (b as u8 as usize)] as i64)
    }

    pub fn operator(&self) -> Operator {
        self.op
    }

    /// How each pass executes (listing / fast-path tests).
    pub fn pass_kinds(&self) -> Vec<PassKind> {
        self.passes.iter().map(|p| p.kind()).collect()
    }

    /// Run the whole program over a zero-padding-included window (the
    /// contract of [`ColSumKernel::run`]); multi-pass operators combine
    /// components with the saturating magnitude sum.
    pub fn run_window(
        &self,
        src: &[u8],
        src_stride: usize,
        out: &mut [u8],
        out_stride: usize,
        out_w: usize,
        out_h: usize,
    ) {
        if out_w == 0 || out_h == 0 {
            return;
        }
        self.passes[0].run(src, src_stride, out, out_stride, out_w, out_h);
        if self.passes.len() > 1 {
            let mut scratch = vec![0u8; out_w * out_h];
            for p in &self.passes[1..] {
                p.run(src, src_stride, &mut scratch, out_w, out_w, out_h);
                for oy in 0..out_h {
                    combine_magnitude(
                        &mut out[oy * out_stride..oy * out_stride + out_w],
                        &scratch[oy * out_w..(oy + 1) * out_w],
                    );
                }
            }
        }
    }

    /// Convolve a whole image (zero padding at the borders, one padded
    /// copy shared by all passes).
    pub fn apply(&self, img: &Image) -> Image {
        let (w, h) = (img.width, img.height);
        let mut out = Image::new(w, h);
        if w == 0 || h == 0 {
            return out;
        }
        let padded = padded_copy(img);
        self.run_window(&padded, w + 2, &mut out.data, w, w, h);
        out
    }
}

/// Run an operator through the functional-model reference path: one
/// direct [`conv3x3`] per pass (every MAC through `model`), gradient
/// components combined with the saturating magnitude sum.
pub fn apply_operator(img: &Image, op: Operator, model: &dyn MultiplierModel) -> Image {
    let mut it = op.passes().iter();
    let first = it.next().expect("operator has at least one pass");
    let mut out = conv3x3(img, &first.kernel, model, first.post);
    for p in it {
        let comp = conv3x3(img, &p.kernel, model, p.post);
        combine_magnitude(&mut out.data, &comp.data);
    }
    out
}

/// Run an operator through the table-backed fast path (the program the
/// serving engines execute). Bit-exact with [`apply_operator`] for the
/// design the table was generated from.
pub fn apply_operator_lut(img: &Image, op: Operator, lut: &[i32]) -> Image {
    OpProgram::from_lut(op, lut).apply(img)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::synthetic_scene;
    use crate::multipliers::{lut::product_table, registry};

    fn exact_lut() -> Vec<i32> {
        product_table(registry().build_str("exact@8").unwrap().as_ref())
    }

    #[test]
    fn keys_roundtrip_and_ids_are_stable() {
        for (i, op) in Operator::all().into_iter().enumerate() {
            assert_eq!(op.id() as usize, i);
            assert_eq!(Operator::from_id(op.id()), Some(op));
            assert_eq!(op.key().parse::<Operator>().unwrap(), op);
            assert_eq!(op.to_string(), op.key());
        }
        assert_eq!(Operator::Laplacian.id(), 0, "laplacian is the wire default");
        assert!("canny".parse::<Operator>().is_err());
        assert!(Operator::from_id(OPERATOR_COUNT as u8).is_none());
    }

    #[test]
    fn all_coefficients_fit_the_signed_operand() {
        for op in Operator::all() {
            for p in op.passes() {
                for row in &p.kernel {
                    for &k in row {
                        let scaled = k << KERNEL_PRESCALE_SHIFT;
                        assert_eq!(scaled as i8 as i64, scaled, "{op} {k}");
                    }
                }
            }
        }
    }

    #[test]
    fn laplacian_post_matches_historical_rule() {
        for acc in [-100_000i64, -31, 0, 31, 32, 8_191, 100_000] {
            assert_eq!(Post::LAPLACIAN.apply(acc), crate::image::colsum::postprocess(acc));
        }
        // saturate floors negatives at black instead of mirroring them
        assert_eq!(Post::saturate(0).apply(-400), 0);
        assert_eq!(Post::magnitude(0).apply(-400), 100);
    }

    /// The hoisted row form of the output rule is bit-exact with the
    /// per-pixel form for every registered post rule, across sign,
    /// clamp-edge, and saturation cases.
    #[test]
    fn apply_row_matches_apply_per_pixel() {
        let mut posts: Vec<Post> = vec![Post::LAPLACIAN];
        for op in Operator::all() {
            posts.extend(op.passes().iter().map(|p| p.post));
        }
        let acc: Vec<i32> = vec![
            i32::MIN / 16,
            -1_000_000,
            -8192,
            -8191,
            -400,
            -32,
            -31,
            -1,
            0,
            1,
            31,
            32,
            8191,
            8192,
            1_000_000,
            i32::MAX / 16,
        ];
        for post in posts {
            let mut row = vec![0u8; acc.len()];
            post.apply_row(&acc, &mut row);
            for (&a, &got) in acc.iter().zip(&row) {
                assert_eq!(got, post.apply(a as i64), "{post:?} acc {a}");
            }
        }
    }

    #[test]
    fn lut_path_matches_model_path_for_every_operator() {
        for name in ["exact@8", "proposed@8"] {
            let model = registry().build_str(name).unwrap();
            let lut = product_table(model.as_ref());
            let img = synthetic_scene(40, 33, 9);
            for op in Operator::all() {
                assert_eq!(
                    apply_operator_lut(&img, op, &lut),
                    apply_operator(&img, op, model.as_ref()),
                    "{name} {op}"
                );
            }
        }
    }

    #[test]
    fn laplacian_program_takes_the_colsum_fast_path() {
        let lut = exact_lut();
        let prog = OpProgram::from_lut(Operator::Laplacian, &lut);
        assert_eq!(prog.pass_kinds(), vec![PassKind::ColSum]);
    }

    /// Zero-tap elision is keyed on folded-table content: with the exact
    /// multiplier (zero products are zero) Roberts keeps only its 2 live
    /// taps and the Sobel passes keep 6; a design whose zero-coefficient
    /// products are nonzero (here: a doctored table) keeps all 9.
    #[test]
    fn zero_taps_elide_only_when_products_vanish() {
        let lut = exact_lut();
        let roberts = OpProgram::from_lut(Operator::Roberts, &lut);
        assert_eq!(roberts.pass_kinds(), vec![PassKind::Taps(2), PassKind::Taps(2)]);
        let sobel = OpProgram::from_lut(Operator::Sobel, &lut);
        assert_eq!(sobel.pass_kinds(), vec![PassKind::Taps(6), PassKind::Taps(6)]);

        let mut biased = lut.clone();
        for a in 0..256usize {
            biased[a << 8] = 1; // multiply(a, 0) == 1: k=0 taps now live
        }
        let roberts_biased = OpProgram::from_lut(Operator::Roberts, &biased);
        assert_eq!(
            roberts_biased.pass_kinds(),
            vec![PassKind::Taps(9), PassKind::Taps(9)]
        );
    }

    #[test]
    fn sobel_detects_a_vertical_step_edge() {
        let mut img = Image::new(16, 16);
        for y in 0..16 {
            for x in 0..16 {
                img.set(x, y, if x < 8 { 20 } else { 220 });
            }
        }
        let exact = registry().build_str("exact@8").unwrap();
        let edges = apply_operator(&img, Operator::Sobel, exact.as_ref());
        assert!(edges.get(7, 8) > 50, "step column must respond, got {}", edges.get(7, 8));
        assert_eq!(edges.get(3, 8), 0, "flat interior is silent");
        assert_eq!(edges.get(12, 8), 0);
    }

    /// The magnitude combine saturates at 255 instead of wrapping. (With
    /// the exact multiplier each normalised component tops out near 127,
    /// so the clamp is the safety net for approximate-design overshoot —
    /// exercise it directly.)
    #[test]
    fn magnitude_combine_saturates() {
        let mut a = [200u8, 10, 255, 0];
        combine_magnitude(&mut a, &[100, 5, 255, 0]);
        assert_eq!(a, [255, 15, 255, 0]);
    }

    /// A corner against zero padding drives both gradient components at
    /// once; the flat interior stays silent.
    #[test]
    fn gradient_corner_responds_in_both_components() {
        let mut img = Image::new(8, 8);
        img.data.fill(255);
        let exact = registry().build_str("exact@8").unwrap();
        let edges = apply_operator(&img, Operator::Scharr, exact.as_ref());
        assert!(edges.get(0, 0) > 150, "corner response {}", edges.get(0, 0));
        assert_eq!(edges.get(4, 4), 0, "flat interior stays black");
    }

    /// Gaussian smoothing with the exact multiplier reproduces a flat
    /// image up to the pixel pre-shift quantisation, and sharpen is
    /// identity-plus-detail on flat input.
    #[test]
    fn saturate_filters_preserve_flat_interiors() {
        let mut img = Image::new(12, 12);
        img.data.fill(200);
        let exact = registry().build_str("exact@8").unwrap();
        let smooth = apply_operator(&img, Operator::Gaussian3, exact.as_ref());
        let sharp = apply_operator(&img, Operator::Sharpen, exact.as_ref());
        for y in 2..10 {
            for x in 2..10 {
                assert_eq!(smooth.get(x, y), 200, "gaussian interior ({x},{y})");
                assert_eq!(sharp.get(x, y), 200, "sharpen interior ({x},{y})");
            }
        }
    }

    #[test]
    fn empty_images_are_handled() {
        let lut = exact_lut();
        for (w, h) in [(0usize, 0usize), (0, 4), (4, 0)] {
            let img = Image::new(w, h);
            let out = apply_operator_lut(&img, Operator::Sobel, &lut);
            assert_eq!((out.width, out.height), (w, h));
        }
    }
}

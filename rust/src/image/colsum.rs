//! Sliding column-sum 3×3 convolution core — the shared hot path of the
//! direct LUT convolution and every table-backed serving engine.
//!
//! The Laplacian of Eq. (6) has only **two distinct coefficients**: the
//! centre (+8) and a uniform ring (−1). After tap folding (pixel
//! pre-shift and kernel pre-scale baked into 256-entry tables) an output
//! pixel is
//!
//! ```text
//! acc(x, y) = Σ_ring tr[px] + tc[centre px]
//!           = Σ_{3×3}  tr[px] + Δ[centre px]        Δ = tc − tr
//! ```
//!
//! so the 9-lookup / 8-add inner loop collapses into a separable sum:
//! keep per-row *tap vectors* `tv[r][x] = tr[row_r[x]]` in three rolling
//! buffers (when the window moves down one output row, two of the three
//! rows are reused verbatim and only the incoming row is looked up), fold
//! them into *column sums* `cs[x] = tv0[x] + tv1[x] + tv2[x]`, and emit
//!
//! ```text
//! out[x] = postprocess(cs[x] + cs[x+1] + cs[x+2] + Δ[mid[x+1]])
//! ```
//!
//! — amortised ≈2 table lookups + 5 adds per output pixel (one fresh-row
//! `tap_ring` fill plus the unconditional `Δ` lookup) instead of
//! 9 lookups + 8 adds. The per-row stages are flat `i32`-slice loops
//! with no per-pixel branch: the column-sum fold and sliding-window sum
//! dispatch to explicit SSE2/AVX2 kernels on x86-64 (runtime feature
//! detection, std-only, scalar fallback everywhere else — set
//! `SFCMUL_NO_SIMD=1` to force scalar), and the output rule runs
//! row-at-a-time via [`Post::apply_row`]. Tap tables are `i32` (1 KiB
//! each, L1-resident, SIMD-friendly) instead of the historical `i64`;
//! [`MAX_TAP_ABS`] bounds
//! every tap so the widest possible i32 accumulation cannot wrap, keeping
//! the kernel bit-exact with the i64 reference
//! ([`crate::coordinator::engine::conv_tile_taps`], retained as the
//! pre-colsum baseline and wide-design fallback).

use super::conv::{KERNEL_PRESCALE_SHIFT, PIXEL_SHIFT};
use super::ops::Post;

/// Elementwise three-way add — the column-sum fold `cs[x] = tv0[x] +
/// tv1[x] + tv2[x]`. The scalar reference the SIMD paths are proved
/// bit-identical to (i32 wrapping add is associative lane-wise, so the
/// vector forms cannot diverge; the tests pin it anyway).
fn sum3_rows_scalar(a: &[i32], b: &[i32], c: &[i32], cs: &mut [i32]) {
    for (((o, &x), &y), &z) in cs.iter_mut().zip(a).zip(b).zip(c) {
        *o = x + y + z;
    }
}

/// Sliding 3-window sum over the column sums: `acc[x] = cs[x] + cs[x+1]
/// + cs[x+2]` for `x` in `0..cs.len()-2`. Scalar reference.
fn window3_scalar(cs: &[i32], acc: &mut [i32]) {
    debug_assert_eq!(acc.len() + 2, cs.len());
    for (x, o) in acc.iter_mut().enumerate() {
        *o = cs[x] + cs[x + 1] + cs[x + 2];
    }
}

/// Explicit x86-64 vector forms of the two row primitives, selected at
/// runtime ([`isa`]) — std-only (`std::arch` + `is_x86_feature_detected!`),
/// scalar fallback everywhere else. Both loops are pure unaligned
/// i32-lane loads + adds; tails shorter than one vector run the scalar
/// form, so every width down to 1 is served.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sum3_rows_avx2(a: &[i32], b: &[i32], c: &[i32], cs: &mut [i32]) {
        let n = cs.len();
        let mut x = 0usize;
        while x + 8 <= n {
            let va = _mm256_loadu_si256(a.as_ptr().add(x) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(x) as *const __m256i);
            let vc = _mm256_loadu_si256(c.as_ptr().add(x) as *const __m256i);
            let s = _mm256_add_epi32(_mm256_add_epi32(va, vb), vc);
            _mm256_storeu_si256(cs.as_mut_ptr().add(x) as *mut __m256i, s);
            x += 8;
        }
        super::sum3_rows_scalar(&a[x..n], &b[x..n], &c[x..n], &mut cs[x..n]);
    }

    /// # Safety
    /// SSE2 is part of the x86-64 baseline; callers reach this only on
    /// x86-64, so the target feature is always present.
    #[target_feature(enable = "sse2")]
    pub unsafe fn sum3_rows_sse2(a: &[i32], b: &[i32], c: &[i32], cs: &mut [i32]) {
        let n = cs.len();
        let mut x = 0usize;
        while x + 4 <= n {
            let va = _mm_loadu_si128(a.as_ptr().add(x) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(x) as *const __m128i);
            let vc = _mm_loadu_si128(c.as_ptr().add(x) as *const __m128i);
            let s = _mm_add_epi32(_mm_add_epi32(va, vb), vc);
            _mm_storeu_si128(cs.as_mut_ptr().add(x) as *mut __m128i, s);
            x += 4;
        }
        super::sum3_rows_scalar(&a[x..n], &b[x..n], &c[x..n], &mut cs[x..n]);
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn window3_avx2(cs: &[i32], acc: &mut [i32]) {
        let n = acc.len(); // cs.len() - 2, so x + 2 + 8 <= cs.len() holds below
        let mut x = 0usize;
        while x + 8 <= n {
            let v0 = _mm256_loadu_si256(cs.as_ptr().add(x) as *const __m256i);
            let v1 = _mm256_loadu_si256(cs.as_ptr().add(x + 1) as *const __m256i);
            let v2 = _mm256_loadu_si256(cs.as_ptr().add(x + 2) as *const __m256i);
            let s = _mm256_add_epi32(_mm256_add_epi32(v0, v1), v2);
            _mm256_storeu_si256(acc.as_mut_ptr().add(x) as *mut __m256i, s);
            x += 8;
        }
        super::window3_scalar(&cs[x..], &mut acc[x..]);
    }

    /// # Safety
    /// SSE2 is part of the x86-64 baseline (see [`sum3_rows_sse2`]).
    #[target_feature(enable = "sse2")]
    pub unsafe fn window3_sse2(cs: &[i32], acc: &mut [i32]) {
        let n = acc.len();
        let mut x = 0usize;
        while x + 4 <= n {
            let v0 = _mm_loadu_si128(cs.as_ptr().add(x) as *const __m128i);
            let v1 = _mm_loadu_si128(cs.as_ptr().add(x + 1) as *const __m128i);
            let v2 = _mm_loadu_si128(cs.as_ptr().add(x + 2) as *const __m128i);
            let s = _mm_add_epi32(_mm_add_epi32(v0, v1), v2);
            _mm_storeu_si128(acc.as_mut_ptr().add(x) as *mut __m128i, s);
            x += 4;
        }
        super::window3_scalar(&cs[x..], &mut acc[x..]);
    }
}

/// Instruction set the row primitives dispatch to, detected once per
/// process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Isa {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Sse2,
    #[cfg(target_arch = "x86_64")]
    Avx2,
}

fn isa() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        use std::sync::OnceLock;
        static ISA: OnceLock<Isa> = OnceLock::new();
        *ISA.get_or_init(|| {
            if std::env::var_os("SFCMUL_NO_SIMD").is_some() {
                Isa::Scalar
            } else if std::is_x86_feature_detected!("avx2") {
                Isa::Avx2
            } else {
                // SSE2 is architecturally guaranteed on x86-64.
                Isa::Sse2
            }
        })
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        Isa::Scalar
    }
}

/// `cs[x] = a[x] + b[x] + c[x]`, dispatched to the widest available ISA.
fn sum3_rows(a: &[i32], b: &[i32], c: &[i32], cs: &mut [i32]) {
    assert!(a.len() >= cs.len() && b.len() >= cs.len() && c.len() >= cs.len());
    match isa() {
        Isa::Scalar => sum3_rows_scalar(a, b, c, cs),
        // SAFETY: variant selected only after runtime feature detection.
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::sum3_rows_sse2(a, b, c, cs) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::sum3_rows_avx2(a, b, c, cs) },
    }
}

/// `acc[x] = cs[x] + cs[x+1] + cs[x+2]`, dispatched like [`sum3_rows`].
fn window3(cs: &[i32], acc: &mut [i32]) {
    assert_eq!(acc.len() + 2, cs.len());
    match isa() {
        Isa::Scalar => window3_scalar(cs, acc),
        // SAFETY: variant selected only after runtime feature detection.
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::window3_sse2(cs, acc) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::window3_avx2(cs, acc) },
    }
}

/// The historical Laplacian output rule, shared by the retained
/// pre-operator-pipeline baselines (9-lookup kernels, benches): the
/// accumulator holds `Σ (k << KERNEL_PRESCALE_SHIFT) · (px >>
/// PIXEL_SHIFT) = 4·Σ k·px`; the displayed edge magnitude is
/// `|Σ k·px| >> OUTPUT_NORM_SHIFT` clamped to 0..255. Operator-aware
/// paths carry their own [`Post`] instead ([`Post::LAPLACIAN`] is this
/// exact rule).
#[inline]
pub fn postprocess(acc: i64) -> u8 {
    Post::LAPLACIAN.apply(acc)
}

/// Largest tap magnitude the i32 accumulation path absorbs safely: one
/// output sums three column sums (3 taps each) plus one centre delta
/// (±2 taps) — at most 11 tap magnitudes — so taps bounded by
/// `i32::MAX / 16` can never wrap. Every 8-bit product table fits by
/// orders of magnitude (16-bit product bus); only very wide compensated
/// netlist designs can exceed it, and those fall back to the i64 path.
pub const MAX_TAP_ABS: i64 = (i32::MAX / 16) as i64;

/// Fold per-coefficient i64 tap tables from a 256×256 product table:
/// `tap[px] = lut[(px >> PIXEL_SHIFT) << 8 | byte(k << PRESCALE)]`.
fn fold_taps_i64(lut: &[i32], k_center: i64, k_ring: i64) -> (Box<[i64; 256]>, Box<[i64; 256]>) {
    assert_eq!(lut.len(), 65536);
    let kb_center = ((k_center << KERNEL_PRESCALE_SHIFT) as i8) as u8 as usize;
    let kb_ring = ((k_ring << KERNEL_PRESCALE_SHIFT) as i8) as u8 as usize;
    let mut tap_center = Box::new([0i64; 256]);
    let mut tap_ring = Box::new([0i64; 256]);
    for px in 0..256usize {
        let row = (px >> PIXEL_SHIFT) << 8;
        tap_center[px] = lut[row | kb_center] as i64;
        tap_ring[px] = lut[row | kb_ring] as i64;
    }
    (tap_center, tap_ring)
}

/// The Laplacian's centre/ring tap tables in the historical i64 form —
/// the **single** fold shared by the [`ColSumKernel`] constructors, the
/// engines' wide-tap fallback, and the retained 9-lookup baselines in
/// benches and equivalence tests.
pub fn laplacian_taps_i64(lut: &[i32]) -> (Box<[i64; 256]>, Box<[i64; 256]>) {
    let k = &super::conv::LAPLACIAN;
    fold_taps_i64(lut, k[1][1], k[0][0])
}

/// The **single** uniform-ring eligibility test: `Some((center, ring))`
/// when all eight non-centre coefficients are one value — the structural
/// precondition of the column-sum identity. Shared by
/// [`ColSumKernel::for_kernel`] and the operator-program compiler
/// ([`crate::image::ops`]), so the direct path and the serving engines
/// can never classify the same kernel differently.
pub fn uniform_ring(kernel: &[[i64; 3]; 3]) -> Option<(i64, i64)> {
    let ring = kernel[0][0];
    let uniform = (0..9).filter(|t| *t != 4).all(|t| kernel[t / 3][t % 3] == ring);
    uniform.then_some((kernel[1][1], ring))
}

/// Folded two-coefficient tap tables for the sliding column-sum kernel.
///
/// `tap_ring[px]` is the pre-scaled ring product for a raw pixel byte
/// (pixel pre-shift baked in); `center_delta[px] = tap_center[px] −
/// tap_ring[px]` corrects the uniform 3×3 ring sum at the centre tap.
/// Works for **any** uniform-ring kernel and output rule — the centre and
/// ring coefficients and the [`Post`] are the caller's (the operator
/// registry of [`super::ops`] decides both).
pub struct ColSumKernel {
    tap_ring: Box<[i32; 256]>,
    center_delta: Box<[i32; 256]>,
    post: Post,
}

impl ColSumKernel {
    /// Build from explicit centre/ring tap tables (the form the bitsim
    /// engine produces by sweeping a netlist). Returns `None` when any
    /// tap exceeds [`MAX_TAP_ABS`] — the caller must then keep the i64
    /// reference path.
    pub fn try_from_taps(
        tap_center: &[i64; 256],
        tap_ring: &[i64; 256],
        post: Post,
    ) -> Option<Self> {
        if tap_center.iter().chain(tap_ring.iter()).any(|v| v.abs() > MAX_TAP_ABS) {
            return None;
        }
        let mut ring = Box::new([0i32; 256]);
        let mut delta = Box::new([0i32; 256]);
        for px in 0..256 {
            ring[px] = tap_ring[px] as i32;
            delta[px] = (tap_center[px] - tap_ring[px]) as i32;
        }
        Some(Self { tap_ring: ring, center_delta: delta, post })
    }

    /// Fold a 256×256 product table (index `(a_byte << 8) | b_byte`) for
    /// a 3×3 kernel with a *uniform ring*; `None` when the ring
    /// coefficients differ (the column-sum identity needs one ring
    /// coefficient). Kernel coefficients are pre-scaled by
    /// `KERNEL_PRESCALE_SHIFT` and the pixel pre-shift is baked in,
    /// exactly like the historical per-tap fold.
    pub fn for_kernel(kernel: &[[i64; 3]; 3], lut: &[i32], post: Post) -> Option<Self> {
        assert_eq!(lut.len(), 65536);
        let (center, ring) = uniform_ring(kernel)?;
        let (tap_center, tap_ring) = fold_taps_i64(lut, center, ring);
        Self::try_from_taps(&tap_center, &tap_ring, post)
    }

    /// Convolve one zero-padding-included window.
    ///
    /// `src` is a row-major byte buffer whose rows are `src_stride` wide;
    /// the `(out_h + 2) × (out_w + 2)` window starting at `src[0]` must
    /// be in bounds (callers pass a haloed tile or a padded image copy).
    /// Writes `out_w × out_h` post-processed pixels into `out` with rows
    /// `out_stride` apart.
    pub fn run(
        &self,
        src: &[u8],
        src_stride: usize,
        out: &mut [u8],
        out_stride: usize,
        out_w: usize,
        out_h: usize,
    ) {
        assert!(out_w >= 1 && out_h >= 1, "empty output window");
        let w2 = out_w + 2;
        assert!(src_stride >= w2, "src rows narrower than the window");
        assert!(out_stride >= out_w, "out rows narrower than the output");
        assert!(src.len() >= (out_h + 1) * src_stride + w2, "src window out of bounds");
        assert!(out.len() >= (out_h - 1) * out_stride + out_w, "out buffer too small");
        let tr = &self.tap_ring;
        let fill = |tv: &mut [i32], row: &[u8]| {
            for (t, &p) in tv.iter_mut().zip(row) {
                *t = tr[p as usize];
            }
        };
        // Rolling per-row tap vectors: rows oy, oy+1, oy+2 of the window.
        // Every per-row stage below is a flat i32-slice loop with no
        // per-pixel branch: the column-sum fold and the sliding window
        // sum dispatch to SSE2/AVX2 on x86-64 (scalar elsewhere), the two
        // table gathers (`fill`, centre delta) are straight-line scalar
        // loops, and the output rule is applied row-at-a-time with the
        // mode branch hoisted ([`Post::apply_row`]).
        let mut tv0 = vec![0i32; w2];
        let mut tv1 = vec![0i32; w2];
        let mut tv2 = vec![0i32; w2];
        let mut cs = vec![0i32; w2];
        let mut acc = vec![0i32; out_w];
        fill(&mut tv0[..], &src[0..w2]);
        fill(&mut tv1[..], &src[src_stride..src_stride + w2]);
        for oy in 0..out_h {
            let base = (oy + 2) * src_stride;
            fill(&mut tv2[..], &src[base..base + w2]); // the one fresh lookup row
            sum3_rows(&tv0, &tv1, &tv2, &mut cs);
            window3(&cs, &mut acc);
            let mid = &src[(oy + 1) * src_stride + 1..(oy + 1) * src_stride + 1 + out_w];
            for (a, &p) in acc.iter_mut().zip(mid) {
                *a += self.center_delta[p as usize];
            }
            self.post.apply_row(&acc, &mut out[oy * out_stride..oy * out_stride + out_w]);
            // Slide down one row: tv0 ← tv1, tv1 ← tv2, old tv0 becomes
            // next iteration's scratch.
            std::mem::swap(&mut tv0, &mut tv1);
            std::mem::swap(&mut tv1, &mut tv2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    /// Exact signed-byte product table, the layout `product_table` uses.
    fn exact_lut() -> Vec<i32> {
        let mut lut = vec![0i32; 65536];
        for a in 0..256usize {
            for b in 0..256usize {
                lut[(a << 8) | b] = ((a as u8 as i8) as i32) * ((b as u8 as i8) as i32);
            }
        }
        lut
    }

    fn naive_9lookup(
        tc: &[i64; 256],
        tr: &[i64; 256],
        src: &[u8],
        stride: usize,
        out_w: usize,
        out_h: usize,
    ) -> Vec<u8> {
        let mut out = vec![0u8; out_w * out_h];
        for oy in 0..out_h {
            for ox in 0..out_w {
                let mut acc = 0i64;
                for ky in 0..3 {
                    for kx in 0..3 {
                        let px = src[(oy + ky) * stride + ox + kx] as usize;
                        acc += if ky == 1 && kx == 1 { tc[px] } else { tr[px] };
                    }
                }
                out[oy * out_w + ox] = postprocess(acc);
            }
        }
        out
    }

    #[test]
    fn colsum_matches_naive_9lookup_on_ragged_windows() {
        let lut = exact_lut();
        let k = ColSumKernel::for_kernel(&crate::image::conv::LAPLACIAN, &lut, Post::LAPLACIAN)
            .expect("Laplacian taps fit the i32 bound");
        let (tc, tr) = laplacian_taps_i64(&lut);
        let mut rng = Xoshiro256::seeded(42);
        for &(out_w, out_h, stride_pad) in &[
            (1usize, 1usize, 0usize),
            (1, 7, 3),
            (7, 1, 0),
            (5, 4, 2),
            (64, 64, 0),
            (63, 2, 5),
            (63, 3, 0),
            (64, 3, 1),
            (65, 3, 0),
        ] {
            let stride = out_w + 2 + stride_pad;
            let mut src = vec![0u8; (out_h + 2) * stride];
            for b in src.iter_mut() {
                *b = rng.below(256) as u8;
            }
            let mut got = vec![0u8; out_w * out_h];
            k.run(&src, stride, &mut got, out_w, out_w, out_h);
            let want = naive_9lookup(&tc, &tr, &src, stride, out_w, out_h);
            assert_eq!(got, want, "{out_w}x{out_h} stride {stride}");
        }
    }

    #[test]
    fn for_kernel_rejects_non_uniform_ring() {
        let lut = exact_lut();
        let sobel_x = [[-1i64, 0, 1], [-2, 0, 2], [-1, 0, 1]];
        assert!(ColSumKernel::for_kernel(&sobel_x, &lut, Post::LAPLACIAN).is_none());
        assert!(
            ColSumKernel::for_kernel(&crate::image::conv::LAPLACIAN, &lut, Post::LAPLACIAN)
                .is_some()
        );
    }

    /// The core serves any uniform-ring kernel and output rule, not just
    /// the Laplacian: a 3×3 box blur (uniform ring == centre) under a
    /// saturating post matches its naive 9-lookup expansion.
    #[test]
    fn generalised_uniform_ring_kernel_runs() {
        let lut = exact_lut();
        let box3 = [[1i64, 1, 1], [1, 1, 1], [1, 1, 1]];
        let post = Post::saturate(3);
        let k = ColSumKernel::for_kernel(&box3, &lut, post).expect("box taps fit");
        let (tc, tr) = fold_taps_i64(&lut, 1, 1);
        let mut rng = Xoshiro256::seeded(7);
        let (out_w, out_h, stride) = (17usize, 9usize, 19usize);
        let mut src = vec![0u8; (out_h + 2) * stride];
        for b in src.iter_mut() {
            *b = rng.below(256) as u8;
        }
        let mut got = vec![0u8; out_w * out_h];
        k.run(&src, stride, &mut got, out_w, out_w, out_h);
        let mut want = vec![0u8; out_w * out_h];
        for oy in 0..out_h {
            for ox in 0..out_w {
                let mut acc = 0i64;
                for ky in 0..3 {
                    for kx in 0..3 {
                        let px = src[(oy + ky) * stride + ox + kx] as usize;
                        acc += if ky == 1 && kx == 1 { tc[px] } else { tr[px] };
                    }
                }
                want[oy * out_w + ox] = post.apply(acc);
            }
        }
        assert_eq!(got, want);
    }

    /// The dispatched row primitives (and, on x86-64, each explicit ISA
    /// form) are bit-identical to the scalar references on every ragged
    /// width the vector tails must handle — including widths below one
    /// vector (1), one lane short of a 64-wide row (63), and one past it
    /// (65). Values stay within the [`MAX_TAP_ABS`]-derived bound so the
    /// scalar adds cannot overflow under debug assertions.
    #[test]
    fn row_primitives_vector_paths_match_scalar_on_ragged_widths() {
        let mut rng = Xoshiro256::seeded(2024);
        let bounded = |rng: &mut Xoshiro256| rng.below(2 * 100_000) as i32 - 100_000;
        for &out_w in &[1usize, 2, 3, 7, 63, 64, 65, 129] {
            let w2 = out_w + 2;
            let a: Vec<i32> = (0..w2).map(|_| bounded(&mut rng)).collect();
            let b: Vec<i32> = (0..w2).map(|_| bounded(&mut rng)).collect();
            let c: Vec<i32> = (0..w2).map(|_| bounded(&mut rng)).collect();
            let mut want_cs = vec![0i32; w2];
            sum3_rows_scalar(&a, &b, &c, &mut want_cs);
            let mut got_cs = vec![0i32; w2];
            sum3_rows(&a, &b, &c, &mut got_cs);
            assert_eq!(got_cs, want_cs, "sum3 dispatch, width {out_w}");
            let mut want_acc = vec![0i32; out_w];
            window3_scalar(&want_cs, &mut want_acc);
            let mut got_acc = vec![0i32; out_w];
            window3(&want_cs, &mut got_acc);
            assert_eq!(got_acc, want_acc, "window3 dispatch, width {out_w}");
            #[cfg(target_arch = "x86_64")]
            {
                // SAFETY: SSE2 is part of the x86-64 baseline.
                let mut v = vec![0i32; w2];
                unsafe { x86::sum3_rows_sse2(&a, &b, &c, &mut v) };
                assert_eq!(v, want_cs, "sum3 sse2, width {out_w}");
                let mut w = vec![0i32; out_w];
                unsafe { x86::window3_sse2(&want_cs, &mut w) };
                assert_eq!(w, want_acc, "window3 sse2, width {out_w}");
                if std::is_x86_feature_detected!("avx2") {
                    // SAFETY: AVX2 verified present just above.
                    unsafe { x86::sum3_rows_avx2(&a, &b, &c, &mut v) };
                    assert_eq!(v, want_cs, "sum3 avx2, width {out_w}");
                    unsafe { x86::window3_avx2(&want_cs, &mut w) };
                    assert_eq!(w, want_acc, "window3 avx2, width {out_w}");
                }
            }
        }
    }

    #[test]
    fn oversized_taps_are_rejected() {
        let mut tc = [0i64; 256];
        let tr = [0i64; 256];
        assert!(ColSumKernel::try_from_taps(&tc, &tr, Post::LAPLACIAN).is_some());
        tc[7] = MAX_TAP_ABS + 1;
        assert!(ColSumKernel::try_from_taps(&tc, &tr, Post::LAPLACIAN).is_none());
        tc[7] = -(MAX_TAP_ABS + 1);
        assert!(ColSumKernel::try_from_taps(&tc, &tr, Post::LAPLACIAN).is_none());
    }
}

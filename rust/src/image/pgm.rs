//! 8-bit grayscale images with binary PGM (P5) I/O — the interchange
//! format the examples and the edge-detection CLI use.

use std::io::{BufRead, Read, Write};
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    /// Row-major, `height * width` bytes.
    pub data: Vec<u8>,
}

impl Image {
    pub fn new(width: usize, height: usize) -> Self {
        Self { width, height, data: vec![0; width * height] }
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> u8 {
        self.data[y * self.width + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: u8) {
        self.data[y * self.width + x] = v;
    }

    /// Zero-padded access (paper §4: zero padding preserves boundaries).
    #[inline]
    pub fn get_padded(&self, x: isize, y: isize) -> u8 {
        if x < 0 || y < 0 || x as usize >= self.width || y as usize >= self.height {
            0
        } else {
            self.get(x as usize, y as usize)
        }
    }

    /// Write binary PGM (P5).
    pub fn write_pgm(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        write!(f, "P5\n{} {}\n255\n", self.width, self.height)?;
        f.write_all(&self.data)?;
        Ok(())
    }

    /// Read binary PGM (P5), tolerating comment lines.
    pub fn read_pgm(path: &Path) -> std::io::Result<Self> {
        let mut reader = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut header_fields: Vec<String> = Vec::new();
        // Parse "P5", width, height, maxval — whitespace/comment tolerant.
        let mut line = String::new();
        while header_fields.len() < 4 {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "truncated PGM header",
                ));
            }
            let no_comment = line.split('#').next().unwrap_or("");
            header_fields.extend(no_comment.split_whitespace().map(String::from));
        }
        if header_fields[0] != "P5" {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "not a binary PGM (P5)",
            ));
        }
        let parse = |s: &str| {
            s.parse::<usize>().map_err(|e| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, format!("bad header: {e}"))
            })
        };
        let width = parse(&header_fields[1])?;
        let height = parse(&header_fields[2])?;
        let maxval = parse(&header_fields[3])?;
        if maxval != 255 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "only 8-bit PGM supported",
            ));
        }
        let mut data = vec![0u8; width * height];
        reader.read_exact(&mut data)?;
        Ok(Self { width, height, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pgm_roundtrip() {
        let mut img = Image::new(13, 7);
        for (i, px) in img.data.iter_mut().enumerate() {
            *px = (i * 37 % 256) as u8;
        }
        let dir = std::env::temp_dir().join("sfcmul_pgm_test");
        let path = dir.join("t.pgm");
        img.write_pgm(&path).unwrap();
        let back = Image::read_pgm(&path).unwrap();
        assert_eq!(img, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn padded_access() {
        let mut img = Image::new(2, 2);
        img.set(0, 0, 9);
        assert_eq!(img.get_padded(-1, 0), 0);
        assert_eq!(img.get_padded(0, -1), 0);
        assert_eq!(img.get_padded(2, 0), 0);
        assert_eq!(img.get_padded(0, 0), 9);
    }

    #[test]
    fn rejects_non_p5() {
        let dir = std::env::temp_dir().join("sfcmul_pgm_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.pgm");
        std::fs::write(&path, b"P2\n2 2\n255\n0 1 2 3\n").unwrap();
        assert!(Image::read_pgm(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[cfg(test)]
mod edge_case_tests {
    use super::*;

    #[test]
    fn one_pixel_image_roundtrip() {
        let mut img = Image::new(1, 1);
        img.set(0, 0, 42);
        let dir = std::env::temp_dir().join("sfcmul_pgm_1px");
        let p = dir.join("t.pgm");
        img.write_pgm(&p).unwrap();
        assert_eq!(Image::read_pgm(&p).unwrap(), img);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn header_with_comments_parses() {
        let dir = std::env::temp_dir().join("sfcmul_pgm_comments");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.pgm");
        let mut bytes = b"P5\n# a comment\n2 2\n# another\n255\n".to_vec();
        bytes.extend_from_slice(&[1, 2, 3, 4]);
        std::fs::write(&p, &bytes).unwrap();
        let img = Image::read_pgm(&p).unwrap();
        assert_eq!(img.data, vec![1, 2, 3, 4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_payload_is_an_error() {
        let dir = std::env::temp_dir().join("sfcmul_pgm_trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.pgm");
        std::fs::write(&p, b"P5\n4 4\n255\nxx").unwrap();
        assert!(Image::read_pgm(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

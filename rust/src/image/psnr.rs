//! Peak signal-to-noise ratio (paper §4, Fig. 9's fidelity metric).

use super::pgm::Image;

/// PSNR in dB between two same-sized 8-bit images:
/// `10·log10(255² / MSE)`. Returns `f64::INFINITY` for identical images.
pub fn psnr(reference: &Image, test: &Image) -> f64 {
    assert_eq!(reference.width, test.width);
    assert_eq!(reference.height, test.height);
    let mse: f64 = reference
        .data
        .iter()
        .zip(test.data.iter())
        .map(|(&a, &b)| {
            let d = a as f64 - b as f64;
            d * d
        })
        .sum::<f64>()
        / reference.data.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (255.0f64 * 255.0 / mse).log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_images_have_infinite_psnr() {
        let img = crate::image::synth::synthetic_scene(32, 32, 1);
        assert_eq!(psnr(&img, &img), f64::INFINITY);
    }

    #[test]
    fn known_mse_psnr() {
        let mut a = Image::new(10, 10);
        let mut b = Image::new(10, 10);
        a.data.fill(100);
        b.data.fill(105); // MSE = 25
        let expect = 10.0 * (255.0f64 * 255.0 / 25.0).log10();
        assert!((psnr(&a, &b) - expect).abs() < 1e-12);
    }

    #[test]
    fn more_noise_lower_psnr() {
        let reference = crate::image::synth::synthetic_scene(64, 64, 2);
        let mut small = reference.clone();
        let mut big = reference.clone();
        for (i, px) in small.data.iter_mut().enumerate() {
            if i % 7 == 0 {
                *px = px.wrapping_add(4);
            }
        }
        for (i, px) in big.data.iter_mut().enumerate() {
            if i % 3 == 0 {
                *px = px.wrapping_add(40);
            }
        }
        assert!(psnr(&reference, &small) > psnr(&reference, &big));
    }
}

//! Deterministic synthetic test scenes.
//!
//! The paper's Fig. 9 uses camera photographs we do not have; PSNR there is
//! computed *against the exact-multiplier edge map*, so any image with a
//! mix of smooth gradients, hard edges and texture exercises the same
//! comparison. `synthetic_scene` composes all three plus mild deterministic
//! noise.

use super::pgm::Image;
use crate::util::prng::Xoshiro256;

/// Composite scene: diagonal gradient background, filled rectangle and
/// circle (hard edges), concentric sine rings (texture), salt noise.
pub fn synthetic_scene(width: usize, height: usize, seed: u64) -> Image {
    let mut img = Image::new(width, height);
    let mut rng = Xoshiro256::seeded(seed);
    for y in 0..height {
        for x in 0..width {
            // gradient background
            let mut v = ((x + y) * 160 / (width + height)) as i32 + 40;
            // rectangle
            if x > width / 8 && x < width * 3 / 8 && y > height / 6 && y < height / 2 {
                v = 210;
            }
            // circle
            let (cx, cy) = (width as f64 * 0.68, height as f64 * 0.62);
            let r = (width.min(height) as f64) * 0.22;
            let d = ((x as f64 - cx).powi(2) + (y as f64 - cy).powi(2)).sqrt();
            if d < r {
                v = 25;
            }
            // texture rings in the lower-left quadrant
            if x < width / 3 && y > height * 2 / 3 {
                let ring = ((x as f64 * 0.7).sin() * (y as f64 * 0.5).cos() * 40.0) as i32;
                v += ring;
            }
            img.set(x, y, v.clamp(0, 255) as u8);
        }
    }
    // sparse salt-and-pepper noise (1/256 of pixels)
    let noisy = width * height / 256;
    for _ in 0..noisy {
        let x = rng.below(width as u64) as usize;
        let y = rng.below(height as u64) as usize;
        img.set(x, y, if rng.chance(0.5) { 255 } else { 0 });
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scene_is_deterministic() {
        let a = synthetic_scene(64, 64, 1);
        let b = synthetic_scene(64, 64, 1);
        assert_eq!(a, b);
        let c = synthetic_scene(64, 64, 2);
        assert_ne!(a, c);
    }

    #[test]
    fn scene_has_dynamic_range_and_edges() {
        let img = synthetic_scene(128, 128, 7);
        let min = *img.data.iter().min().unwrap();
        let max = *img.data.iter().max().unwrap();
        assert!(min < 30 && max > 200, "range {min}..{max}");
        // count strong horizontal transitions — edges must exist
        let mut edges = 0;
        for y in 0..img.height {
            for x in 1..img.width {
                if (img.get(x, y) as i32 - img.get(x - 1, y) as i32).abs() > 60 {
                    edges += 1;
                }
            }
        }
        assert!(edges > 50, "expected many hard edges, got {edges}");
    }
}

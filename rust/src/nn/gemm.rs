//! Output-stationary tiled signed GEMM (`i8 × i8 → i32` accumulate)
//! where every MAC routes through a registry multiplier design.
//!
//! The blocking mirrors the systolic-array formulation of *Energy
//! Efficient Exact and Approximate Systolic Array Architecture for
//! Matrix Multiplication* (arXiv 2509.00778): C is computed in
//! [`MC`]-row × [`NR`]-column output-stationary blocks, streaming
//! [`KC`]-deep operand panels through the MAC array — here the "array"
//! is a 256×256 per-design product table ([`lut_product`]), so each MAC
//! is one L1/L2-resident load + add and the *approximate product* of the
//! design under test is what accumulates, exactly as in hardware.
//!
//! Four product sources serve the same GEMM (and are proved equal by
//! `rust/tests/nn_gemm_equiv.rs`):
//!
//! * the **LUT fast path** ([`gemm_tiled`]) — a table generated from the
//!   design's functional model ([`crate::multipliers::lut::product_table`]);
//! * the **bitsim-swept table** — the same 65 536-entry layout swept out
//!   of the design's gate-level netlist by the bitsliced simulator
//!   ([`crate::multipliers::verify::netlist_multiply_all`]), giving
//!   netlist-true GEMM results;
//! * the **live gate stream** ([`gemm_bitsim`]) — no tables at all:
//!   every MAC runs through the netlist *at serve time*, 64 operand
//!   pairs per bitsliced gate-program pass;
//! * the **per-element reference** ([`gemm_naive`]) — every MAC calls
//!   the multiplier model directly, no tiling, no tables.
//!
//! Overflow: any 8-bit design's product fits 16 signed bits
//! (`|p| ≤ 2^15`), so a depth-`K` accumulation is bounded by `K · 2^15`;
//! [`gemm_naive`]/[`gemm_tiled`] assert `K ≤ 2^15` so accumulators can
//! never leave i32.

use crate::multipliers::traits::from_bits;
use crate::multipliers::verify::operand_code;
use crate::netlist::prelude::{BitSim, Netlist};
use crate::util::prng::Xoshiro256;

/// Maximum GEMM depth (K) the i32 accumulator provably cannot overflow
/// at: `2^15 · 2^15 = 2^30 < i32::MAX`.
pub const MAX_GEMM_DEPTH: usize = 1 << 15;

/// Rows of C per output-stationary block (also the coordinator's
/// GEMM-task row granularity).
pub const MC: usize = 32;
/// Depth (K) panel streamed per block iteration.
pub const KC: usize = 64;
/// C columns per register tile.
pub const NR: usize = 8;
/// C columns per coordinator GEMM task (a multiple of [`NR`]). Served
/// jobs split along *both* C dimensions: convolution GEMMs have few
/// rows (A = the weight matrix, `out_c` rows) but thousands of columns
/// (im2col output pixels), so the column split is what actually spreads
/// a conv layer across the worker fleet.
pub const NC: usize = 256;

/// Row-major signed 8-bit matrix — the quantized operand type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MatI8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
}

impl MatI8 {
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> i8) -> Self {
        let mut m = Self::new(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    /// Uniform random entries over the full i8 range (test workloads).
    pub fn random(rows: usize, cols: usize, rng: &mut Xoshiro256) -> Self {
        Self::from_fn(rows, cols, |_, _| rng.next_i8())
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i8 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i8) {
        self.data[r * self.cols + c] = v;
    }
}

/// Row-major i32 accumulator matrix — the GEMM output type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MatI32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl MatI32 {
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0; rows * cols] }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.cols + c]
    }
}

/// One product out of a 256×256 table (index `(a_byte << 8) | b_byte` —
/// the [`crate::multipliers::lut::product_table`] layout, which
/// [`crate::multipliers::verify::netlist_multiply_all`] shares at N=8).
#[inline]
pub fn lut_product(table: &[i32], a: i8, b: i8) -> i32 {
    table[((a as u8 as usize) << 8) | (b as u8 as usize)]
}

fn check_shapes(a: &MatI8, b: &MatI8) {
    assert_eq!(
        a.cols, b.rows,
        "GEMM shape mismatch: {}x{} × {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    assert!(
        a.cols <= MAX_GEMM_DEPTH,
        "GEMM depth {} exceeds the i32-safe bound {MAX_GEMM_DEPTH}",
        a.cols
    );
}

/// Reference GEMM: plain triple loop, every MAC through `mul` (the
/// multiplier functional model on the per-element path). No tiling —
/// this is what the tiled paths are proved equal to.
pub fn gemm_naive(a: &MatI8, b: &MatI8, mul: &dyn Fn(i8, i8) -> i32) -> MatI32 {
    check_shapes(a, b);
    let mut c = MatI32::new(a.rows, b.cols);
    for i in 0..a.rows {
        for k in 0..a.cols {
            let av = a.get(i, k);
            let brow = &b.data[k * b.cols..(k + 1) * b.cols];
            let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += mul(av, bv);
            }
        }
    }
    c
}

/// Compute the `rows × cols` block of C at `(row0, col0)` into `out`
/// (row-major, `rows × cols`), with the table-backed fast path.
///
/// This is the unit of work the coordinator dispatches per GEMM task;
/// [`gemm_tiled`] is exactly a loop over these blocks, so the served and
/// direct paths share one kernel. Inside the block: output-stationary
/// [`NR`]-column tiles, [`KC`]-deep panels, and a per-`a`-operand table
/// row slice so the inner loop is one byte-indexed load + add per MAC.
pub fn gemm_block_lut(
    a: &MatI8,
    b: &MatI8,
    table: &[i32],
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    out: &mut [i32],
) {
    check_shapes(a, b);
    assert_eq!(table.len(), 65536);
    let (k, n) = (a.cols, b.cols);
    assert!(row0 + rows <= a.rows && col0 + cols <= n);
    assert_eq!(out.len(), rows * cols);
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        for j0 in (col0..col0 + cols).step_by(NR) {
            let nr = NR.min(col0 + cols - j0);
            for i in 0..rows {
                // Slice the A panel directly at its offset (an
                // `enumerate().skip(k0)` here re-walks the row from 0 on
                // every KC panel — O(K²) per row) and accumulate the NR
                // output columns in a register tile, touching `out` once
                // per (k0, j0, i) instead of once per MAC.
                let apanel = &a.data[(row0 + i) * k + k0..(row0 + i) * k + k0 + kc];
                let mut acc = [0i32; NR];
                for (kk, &av) in apanel.iter().enumerate() {
                    let base = (av as u8 as usize) << 8;
                    let atab = &table[base..base + 256];
                    let brow = &b.data[(k0 + kk) * n + j0..(k0 + kk) * n + j0 + nr];
                    for (o, &bv) in acc[..nr].iter_mut().zip(brow) {
                        *o += atab[bv as u8 as usize];
                    }
                }
                let obase = i * cols + (j0 - col0);
                for (o, &v) in out[obase..obase + nr].iter_mut().zip(&acc[..nr]) {
                    *o += v;
                }
            }
        }
    }
}

/// Per-element form of [`gemm_block_lut`]: the same block through a
/// product function instead of a table (the coordinator's model-backed
/// reference engines use this).
pub fn gemm_block_mul(
    a: &MatI8,
    b: &MatI8,
    mul: &dyn Fn(i8, i8) -> i32,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    out: &mut [i32],
) {
    check_shapes(a, b);
    let (k, n) = (a.cols, b.cols);
    assert!(row0 + rows <= a.rows && col0 + cols <= n);
    assert_eq!(out.len(), rows * cols);
    for i in 0..rows {
        for kk in 0..k {
            let av = a.get(row0 + i, kk);
            let brow = &b.data[kk * n + col0..kk * n + col0 + cols];
            let orow = &mut out[i * cols..(i + 1) * cols];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += mul(av, bv);
            }
        }
    }
}

/// Live gate-level block kernel: the same output block as
/// [`gemm_block_lut`], but every MAC is computed **at serve time** by the
/// bitsliced netlist simulator — 64 operand pairs per gate-program pass,
/// no product table and no construction-time sweep. Each inner row
/// batches one `a` operand against up to 64 consecutive `b` operands
/// into one [`BitSim::run_codes_into`] pass, so netlist-true serving
/// runs at ~64× the scalar gate-walk throughput.
///
/// `sim` must be compiled from an 8-bit multiplier netlist (the i8 nn
/// datapath; its 16-bit products always fit the i32 accumulators).
pub fn gemm_block_bitsim(
    a: &MatI8,
    b: &MatI8,
    sim: &mut BitSim,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    out: &mut [i32],
) {
    check_shapes(a, b);
    assert_eq!(sim.num_inputs(), 16, "live GEMM requires an 8-bit multiplier netlist");
    let (k, n) = (a.cols, b.cols);
    assert!(row0 + rows <= a.rows && col0 + cols <= n);
    assert_eq!(out.len(), rows * cols);
    let mut codes = [0u64; 64];
    let mut prods = [0u64; 64];
    for i in 0..rows {
        let arow = &a.data[(row0 + i) * k..(row0 + i) * k + k];
        let orow = &mut out[i * cols..(i + 1) * cols];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b.data[kk * n + col0..kk * n + col0 + cols];
            let mut j = 0usize;
            while j < cols {
                let lanes = (cols - j).min(64);
                for (c, &bv) in codes[..lanes].iter_mut().zip(&brow[j..j + lanes]) {
                    *c = operand_code(av as i64, bv as i64, 8);
                }
                sim.run_codes_into(&codes[..lanes], &mut prods[..lanes]);
                for (o, &p) in orow[j..j + lanes].iter_mut().zip(&prods[..lanes]) {
                    *o += from_bits(p, 16) as i32;
                }
                j += lanes;
            }
        }
    }
}

/// Whole-product convenience over [`gemm_block_bitsim`]: `C = A × B`
/// with every MAC streamed through `nl`'s gates at serve time (one
/// simulator instance reused across all blocks).
pub fn gemm_bitsim(a: &MatI8, b: &MatI8, nl: &Netlist) -> MatI32 {
    check_shapes(a, b);
    let mut c = MatI32::new(a.rows, b.cols);
    if a.rows == 0 || b.cols == 0 {
        return c;
    }
    let mut sim = BitSim::new(nl);
    let n = b.cols;
    let mut row0 = 0;
    while row0 < a.rows {
        let rows = MC.min(a.rows - row0);
        gemm_block_bitsim(a, b, &mut sim, row0, rows, 0, n, &mut c.data[row0 * n..(row0 + rows) * n]);
        row0 += rows;
    }
    c
}

/// Tiled table-backed GEMM: `C = A × B` with every product read from the
/// design's 256×256 table, blocked [`MC`] × [`KC`] × [`NR`].
pub fn gemm_tiled(a: &MatI8, b: &MatI8, table: &[i32]) -> MatI32 {
    check_shapes(a, b);
    let mut c = MatI32::new(a.rows, b.cols);
    let n = b.cols;
    let mut row0 = 0;
    while row0 < a.rows {
        let rows = MC.min(a.rows - row0);
        gemm_block_lut(a, b, table, row0, rows, 0, n, &mut c.data[row0 * n..(row0 + rows) * n]);
        row0 += rows;
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::{lut::product_table, registry};

    fn exact_lut() -> Vec<i32> {
        product_table(registry().build_str("exact@8").unwrap().as_ref())
    }

    #[test]
    fn tiny_gemm_by_hand() {
        // [1 2; 3 4] × [5 6; 7 8] = [19 22; 43 50]
        let a = MatI8::from_fn(2, 2, |r, c| [[1, 2], [3, 4]][r][c]);
        let b = MatI8::from_fn(2, 2, |r, c| [[5, 6], [7, 8]][r][c]);
        let c = gemm_naive(&a, &b, &|x, y| x as i32 * y as i32);
        assert_eq!(c.data, vec![19, 22, 43, 50]);
        let lut = exact_lut();
        assert_eq!(gemm_tiled(&a, &b, &lut).data, c.data);
    }

    #[test]
    fn tiled_equals_naive_on_shapes_straddling_every_block_edge() {
        let lut = exact_lut();
        let mut rng = Xoshiro256::seeded(41);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (MC, KC, NR),
            (MC + 1, KC + 1, NR + 1),
            (MC - 1, 3, NR - 1),
            (2 * MC + 5, KC + 17, 2 * NR + 3),
        ] {
            let a = MatI8::random(m, k, &mut rng);
            let b = MatI8::random(k, n, &mut rng);
            let want = gemm_naive(&a, &b, &|x, y| lut_product(&lut, x, y));
            assert_eq!(gemm_tiled(&a, &b, &lut), want, "{m}x{k}x{n}");
        }
    }

    /// 2-D block-by-block assembly (the coordinator's dispatch shape,
    /// including off-origin column blocks) reproduces the whole product,
    /// through both the table and per-element block kernels.
    #[test]
    fn blocks_cover_the_full_product() {
        let lut = exact_lut();
        let mut rng = Xoshiro256::seeded(5);
        let a = MatI8::random(MC + 7, 19, &mut rng);
        let b = MatI8::random(19, 2 * NR + 3, &mut rng);
        let whole = gemm_tiled(&a, &b, &lut);
        let n = b.cols;
        let mut out = vec![0i32; a.rows * n];
        let col_step = NR + 1; // deliberately not a tile multiple
        let mut row0 = 0;
        while row0 < a.rows {
            let rows = MC.min(a.rows - row0);
            let mut col0 = 0;
            while col0 < n {
                let cols = col_step.min(n - col0);
                let mut block = vec![0i32; rows * cols];
                gemm_block_lut(&a, &b, &lut, row0, rows, col0, cols, &mut block);
                for i in 0..rows {
                    out[(row0 + i) * n + col0..(row0 + i) * n + col0 + cols]
                        .copy_from_slice(&block[i * cols..(i + 1) * cols]);
                }
                col0 += cols;
            }
            row0 += rows;
        }
        assert_eq!(out, whole.data);
        // the per-element block form agrees on an interior sub-block
        let mut block = vec![0i32; 2 * 5];
        gemm_block_mul(&a, &b, &|x, y| lut_product(&lut, x, y), 3, 2, 4, 5, &mut block);
        for i in 0..2 {
            assert_eq!(
                block[i * 5..(i + 1) * 5],
                whole.data[(3 + i) * n + 4..(3 + i) * n + 9]
            );
        }
    }

    #[test]
    fn degenerate_shapes_are_served() {
        let lut = exact_lut();
        // K = 0: all-zero accumulators
        let a = MatI8::new(3, 0);
        let b = MatI8::new(0, 4);
        assert_eq!(gemm_tiled(&a, &b, &lut).data, vec![0; 12]);
        // N = 0 and M = 0: empty outputs
        assert_eq!(gemm_tiled(&MatI8::new(3, 2), &MatI8::new(2, 0), &lut).data.len(), 0);
        assert_eq!(gemm_tiled(&MatI8::new(0, 2), &MatI8::new(2, 3), &lut).data.len(), 0);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mismatched_inner_dims_panic() {
        let lut = exact_lut();
        gemm_tiled(&MatI8::new(2, 3), &MatI8::new(4, 2), &lut);
    }

    /// Worst-case accumulation at the documented depth bound stays in
    /// i32: K entries of (-128)·(-128) = 16384 each.
    #[test]
    fn accumulator_bound_holds_at_max_magnitude() {
        let lut = exact_lut();
        let k = 4096; // large depth, well under MAX_GEMM_DEPTH
        let a = MatI8::from_fn(1, k, |_, _| -128);
        let b = MatI8::from_fn(k, 1, |_, _| -128);
        let c = gemm_tiled(&a, &b, &lut);
        assert_eq!(c.data[0], (k as i32) * 16384);
    }
}

//! Quantized `Conv2d` lowered onto the tiled GEMM via im2col, plus the
//! fixed conv→relu→conv demo network the `infer` CLI serves.
//!
//! A convolution with `out_c` filters of shape `in_c × kh × kw` over a
//! CHW input is exactly the matrix product
//!
//! ```text
//! W (out_c × in_c·kh·kw)  ×  im2col(x) (in_c·kh·kw × oh·ow)
//! ```
//!
//! so every conv MAC routes through the same approximate-multiplier GEMM
//! core ([`super::gemm`]) — the "custom convolution layer" of the source
//! paper's §4 generalised from 3×3 single-channel edge kernels to
//! arbitrary channels, stride and padding. The epilogue (per-channel
//! i32 bias, [`Requant`] back to i8, optional ReLU) is integer-only.
//!
//! [`conv2d_direct`] is the no-im2col nested-loop foil the property
//! tests compare against: `conv2d == im2col + gemm` is *asserted*, not
//! assumed.

use super::gemm::{gemm_naive, gemm_tiled, MatI32, MatI8};
use super::quant::Requant;
use crate::image::Image;
use crate::util::prng::Xoshiro256;

/// Signed 8-bit activation tensor, CHW layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorI8 {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<i8>,
}

impl TensorI8 {
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Self { c, h, w, data: vec![0; c * h * w] }
    }

    #[inline]
    pub fn get(&self, c: usize, y: usize, x: usize) -> i8 {
        self.data[(c * self.h + y) * self.w + x]
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: i8) {
        self.data[(c * self.h + y) * self.w + x] = v;
    }

    /// Input sample with zero padding outside the spatial extent.
    #[inline]
    pub fn get_padded(&self, c: usize, y: isize, x: isize) -> i8 {
        if y < 0 || x < 0 || y as usize >= self.h || x as usize >= self.w {
            0
        } else {
            self.get(c, y as usize, x as usize)
        }
    }
}

/// Activation-fidelity statistics between two same-shape tensors — the
/// single definition of the mismatch/|Δ| figures reported by the
/// `infer` CLI and the `tables --id nn` matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fidelity {
    /// Elements where the two tensors differ.
    pub mismatched: usize,
    /// Total elements compared.
    pub total: usize,
    /// Mean |a − b| in i8 codes (0.0 for empty tensors).
    pub mean_abs: f64,
    /// Max |a − b| in i8 codes.
    pub max_abs: i64,
}

impl Fidelity {
    /// Mismatched fraction in [0, 1] (0.0 for empty tensors).
    pub fn mismatch_rate(&self) -> f64 {
        self.mismatched as f64 / self.total.max(1) as f64
    }
}

/// Compare two same-shape activation tensors element-wise.
pub fn fidelity(a: &TensorI8, b: &TensorI8) -> Fidelity {
    assert_eq!(
        (a.c, a.h, a.w),
        (b.c, b.h, b.w),
        "fidelity compares same-shape tensors"
    );
    let mut mismatched = 0usize;
    let (mut sum_abs, mut max_abs) = (0i64, 0i64);
    for (&x, &y) in a.data.iter().zip(&b.data) {
        let d = (x as i64 - y as i64).abs();
        if d != 0 {
            mismatched += 1;
        }
        sum_abs += d;
        max_abs = max_abs.max(d);
    }
    Fidelity {
        mismatched,
        total: a.data.len(),
        mean_abs: sum_abs as f64 / a.data.len().max(1) as f64,
        max_abs,
    }
}

/// Quantize a grayscale image onto the symmetric i8 grid: `q = px − 128`
/// (mid-gray is the zero code, implied scale 1/128) — the integer-exact
/// input conditioning of the demo network.
pub fn quantize_image(img: &Image) -> TensorI8 {
    let mut t = TensorI8::new(1, img.height, img.width);
    for (q, &px) in t.data.iter_mut().zip(&img.data) {
        *q = (px as i16 - 128) as i8;
    }
    t
}

/// Unfold a CHW tensor into the GEMM operand: row `ci·kh·kw + ky·kw + kx`,
/// column `oy·ow + ox` holds `x[ci][oy·stride + ky − pad][ox·stride + kx − pad]`
/// (zero outside the input — the same zero-padding rule as the
/// edge-detection datapath).
pub fn im2col(x: &TensorI8, kh: usize, kw: usize, stride: usize, pad: usize) -> MatI8 {
    assert!(stride >= 1, "stride must be at least 1");
    let (oh, ow) = out_dims(x.h, x.w, kh, kw, stride, pad);
    let mut m = MatI8::new(x.c * kh * kw, oh * ow);
    for ci in 0..x.c {
        for ky in 0..kh {
            for kx in 0..kw {
                let row = (ci * kh + ky) * kw + kx;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let sy = (oy * stride + ky) as isize - pad as isize;
                        let sx = (ox * stride + kx) as isize - pad as isize;
                        m.set(row, oy * ow + ox, x.get_padded(ci, sy, sx));
                    }
                }
            }
        }
    }
    m
}

/// Output spatial dims of a `kh × kw` / `stride` / `pad` convolution
/// over an `h × w` input (0 when the padded input is smaller than the
/// kernel).
pub fn out_dims(h: usize, w: usize, kh: usize, kw: usize, stride: usize, pad: usize) -> (usize, usize) {
    let span = |n: usize, k: usize| {
        let padded = n + 2 * pad;
        if padded < k {
            0
        } else {
            (padded - k) / stride + 1
        }
    };
    (span(h, kh), span(w, kw))
}

/// A quantized convolution layer: i8 weights, i32 bias (accumulator
/// scale), fixed-point requantization, optional fused ReLU.
#[derive(Debug, Clone)]
pub struct Conv2d {
    /// `out_c × (in_c·kh·kw)` filter matrix — the GEMM A operand.
    pub weight: MatI8,
    /// Per-output-channel bias, added to the raw accumulator.
    pub bias: Vec<i32>,
    pub in_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    pub requant: Requant,
    pub relu: bool,
}

impl Conv2d {
    pub fn out_c(&self) -> usize {
        self.weight.rows
    }

    /// Output spatial dims for an `h × w` input.
    pub fn out_dims(&self, h: usize, w: usize) -> (usize, usize) {
        out_dims(h, w, self.kh, self.kw, self.stride, self.pad)
    }

    /// Collapse raw GEMM accumulators (`out_c × oh·ow`) to the output
    /// tensor: bias, requantize, optional ReLU.
    pub fn epilogue(&self, acc: &MatI32, oh: usize, ow: usize) -> TensorI8 {
        assert_eq!(acc.rows, self.out_c());
        assert_eq!(acc.cols, oh * ow);
        let mut out = TensorI8::new(self.out_c(), oh, ow);
        for co in 0..self.out_c() {
            let bias = self.bias[co];
            let arow = &acc.data[co * acc.cols..(co + 1) * acc.cols];
            let orow = &mut out.data[co * oh * ow..(co + 1) * oh * ow];
            for (o, &a) in orow.iter_mut().zip(arow) {
                // Saturate, matching the clamp semantics of the requant:
                // bias is a caller-supplied i32, so the sum may exceed
                // i32 even though in-bound GEMM accumulators cannot.
                let mut v = self.requant.apply(a.saturating_add(bias));
                if self.relu {
                    v = v.max(0);
                }
                *o = v;
            }
        }
        out
    }

    /// Reference forward pass: im2col + per-element GEMM (`mul` is the
    /// multiplier functional model) + epilogue.
    pub fn forward(&self, x: &TensorI8, mul: &dyn Fn(i8, i8) -> i32) -> TensorI8 {
        assert_eq!(x.c, self.in_c, "input channel mismatch");
        let (oh, ow) = self.out_dims(x.h, x.w);
        let cols = im2col(x, self.kh, self.kw, self.stride, self.pad);
        self.epilogue(&gemm_naive(&self.weight, &cols, mul), oh, ow)
    }

    /// Table-backed forward pass: im2col + tiled LUT GEMM + epilogue —
    /// the production path (and what the coordinator serves blockwise).
    pub fn forward_tiled(&self, x: &TensorI8, table: &[i32]) -> TensorI8 {
        assert_eq!(x.c, self.in_c, "input channel mismatch");
        let (oh, ow) = self.out_dims(x.h, x.w);
        let cols = im2col(x, self.kh, self.kw, self.stride, self.pad);
        self.epilogue(&gemm_tiled(&self.weight, &cols, table), oh, ow)
    }
}

/// Direct nested-loop convolution — no im2col, no GEMM. The independent
/// foil `conv2d == im2col + gemm` is property-tested against.
pub fn conv2d_direct(x: &TensorI8, layer: &Conv2d, mul: &dyn Fn(i8, i8) -> i32) -> TensorI8 {
    assert_eq!(x.c, layer.in_c, "input channel mismatch");
    let (oh, ow) = layer.out_dims(x.h, x.w);
    let mut acc = MatI32::new(layer.out_c(), oh * ow);
    for co in 0..layer.out_c() {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut s = 0i32;
                for ci in 0..layer.in_c {
                    for ky in 0..layer.kh {
                        for kx in 0..layer.kw {
                            let sy = (oy * layer.stride + ky) as isize - layer.pad as isize;
                            let sx = (ox * layer.stride + kx) as isize - layer.pad as isize;
                            let w = layer.weight.get(co, (ci * layer.kh + ky) * layer.kw + kx);
                            s += mul(w, x.get_padded(ci, sy, sx));
                        }
                    }
                }
                acc.data[co * oh * ow + oy * ow + ox] = s;
            }
        }
    }
    layer.epilogue(&acc, oh, ow)
}

/// The fixed conv→relu→conv demo network: deterministic i8 weights,
/// integer-only inference, built once and shared by the `infer` CLI, the
/// `tables --id nn` accuracy matrix and the test suite.
///
/// * layer 1 — `1 → 4` channels, 3×3, stride 1, pad 1, ReLU. The four
///   filters are classic feature extractors (Sobel-x, Sobel-y, centre
///   blur, Laplacian ring) so activations carry recognisable structure.
/// * layer 2 — `4 → 2` channels, 3×3, stride 2, pad 1, no ReLU, weights
///   drawn deterministically from the crate PRNG in `[-4, 4]`.
#[derive(Debug, Clone)]
pub struct Network {
    pub layers: Vec<Conv2d>,
}

impl Network {
    pub fn demo() -> Self {
        let l1_filters: [[[i8; 3]; 3]; 4] = [
            [[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]],    // sobel-x
            [[-1, -2, -1], [0, 0, 0], [1, 2, 1]],    // sobel-y
            [[1, 1, 1], [1, 2, 1], [1, 1, 1]],       // blur
            [[-1, -1, -1], [-1, 8, -1], [-1, -1, -1]], // laplacian
        ];
        let w1 = MatI8::from_fn(4, 9, |co, i| l1_filters[co][i / 3][i % 3]);
        let l1 = Conv2d {
            weight: w1,
            bias: vec![0, 0, -640, 64],
            in_c: 1,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            requant: Requant::from_shift(4),
            relu: true,
        };
        // layer 2: deterministic pseudo-random mixing weights
        let mut rng = Xoshiro256::seeded(0x5fc_0002);
        let w2 = MatI8::from_fn(2, 4 * 9, |_, _| rng.range_i64(-4, 4) as i8);
        let l2 = Conv2d {
            weight: w2,
            bias: vec![16, -16],
            in_c: 4,
            kh: 3,
            kw: 3,
            stride: 2,
            pad: 1,
            requant: Requant::from_shift(6),
            relu: false,
        };
        Self { layers: vec![l1, l2] }
    }

    /// Reference inference: every layer through the per-element GEMM.
    pub fn run(&self, x: &TensorI8, mul: &dyn Fn(i8, i8) -> i32) -> TensorI8 {
        self.run_layers(x, mul).pop().expect("network has layers")
    }

    /// Reference inference keeping every layer's activations (the
    /// per-layer accuracy matrix reads these).
    pub fn run_layers(&self, x: &TensorI8, mul: &dyn Fn(i8, i8) -> i32) -> Vec<TensorI8> {
        let mut outs = Vec::with_capacity(self.layers.len());
        let mut cur = x.clone();
        for layer in &self.layers {
            cur = layer.forward(&cur, mul);
            outs.push(cur.clone());
        }
        outs
    }

    /// Table-backed inference (tiled LUT GEMM per layer).
    pub fn run_tiled(&self, x: &TensorI8, table: &[i32]) -> TensorI8 {
        self.run_tiled_layers(x, table).pop().expect("network has layers")
    }

    /// Table-backed inference keeping every layer's activations.
    pub fn run_tiled_layers(&self, x: &TensorI8, table: &[i32]) -> Vec<TensorI8> {
        let mut outs = Vec::with_capacity(self.layers.len());
        let mut cur = x.clone();
        for layer in &self.layers {
            cur = layer.forward_tiled(&cur, table);
            outs.push(cur.clone());
        }
        outs
    }

    /// Serve inference through a running coordinator: each layer is one
    /// [`crate::coordinator::Coordinator::submit_conv2d`] job routed to
    /// `engine` (None = default), epilogues applied as results return.
    pub fn run_served(
        &self,
        coord: &crate::coordinator::Coordinator,
        engine: Option<&str>,
        x: &TensorI8,
    ) -> crate::Result<TensorI8> {
        let mut cur = x.clone();
        for layer in &self.layers {
            let (oh, ow) = layer.out_dims(cur.h, cur.w);
            let res = coord.submit_conv2d(&cur, layer, engine)?.wait()?;
            cur = layer.epilogue(&res.out, oh, ow);
        }
        Ok(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic_scene;
    use crate::multipliers::{lut::product_table, registry};

    fn exact_mul() -> impl Fn(i8, i8) -> i32 {
        |a, b| a as i32 * b as i32
    }

    #[test]
    fn out_dims_match_the_usual_formula() {
        assert_eq!(out_dims(8, 8, 3, 3, 1, 1), (8, 8));
        assert_eq!(out_dims(8, 8, 3, 3, 1, 0), (6, 6));
        assert_eq!(out_dims(8, 8, 3, 3, 2, 1), (4, 4));
        assert_eq!(out_dims(1, 1, 3, 3, 1, 0), (0, 0), "kernel larger than input");
        assert_eq!(out_dims(1, 1, 3, 3, 1, 1), (1, 1));
    }

    #[test]
    fn im2col_reproduces_padded_windows() {
        let mut x = TensorI8::new(2, 3, 4);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = i as i8 - 12;
        }
        let m = im2col(&x, 3, 3, 1, 1);
        assert_eq!(m.rows, 2 * 9);
        assert_eq!(m.cols, 3 * 4);
        // spot-check: row (ci=1, ky=0, kx=0), output (oy=0, ox=0) reads
        // x[1][-1][-1] = 0 (padding); output (1,2) reads x[1][0][1]
        assert_eq!(m.get(9, 0), 0);
        assert_eq!(m.get(9, 4 + 2), x.get(1, 0, 1));
    }

    #[test]
    fn quantize_image_is_centered_and_exact() {
        let mut img = Image::new(2, 1);
        img.data = vec![0, 255];
        let t = quantize_image(&img);
        assert_eq!(t.data, vec![-128, 127]);
        assert_eq!((t.c, t.h, t.w), (1, 1, 2));
    }

    #[test]
    fn direct_conv_equals_im2col_gemm_on_the_demo_layers() {
        let net = Network::demo();
        let img = synthetic_scene(13, 11, 3);
        let x = quantize_image(&img);
        let mul = exact_mul();
        let l1 = &net.layers[0];
        assert_eq!(conv2d_direct(&x, l1, &mul), l1.forward(&x, &mul));
        let mid = l1.forward(&x, &mul);
        let l2 = &net.layers[1];
        assert_eq!(conv2d_direct(&mid, l2, &mul), l2.forward(&mid, &mul));
    }

    #[test]
    fn tiled_forward_equals_reference_forward() {
        let exact = registry().build_str("exact@8").unwrap();
        let lut = product_table(exact.as_ref());
        let net = Network::demo();
        let img = synthetic_scene(17, 9, 5);
        let x = quantize_image(&img);
        let mul = exact_mul();
        assert_eq!(net.run_tiled(&x, &lut), net.run(&x, &mul));
    }

    #[test]
    fn demo_network_output_is_deterministic_and_alive() {
        let exact = registry().build_str("exact@8").unwrap();
        let lut = product_table(exact.as_ref());
        let net = Network::demo();
        let x = quantize_image(&synthetic_scene(32, 32, 2024));
        let y1 = net.run_tiled(&x, &lut);
        let y2 = net.run_tiled(&x, &lut);
        assert_eq!(y1, y2);
        assert_eq!((y1.c, y1.h, y1.w), (2, 16, 16));
        let nonzero = y1.data.iter().filter(|&&v| v != 0).count();
        assert!(nonzero > y1.data.len() / 8, "activations are degenerate: {nonzero} nonzero");
        let distinct: std::collections::BTreeSet<i8> = y1.data.iter().copied().collect();
        assert!(distinct.len() > 8, "activations carry structure: {} levels", distinct.len());
    }

    #[test]
    fn fidelity_counts_and_averages() {
        let mut a = TensorI8::new(1, 2, 2);
        let mut b = TensorI8::new(1, 2, 2);
        a.data = vec![10, -5, 0, 100];
        b.data = vec![10, -8, 0, 90];
        let f = fidelity(&a, &b);
        assert_eq!((f.mismatched, f.total), (2, 4));
        assert!((f.mismatch_rate() - 0.5).abs() < 1e-12);
        assert!((f.mean_abs - 13.0 / 4.0).abs() < 1e-12);
        assert_eq!(f.max_abs, 10);
        let zero = fidelity(&a, &a);
        assert_eq!((zero.mismatched, zero.max_abs), (0, 0));
        assert_eq!(zero.mean_abs, 0.0);
    }

    #[test]
    fn relu_floors_layer1_activations() {
        let exact = registry().build_str("exact@8").unwrap();
        let lut = product_table(exact.as_ref());
        let net = Network::demo();
        let x = quantize_image(&synthetic_scene(16, 16, 7));
        let mid = net.layers[0].forward_tiled(&x, &lut);
        assert!(mid.data.iter().all(|&v| v >= 0), "ReLU output must be non-negative");
    }
}

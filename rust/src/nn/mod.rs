//! Approximate quantized inference — the "nn" workload layer.
//!
//! The source paper's headline application is the approximate signed
//! multiplier *integrated into a custom convolution layer* for
//! machine-learning workloads; related work evaluates the same
//! multiplier family inside DNN layers (arXiv 2509.00764) with the
//! tiled-GEMM formulation of systolic arrays (arXiv 2509.00778). This
//! module opens that workload on top of the existing registry/serving
//! stack:
//!
//! * [`quant`] — symmetric i8 quantization: scale/zero-point-0 params,
//!   the rounding right-shift, and fixed-point [`Requant`] back to i8.
//! * [`gemm`] — output-stationary tiled signed GEMM (`i8 × i8 → i32`)
//!   blocked [`gemm::MC`] × [`gemm::KC`] × [`gemm::NR`], where every MAC
//!   routes through a registry design: a 256×256 product-LUT fast path,
//!   a bitsim-swept (netlist-true) table path, and a per-element
//!   functional-model reference — proved equal in
//!   `rust/tests/nn_gemm_equiv.rs`.
//! * [`conv2d`] — `Conv2d` (arbitrary channels/stride/padding) lowered
//!   via [`conv2d::im2col`] onto that GEMM, ReLU + requantize, and the
//!   fixed conv→relu→conv [`Network`] the `sfcmul infer` CLI runs on
//!   `synthetic_scene` inputs.
//!
//! Serving: the coordinator accepts GEMM/conv2d jobs next to image
//! tiles ([`crate::coordinator::Coordinator::submit_gemm`] /
//! [`crate::coordinator::Coordinator::submit_conv2d`]); engines opt in
//! via [`crate::coordinator::engine::TileEngine::nn_backend`], and
//! `tables --id nn` prints the design × layer accuracy matrix.

pub mod conv2d;
pub mod gemm;
pub mod quant;

pub use conv2d::{
    conv2d_direct, fidelity, im2col, out_dims, quantize_image, Conv2d, Fidelity, Network,
    TensorI8,
};
pub use gemm::{
    gemm_bitsim, gemm_block_bitsim, gemm_block_lut, gemm_block_mul, gemm_naive, gemm_tiled,
    lut_product, MatI32, MatI8, KC, MAX_GEMM_DEPTH, MC, NC, NR,
};
pub use quant::{quantize_symmetric, rounding_shift, QuantParams, Requant};

//! Symmetric i8 quantization — the fixed-point numerics of the
//! quantized-inference datapath.
//!
//! The scheme is the standard *symmetric per-tensor* one used by the
//! DNN-with-approximate-multiplier literature (e.g. arXiv 2509.00764):
//! a real value `x` is represented as `q * scale` with `q` a signed
//! 8-bit integer and zero-point fixed at 0, so the multiplier under test
//! sees plain signed i8×i8 products and the sign-focused compressor path
//! is exercised exactly as in the edge-detection workload. Accumulators
//! are i32 (scale `s_a · s_b`); [`Requant`] folds the scale ratio back
//! to the next layer's i8 domain as an integer multiply plus a rounding
//! right-shift — no floating point anywhere at inference time.

/// Symmetric quantization parameters: `value ≈ q * scale`, zero-point 0.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
}

impl QuantParams {
    /// Parameters covering `[-max_abs, max_abs]` on the symmetric i8
    /// grid `-127..=127` (the -128 code is unused, keeping the grid
    /// symmetric so negation is exact).
    pub fn from_max_abs(max_abs: f32) -> Self {
        let bound = if max_abs > 0.0 { max_abs } else { 1.0 };
        Self { scale: bound / 127.0 }
    }

    /// Quantize one value (round half away from zero, clamp to ±127).
    pub fn quantize(&self, x: f32) -> i8 {
        let q = (x / self.scale).round();
        q.clamp(-127.0, 127.0) as i8
    }

    /// Real value of a quantized code.
    pub fn dequantize(&self, q: i8) -> f32 {
        q as f32 * self.scale
    }
}

/// Quantize a tensor symmetrically, deriving the scale from its own
/// max-|x| (the calibration rule used for the fixed demo weights).
pub fn quantize_symmetric(xs: &[f32]) -> (Vec<i8>, QuantParams) {
    let max_abs = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
    let p = QuantParams::from_max_abs(max_abs);
    (xs.iter().map(|&x| p.quantize(x)).collect(), p)
}

/// Rounding arithmetic right shift: `round(v / 2^s)` with ties toward
/// +∞ (`(v + 2^(s-1)) >> s`), the hardware-friendly rounding used by
/// every requantization step. `s == 0` is the identity.
#[inline]
pub fn rounding_shift(v: i64, s: u32) -> i64 {
    if s == 0 {
        v
    } else {
        (v + (1i64 << (s - 1))) >> s
    }
}

/// Fixed-point requantization: maps an i32 accumulator to an i8
/// activation as `clamp(round(acc * mult / 2^shift))` — the integer-only
/// encoding of the real scale ratio `s_in / s_out`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Requant {
    /// Positive integer multiplier (≈ 15-bit mantissa of the ratio).
    pub mult: i32,
    /// Rounding right-shift applied after the multiply.
    pub shift: u32,
}

impl Requant {
    /// A pure power-of-two requantization (`mult == 1`) — what the fixed
    /// demo network uses, so its arithmetic is exactly reproducible by
    /// eye.
    pub const fn from_shift(shift: u32) -> Self {
        Self { mult: 1, shift }
    }

    /// Encode a positive real ratio as mult/2^shift with a 15-bit
    /// mantissa (`mult` in `[2^14, 2^15)` whenever the ratio allows a
    /// non-negative shift; very large ratios saturate at `shift == 0`).
    pub fn from_ratio(ratio: f64) -> Self {
        assert!(ratio > 0.0 && ratio.is_finite(), "requant ratio must be positive");
        let mut mult = ratio;
        let mut shift = 0u32;
        while mult < (1 << 14) as f64 && shift < 62 {
            mult *= 2.0;
            shift += 1;
        }
        while mult >= (1 << 15) as f64 && shift > 0 {
            mult /= 2.0;
            shift -= 1;
        }
        Self { mult: mult.round().min(i32::MAX as f64) as i32, shift }
    }

    /// Requantize one accumulator to i8.
    #[inline]
    pub fn apply(&self, acc: i32) -> i8 {
        rounding_shift(acc as i64 * self.mult as i64, self.shift).clamp(-128, 127) as i8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrips_grid_points() {
        let p = QuantParams::from_max_abs(1.0);
        for q in [-127i8, -64, -1, 0, 1, 64, 127] {
            assert_eq!(p.quantize(p.dequantize(q)), q, "{q}");
        }
        // symmetric grid: negation is exact
        assert_eq!(p.quantize(-1.0), -127);
        assert_eq!(p.quantize(1.0), 127);
        // out-of-range clamps, never touches -128
        assert_eq!(p.quantize(-100.0), -127);
    }

    #[test]
    fn quantize_symmetric_calibrates_to_max_abs() {
        let (q, p) = quantize_symmetric(&[0.5, -2.0, 1.0]);
        assert_eq!(q[1], -127, "max-|x| element maps to the grid edge");
        assert!((p.dequantize(q[2]) - 1.0).abs() < 0.02);
        // all-zero input stays finite
        let (q0, p0) = quantize_symmetric(&[0.0, 0.0]);
        assert_eq!(q0, vec![0, 0]);
        assert!(p0.scale > 0.0);
    }

    #[test]
    fn rounding_shift_rounds_half_up() {
        assert_eq!(rounding_shift(5, 0), 5);
        assert_eq!(rounding_shift(5, 1), 3); // 2.5 → 3
        assert_eq!(rounding_shift(-5, 1), -2); // -2.5 → -2 (toward +∞)
        assert_eq!(rounding_shift(4, 2), 1);
        assert_eq!(rounding_shift(6, 2), 2); // 1.5 → 2
        assert_eq!(rounding_shift(-1024, 4), -64);
    }

    #[test]
    fn requant_shift_form_divides_exactly() {
        let r = Requant::from_shift(4);
        assert_eq!(r.apply(160), 10);
        assert_eq!(r.apply(-160), -10);
        assert_eq!(r.apply(1 << 20), 127, "saturates high");
        assert_eq!(r.apply(-(1 << 20)), -128, "saturates low");
    }

    #[test]
    fn requant_ratio_tracks_real_arithmetic() {
        for ratio in [0.003, 0.06, 0.5, 1.0, 3.7] {
            let r = Requant::from_ratio(ratio);
            for acc in [-12_000i32, -100, -1, 0, 1, 99, 12_000] {
                let want = (acc as f64 * ratio).round().clamp(-128.0, 127.0);
                let got = r.apply(acc) as f64;
                // 15-bit mantissa: within 1 code of the real rounding
                assert!(
                    (want - got).abs() <= 1.0,
                    "ratio {ratio} acc {acc}: want {want} got {got}"
                );
            }
        }
    }
}

//! `sfcmul` — CLI for the approximate signed multiplier reproduction.
//!
//! Subcommands:
//!   tables  --id <t1|t2|t3|t4|t5|f9|f10|all> [--seed S] [--out out/]
//!   edge    --input img.pgm --output edges.pgm [--design proposed] [--engine lut|pjrt|model|rowbuf]
//!   serve   --demo [--jobs N] [--workers W] [--engine lut|pjrt] [--design proposed]
//!   ablate  [--seed S]                      (design-space ablation report)
//!   dump-lut --design proposed --out artifacts/proposed_lut_rust.i32
//!   hw      [--seed S]                      (raw unit-gate figures)
//!   help

use sfcmul::coordinator::{Coordinator, CoordinatorConfig, LutTileEngine, ModelTileEngine, TileEngine};
use sfcmul::image::{conv3x3_rowbuf, edge_detect, synthetic_scene, Image, LAPLACIAN};
use sfcmul::multipliers::{build_design, design_by_name, lut, DesignId};
use sfcmul::runtime::{artifacts_dir, PjrtTileEngine};
use sfcmul::util::cli::Args;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

const USAGE: &str = "\
sfcmul — Approximate Signed Multiplier with Sign-Focused Compressors (CS.AR 2025 reproduction)

USAGE: sfcmul <subcommand> [options]

  tables   --id t1|t2|t3|t4|t5|f9|f10|all [--seed S] [--out DIR]
           regenerate a paper table/figure
  edge     --input in.pgm --output out.pgm [--design NAME] [--engine lut|model|rowbuf|pjrt]
           run edge detection on an image (or --demo for the synthetic scene)
  serve    --demo [--jobs N] [--workers W] [--batch B] [--engine lut|pjrt] [--design NAME]
           run the streaming coordinator on a synthetic job stream, print metrics
  ablate   [--seed S]
           design-space ablation (compressor candidates, compensation, truncation)
  dump-lut [--design NAME] [--out FILE]
           export a design's 256x256 product table (cross-check with python)
  hw       [--seed S]
           raw unit-gate hardware figures per design

designs: exact, proposed, d1, d2, d4, d5, d7, d12
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("tables") => cmd_tables(&args),
        Some("edge") => cmd_edge(&args),
        Some("serve") => cmd_serve(&args),
        Some("ablate") => cmd_ablate(&args),
        Some("dump-lut") => cmd_dump_lut(&args),
        Some("hw") => cmd_hw(&args),
        Some("help") | None => {
            print!("{USAGE}");
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn seed_of(args: &Args) -> u64 {
    args.get_parse("seed", 42u64).unwrap_or(42)
}

fn cmd_tables(args: &Args) -> i32 {
    let id = args.get_or("id", "all").to_string();
    let out_dir = PathBuf::from(args.get_or("out", "out"));
    match sfcmul::tables::generate(&id, seed_of(args), &out_dir) {
        Ok(text) => {
            print!("{text}");
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn load_model(args: &Args) -> Arc<dyn sfcmul::multipliers::MultiplierModel> {
    let name = args.get_or("design", "proposed");
    design_by_name(name, 8).unwrap_or_else(|| {
        eprintln!("unknown design {name:?}; using proposed");
        build_design(DesignId::Proposed, 8)
    })
}

fn make_engine(args: &Args, model: &Arc<dyn sfcmul::multipliers::MultiplierModel>) -> Arc<dyn TileEngine> {
    match args.get_or("engine", "lut") {
        "pjrt" => {
            let table = lut::product_table(model.as_ref());
            match PjrtTileEngine::new(&artifacts_dir(), &model.name(), table) {
                Ok(e) => Arc::new(e),
                Err(e) => {
                    eprintln!("pjrt engine unavailable ({e}); falling back to lut");
                    Arc::new(LutTileEngine::new(model.as_ref()))
                }
            }
        }
        "model" => Arc::new(ModelTileEngine::new(model.clone())),
        _ => Arc::new(LutTileEngine::new(model.as_ref())),
    }
}

fn cmd_edge(args: &Args) -> i32 {
    let model = load_model(args);
    let img = if args.flag("demo") || args.get("input").is_none() {
        synthetic_scene(256, 256, seed_of(args))
    } else {
        match Image::read_pgm(Path::new(args.get("input").unwrap())) {
            Ok(i) => i,
            Err(e) => {
                eprintln!("cannot read input: {e}");
                return 1;
            }
        }
    };
    let t0 = Instant::now();
    let edges = if args.get_or("engine", "lut") == "rowbuf" {
        conv3x3_rowbuf(&img, &LAPLACIAN, model.as_ref())
    } else {
        let engine = make_engine(args, &model);
        let coord = Coordinator::start(engine, CoordinatorConfig::default());
        coord.run(img.clone()).edges
    };
    let dt = t0.elapsed();
    let out = PathBuf::from(args.get_or("output", "out/edges.pgm"));
    if let Err(e) = edges.write_pgm(&out) {
        eprintln!("cannot write output: {e}");
        return 1;
    }
    // PSNR vs exact for context
    let exact = build_design(DesignId::Exact, 8);
    let reference = edge_detect(&img, exact.as_ref());
    println!(
        "{}x{} image, design {}, {:.1} ms -> {} (PSNR vs exact: {:.2} dB)",
        img.width,
        img.height,
        model.name(),
        dt.as_secs_f64() * 1e3,
        out.display(),
        sfcmul::image::psnr(&reference, &edges)
    );
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let model = load_model(args);
    let engine = make_engine(args, &model);
    let workers = args.get_parse("workers", 4usize).unwrap_or(4);
    let batch = args.get_parse("batch", 8usize).unwrap_or(8);
    let jobs = args.get_parse("jobs", 64usize).unwrap_or(64);
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig { workers, queue_capacity: 256, max_batch: batch },
    );
    println!(
        "serving {jobs} synthetic jobs through engine {} ({workers} workers, batch {batch})",
        coord.engine_name()
    );
    let t0 = Instant::now();
    let handles: Vec<_> = (0..jobs)
        .map(|i| coord.submit(synthetic_scene(256, 256, i as u64)))
        .collect();
    let mut px_total = 0usize;
    for h in handles {
        let r = h.wait();
        px_total += r.edges.width * r.edges.height;
    }
    let wall = t0.elapsed();
    let m = coord.shutdown();
    println!(
        "completed {} jobs / {} tiles in {:.2} s  ({:.1} Mpix/s, mean batch {:.2})",
        m.jobs_completed,
        m.tiles_processed,
        wall.as_secs_f64(),
        px_total as f64 / wall.as_secs_f64() / 1e6,
        m.mean_batch_size
    );
    println!(
        "latency p50/p90/p99 = {:.1} / {:.1} / {:.1} ms; engine busy {:.2} s",
        m.latency_p50_ms,
        m.latency_p90_ms,
        m.latency_p99_ms,
        m.engine_busy.as_secs_f64()
    );
    0
}

fn cmd_ablate(args: &Args) -> i32 {
    print!("{}", sfcmul::tables::ablation_report(seed_of(args)));
    0
}

fn cmd_dump_lut(args: &Args) -> i32 {
    let model = load_model(args);
    let default_out = format!(
        "artifacts/{}_lut_rust.i32",
        args.get_or("design", "proposed").to_lowercase()
    );
    let out = PathBuf::from(args.get_or("out", &default_out));
    let table = lut::product_table(model.as_ref());
    match lut::write_i32_le(&out, &table) {
        Ok(()) => {
            println!("wrote {} (design {})", out.display(), model.name());
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_hw(args: &Args) -> i32 {
    println!("raw unit-gate figures (seed {}):", seed_of(args));
    for (id, m) in sfcmul::multipliers::all_designs_hw(8) {
        let raw = sfcmul::hwmodel::raw_hw(m.as_ref(), seed_of(args));
        println!(
            "  {:<17} area {:>6.1} GE  delay {:>5.1}  swcap {:>7.2}  gates {:>4}  depth {:>2}",
            id.paper_name(),
            raw.area_ge,
            raw.delay_units,
            raw.switched_cap,
            raw.gates,
            raw.depth
        );
    }
    0
}

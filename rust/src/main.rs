//! `sfcmul` — CLI for the approximate signed multiplier reproduction.
//!
//! Subcommands:
//!   tables   --id <t1|...|gates|all> [--seed S] [--out out/]  (ids from tables::TABLES)
//!   edge     --input img.pgm --output edges.pgm [--design SPEC] [--engine SPEC] [--op OP]
//!   serve    --demo [--jobs N] [--workers W] [--designs SPEC,SPEC,...] [--engine SPEC] [--op OP]
//!   serve    --listen ADDR [--conn-workers C] [--max-inflight J] [--quota-rps R] [--quota-burst B]
//!            (network mode: the SFC/1 TCP job protocol + GET /metrics HTTP on one
//!            listener, SIGINT-safe graceful drain — see `sfcmul::server`)
//!   infer    [--design SPEC] [--engine lut|bitsim|bitsim-live|model] [--seed S] [--size N]
//!            (quantized conv→relu→conv inference through the coordinator)
//!   trace    --input trace.json [--min-events N] | --addr HOST:PORT
//!            (validate a Chrome trace-event export, or fetch one live)
//!   ablate   [--seed S]                      (design-space ablation report)
//!   designs                                  (list the design registry)
//!   ops                                      (list the operator registry)
//!   dump-lut --design proposed@8 --out artifacts/proposed_lut_rust.i32
//!   export   --design proposed@8 [--out design.v]   (structural Verilog)
//!   hw       [--seed S]                      (raw unit-gate figures)
//!   help
//!
//! Design specs (`--design` / `--designs`) follow the grammar of
//! `multipliers::spec`: `family[@bits][:trunc=...][:comp=...][:opt=...]`,
//! e.g. `proposed@8`, `proposed@16:comp=const`, `d2@8:opt=none`. Engine
//! specs (`--engine`) are one of `lut | model | rowbuf | bitsim |
//! bitsim-live | pjrt`,
//! resolved through `coordinator::engines::resolve`. Operators (`--op`)
//! are the registry of `image::ops` (`laplacian` default, `sobel`,
//! `prewitt`, `scharr`, `roberts`, `sharpen`, `gaussian3`).

use sfcmul::coordinator::{
    engines, silence_worker_panics, Coordinator, CoordinatorConfig, EngineSpec, FaultEngine,
    FaultPlan, TileEngine,
};
use sfcmul::image::ops::{apply_operator, OpProgram, Operator};
use sfcmul::image::{synthetic_scene, Image};
use sfcmul::multipliers::{lut, registry, DesignSpec};
use sfcmul::nn::{fidelity as nn_fidelity, quantize_image, Network};
use sfcmul::server::{shutdown, Server, ServerConfig};
use sfcmul::util::cli::Args;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

const USAGE: &str = "\
sfcmul — Approximate Signed Multiplier with Sign-Focused Compressors (CS.AR 2025 reproduction)

USAGE: sfcmul <subcommand> [options]

  tables   --id t1|t2|t3|t4|t5|f9|f10|ops|nn|sweep|ablation|gates|all
           [--seed S] [--out DIR]
           regenerate a paper table/figure or an extension study (ops =
           design x operator PSNR, nn = quantized-inference accuracy,
           gates = netlist stats pre/post optimization; `sfcmul tables`
           with a bad id lists every registered table)
  edge     --input in.pgm --output out.pgm [--design SPEC] [--engine SPEC] [--op OP]
           run an operator on an image (or --demo for the synthetic scene)
  serve    --demo [--jobs N] [--workers W] [--batch B] [--designs SPEC,SPEC,...]
           [--engine SPEC] [--op OP]
           run the streaming coordinator on a synthetic job stream, round-robin
           across the listed designs, print aggregate + per-design metrics
           (default designs: proposed@8,exact@8 — an exact-vs-approximate A/B)
           fault-tolerance knobs (both serve modes):
           --fault PLAN            wrap every engine in a deterministic fault
                                   injector; PLAN = <panic|delay|wrong>@<every>
                                   [,ms=<delay>][,limit=<n>], e.g. panic@7 or
                                   delay@3,ms=20,limit=50
           --deadline-ms D         watchdog: fail jobs older than D ms
           --breaker-threshold K   consecutive failures tripping an engine's
                                   circuit breaker (0 disables; default 5)
           --breaker-cooldown-ms C open-breaker cooldown before a half-open
                                   probe (default 500)
           --fallback FROM=TO,..   serve FROM's jobs on TO while FROM's
                                   breaker is open (names from --designs)
           observability knobs (both serve modes):
           --trace PATH            record structured span events (submit ->
                                   queued -> dispatched -> batch -> terminal)
                                   and export them as Chrome trace-event JSON
                                   on exit; the SFCMUL_TRACE=PATH environment
                                   variable does the same. Load the file in
                                   Perfetto or chrome://tracing, or check it
                                   with `sfcmul trace --input PATH`.
           --quality-sample-n N    live approximation-quality telemetry:
                                   shadow-recompute 1 in N served work units
                                   (conv tiles / GEMM blocks) against the
                                   exact product and publish running MED /
                                   NMED / mismatch-rate per engine in the
                                   snapshot and /metrics (0 = off, default)
  serve    --listen ADDR [--workers W] [--batch B] [--designs SPEC,SPEC,...]
           [--conn-workers C] [--max-inflight J] [--quota-rps R] [--quota-burst B]
           network mode: serve the fleet over TCP (line-delimited SFC/1 job
           protocol with streaming connections, plus GET /metrics and
           GET /healthz HTTP on the same port). --max-inflight bounds
           concurrent jobs (excess gets ERR busy); --quota-rps/--quota-burst
           set per-client token-bucket quotas (ERR quota). Ctrl-C drains
           in-flight jobs and prints a final metrics snapshot.
  infer    [--design SPEC] [--engine lut|bitsim|bitsim-live|model] [--seed S] [--size N]
           run the fixed quantized conv->relu->conv network on a synthetic
           scene through the coordinator (i8 im2col + tiled GEMM, every MAC
           through the design; prints final-activation fidelity vs exact)
  trace    --input trace.json [--min-events N] | --addr HOST:PORT
           validate a Chrome trace-event export (JSON schema + span balance
           + event counts), or fetch the live trace ring from a serving
           instance over the TRACE frame and validate that
  ablate   [--seed S]
           design-space ablation (compressor candidates, compensation, truncation)
  designs  list every registered design family and example spec strings
  ops      list every registered operator (kernels, post rule, fast path)
  dump-lut [--design SPEC] [--out FILE]
           export an 8-bit design's 256x256 product table (cross-check with python)
  export   [--design SPEC] [--out FILE]
           emit the design's gate-level netlist as structural Verilog
           (after the spec's :opt= pass pipeline; stdout without --out)
  hw       [--seed S]
           raw unit-gate hardware figures per design

design SPEC grammar:  family[@bits][:trunc=paper|none|K][:comp=paper|none|const][:opt=none|fold|full]
  families: exact, proposed, d1, d2, d4, d5, d7, d12   (default bits: 8)
  examples: proposed@8   proposed@16:comp=const   d2@8:trunc=none   exact@8:opt=none
engine SPEC: lut (8-bit table, default) | model (any width) | rowbuf
             | bitsim (gate-level netlist via bitsliced sim, widths 8..=31)
             | bitsim-live (serve-time gate streaming, 64 MACs/pass, no tables)
             | pjrt
             | fault/<plan>/<engine> (deterministic fault injector, e.g.
               fault/panic@7/lut — same plan grammar as --fault)
operator OP: laplacian (default) | sobel | prewitt | scharr | roberts
             | sharpen | gaussian3
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("tables") => cmd_tables(&args),
        Some("edge") => cmd_edge(&args),
        Some("serve") => cmd_serve(&args),
        Some("infer") => cmd_infer(&args),
        Some("trace") => cmd_trace(&args),
        Some("ablate") => cmd_ablate(&args),
        Some("designs") => cmd_designs(),
        Some("ops") => cmd_ops(),
        Some("dump-lut") => cmd_dump_lut(&args),
        Some("export") => cmd_export(&args),
        Some("hw") => cmd_hw(&args),
        Some("help") | None => {
            print!("{USAGE}");
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn seed_of(args: &Args) -> u64 {
    args.get_parse("seed", 42u64).unwrap_or(42)
}

fn cmd_tables(args: &Args) -> i32 {
    let id = args.get_or("id", "all").to_string();
    let out_dir = PathBuf::from(args.get_or("out", "out"));
    match sfcmul::tables::generate(&id, seed_of(args), &out_dir) {
        Ok(text) => {
            print!("{text}");
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// Parse `--design` into a spec (exits with a message on bad input).
fn design_spec_of(args: &Args) -> Result<DesignSpec, i32> {
    let raw = args.get_or("design", "proposed@8");
    raw.parse::<DesignSpec>().map_err(|e| {
        eprintln!("invalid --design {raw:?}: {e}");
        2
    })
}

/// Parse `--op` into an operator (exits with a message on bad input).
fn operator_of(args: &Args) -> Result<Operator, i32> {
    let raw = args.get_or("op", "laplacian");
    raw.parse::<Operator>().map_err(|e| {
        eprintln!("invalid --op: {e}");
        2
    })
}

/// Resolve one design × engine pair through the shared fallback path
/// (PJRT degrades to the LUT engine when unavailable); reports the
/// backend actually used.
fn engine_for(
    engine: EngineSpec,
    design: &DesignSpec,
) -> Result<(Arc<dyn TileEngine>, EngineSpec), String> {
    engines::resolve_with_fallback(engine, design).map_err(|e| e.to_string())
}

fn cmd_edge(args: &Args) -> i32 {
    let spec = match design_spec_of(args) {
        Ok(s) => s,
        Err(code) => return code,
    };
    let op = match operator_of(args) {
        Ok(o) => o,
        Err(code) => return code,
    };
    let engine_spec: EngineSpec = match args.get_or("engine", "lut").parse() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("invalid --engine: {e}");
            return 2;
        }
    };
    let engine = match engine_for(engine_spec, &spec) {
        Ok((e, _actual)) => e,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    if !engine.supports_op(op) {
        // Bad request, same exit class as serve's pre-check (the PJRT
        // artifact is laplacian-only).
        eprintln!("engine {} cannot serve operator {op} (try --engine lut)", engine.name());
        return 2;
    }
    let img = if args.flag("demo") || args.get("input").is_none() {
        synthetic_scene(256, 256, seed_of(args))
    } else {
        match Image::read_pgm(Path::new(args.get("input").unwrap())) {
            Ok(i) => i,
            Err(e) => {
                eprintln!("cannot read input: {e}");
                return 1;
            }
        }
    };
    let t0 = Instant::now();
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let result = match coord.submit_to(img.clone(), None, op) {
        Ok(handle) => handle.wait(),
        Err(e) => Err(e),
    };
    let result = match result {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let edges = result.edges;
    let dt = t0.elapsed();
    let out = PathBuf::from(args.get_or("output", "out/edges.pgm"));
    if let Err(e) = edges.write_pgm(&out) {
        eprintln!("cannot write output: {e}");
        return 1;
    }
    // PSNR vs the exact multiplier at the same width and operator, for
    // context
    let exact = registry()
        .build_str(&format!("exact@{}", spec.bits))
        .expect("exact design");
    let reference = apply_operator(&img, op, exact.as_ref());
    println!(
        "{}x{} image, design {} op {} via {}, {:.1} ms -> {} (PSNR vs exact: {:.2} dB)",
        img.width,
        img.height,
        spec,
        op,
        coord.engine_name(),
        dt.as_secs_f64() * 1e3,
        out.display(),
        sfcmul::image::psnr(&reference, &edges)
    );
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let engine_spec: EngineSpec = match args.get_or("engine", "lut").parse() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("invalid --engine: {e}");
            return 2;
        }
    };
    let op = match operator_of(args) {
        Ok(o) => o,
        Err(code) => return code,
    };
    // --fault wraps every resolved engine in a deterministic injector;
    // per-engine plans are also reachable through the engine spec
    // grammar (fault/<plan>/<engine>).
    let fault_plan: Option<FaultPlan> = match args.get("fault") {
        None => None,
        Some(raw) => match raw.parse::<FaultPlan>() {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("invalid --fault: {e}");
                return 2;
            }
        },
    };
    // --designs a,b,c; a lone --design is honoured; the default A/Bs the
    // proposed approximate design against the exact multiplier.
    let designs_raw = args
        .get("designs")
        .or_else(|| args.get("design"))
        .unwrap_or("proposed@8,exact@8")
        .to_string();
    let mut named: Vec<(String, Arc<dyn TileEngine>)> = Vec::new();
    let mut backends: Vec<EngineSpec> = Vec::new();
    for part in designs_raw.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let spec: DesignSpec = match part.parse() {
            Ok(s) => s,
            Err(e) => {
                eprintln!("invalid design spec {part:?}: {e}");
                return 2;
            }
        };
        let key = spec.to_string();
        if named.iter().any(|(n, _)| *n == key) {
            continue; // duplicate spec in the list
        }
        match engine_for(engine_spec.clone(), &spec) {
            Ok((engine, actual)) => {
                if !engine.supports_op(op) {
                    eprintln!(
                        "engine {actual} for {part:?} cannot serve operator {op} \
                         (the PJRT artifact is laplacian-only; try --engine lut)"
                    );
                    return 2;
                }
                backends.push(actual);
                let engine = match &fault_plan {
                    Some(plan) => {
                        Arc::new(FaultEngine::new(engine, plan.clone())) as Arc<dyn TileEngine>
                    }
                    None => engine,
                };
                named.push((key, engine));
            }
            Err(e) => {
                eprintln!("error resolving {part:?}: {e}");
                return 1;
            }
        }
    }
    if named.is_empty() {
        eprintln!("no designs given");
        return 2;
    }
    let keys: Vec<String> = named.iter().map(|(n, _)| n.clone()).collect();
    // --fallback FROM=TO pairs, validated here so a typo is a clean CLI
    // error rather than a coordinator panic.
    let mut fallbacks: Vec<(String, String)> = Vec::new();
    if let Some(raw) = args.get("fallback") {
        for pair in raw.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let Some((from, to)) = pair.split_once('=') else {
                eprintln!("invalid --fallback {pair:?} (expected FROM=TO)");
                return 2;
            };
            let (from, to) = (from.trim().to_string(), to.trim().to_string());
            if !keys.contains(&from) || !keys.contains(&to) || from == to {
                eprintln!(
                    "--fallback {pair:?} must name two distinct designs from [{}]",
                    keys.join(", ")
                );
                return 2;
            }
            fallbacks.push((from, to));
        }
    }
    let workers = args.get_parse("workers", 4usize).unwrap_or(4);
    let batch = args.get_parse("batch", 8usize).unwrap_or(8);
    let dflt = CoordinatorConfig::default();
    let deadline_ms = args.get_parse("deadline-ms", 0u64).unwrap_or(0);
    let cfg = CoordinatorConfig {
        workers,
        queue_capacity: 256,
        max_batch: batch,
        deadline: (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms)),
        breaker_threshold: args
            .get_parse("breaker-threshold", dflt.breaker_threshold)
            .unwrap_or(dflt.breaker_threshold),
        breaker_cooldown: std::time::Duration::from_millis(
            args.get_parse("breaker-cooldown-ms", dflt.breaker_cooldown.as_millis() as u64)
                .unwrap_or(dflt.breaker_cooldown.as_millis() as u64),
        ),
        quality_sample_n: args.get_parse("quality-sample-n", 0u64).unwrap_or(0),
    };
    if fault_plan.is_some() {
        // Injected panics are caught and counted by the workers; keep
        // the default hook from spraying backtraces over the report.
        silence_worker_panics();
    }
    let coord = Coordinator::start_named_with_fallbacks(named, cfg, fallbacks);
    // --trace / SFCMUL_TRACE: flip the tracer on before the first job so
    // every span is captured; the export happens right before shutdown.
    let trace_path = trace_path_of(args);
    if trace_path.is_some() {
        coord.tracer().enable();
    }
    backends.sort_by_key(|e| e.key());
    backends.dedup();
    let backend_list =
        backends.iter().map(|e| e.key()).collect::<Vec<_>>().join("+");
    // Ctrl-C must drain in-flight jobs and print a final snapshot, not
    // abort mid-batch — both serve modes share the flag.
    shutdown::install();
    if let Some(addr) = args.get("listen") {
        return serve_listen(args, coord, addr.to_string(), &keys, &backend_list, trace_path);
    }
    let jobs = args.get_parse("jobs", 64usize).unwrap_or(64);
    println!(
        "serving {jobs} synthetic {op} jobs round-robin across [{}] via engine {backend_list} ({workers} workers, batch {batch})",
        keys.join(", "),
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for i in 0..jobs {
        if shutdown::signalled() {
            println!("interrupt: stopping intake after {i} submissions, draining in-flight jobs");
            break;
        }
        let key = keys[i % keys.len()].as_str();
        // Under fault injection a submit may bounce off an open breaker;
        // report it and keep the stream going — degraded, not dead.
        match coord.submit_to(synthetic_scene(256, 256, i as u64), Some(key), op) {
            Ok(h) => handles.push(h),
            Err(e) => eprintln!("job {i} rejected: {e}"),
        }
    }
    let mut px_total = 0usize;
    let mut failed = 0usize;
    for h in handles {
        match h.wait() {
            Ok(r) => px_total += r.edges.width * r.edges.height,
            Err(e) => {
                failed += 1;
                eprintln!("job failed: {e}");
            }
        }
    }
    let wall = t0.elapsed();
    if let Some(path) = &trace_path {
        export_trace(&coord, path);
    }
    let m = coord.shutdown();
    println!(
        "completed {} jobs / {} tiles in {:.2} s  ({:.1} Mpix/s, mean batch {:.2}{})",
        m.jobs_completed,
        m.tiles_processed,
        wall.as_secs_f64(),
        px_total as f64 / wall.as_secs_f64() / 1e6,
        m.mean_batch_size,
        if failed > 0 { format!(", {failed} failed") } else { String::new() }
    );
    print_snapshot(&m);
    0
}

/// Resolve the trace export path: `--trace PATH` wins, then the
/// `SFCMUL_TRACE` environment variable (empty value = off).
fn trace_path_of(args: &Args) -> Option<PathBuf> {
    args.get("trace").map(PathBuf::from).or_else(|| {
        std::env::var("SFCMUL_TRACE").ok().filter(|s| !s.is_empty()).map(PathBuf::from)
    })
}

/// Export the coordinator's trace ring as Chrome trace-event JSON.
fn export_trace(coord: &Coordinator, path: &Path) {
    let tracer = coord.tracer();
    let text = tracer.chrome_trace_json(coord.engine_names());
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Err(e) = std::fs::create_dir_all(dir) {
                eprintln!("cannot create {}: {e}", dir.display());
                return;
            }
        }
    }
    match std::fs::write(path, &text) {
        Ok(()) => println!(
            "trace: wrote {} events to {} ({} dropped by the ring; open in Perfetto \
             or validate with `sfcmul trace --input {}`)",
            tracer.recorded().saturating_sub(tracer.dropped()),
            path.display(),
            tracer.dropped(),
            path.display()
        ),
        Err(e) => eprintln!("cannot write trace {}: {e}", path.display()),
    }
}

/// Mean of one stage histogram in milliseconds (0 when empty).
fn stage_mean_ms(h: &sfcmul::obs::hist::HistSnapshot) -> f64 {
    if h.count == 0 {
        0.0
    } else {
        h.sum_seconds / h.count as f64 * 1e3
    }
}

/// Shared tail of both serve modes: fleet-wide counters + quantiles and
/// the per-design metric rows.
fn print_snapshot(m: &sfcmul::coordinator::MetricsSnapshot) {
    println!(
        "jobs accepted/rejected/completed/failed = {}/{}/{}/{}; queue depth {}",
        m.jobs_accepted, m.jobs_rejected, m.jobs_completed, m.jobs_failed, m.queue_depth
    );
    println!(
        "latency p50/p90/p99 = {:.1} / {:.1} / {:.1} ms; engine busy {:.2} s",
        m.latency_p50_ms,
        m.latency_p90_ms,
        m.latency_p99_ms,
        m.engine_busy.as_secs_f64()
    );
    println!("per-design metrics:");
    for row in &m.per_engine {
        let health = if row.jobs_failed > 0
            || row.breaker != sfcmul::coordinator::BreakerState::Closed
        {
            format!(
                "  failed {} (panics {}, deadline {})  breaker {}",
                row.jobs_failed, row.panics_caught, row.deadline_misses, row.breaker
            )
        } else {
            String::new()
        };
        println!(
            "  {:<24} jobs {:>4}  tiles {:>6}  p50/p99 {:>6.1}/{:>6.1} ms  busy {:.2} s{health}",
            row.name,
            row.jobs_completed,
            row.tiles_processed,
            row.latency_p50_ms,
            row.latency_p99_ms,
            row.engine_busy.as_secs_f64()
        );
        // Stage means come from the log2 histograms behind /metrics.
        let [qw, cp, e2] = &row.stages;
        if qw.count + cp.count + e2.count > 0 {
            println!(
                "      stages: queue-wait {:.2} ms ({} obs)  compute {:.2} ms ({})  e2e {:.2} ms ({})",
                stage_mean_ms(qw),
                qw.count,
                stage_mean_ms(cp),
                cp.count,
                stage_mean_ms(e2),
                e2.count
            );
        }
        // Live quality telemetry (only with --quality-sample-n > 0).
        let q = &row.quality;
        if q.units > 0 {
            println!(
                "      quality: {} units / {} pairs sampled  mismatch {:.2}%  MED {:.3}  \
                 NMED {:.6}  max|ED| {}",
                q.units,
                q.pairs,
                q.mismatch_rate() * 100.0,
                q.med(),
                q.nmed(),
                q.max_ed
            );
        }
    }
}

/// Network serve mode: run the fleet behind the TCP/HTTP front-end until
/// SIGINT/SIGTERM, then drain connections, drain the fleet, and print
/// the final snapshot.
fn serve_listen(
    args: &Args,
    coord: Coordinator,
    addr: String,
    keys: &[String],
    backend_list: &str,
    trace_path: Option<PathBuf>,
) -> i32 {
    let cfg = ServerConfig {
        addr,
        conn_workers: args.get_parse("conn-workers", 8usize).unwrap_or(8),
        pending_conns: args.get_parse("pending-conns", 32usize).unwrap_or(32),
        max_inflight: args.get_parse("max-inflight", 64usize).unwrap_or(64),
        quota_rps: args.get_parse("quota-rps", 0.0f64).unwrap_or(0.0),
        quota_burst: args.get_parse("quota-burst", 8.0f64).unwrap_or(8.0),
    };
    let coord = Arc::new(coord);
    let server = match Server::start(coord.clone(), cfg.clone()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "listening on {} — engines [{}] via {backend_list}; {} conn workers, \
         max {} jobs in flight{}",
        server.local_addr(),
        keys.join(", "),
        cfg.conn_workers,
        cfg.max_inflight,
        if cfg.quota_rps > 0.0 {
            format!(", per-client quota {}/s (burst {})", cfg.quota_rps, cfg.quota_burst)
        } else {
            String::new()
        }
    );
    println!(
        "job protocol: EDGE/GEMM/METRICS/TRACE/PING frames; HTTP: GET /metrics, GET /healthz"
    );
    while !shutdown::signalled() {
        std::thread::sleep(std::time::Duration::from_millis(150));
    }
    println!("signal received: draining connections, then the fleet");
    let stats = server.stop();
    if let Some(path) = &trace_path {
        export_trace(&coord, path);
    }
    let m = match Arc::try_unwrap(coord) {
        Ok(c) => c.shutdown(),
        // A handler leaked an Arc clone (cannot happen after stop(), but
        // stay defensive): read the metrics and let Drop shut down.
        Err(c) => c.metrics(),
    };
    print_snapshot(&m);
    println!(
        "server: {} connections ({} still open), {} ok replies, rejected busy/quota = {}/{}, \
         protocol errors {}, http requests {}",
        stats.connections_total,
        stats.connections_open,
        stats.requests_ok,
        stats.rejected_busy,
        stats.rejected_quota,
        stats.protocol_errors,
        stats.http_requests
    );
    0
}

/// Quantized inference: the fixed conv→relu→conv demo network on a
/// synthetic scene, every MAC through the selected design, served as
/// coordinator GEMM jobs (one per layer).
fn cmd_infer(args: &Args) -> i32 {
    let spec = match design_spec_of(args) {
        Ok(s) => s,
        Err(code) => return code,
    };
    if spec.bits != 8 {
        eprintln!("infer runs the i8 quantized datapath; need an 8-bit design (got {spec})");
        return 2;
    }
    let engine_spec: EngineSpec = match args.get_or("engine", "lut").parse() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("invalid --engine: {e}");
            return 2;
        }
    };
    let (engine, actual) = match engine_for(engine_spec, &spec) {
        Ok(pair) => pair,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    if engine.nn_backend().is_none() {
        // Same exit class as the operator pre-checks: the request names
        // an engine that cannot carry the i8 GEMM datapath.
        eprintln!(
            "engine {actual} cannot serve quantized-inference jobs \
             (try --engine lut | bitsim | bitsim-live | model)"
        );
        return 2;
    }
    let size = args.get_parse("size", 64usize).unwrap_or(64);
    let seed = seed_of(args);
    let net = Network::demo();
    let img = synthetic_scene(size, size, seed);
    let x = quantize_image(&img);
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let t0 = Instant::now();
    let served = match net.run_served(&coord, None, &x) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let dt = t0.elapsed();
    // Reference: the same network with the exact multiplier.
    let exact = registry().build_str("exact@8").expect("exact design");
    let exact_lut = lut::product_table(exact.as_ref());
    let reference = net.run_tiled(&x, &exact_lut);
    let fid = nn_fidelity(&served, &reference);
    let engine_label = coord.engine_name().to_string();
    let m = coord.shutdown();
    println!(
        "infer: conv(1->4, 3x3, s1, p1)+relu -> conv(4->2, 3x3, s2, p1) on a {size}x{size} \
         synthetic scene (seed {seed})"
    );
    let mut shape = format!("1x{}x{}", size, size);
    let (mut h, mut w) = (size, size);
    for layer in &net.layers {
        let (oh, ow) = layer.out_dims(h, w);
        shape.push_str(&format!(" -> {}x{}x{}", layer.out_c(), oh, ow));
        (h, w) = (oh, ow);
    }
    println!("layers: {shape}  (design {spec} via {engine_label})");
    println!(
        "final activations vs exact@8: {}/{} mismatched ({:.2}%), mean |d| {:.3}, max |d| {}",
        fid.mismatched,
        fid.total,
        fid.mismatch_rate() * 100.0,
        fid.mean_abs,
        fid.max_abs
    );
    println!(
        "served {} GEMM jobs ({} blocks) in {:.2} ms (engine busy {:.2} ms)",
        m.jobs_completed,
        m.tiles_processed,
        dt.as_secs_f64() * 1e3,
        m.engine_busy.as_secs_f64() * 1e3
    );
    0
}

/// Validate a Chrome trace-event export, either from a file written by
/// `serve --trace` (`--input`) or fetched live from a serving instance
/// over the `TRACE` frame (`--addr`). Exits non-zero on schema
/// violations or (with `--min-events`) an emptier-than-expected trace —
/// the CI smoke leg keys on that.
fn cmd_trace(args: &Args) -> i32 {
    let text = if let Some(path) = args.get("input") {
        match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("cannot read {path}: {e}");
                return 1;
            }
        }
    } else if let Some(addr) = args.get("addr") {
        let mut client = match sfcmul::server::Client::connect(addr) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("cannot connect to {addr}: {e}");
                return 1;
            }
        };
        match client.trace_text() {
            Ok(t) => t,
            Err(e) => {
                eprintln!("TRACE fetch from {addr} failed: {e}");
                return 1;
            }
        }
    } else {
        eprintln!("trace needs --input FILE or --addr HOST:PORT");
        return 2;
    };
    match sfcmul::obs::trace::validate_chrome_trace(&text) {
        Ok(s) => {
            let min = args.get_parse("min-events", 0usize).unwrap_or(0);
            if s.events < min {
                eprintln!(
                    "trace is valid but has {} events (< --min-events {min}) — \
                     was tracing enabled on the serving side?",
                    s.events
                );
                return 1;
            }
            println!(
                "valid Chrome trace: {} events ({} span begins, {} span ends, \
                 {} instants, {} metadata)",
                s.events, s.begins, s.ends, s.instants, s.metadata
            );
            0
        }
        Err(e) => {
            eprintln!("invalid trace: {e}");
            1
        }
    }
}

fn cmd_ablate(args: &Args) -> i32 {
    print!("{}", sfcmul::tables::ablation_report(seed_of(args)));
    0
}

fn cmd_designs() -> i32 {
    println!("registered design families (canonical spec @ 8 and 16 bit):");
    for spec in registry().specs(8) {
        let wide = DesignSpec { bits: 16, ..spec.clone() };
        println!(
            "  {:<12} {:<14} e.g. {}  |  {}",
            spec.compressors.key(),
            spec.compressors.paper_name(),
            spec,
            wide
        );
    }
    println!("options: :trunc=paper|none|K  :comp=paper|none|const  :opt=none|fold|full");
    0
}

fn cmd_ops() -> i32 {
    // Fast-path classification is data-driven (folded against the exact
    // product table): uniform-ring operators compile to the sliding
    // column-sum core, the rest to the zero-tap-elided folded path.
    let exact = registry().build_str("exact@8").expect("exact design");
    let table = lut::product_table(exact.as_ref());
    println!("registered operators (--op KEY; kernels pre-scaled x8 on the 8-bit datapath):");
    for op in Operator::all() {
        let prog = OpProgram::from_lut(op, &table);
        let kinds: Vec<String> =
            prog.pass_kinds().iter().map(|k| k.to_string()).collect();
        let passes: Vec<String> = op
            .passes()
            .iter()
            .map(|p| {
                let rule = match p.post.mode {
                    sfcmul::image::ops::PostMode::Magnitude => "|acc|",
                    sfcmul::image::ops::PostMode::Saturate => "acc",
                };
                format!("{} {:?}  {rule}>>{}", p.label, p.kernel, p.post.norm_shift)
            })
            .collect();
        println!(
            "  {:<10} {:<7} fast path {:<18} {}",
            op.key(),
            if op.is_gradient_pair() { "gx+gy" } else { "single" },
            kinds.join("+"),
            op.describe(),
        );
        for p in passes {
            println!("             {p}");
        }
    }
    println!("gradient operators combine as min(255, |Gx| + |Gy|) (saturating integer sum)");
    0
}

fn cmd_dump_lut(args: &Args) -> i32 {
    let spec = match design_spec_of(args) {
        Ok(s) => s,
        Err(code) => return code,
    };
    if spec.bits != 8 {
        eprintln!("dump-lut exports 256x256 tables; need an 8-bit design (got {spec})");
        return 2;
    }
    let model = match registry().build(&spec) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    // Canonical specs keep the historical short stem ("proposed_lut_rust");
    // variant specs encode their options so they never clobber it.
    let stem = if spec.is_canonical() {
        spec.compressors.key().to_string()
    } else {
        spec.to_string().replace(['@', ':', '='], "_")
    };
    let default_out = format!("artifacts/{stem}_lut_rust.i32");
    let out = PathBuf::from(args.get_or("out", &default_out));
    let table = lut::product_table(model.as_ref());
    match lut::write_i32_le(&out, &table) {
        Ok(()) => {
            println!("wrote {} (design {})", out.display(), model.name());
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// Emit a design's netlist as structural Verilog (`sfcmul export`): the
/// spec's `:opt=` level decides what the external flow sees — `:opt=none`
/// exports the raw generator output, the default exports the optimized
/// netlist.
fn cmd_export(args: &Args) -> i32 {
    let spec = match design_spec_of(args) {
        Ok(s) => s,
        Err(code) => return code,
    };
    let model = match registry().build(&spec) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let nl = model.build_netlist();
    let module = spec.to_string().replace(['@', ':', '='], "_");
    let text = sfcmul::netlist::export_verilog(&nl, &module);
    match args.get("out") {
        Some(path) => {
            let out = PathBuf::from(path);
            if let Some(dir) = out.parent() {
                if !dir.as_os_str().is_empty() {
                    if let Err(e) = std::fs::create_dir_all(dir) {
                        eprintln!("cannot create {}: {e}", dir.display());
                        return 1;
                    }
                }
            }
            if let Err(e) = std::fs::write(&out, &text) {
                eprintln!("cannot write {}: {e}", out.display());
                return 1;
            }
            println!(
                "wrote {} (module {module}, {} gates, {:.1} GE)",
                out.display(),
                nl.logic_gate_count(),
                nl.area()
            );
        }
        None => print!("{text}"),
    }
    0
}

fn cmd_hw(args: &Args) -> i32 {
    println!("raw unit-gate figures (seed {}):", seed_of(args));
    for (id, m) in sfcmul::multipliers::all_designs_hw(8) {
        let raw = sfcmul::hwmodel::raw_hw(m.as_ref(), seed_of(args));
        println!(
            "  {:<17} area {:>6.1} GE  delay {:>5.1}  swcap {:>7.2}  gates {:>4}  depth {:>2}",
            id.paper_name(),
            raw.area_ge,
            raw.delay_units,
            raw.switched_cap,
            raw.gates,
            raw.depth
        );
    }
    0
}

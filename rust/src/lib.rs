//! # sfcmul — Approximate Signed Multiplier with Sign-Focused Compressors
//!
//! Full-system reproduction of *"Approximate Signed Multiplier with
//! Sign-Focused Compressor for Edge Detection Applications"* (CS.AR 2025)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate is organised bottom-up:
//!
//! * [`util`] — self-contained substrates (PRNG, property testing,
//!   micro-benchmark harness, CLI parsing, error type, JSON emission,
//!   thread pool) built from scratch because the build environment is
//!   fully offline.
//! * [`netlist`] — a miniature gate-level EDA toolkit: netlist construction,
//!   the mutable graph core with its optimization pass pipeline
//!   ([`netlist::graph`]/[`netlist::opt`]: constant folding, structural
//!   CSE, dead-gate elimination — run on every registry design per the
//!   `:opt=` spec knob), structural Verilog export
//!   ([`netlist::export_verilog`], `sfcmul export`), functional
//!   simulation (scalar reference, word-level packed, and the
//!   bitsliced 64-lane batch engine [`netlist::bitslice::BitSim`] with its
//!   bit-matrix transposition layer — the substrate of every operand-space
//!   sweep), static timing, unit-gate area and switching-activity power
//!   models. This substitutes for the paper's Synopsys DC + UMC 90nm flow.
//!   `use sfcmul::netlist::prelude::*` is the one-stop import.
//! * [`circuits`] — generic adder/compressor building blocks (HA, FA, the
//!   3:2 compressor of paper ref. [8], exact 4:2, ripple/carry-save adders,
//!   Dadda-style column reduction).
//! * [`compressors`] — every sign-focused compressor in the paper:
//!   the proposed exact/approximate `A+B+C+1` and `A+B+C+D+1`, and the
//!   baseline designs AC1..AC5 and the 4:2 designs of refs. [1]/[7]
//!   (paper Tables 2 and 3), with probabilistic error statistics.
//! * [`multipliers`] — the construction layer. [`multipliers::spec`]
//!   defines the declarative [`multipliers::DesignSpec`] (compressor
//!   family × bitwidth × truncation × compensation × optimization level,
//!   round-tripping a compact string form such as
//!   `proposed@16:comp=const` or `exact@8:opt=none`) and the
//!   [`multipliers::Registry`] that maps design names to factories —
//!   every multiplier in the system is built through it. The paper's
//!   comparison set (Tables 4/5) is registered out of the box;
//!   [`multipliers::DesignId`] remains as a thin alias over canonical
//!   specs for the paper-table call sites. Each design exists as both a
//!   gate-level netlist and a fast bit-parallel functional model,
//!   cross-checked exhaustively at N=8 and by sampling at wider widths.
//! * [`error`] — ER / MED / NMED / MRED error-metric harness (Table 4).
//! * [`hwmodel`] — unit-gate → calibrated area/power/delay/PDP model
//!   (Table 5, Fig 10).
//! * [`image`] — PGM I/O, synthetic scenes, the operator registry
//!   ([`image::ops`]: Laplacian, Sobel/Prewitt/Scharr/Roberts gradient
//!   magnitudes, sharpen, gaussian3 — per-operator kernels, post rules
//!   and folded-tap execution programs), the convolution cores (direct,
//!   LUT/colsum, row-buffer streaming), PSNR (Fig 9).
//! * [`nn`] — approximate quantized inference: symmetric i8
//!   quantization, an output-stationary tiled signed GEMM
//!   (`i8 × i8 → i32`) where every MAC routes through a registry design
//!   (product-LUT fast path, bitsim-swept netlist-true tables, and a
//!   per-element reference), and `Conv2d`/`Network` lowered via im2col
//!   onto that GEMM — served through the coordinator as a second job
//!   kind next to image tiles (`sfcmul infer`).
//! * [`obs`] — the observability layer: bounded structured tracing
//!   (Chrome trace-event export, `sfcmul trace`), per-(engine, stage)
//!   log₂ latency histograms behind the Prometheus exposition, and the
//!   live approximation-quality sampler (running MED/NMED/mismatch-rate
//!   per engine, shadow-recomputed from sampled traffic).
//! * [`coordinator`] — the L3 serving layer: halo tiling, dynamic batching,
//!   worker pool with backpressure, latency/throughput metrics (Fig 8).
//!   A [`coordinator::Coordinator`] now serves a *set of named engines*
//!   (one per design/backend pair, resolved through
//!   [`coordinator::engines::resolve`]); each job may select its engine by
//!   key **and its operator** (tap tables are built per (design,
//!   operator) pair), and [`coordinator::MetricsSnapshot`] reports
//!   per-design rows — one service instance can A/B exact vs.
//!   approximate designs across heterogeneous workloads under load.
//! * [`server`] — the L4 network front-end: a `std::net`-only TCP
//!   listener speaking a streaming job protocol plus `GET /metrics`
//!   HTTP on one port, with a bounded handler pool, admission control
//!   (in-flight bound + per-client token-bucket quotas), SIGINT-safe
//!   graceful drain, and a blocking [`server::Client`]
//!   (`sfcmul serve --listen ADDR`).
//! * [`runtime`] — PJRT client wrapper that loads the AOT-compiled
//!   JAX/Pallas artifacts (`artifacts/*.hlo.txt`) and executes them from
//!   the Rust hot path (feature `pjrt`; a stub that reports the feature as
//!   unavailable ships by default so the offline build needs no XLA
//!   dependency). Python never runs at request time.
//! * [`tables`] — one generator per paper table/figure (T1..T5, F9, F10).

pub mod util;
pub mod netlist;
pub mod circuits;
pub mod compressors;
pub mod multipliers;
pub mod error;
pub mod hwmodel;
pub mod image;
pub mod nn;
pub mod obs;
pub mod coordinator;
pub mod server;
pub mod runtime;
pub mod tables;

/// Crate-wide result alias (see [`util::error::Error`]).
pub type Result<T> = std::result::Result<T, util::error::Error>;

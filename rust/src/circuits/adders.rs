//! Adder cells: half adder, full adder, the 3:2 compressor of the paper's
//! ref. [8] (Krishna et al., VLSID 2025 — an energy-optimised full-adder
//! realisation), an exact 4:2 compressor, and a ripple-carry adder for the
//! final summation stage.

use crate::netlist::{Netlist, SigId};

/// Half adder: returns (sum, carry).
pub fn half_adder(n: &mut Netlist, a: SigId, b: SigId) -> (SigId, SigId) {
    let sum = n.xor2(a, b);
    let carry = n.and2(a, b);
    (sum, carry)
}

/// Canonical full adder: sum = a⊕b⊕c, carry = maj(a,b,c). Returns
/// (sum, carry).
pub fn full_adder(n: &mut Netlist, a: SigId, b: SigId, c: SigId) -> (SigId, SigId) {
    let sum = n.xor3(a, b, c);
    let carry = n.maj3(a, b, c);
    (sum, carry)
}

/// The 3:2 compressor of ref. [8]: functionally a full adder, implemented
/// with the XOR/MUX factoring that the reference optimises for energy
/// (carry through a mux selected by the propagate signal instead of a
/// majority cell — one less XOR on the carry path).
pub fn compressor32_ref8(n: &mut Netlist, a: SigId, b: SigId, c: SigId) -> (SigId, SigId) {
    let p = n.xor2(a, b); // propagate
    let sum = n.xor2(p, c);
    // carry = p ? c : a   (classic mux-based carry)
    let carry = n.mux2(p, a, c);
    (sum, carry)
}

/// Exact 4:2 compressor (two chained 3:2 stages): inputs a..d plus carry-in
/// `cin`; returns (sum, carry, cout) where the column value is
/// `a+b+c+d+cin = sum + 2·(carry + cout)`.
pub fn compressor42_exact(
    n: &mut Netlist,
    a: SigId,
    b: SigId,
    c: SigId,
    d: SigId,
    cin: SigId,
) -> (SigId, SigId, SigId) {
    let (s1, cout) = compressor32_ref8(n, a, b, c);
    let (sum, carry) = compressor32_ref8(n, s1, d, cin);
    (sum, carry, cout)
}

/// Ripple-carry adder over two LSB-first buses of equal width, with
/// carry-in. Returns (sum bus of the same width, carry-out).
pub fn ripple_adder(
    n: &mut Netlist,
    a: &[SigId],
    b: &[SigId],
    cin: SigId,
) -> (Vec<SigId>, SigId) {
    assert_eq!(a.len(), b.len());
    let mut carry = cin;
    let mut sums = Vec::with_capacity(a.len());
    for (&ai, &bi) in a.iter().zip(b.iter()) {
        let (s, c) = full_adder(n, ai, bi, carry);
        sums.push(s);
        carry = c;
    }
    (sums, carry)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::eval_outputs_bool;

    fn check_adder_cell(build: impl Fn(&mut Netlist, &[SigId]) -> Vec<SigId>, arity: usize) {
        // Exhaustively verify Σinputs == Σ 2^k · output_k
        let mut n = Netlist::new("cell");
        let ins = n.input_bus("i", arity);
        let outs = build(&mut n, &ins);
        n.output_bus("o", &outs);
        n.validate().unwrap();
        for bits in 0..(1u32 << arity) {
            let input: Vec<bool> = (0..arity).map(|k| bits >> k & 1 == 1).collect();
            let expect: u32 = input.iter().map(|&b| b as u32).sum();
            let got: u32 = eval_outputs_bool(&n, &input)
                .iter()
                .enumerate()
                .map(|(k, &b)| (b as u32) << k)
                .sum();
            assert_eq!(got, expect, "inputs {bits:0width$b}", width = arity);
        }
    }

    #[test]
    fn half_adder_exhaustive() {
        check_adder_cell(
            |n, ins| {
                let (s, c) = half_adder(n, ins[0], ins[1]);
                vec![s, c]
            },
            2,
        );
    }

    #[test]
    fn full_adder_exhaustive() {
        check_adder_cell(
            |n, ins| {
                let (s, c) = full_adder(n, ins[0], ins[1], ins[2]);
                vec![s, c]
            },
            3,
        );
    }

    #[test]
    fn compressor32_ref8_is_a_full_adder() {
        check_adder_cell(
            |n, ins| {
                let (s, c) = compressor32_ref8(n, ins[0], ins[1], ins[2]);
                vec![s, c]
            },
            3,
        );
    }

    #[test]
    fn compressor32_ref8_cheaper_carry_path_than_canonical_fa() {
        let mut canon = Netlist::new("fa");
        let i = canon.input_bus("i", 3);
        let (s, c) = full_adder(&mut canon, i[0], i[1], i[2]);
        canon.output("s", s);
        canon.output("c", c);

        let mut opt = Netlist::new("c32");
        let i = opt.input_bus("i", 3);
        let (s, c) = compressor32_ref8(&mut opt, i[0], i[1], i[2]);
        opt.output("s", s);
        opt.output("c", c);

        // The ref-[8] cell must not be larger than the canonical FA.
        assert!(opt.area() <= canon.area());
    }

    #[test]
    fn compressor42_exhaustive() {
        // value = sum + 2*(carry + cout)
        let mut n = Netlist::new("c42");
        let ins = n.input_bus("i", 5);
        let (s, c, co) = compressor42_exact(&mut n, ins[0], ins[1], ins[2], ins[3], ins[4]);
        n.output("s", s);
        n.output("c", c);
        n.output("co", co);
        for bits in 0..32u32 {
            let input: Vec<bool> = (0..5).map(|k| bits >> k & 1 == 1).collect();
            let expect: u32 = input.iter().map(|&b| b as u32).sum();
            let o = eval_outputs_bool(&n, &input);
            let got = o[0] as u32 + 2 * (o[1] as u32 + o[2] as u32);
            assert_eq!(got, expect, "inputs {bits:05b}");
        }
    }

    #[test]
    fn ripple_adder_matches_integer_addition() {
        let width = 8;
        let mut n = Netlist::new("rca");
        let a = n.input_bus("a", width);
        let b = n.input_bus("b", width);
        let cin = n.input("cin");
        let (sums, cout) = ripple_adder(&mut n, &a, &b, cin);
        n.output_bus("s", &sums);
        n.output("cout", cout);
        // spot-check 1000 random and corner cases
        let cases: Vec<(u32, u32, u32)> = {
            let mut v = vec![(0, 0, 0), (255, 255, 1), (170, 85, 0), (255, 1, 0)];
            let mut rng = crate::util::prng::Xoshiro256::seeded(5);
            for _ in 0..1000 {
                v.push((rng.next_u32() & 0xFF, rng.next_u32() & 0xFF, rng.next_u32() & 1));
            }
            v
        };
        for (x, y, ci) in cases {
            let mut input = Vec::new();
            for k in 0..width {
                input.push(x >> k & 1 == 1);
            }
            for k in 0..width {
                input.push(y >> k & 1 == 1);
            }
            input.push(ci == 1);
            let o = eval_outputs_bool(&n, &input);
            let got: u32 = o
                .iter()
                .enumerate()
                .map(|(k, &bit)| (bit as u32) << k)
                .sum();
            assert_eq!(got, x + y + ci, "{x}+{y}+{ci}");
        }
    }
}

//! Dadda-style column reduction.
//!
//! A partial-product matrix is represented as [`Columns`]: `cols[w]` holds
//! the signals of weight `2^w`. [`reduce_columns`] compresses every column
//! to height ≤ 2 using full/half adders (the 3:2 compressor of ref. [8]),
//! then a final ripple-carry stage produces the LSB-first result bus. This
//! is the "combination of adders and compressors [8] used in the MSP"
//! (paper §3.3); the proposed multiplier seeds the CSP columns with
//! sign-focused compressors first and hands the leftovers to this engine.

use super::adders::{compressor32_ref8, half_adder, ripple_adder};
use crate::netlist::{Netlist, SigId};

/// Partial-product columns, LSB-first: `cols[w]` = signals of weight 2^w.
#[derive(Debug, Clone, Default)]
pub struct Columns {
    pub cols: Vec<Vec<SigId>>,
}

impl Columns {
    pub fn new(width: usize) -> Self {
        Self { cols: vec![Vec::new(); width] }
    }

    /// Add a signal at weight `2^w`, growing the matrix as needed.
    pub fn push(&mut self, w: usize, sig: SigId) {
        if w >= self.cols.len() {
            self.cols.resize(w + 1, Vec::new());
        }
        self.cols[w].push(sig);
    }

    pub fn width(&self) -> usize {
        self.cols.len()
    }

    pub fn max_height(&self) -> usize {
        self.cols.iter().map(|c| c.len()).max().unwrap_or(0)
    }

    /// Total number of partial-product bits.
    pub fn bit_count(&self) -> usize {
        self.cols.iter().map(|c| c.len()).sum()
    }
}

/// Reduce all columns to height ≤ 2 with 3:2/2:2 counters, then add the two
/// remaining rows with a ripple-carry adder. Returns the LSB-first product
/// bus of width `columns.width() + 1` (the +1 absorbs the final carry-out;
/// callers truncate to their product width).
///
/// Reduction policy (Dadda-flavoured): per stage, process columns LSB→MSB;
/// while a column has ≥ 3 live bits, consume three into a 3:2 compressor
/// (sum stays, carry promotes); a final pair may go through a half adder
/// when the column still exceeds the stage target. Stages repeat until all
/// columns have ≤ 2 bits.
pub fn reduce_columns(n: &mut Netlist, mut columns: Columns) -> Vec<SigId> {
    // Iteratively compress. Each pass handles every column once; carries
    // are injected into the *next* column's pending list for the following
    // pass (classic carry-save discipline, keeps stages well-defined for
    // timing).
    while columns.max_height() > 2 {
        let width = columns.width();
        let mut next = Columns::new(width + 1);
        for w in 0..width {
            let bits = std::mem::take(&mut columns.cols[w]);
            let mut queue = bits;
            // absorb bits carried into this column during this same pass
            if w < next.cols.len() {
                queue.extend(std::mem::take(&mut next.cols[w]));
            }
            let mut keep: Vec<SigId> = Vec::new();
            let mut i = 0;
            while queue.len() - i >= 3 {
                let (a, b, c) = (queue[i], queue[i + 1], queue[i + 2]);
                i += 3;
                let (s, cy) = compressor32_ref8(n, a, b, c);
                keep.push(s);
                next.push(w + 1, cy);
            }
            let rem = queue.len() - i;
            if rem == 2 && keep.len() + 2 > 2 {
                // half-adder the pair only if the column would stay too tall
                let (s, cy) = half_adder(n, queue[i], queue[i + 1]);
                keep.push(s);
                next.push(w + 1, cy);
            } else {
                for &q in &queue[i..] {
                    keep.push(q);
                }
            }
            next.cols[w].extend(keep);
        }
        columns = next;
    }

    // Final stage: two rows → ripple adder.
    let width = columns.width();
    let zero = n.const0();
    let mut row_a = Vec::with_capacity(width);
    let mut row_b = Vec::with_capacity(width);
    for w in 0..width {
        let col = &columns.cols[w];
        row_a.push(*col.first().unwrap_or(&zero));
        row_b.push(*col.get(1).unwrap_or(&zero));
    }
    let (mut sums, cout) = ripple_adder(n, &row_a, &row_b, zero);
    sums.push(cout);
    sums
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::eval_outputs_bool;
    use crate::util::prng::Xoshiro256;

    /// Build a reducer over `heights[w]` input bits per column and check the
    /// weighted sum against direct integer arithmetic for random vectors.
    fn check_reduction(heights: &[usize], trials: usize, seed: u64) {
        let mut n = Netlist::new("red");
        let mut cols = Columns::new(heights.len());
        let mut input_weights = Vec::new();
        for (w, &h) in heights.iter().enumerate() {
            for k in 0..h {
                let sig = n.input(&format!("c{w}b{k}"));
                cols.push(w, sig);
                input_weights.push(w);
            }
        }
        let out = reduce_columns(&mut n, cols);
        n.output_bus("p", &out);
        assert_eq!(n.validate().unwrap(), 0, "reducer should not emit dead logic");

        let mut rng = Xoshiro256::seeded(seed);
        for _ in 0..trials {
            let bits: Vec<bool> = input_weights.iter().map(|_| rng.chance(0.5)).collect();
            let expect: u64 = bits
                .iter()
                .zip(&input_weights)
                .map(|(&b, &w)| (b as u64) << w)
                .sum();
            let o = eval_outputs_bool(&n, &bits);
            let got: u64 = o.iter().enumerate().map(|(k, &b)| (b as u64) << k).sum();
            assert_eq!(got, expect, "heights {heights:?}");
        }
    }

    #[test]
    fn single_tall_column() {
        check_reduction(&[7], 200, 1);
    }

    #[test]
    fn multiplier_shaped_triangle() {
        // 8x8 unsigned PPM shape: heights 1..8..1
        let mut h: Vec<usize> = (1..=8).collect();
        h.extend((1..=7).rev());
        check_reduction(&h, 300, 2);
    }

    #[test]
    fn ragged_columns() {
        check_reduction(&[3, 0, 5, 1, 4, 0, 2], 200, 3);
    }

    #[test]
    fn already_reduced_passthrough() {
        check_reduction(&[2, 2, 2, 2], 100, 4);
    }

    #[test]
    fn empty_matrix_is_zero() {
        let mut n = Netlist::new("empty");
        let cols = Columns::new(4);
        let out = reduce_columns(&mut n, cols);
        n.output_bus("p", &out);
        let o = eval_outputs_bool(&n, &[]);
        assert!(o.iter().all(|&b| !b));
    }
}

//! Generic arithmetic building blocks: half/full adders, the energy-
//! efficient 3:2 compressor of the paper's ref. [8], an exact 4:2
//! compressor, ripple-carry / carry-save adders, and a Dadda-style
//! column-reduction engine used by every multiplier in
//! [`crate::multipliers`].

pub mod adders;
pub mod reduce;

pub use adders::{full_adder, half_adder, compressor32_ref8, compressor42_exact, ripple_adder};
pub use reduce::{reduce_columns, Columns};

//! Structured job tracing: a bounded, lock-light ring buffer of span
//! events, exported as Chrome trace-event JSON.
//!
//! Every job flowing through the coordinator leaves a breadcrumb trail —
//! `submit → queued → dispatched → batch_start/batch_end →
//! completed | failed{panic,deadline,error} | rerouted` — keyed by job id
//! and labelled with engine / operator / job-kind. The [`Tracer`] is
//! always wired in but starts disabled: the contract (locked by a bench
//! row, `job_roundtrip_256_trace_{off,on}`) is that a *disabled* tracer
//! costs exactly one relaxed atomic load per event site — the first
//! statement of [`Tracer::record`] — so tracing can ship in the hot path
//! unconditionally.
//!
//! When enabled (`sfcmul serve --trace PATH`, `SFCMUL_TRACE=PATH`, or
//! [`Tracer::enable`] in-process), events land in a fixed-capacity ring
//! (oldest overwritten first; [`Tracer::dropped`] reports the loss) under
//! a single short mutex. [`Tracer::chrome_trace_json`] renders the ring
//! as the Chrome trace-event format — async `b`/`e` spans per job id plus
//! instant events — loadable in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`. [`validate_chrome_trace`] is the schema check the
//! tests, the `sfcmul trace` CLI, and the ci.sh smoke leg share.

use crate::util::json::Json;
use crate::util::sync::lock;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Default ring capacity (events). At ~40 bytes/event this bounds the
/// tracer at a few MiB; a 256×256 demo job emits ~20 events.
pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

/// Span-event kinds, in lifecycle order. Exactly one *terminal* kind
/// ([`TraceKind::is_terminal`]) is recorded per accepted job — the
/// invariant the chaos-soak trace test locks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Job accepted and routed (carries op + unit count).
    Submit,
    /// All the job's work units are on the bounded queue.
    Queued,
    /// A worker picked up (some of) the job's units.
    Dispatched,
    /// An engine batch containing this job's units starts computing.
    BatchStart,
    /// That batch finished.
    BatchEnd,
    /// Terminal: all units reassembled, result delivered.
    Completed,
    /// Terminal: a unit panicked inside the engine (caught).
    FailedPanic,
    /// Terminal: the job's deadline expired before completion.
    FailedDeadline,
    /// Terminal: engine contract violation or backend error.
    FailedError,
    /// The job was rerouted to its fallback engine at submit time
    /// (annotation, not terminal — the span still completes or fails).
    Rerouted,
}

impl TraceKind {
    pub fn label(self) -> &'static str {
        match self {
            TraceKind::Submit => "submit",
            TraceKind::Queued => "queued",
            TraceKind::Dispatched => "dispatched",
            TraceKind::BatchStart => "batch_start",
            TraceKind::BatchEnd => "batch_end",
            TraceKind::Completed => "completed",
            TraceKind::FailedPanic => "failed_panic",
            TraceKind::FailedDeadline => "failed_deadline",
            TraceKind::FailedError => "failed_error",
            TraceKind::Rerouted => "rerouted",
        }
    }

    /// True for the kinds that end a job's span.
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            TraceKind::Completed
                | TraceKind::FailedPanic
                | TraceKind::FailedDeadline
                | TraceKind::FailedError
        )
    }
}

/// Work-unit kind a trace event belongs to.
pub const JOB_KIND_CONV: u8 = 0;
pub const JOB_KIND_GEMM: u8 = 1;

/// One recorded span event. Fixed-size on purpose: the ring is
/// preallocated and recording never allocates.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// Microseconds since the tracer's epoch (coordinator start).
    pub ts_us: u64,
    pub job_id: u64,
    pub kind: TraceKind,
    /// Engine index the event happened on (routing index, not name).
    pub engine: u8,
    /// Operator id (meaningful on `Submit` for conv jobs; 0 otherwise).
    pub op: u8,
    /// [`JOB_KIND_CONV`] or [`JOB_KIND_GEMM`].
    pub job_kind: u8,
    /// Work units involved (tiles / GEMM blocks; batch size for
    /// `BatchStart`/`BatchEnd`).
    pub units: u32,
}

/// Fixed-capacity overwrite-oldest ring.
struct Ring {
    buf: Vec<TraceEvent>,
    cap: usize,
    /// Next write position once `buf` is full.
    next: usize,
    /// Total events ever recorded (>= buf.len()).
    total: u64,
}

impl Ring {
    fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
        }
        self.next = (self.next + 1) % self.cap;
        self.total += 1;
    }

    /// Events in recording order (oldest first).
    fn ordered(&self) -> Vec<TraceEvent> {
        if self.buf.len() < self.cap {
            self.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.buf.len());
            out.extend_from_slice(&self.buf[self.next..]);
            out.extend_from_slice(&self.buf[..self.next]);
            out
        }
    }
}

/// The bounded span-event recorder. One per coordinator, shared by
/// submit paths, workers, the watchdog, and the server's `TRACE` verb.
pub struct Tracer {
    enabled: AtomicBool,
    epoch: Instant,
    ring: Mutex<Ring>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_TRACE_CAPACITY)
    }
}

impl Tracer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            ring: Mutex::new(Ring { buf: Vec::new(), cap: cap.max(1), next: 0, total: 0 }),
        }
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// The cost of every event site when tracing is off is exactly this
    /// load (checked relaxed — no fence, no lock).
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record one span event. First statement is the disabled-path
    /// early-out — keep it first; the overhead bench row prices it.
    pub fn record(&self, kind: TraceKind, job_id: u64, engine: u8, op: u8, job_kind: u8, units: u32) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        let ts_us = self.epoch.elapsed().as_micros() as u64;
        lock(&self.ring).push(TraceEvent { ts_us, job_id, kind, engine, op, job_kind, units });
    }

    /// Snapshot of the retained events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        lock(&self.ring).ordered()
    }

    /// Total events recorded since start (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        lock(&self.ring).total
    }

    /// Events lost to ring overwrite.
    pub fn dropped(&self) -> u64 {
        let g = lock(&self.ring);
        g.total - g.buf.len() as u64
    }

    /// Render the ring as Chrome trace-event JSON (the
    /// `{"traceEvents": [...]}` object form). Jobs become async spans
    /// (`ph:"b"` at submit, `ph:"e"` at the terminal event, matched on
    /// `cat:"job"` + id); intermediate events are instants (`ph:"i"`).
    /// `engine_names` maps engine indices to thread labels.
    pub fn chrome_trace_json(&self, engine_names: &[String]) -> String {
        let events = self.events();
        let mut out: Vec<Json> = Vec::with_capacity(events.len() + engine_names.len() + 1);
        // Metadata: name the process and one thread lane per engine.
        out.push(
            Json::obj()
                .set("name", "process_name")
                .set("ph", "M")
                .set("pid", 1i64)
                .set("tid", 0i64)
                .set("args", Json::obj().set("name", "sfcmul")),
        );
        for (i, name) in engine_names.iter().enumerate() {
            out.push(
                Json::obj()
                    .set("name", "thread_name")
                    .set("ph", "M")
                    .set("pid", 1i64)
                    .set("tid", i as i64 + 1)
                    .set("args", Json::obj().set("name", format!("engine:{name}"))),
            );
        }
        for ev in &events {
            let engine_name = engine_names
                .get(ev.engine as usize)
                .map(String::as_str)
                .unwrap_or("?");
            let args = Json::obj()
                .set("job", Json::Int(ev.job_id as i64))
                .set("engine", engine_name)
                .set("op", Json::Int(ev.op as i64))
                .set("kind", if ev.job_kind == JOB_KIND_GEMM { "gemm" } else { "conv" })
                .set("units", Json::Int(ev.units as i64));
            let base = Json::obj()
                .set("ts", Json::Int(ev.ts_us as i64))
                .set("pid", 1i64)
                .set("tid", ev.engine as i64 + 1);
            let j = if ev.kind == TraceKind::Submit {
                base.set("name", "job")
                    .set("cat", "job")
                    .set("ph", "b")
                    .set("id", Json::Int(ev.job_id as i64))
                    .set("args", args)
            } else if ev.kind.is_terminal() {
                base.set("name", "job")
                    .set("cat", "job")
                    .set("ph", "e")
                    .set("id", Json::Int(ev.job_id as i64))
                    .set("args", args.set("outcome", ev.kind.label()))
            } else {
                base.set("name", ev.kind.label())
                    .set("cat", "job")
                    .set("ph", "i")
                    .set("s", "t")
                    .set("args", args)
            };
            out.push(j);
        }
        Json::obj()
            .set("traceEvents", Json::Arr(out))
            .set("displayTimeUnit", "ms")
            .to_string()
    }
}

/// What [`validate_chrome_trace`] found in a trace document.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceSummary {
    /// Non-metadata events.
    pub events: usize,
    /// Async span begins (`ph:"b"`).
    pub begins: usize,
    /// Async span ends (`ph:"e"`).
    pub ends: usize,
    /// Instant events (`ph:"i"`).
    pub instants: usize,
    /// Metadata records (`ph:"M"`).
    pub metadata: usize,
}

/// Schema-check a Chrome trace-event JSON document: parses the text,
/// requires the `traceEvents` array, and checks every event for the
/// fields the viewers require (`name`/`ph` strings; numeric
/// `ts`/`pid`/`tid` on non-metadata events; `id` on async `b`/`e`).
/// Returns per-phase counts on success. Shared by the unit tests, the
/// `sfcmul trace` CLI, and the ci.sh trace smoke leg.
pub fn validate_chrome_trace(text: &str) -> Result<TraceSummary, String> {
    let doc = Json::parse(text)?;
    let Some(events) = doc.get("traceEvents").and_then(Json::as_arr) else {
        return Err("missing top-level \"traceEvents\" array".into());
    };
    let mut summary = TraceSummary { events: 0, begins: 0, ends: 0, instants: 0, metadata: 0 };
    for (i, ev) in events.iter().enumerate() {
        let name = ev.get("name").and_then(Json::as_str);
        let ph = ev.get("ph").and_then(Json::as_str);
        let (Some(_), Some(ph)) = (name, ph) else {
            return Err(format!("event {i}: missing string \"name\"/\"ph\""));
        };
        if ph == "M" {
            summary.metadata += 1;
            continue;
        }
        for field in ["ts", "pid", "tid"] {
            if ev.get(field).and_then(Json::as_f64).is_none() {
                return Err(format!("event {i}: missing numeric \"{field}\""));
            }
        }
        match ph {
            "b" => {
                summary.begins += 1;
                if ev.get("id").is_none() {
                    return Err(format!("event {i}: async begin without \"id\""));
                }
            }
            "e" => {
                summary.ends += 1;
                if ev.get("id").is_none() {
                    return Err(format!("event {i}: async end without \"id\""));
                }
            }
            "i" => summary.instants += 1,
            other => return Err(format!("event {i}: unsupported phase {other:?}")),
        }
        summary.events += 1;
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names() -> Vec<String> {
        vec!["exact".to_string(), "approx".to_string()]
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new();
        t.record(TraceKind::Submit, 1, 0, 0, JOB_KIND_CONV, 4);
        assert_eq!(t.recorded(), 0);
        assert!(t.events().is_empty());
    }

    #[test]
    fn enabled_tracer_keeps_order_and_counts() {
        let t = Tracer::new();
        t.enable();
        t.record(TraceKind::Submit, 7, 1, 2, JOB_KIND_CONV, 4);
        t.record(TraceKind::Queued, 7, 1, 2, JOB_KIND_CONV, 4);
        t.record(TraceKind::Completed, 7, 1, 0, JOB_KIND_CONV, 4);
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].kind, TraceKind::Submit);
        assert_eq!(evs[2].kind, TraceKind::Completed);
        assert!(evs.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_overwrites_oldest_past_capacity() {
        let t = Tracer::with_capacity(4);
        t.enable();
        for id in 0..10u64 {
            t.record(TraceKind::Queued, id, 0, 0, JOB_KIND_CONV, 1);
        }
        let evs = t.events();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs.iter().map(|e| e.job_id).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert_eq!(t.recorded(), 10);
        assert_eq!(t.dropped(), 6);
    }

    #[test]
    fn chrome_export_is_schema_valid() {
        let t = Tracer::new();
        t.enable();
        t.record(TraceKind::Submit, 3, 0, 1, JOB_KIND_CONV, 16);
        t.record(TraceKind::Queued, 3, 0, 1, JOB_KIND_CONV, 16);
        t.record(TraceKind::BatchStart, 3, 0, 0, JOB_KIND_CONV, 8);
        t.record(TraceKind::BatchEnd, 3, 0, 0, JOB_KIND_CONV, 8);
        t.record(TraceKind::Completed, 3, 0, 0, JOB_KIND_CONV, 16);
        t.record(TraceKind::Submit, 4, 1, 0, JOB_KIND_GEMM, 2);
        t.record(TraceKind::FailedPanic, 4, 1, 0, JOB_KIND_GEMM, 2);
        let json = t.chrome_trace_json(&names());
        let s = validate_chrome_trace(&json).expect("valid trace");
        assert_eq!(s.begins, 2, "one b per submit");
        assert_eq!(s.ends, 2, "one e per terminal");
        assert_eq!(s.instants, 3);
        assert_eq!(s.metadata, 1 + 2, "process + one lane per engine");
        assert!(json.contains("\"outcome\":\"failed_panic\""));
        assert!(json.contains("\"kind\":\"gemm\""));
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{}").is_err());
        assert!(validate_chrome_trace("{\"traceEvents\": 3}").is_err());
        // event missing ts
        let bad = "{\"traceEvents\":[{\"name\":\"x\",\"ph\":\"i\",\"pid\":1,\"tid\":1}]}";
        assert!(validate_chrome_trace(bad).unwrap_err().contains("ts"));
        // async begin without id
        let bad =
            "{\"traceEvents\":[{\"name\":\"job\",\"ph\":\"b\",\"ts\":1,\"pid\":1,\"tid\":1}]}";
        assert!(validate_chrome_trace(bad).unwrap_err().contains("id"));
    }

    #[test]
    fn terminal_kinds_are_exactly_the_failure_and_completion_set() {
        use TraceKind::*;
        for k in [Submit, Queued, Dispatched, BatchStart, BatchEnd, Rerouted] {
            assert!(!k.is_terminal(), "{k:?}");
        }
        for k in [Completed, FailedPanic, FailedDeadline, FailedError] {
            assert!(k.is_terminal(), "{k:?}");
        }
    }
}

//! Per-stage latency histograms: fixed log₂ buckets in microseconds.
//!
//! The metrics reservoir (p50/p99 for the CLI snapshot) answers "how
//! slow are jobs?" but not "*where* does the time go?". Each engine row
//! carries one [`Hist`] per [`Stage`] — queue wait (send → worker
//! pickup), compute (engine batch wall time), and end-to-end job latency
//! — so the Prometheus exposition can render proper cumulative
//! `_bucket`/`_sum`/`_count` series per (engine, stage) and an operator
//! can see queueing delay and engine time as separate distributions.
//!
//! Buckets are powers of two in µs: bucket `i` has upper bound `2^i` µs
//! for `i` in `0..FINITE_BUCKETS` (1 µs … ~67 s), plus one overflow
//! bucket that only surfaces in the `+Inf` cumulative count. Recording
//! is O(1) (a leading-zeros bit trick), storage is a fixed 28-slot
//! array — no allocation, safe to hold under the metrics mutex.

use std::time::Duration;

/// Finite bucket count; bucket `i` covers values ≤ `2^i` µs.
pub const FINITE_BUCKETS: usize = 27;
/// Total slots: finite buckets + one overflow slot.
pub const BUCKETS: usize = FINITE_BUCKETS + 1;

/// Upper bound of finite bucket `i` in microseconds; `None` for the
/// overflow slot (rendered as `+Inf`).
pub fn bucket_le_us(i: usize) -> Option<u64> {
    if i < FINITE_BUCKETS {
        Some(1u64 << i)
    } else {
        None
    }
}

/// Smallest bucket whose upper bound holds `us` (ceil log₂).
fn bucket_index(us: u64) -> usize {
    if us <= 1 {
        0
    } else {
        (64 - (us - 1).leading_zeros() as usize).min(BUCKETS - 1)
    }
}

/// The latency stages instrumented per engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Work-unit time on the bounded queue: send → worker pickup.
    QueueWait = 0,
    /// Engine batch wall time (the `process_batch` call).
    Compute = 1,
    /// Whole-job latency: accept → result delivered (completed jobs).
    E2e = 2,
}

impl Stage {
    pub const ALL: [Stage; 3] = [Stage::QueueWait, Stage::Compute, Stage::E2e];

    /// Stable label used as the Prometheus `stage` label value.
    pub fn label(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::Compute => "compute",
            Stage::E2e => "e2e",
        }
    }
}

/// One log₂ histogram. Counts are per-bucket (not cumulative); the
/// exposition layer accumulates for Prometheus' `le` semantics.
#[derive(Debug, Clone)]
pub struct Hist {
    counts: [u64; BUCKETS],
    sum_us: u64,
    count: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Self { counts: [0; BUCKETS], sum_us: 0, count: 0 }
    }
}

impl Hist {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        self.counts[bucket_index(us)] += 1;
        self.sum_us += us;
        self.count += 1;
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            counts: self.counts,
            sum_seconds: self.sum_us as f64 / 1e6,
            count: self.count,
        }
    }
}

/// Point-in-time copy of a [`Hist`] for snapshots and rendering.
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    /// Raw per-bucket counts (index `BUCKETS-1` is the overflow slot).
    pub counts: [u64; BUCKETS],
    /// Total observed time in seconds (Prometheus `_sum`).
    pub sum_seconds: f64,
    /// Total observations (Prometheus `_count`).
    pub count: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self { counts: [0; BUCKETS], sum_seconds: 0.0, count: 0 }
    }
}

impl HistSnapshot {
    /// Cumulative count at finite bucket `i` (Prometheus `le` value).
    pub fn cumulative(&self, i: usize) -> u64 {
        self.counts[..=i.min(BUCKETS - 1)].iter().sum()
    }
}

/// One histogram per [`Stage`] — the per-engine bundle.
#[derive(Debug, Clone, Default)]
pub struct StageHists {
    hists: [Hist; 3],
}

impl StageHists {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, stage: Stage, d: Duration) {
        self.hists[stage as usize].record(d);
    }

    pub fn snapshot(&self) -> [HistSnapshot; 3] {
        [self.hists[0].snapshot(), self.hists[1].snapshot(), self.hists[2].snapshot()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_are_powers_of_two() {
        assert_eq!(bucket_le_us(0), Some(1));
        assert_eq!(bucket_le_us(1), Some(2));
        assert_eq!(bucket_le_us(10), Some(1024));
        assert_eq!(bucket_le_us(FINITE_BUCKETS - 1), Some(1 << (FINITE_BUCKETS - 1)));
        assert_eq!(bucket_le_us(FINITE_BUCKETS), None, "overflow slot is +Inf");
    }

    #[test]
    fn values_land_in_smallest_covering_bucket() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(1024), 10);
        assert_eq!(bucket_index(1025), 11);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        // Every value v in a finite bucket satisfies v <= its le bound.
        for v in [1u64, 2, 3, 7, 100, 4096, 1 << 26] {
            let i = bucket_index(v);
            if let Some(le) = bucket_le_us(i) {
                assert!(v <= le, "{v} > le {le}");
                if i > 0 {
                    assert!(v > bucket_le_us(i - 1).unwrap(), "{v} not minimal at {i}");
                }
            }
        }
    }

    #[test]
    fn record_accumulates_sum_count_and_cumulative() {
        let mut h = Hist::new();
        h.record(Duration::from_micros(1));
        h.record(Duration::from_micros(3));
        h.record(Duration::from_micros(1000));
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert!((s.sum_seconds - 1004e-6).abs() < 1e-12);
        assert_eq!(s.cumulative(0), 1);
        assert_eq!(s.cumulative(2), 2);
        assert_eq!(s.cumulative(BUCKETS - 1), 3, "+Inf covers everything");
    }

    #[test]
    fn overflow_values_count_only_in_inf() {
        let mut h = Hist::new();
        h.record(Duration::from_secs(1 << 20)); // way past 2^26 µs
        let s = h.snapshot();
        assert_eq!(s.cumulative(FINITE_BUCKETS - 1), 0);
        assert_eq!(s.cumulative(BUCKETS - 1), 1);
    }

    #[test]
    fn stage_bundle_routes_by_stage() {
        let mut sh = StageHists::new();
        sh.record(Stage::QueueWait, Duration::from_micros(5));
        sh.record(Stage::Compute, Duration::from_micros(50));
        sh.record(Stage::Compute, Duration::from_micros(70));
        sh.record(Stage::E2e, Duration::from_micros(500));
        let snaps = sh.snapshot();
        assert_eq!(snaps[Stage::QueueWait as usize].count, 1);
        assert_eq!(snaps[Stage::Compute as usize].count, 2);
        assert_eq!(snaps[Stage::E2e as usize].count, 1);
        assert_eq!(Stage::ALL.map(|s| s.label()), ["queue_wait", "compute", "e2e"]);
    }
}

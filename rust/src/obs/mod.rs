//! End-to-end observability: tracing, stage histograms, live quality.
//!
//! The serving stack answers *what* it computed; this layer answers
//! *how* — three pillars, all std-only and zero-dependency like the rest
//! of the crate, threaded through coordinator, server, and CLI:
//!
//! * [`trace`] — structured span tracing. A bounded ring buffer of
//!   timestamped job events (`submit → queued → dispatched →
//!   batch_start/end → completed | failed{panic,deadline,error} |
//!   rerouted`), one relaxed atomic load per event site when disabled,
//!   exported as Chrome trace-event JSON (Perfetto /
//!   `chrome://tracing`), dumped over the wire protocol (`TRACE`), and
//!   schema-checked by the `sfcmul trace` CLI the ci.sh smoke leg runs.
//! * [`hist`] — per-(engine, stage) log₂ latency histograms (queue wait
//!   vs engine compute vs end-to-end) feeding proper Prometheus
//!   `_bucket`/`_sum`/`_count` exposition in `GET /metrics`; the
//!   bounded reservoir keeps serving p50/p99 for the CLI snapshot.
//! * [`quality`] — live approximation-quality telemetry. A
//!   deterministic 1-in-N sampler shadow-recomputes served conv tiles /
//!   GEMM blocks against the exact product and publishes running
//!   per-engine MED / NMED / max-ED and a mismatch-rate gauge — the
//!   paper's Table-4 error metrics, measured on the traffic actually
//!   being served rather than an offline operand sweep.
//!
//! The pieces are deliberately decoupled from the coordinator's types
//! where possible (histograms and the tracer know nothing about jobs
//! beyond ids and labels) so they are reusable by future subsystems.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod hist;
pub mod quality;
pub mod trace;

pub use hist::{bucket_le_us, Hist, HistSnapshot, Stage, StageHists, BUCKETS, FINITE_BUCKETS};
pub use quality::{QualityStats, SampleGate, MAX_EXACT_8BIT};
pub use trace::{
    validate_chrome_trace, TraceEvent, TraceKind, TraceSummary, Tracer, DEFAULT_TRACE_CAPACITY,
    JOB_KIND_CONV, JOB_KIND_GEMM,
};

//! Live approximation-quality telemetry: deterministic 1-in-N shadow
//! sampling of served work units.
//!
//! The offline harness ([`crate::error::metrics`]) sweeps operand spaces
//! and reports the paper's Table-4 metrics; this module measures the
//! same quantities on *live traffic*. A deterministic stratified sampler
//! ([`SampleGate`], seeded PRNG: exactly one unit per window of N,
//! `--quality-sample-n`) admits conv tiles / GEMM blocks for shadow
//! recomputation: every MAC operand pair of the sampled unit is re-run
//! through the engine's product source ([`NnBackend`]) *and* the exact
//! product `a·b`, accumulating error distance into integer counters.
//!
//! Integer accumulators are the point: |ED| ≤ 2¹⁶ per pair and pair
//! counts stay far below 2⁵³, so sums are exact in `u64`/`f64` and the
//! resulting MED/NMED are *order-independent* across worker threads — at
//! `sample_n = 1` the live NMED equals the offline
//! [`crate::error::metrics::error_metrics_for_pairs`] value bit-for-bit
//! on the same operand set, which the test suite asserts exactly.
//!
//! Engines without a product source (`nn_backend() == None`: rowbuf,
//! PJRT) and the gate-streaming [`NnBackend::BitsimLive`] backend (whose
//! per-pair shadow cost would dwarf the serving cost) are not sampled;
//! their quality rows stay at zero pairs.

use crate::coordinator::engine::NnBackend;
use crate::coordinator::tiler::{Tile, TILE_IN};
use crate::image::conv::{KERNEL_PRESCALE_SHIFT, PIXEL_SHIFT};
use crate::image::ops::Operator;
use crate::nn::gemm::{lut_product, MatI8};
use crate::util::prng::Xoshiro256;

/// `max |exact product|` for the 8-bit signed datapath (`2^(2N-2)`, the
/// paper Eq. 8 normaliser). Every samplable backend is 8-bit by
/// construction ([`crate::coordinator::engine::TileEngine::nn_backend`]).
pub const MAX_EXACT_8BIT: i64 = 1 << 14;

/// Deterministic stratified 1-in-N admission: each consecutive window of
/// `n` units admits exactly one, at a PRNG-chosen offset — so a run with
/// fixed seed and unit count samples a reproducible *number* of units
/// regardless of thread interleaving, and `n == 1` admits everything
/// (the configuration the exactness test runs under).
#[derive(Debug)]
pub struct SampleGate {
    n: u64,
    /// Position within the current window.
    pos: u64,
    /// Admitted offset for the current window.
    pick: u64,
    rng: Xoshiro256,
}

impl SampleGate {
    /// `n == 0` disables sampling entirely.
    pub fn new(n: u64, seed: u64) -> Self {
        Self { n, pos: 0, pick: 0, rng: Xoshiro256::seeded(seed) }
    }

    pub fn sample_n(&self) -> u64 {
        self.n
    }

    /// Advance one unit; true when this unit is sampled.
    pub fn admit(&mut self) -> bool {
        match self.n {
            0 => false,
            1 => true,
            n => {
                if self.pos == 0 {
                    self.pick = self.rng.below(n);
                }
                let hit = self.pos == self.pick;
                self.pos = (self.pos + 1) % n;
                hit
            }
        }
    }
}

/// Running error-distance accumulators for one engine. All integer, so
/// merge order never changes the published MED/NMED (see module docs).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QualityStats {
    /// Work units (tiles / GEMM blocks) shadow-recomputed.
    pub units: u64,
    /// Operand pairs compared.
    pub pairs: u64,
    /// Pairs where approx != exact.
    pub mismatches: u64,
    /// Σ |approx − exact|.
    pub sum_ed: u64,
    /// max |approx − exact|.
    pub max_ed: i64,
}

impl QualityStats {
    pub fn record_pair(&mut self, exact: i64, approx: i64) {
        let ed = (approx - exact).abs();
        self.pairs += 1;
        if ed != 0 {
            self.mismatches += 1;
        }
        self.sum_ed += ed as u64;
        self.max_ed = self.max_ed.max(ed);
    }

    /// Fold a per-unit delta into the running totals.
    pub fn merge(&mut self, d: &QualityStats) {
        self.units += d.units;
        self.pairs += d.pairs;
        self.mismatches += d.mismatches;
        self.sum_ed += d.sum_ed;
        self.max_ed = self.max_ed.max(d.max_ed);
    }

    /// Mean error distance; 0 when nothing sampled.
    pub fn med(&self) -> f64 {
        if self.pairs == 0 {
            0.0
        } else {
            self.sum_ed as f64 / self.pairs as f64
        }
    }

    /// MED normalised by the 8-bit `max |exact|` (paper Eq. 8).
    pub fn nmed(&self) -> f64 {
        self.med() / MAX_EXACT_8BIT as f64
    }

    /// Fraction of sampled pairs with any error (the live ER gauge).
    pub fn mismatch_rate(&self) -> f64 {
        if self.pairs == 0 {
            0.0
        } else {
            self.mismatches as f64 / self.pairs as f64
        }
    }
}

/// The engine-side approximate product for one i8 pair, or `None` when
/// the backend cannot be shadow-evaluated per pair (see module docs).
pub fn backend_product(backend: &NnBackend, a: i8, b: i8) -> Option<i64> {
    match backend {
        NnBackend::Table(t) => Some(lut_product(t, a, b) as i64),
        NnBackend::PerElement(m) => Some(m.multiply(a as i64, b as i64)),
        NnBackend::BitsimLive(_) => None,
    }
}

/// Enumerate the MAC operand pairs of a conv tile, exactly as the
/// engine's datapath sees them: pixels pre-shifted by `PIXEL_SHIFT`
/// (0..=127, so the `u8 → i8` reinterpretation is value-preserving),
/// coefficients pre-scaled by `KERNEL_PRESCALE_SHIFT`, every pass of the
/// tile's operator (mirrors
/// `coordinator::engine::conv_tile_model`'s loop structure).
pub fn conv_tile_pairs(tile: &Tile, mut sink: impl FnMut(i8, i8)) {
    let Some(op) = Operator::from_id(tile.op) else {
        return;
    };
    for pass in op.passes() {
        for cy in 0..tile.core_h {
            for cx in 0..tile.core_w {
                for ky in 0..3 {
                    for kx in 0..3 {
                        let px = tile.data[(cy + ky) * TILE_IN + cx + kx] >> PIXEL_SHIFT;
                        let k = (pass.kernel[ky][kx] << KERNEL_PRESCALE_SHIFT) as i8;
                        sink(px as i8, k);
                    }
                }
            }
        }
    }
}

/// Enumerate the MAC operand pairs of one GEMM block (`rows × depth ×
/// cols` triples — the multiset `gemm_block_lut` accumulates).
pub fn gemm_block_pairs(
    a: &MatI8,
    b: &MatI8,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    mut sink: impl FnMut(i8, i8),
) {
    for i in 0..rows {
        for kk in 0..a.cols {
            for j in 0..cols {
                sink(a.get(row0 + i, kk), b.get(kk, col0 + j));
            }
        }
    }
}

/// Shadow-recompute one sampled conv tile; `None` when the backend is
/// absent or not per-pair evaluable.
pub fn sample_conv_tile(backend: &NnBackend, tile: &Tile) -> Option<QualityStats> {
    if matches!(backend, NnBackend::BitsimLive(_)) {
        return None;
    }
    let mut d = QualityStats { units: 1, ..QualityStats::default() };
    conv_tile_pairs(tile, |a, b| {
        if let Some(approx) = backend_product(backend, a, b) {
            d.record_pair(a as i64 * b as i64, approx);
        }
    });
    Some(d)
}

/// Shadow-recompute one sampled GEMM block.
pub fn sample_gemm_block(
    backend: &NnBackend,
    a: &MatI8,
    b: &MatI8,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
) -> Option<QualityStats> {
    if matches!(backend, NnBackend::BitsimLive(_)) {
        return None;
    }
    let mut d = QualityStats { units: 1, ..QualityStats::default() };
    gemm_block_pairs(a, b, row0, rows, col0, cols, |x, y| {
        if let Some(approx) = backend_product(backend, x, y) {
            d.record_pair(x as i64 * y as i64, approx);
        }
    });
    Some(d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tiler::tile_image;
    use crate::image::synth::synthetic_scene;
    use crate::multipliers::{lut::product_table, registry};
    use std::sync::Arc;

    #[test]
    fn gate_disabled_and_always_on_modes() {
        let mut off = SampleGate::new(0, 1);
        assert!((0..100).all(|_| !off.admit()));
        let mut on = SampleGate::new(1, 1);
        assert!((0..100).all(|_| on.admit()));
    }

    #[test]
    fn gate_admits_exactly_one_per_window() {
        for n in [2u64, 3, 7, 16] {
            let mut g = SampleGate::new(n, 0xBEEF ^ n);
            for window in 0..50 {
                let admitted = (0..n).filter(|_| g.admit()).count();
                assert_eq!(admitted, 1, "n={n} window={window}");
            }
        }
    }

    #[test]
    fn gate_is_deterministic_for_fixed_seed() {
        let run = || {
            let mut g = SampleGate::new(5, 42);
            (0..200).map(|_| g.admit()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn stats_accumulate_and_merge_order_independent() {
        let mut a = QualityStats::default();
        a.record_pair(10, 10);
        a.record_pair(10, 13);
        a.record_pair(-5, -9);
        assert_eq!(a.pairs, 3);
        assert_eq!(a.mismatches, 2);
        assert_eq!(a.sum_ed, 7);
        assert_eq!(a.max_ed, 4);
        let mut b = QualityStats::default();
        b.record_pair(100, 90);
        let mut ab = a;
        ab.merge(&b);
        let mut ba = b;
        ba.merge(&a);
        assert_eq!(ab, ba, "integer merge commutes");
        assert_eq!(ab.max_ed, 10);
        assert!((ab.med() - 17.0 / 4.0).abs() < 1e-15);
    }

    #[test]
    fn exact_backend_samples_with_zero_error() {
        let model = registry().build_str("exact@8").unwrap();
        let backend = NnBackend::Table(Arc::new(product_table(model.as_ref())));
        let img = synthetic_scene(66, 66, 3);
        let tiles = tile_image(1, &img);
        let d = sample_conv_tile(&backend, &tiles[0]).expect("table backend samples");
        assert_eq!(d.units, 1);
        assert_eq!(d.pairs, (tiles[0].core_w * tiles[0].core_h * 9) as u64);
        assert_eq!(d.mismatches, 0);
        assert_eq!(d.sum_ed, 0);
        assert_eq!(d.nmed(), 0.0);
    }

    #[test]
    fn table_and_per_element_backends_agree() {
        let model = registry().build_str("proposed@8").unwrap();
        let table = NnBackend::Table(Arc::new(product_table(model.as_ref())));
        let per = NnBackend::PerElement(Arc::from(model));
        let mut rng = Xoshiro256::seeded(0x9A11);
        let a = MatI8::random(7, 5, &mut rng);
        let b = MatI8::random(5, 9, &mut rng);
        let via_table = sample_gemm_block(&table, &a, &b, 0, 7, 0, 9).unwrap();
        let via_model = sample_gemm_block(&per, &a, &b, 0, 7, 0, 9).unwrap();
        assert_eq!(via_table, via_model);
        assert_eq!(via_table.pairs, 7 * 5 * 9);
        assert!(via_table.mismatches > 0, "proposed@8 is approximate");
    }

    #[test]
    fn conv_pairs_cover_all_passes() {
        let img = synthetic_scene(66, 66, 5);
        let mut tiles = tile_image(0, &img);
        tiles[0].op = Operator::Sobel.id(); // two-pass operator
        let mut n = 0u64;
        conv_tile_pairs(&tiles[0], |_, _| n += 1);
        assert_eq!(n, (tiles[0].core_w * tiles[0].core_h * 9 * 2) as u64);
    }
}

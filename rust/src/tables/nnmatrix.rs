//! Quantized-inference accuracy matrix (`sfcmul tables --id nn`):
//! every registered design against the exact multiplier, measured on
//! (a) raw tiled-GEMM outputs and (b) the activations of the fixed
//! conv→relu→conv demo network ([`crate::nn::Network::demo`]).
//!
//! Columns per design:
//!
//! * **GEMM MED** — mean |Δ| of `C = A × B` accumulators vs the exact
//!   product, on a fixed seeded i8 workload (the raw approximation
//!   error before any requantization absorbs it);
//! * **GEMM NMED** — MED normalised by the accumulator bound
//!   `K · 2^14` (max |exact product| per MAC × depth), mirroring the
//!   Eq.-(8) normalisation of the multiplier tables;
//! * **per-layer mismatch** — fraction of i8 activations differing
//!   from the exact network after each layer (requantization and ReLU
//!   mask small accumulator errors; what survives them is what a
//!   deployed network would actually see);
//! * **final mean |Δ|** — mean absolute final-activation difference in
//!   i8 codes.

use crate::image::synthetic_scene;
use crate::multipliers::{lut::product_table, registry, DesignSpec};
use crate::nn::{fidelity, gemm_tiled, quantize_image, MatI8, Network};
use crate::util::prng::Xoshiro256;

/// One design's row of the matrix.
pub struct NnRow {
    pub spec: DesignSpec,
    pub gemm_med: f64,
    pub gemm_nmed: f64,
    /// Mismatch fraction per network layer (demo net: 2 layers).
    pub layer_mismatch: Vec<f64>,
    pub final_mean_abs: f64,
}

/// Compute the matrix rows (Table-5 design order).
pub fn rows(seed: u64) -> Vec<NnRow> {
    let exact = registry().build_str("exact@8").expect("exact design");
    let exact_lut = product_table(exact.as_ref());
    // Fixed GEMM workload: seeded i8 matrices, depth 64.
    let mut rng = Xoshiro256::seeded(seed ^ 0xD00D_F00D);
    let a = MatI8::random(48, 64, &mut rng);
    let b = MatI8::random(64, 40, &mut rng);
    let c_exact = gemm_tiled(&a, &b, &exact_lut);
    let nmed_bound = (a.cols as f64) * 16384.0;
    // Fixed inference workload: the demo network on a synthetic scene.
    let net = Network::demo();
    let x = quantize_image(&synthetic_scene(64, 64, seed));
    let exact_layers = net.run_tiled_layers(&x, &exact_lut);
    registry()
        .specs(8)
        .into_iter()
        .map(|spec| {
            let model = registry().build(&spec).expect("registered design builds");
            let lut = product_table(model.as_ref());
            let c = gemm_tiled(&a, &b, &lut);
            let med = c
                .data
                .iter()
                .zip(&c_exact.data)
                .map(|(&x, &y)| (x as i64 - y as i64).abs() as f64)
                .sum::<f64>()
                / c.data.len() as f64;
            let layers = net.run_tiled_layers(&x, &lut);
            let per_layer: Vec<_> = layers
                .iter()
                .zip(&exact_layers)
                .map(|(l, e)| fidelity(l, e))
                .collect();
            let layer_mismatch: Vec<f64> =
                per_layer.iter().map(|f| f.mismatch_rate()).collect();
            let final_mean_abs =
                per_layer.last().expect("network has layers").mean_abs;
            NnRow {
                spec,
                gemm_med: med,
                gemm_nmed: med / nmed_bound,
                layer_mismatch,
                final_mean_abs,
            }
        })
        .collect()
}

pub fn render(seed: u64) -> String {
    let mut s = String::new();
    s.push_str(
        "== Quantized-inference accuracy matrix: design vs exact on the i8 GEMM/conv \
         datapath ==\n",
    );
    s.push_str(&format!(
        "  {:<17} {:>10} {:>10} {:>10} {:>10} {:>11}\n",
        "design", "gemm MED", "gemm NMED", "conv1 mis", "final mis", "final |d|"
    ));
    for r in rows(seed) {
        s.push_str(&format!(
            "  {:<17} {:>10.2} {:>9.5}% {:>9.2}% {:>9.2}% {:>11.3}\n",
            r.spec.display_name(),
            r.gemm_med,
            r.gemm_nmed * 100.0,
            r.layer_mismatch.first().copied().unwrap_or(0.0) * 100.0,
            r.layer_mismatch.last().copied().unwrap_or(0.0) * 100.0,
            r.final_mean_abs,
        ));
    }
    s.push_str(
        "  (GEMM: 48x64 x 64x40 seeded i8 workload, MED in raw i32 accumulator codes, \
         NMED vs the K*2^14 bound;\n   network: conv(1->4)+relu -> conv(4->2) on a 64x64 \
         synthetic scene — regenerate with `sfcmul tables --id nn`)\n",
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shape and sanity: one row per registered design, two layer
    /// columns, the exact row identically zero everywhere, approximate
    /// rows with genuine (finite, nonzero) GEMM error.
    #[test]
    fn matrix_covers_every_design_with_exact_zero_row() {
        let rows = rows(11);
        assert_eq!(rows.len(), registry().specs(8).len());
        for r in &rows {
            assert_eq!(r.layer_mismatch.len(), 2, "{}", r.spec);
            if r.spec.compressors.key() == "exact" {
                assert_eq!(r.gemm_med, 0.0, "exact GEMM is lossless");
                assert!(r.layer_mismatch.iter().all(|&m| m == 0.0));
                assert_eq!(r.final_mean_abs, 0.0);
            } else {
                assert!(r.gemm_med > 0.0, "{}: approximate design must err", r.spec);
                assert!(r.gemm_nmed < 0.2, "{}: NMED {} out of range", r.spec, r.gemm_nmed);
                assert!(
                    r.layer_mismatch.iter().all(|&m| (0.0..=1.0).contains(&m)),
                    "{}: mismatch out of [0,1]",
                    r.spec
                );
            }
        }
    }
}

//! Gate statistics table (`sfcmul tables --id gates`): per-design netlist
//! cost pre vs post the optimization pass pipeline at N = 8.
//!
//! One TSV row per registered design family: raw generator-output gate
//! count / logic depth / unit-gate area / switched capacitance, the same
//! figures after `opt::optimize` at [`OptLevel::Full`], and the resulting
//! gate reduction. The output is deterministic (seeded activity vectors,
//! fixed formatting), so CI pins it as a golden baseline
//! (`rust/tests/golden/gates.tsv`) and fails any change that regresses an
//! optimized gate count.

use crate::multipliers::registry;
use crate::netlist::prelude::{optimize_netlist, power, timing, Netlist, OptLevel};

/// Power-estimate vector budget; enough for toggle rates to settle while
/// keeping `tables --id gates` instant.
const POWER_VECTORS: usize = 4096;

struct Row {
    design: String,
    raw: Stats,
    opt: Stats,
}

struct Stats {
    gates: usize,
    depth: usize,
    area: f64,
    swcap: f64,
}

fn stats(nl: &Netlist, seed: u64) -> Stats {
    Stats {
        gates: nl.logic_gate_count(),
        depth: timing::analyze(nl).depth,
        area: nl.area(),
        swcap: power::estimate(nl, POWER_VECTORS, seed).switched_cap,
    }
}

fn rows(bits: usize, seed: u64) -> crate::Result<Vec<Row>> {
    registry()
        .specs(bits)
        .into_iter()
        .map(|mut spec| {
            spec.opt = OptLevel::None;
            let raw_nl = registry().build(&spec)?.build_netlist();
            let (opt_nl, _report) = optimize_netlist(&raw_nl, OptLevel::Full);
            Ok(Row {
                design: spec.compressors.key().to_string(),
                raw: stats(&raw_nl, seed),
                opt: stats(&opt_nl, seed),
            })
        })
        .collect()
}

/// Render the gate-statistics TSV for every registered design at N = 8.
pub fn render(seed: u64) -> crate::Result<String> {
    let mut s = String::new();
    s.push_str("# Gate statistics per design at N=8: raw generator netlist vs\n");
    s.push_str("# the full optimization pipeline (const-fold + CSE + DCE).\n");
    s.push_str(
        "design\tbits\tgates_raw\tgates_opt\tdepth_raw\tdepth_opt\t\
         area_raw\tarea_opt\tswcap_raw\tswcap_opt\treduction_pct\n",
    );
    for r in rows(8, seed)? {
        let reduction =
            100.0 * (r.raw.gates.saturating_sub(r.opt.gates)) as f64 / r.raw.gates.max(1) as f64;
        s.push_str(&format!(
            "{}\t8\t{}\t{}\t{}\t{}\t{:.1}\t{:.1}\t{:.1}\t{:.1}\t{:.1}\n",
            r.design,
            r.raw.gates,
            r.opt.gates,
            r.raw.depth,
            r.opt.depth,
            r.raw.area,
            r.opt.area,
            r.raw.swcap,
            r.opt.swcap,
            reduction
        ));
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_deterministic_and_tsv_shaped() {
        let a = render(42).unwrap();
        let b = render(42).unwrap();
        assert_eq!(a, b);
        let data: Vec<&str> =
            a.lines().filter(|l| !l.starts_with('#') && !l.is_empty()).collect();
        assert!(data.len() >= 2, "header + at least one design row");
        let cols = data[0].split('\t').count();
        for line in &data {
            assert_eq!(line.split('\t').count(), cols, "ragged row: {line}");
        }
    }

    /// The acceptance bar for the pass pipeline: strictly fewer gates for
    /// the paper's proposed design and the exact baseline at N = 8.
    #[test]
    fn pipeline_strictly_reduces_proposed_and_exact() {
        for r in rows(8, 42).unwrap() {
            assert!(
                r.opt.gates <= r.raw.gates,
                "{}: optimization grew the netlist",
                r.design
            );
            if r.design == "proposed" || r.design == "exact" {
                assert!(
                    r.opt.gates < r.raw.gates,
                    "{}: expected a strict gate reduction ({} vs {})",
                    r.design,
                    r.opt.gates,
                    r.raw.gates
                );
            }
        }
    }
}

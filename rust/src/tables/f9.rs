//! Fig. 9: edge-detection outputs + PSNR per design.
//!
//! PSNR is computed against the exact-multiplier edge map on the
//! deterministic synthetic scene (the paper's photographs are
//! substituted — see DESIGN.md §Substitutions). Edge maps are written as
//! PGM files next to the textual report.

use crate::image::{edge_detect, psnr, synthetic_scene};
use crate::multipliers::{build_design, DesignId};
use std::path::Path;

/// Paper's headline: the proposed design reaches 20.13 dB, the highest.
pub const PAPER_PROPOSED_PSNR_DB: f64 = 20.13;

pub fn rows(seed: u64) -> Vec<(DesignId, f64)> {
    let img = synthetic_scene(256, 256, seed);
    let exact = build_design(DesignId::Exact, 8);
    let reference = edge_detect(&img, exact.as_ref());
    DesignId::table4_order()
        .into_iter()
        .map(|id| {
            let m = build_design(id, 8);
            let edges = edge_detect(&img, m.as_ref());
            (id, psnr(&reference, &edges))
        })
        .collect()
}

pub fn render(seed: u64, out_dir: &Path) -> crate::Result<String> {
    let img = synthetic_scene(256, 256, seed);
    let exact = build_design(DesignId::Exact, 8);
    let reference = edge_detect(&img, exact.as_ref());
    std::fs::create_dir_all(out_dir)?;
    img.write_pgm(&out_dir.join("scene.pgm"))?;
    reference.write_pgm(&out_dir.join("edges_exact.pgm"))?;

    let mut s = String::new();
    s.push_str("== Fig 9: edge detection, PSNR vs exact edge map (synthetic 256x256 scene) ==\n");
    for id in DesignId::table4_order() {
        let m = build_design(id, 8);
        let edges = edge_detect(&img, m.as_ref());
        let db = psnr(&reference, &edges);
        let fname = format!(
            "edges_{}.pgm",
            id.paper_name().to_lowercase().replace(['[', ']', ' '], "")
        );
        edges.write_pgm(&out_dir.join(&fname))?;
        let marker = if id == DesignId::Proposed {
            format!("   <-- paper: {PAPER_PROPOSED_PSNR_DB} dB (highest)")
        } else {
            String::new()
        };
        s.push_str(&format!(
            "  {:<17}  PSNR = {:>6.2} dB   ({fname}){marker}\n",
            id.paper_name(),
            db
        ));
    }
    s.push_str(&format!("  edge maps written to {}\n", out_dir.display()));
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig 9's shape: the proposed design has the highest PSNR of all
    /// approximate designs, in the paper's ~20 dB regime.
    #[test]
    fn proposed_has_highest_psnr() {
        let rows = rows(11);
        let prop = rows
            .iter()
            .find(|(id, _)| *id == DesignId::Proposed)
            .unwrap()
            .1;
        for (id, db) in &rows {
            if *id != DesignId::Proposed {
                assert!(prop > *db, "proposed {prop:.2} !> {id:?} {db:.2}");
            }
        }
        assert!(
            (prop - PAPER_PROPOSED_PSNR_DB).abs() < 5.0,
            "proposed PSNR {prop:.2} far from paper {PAPER_PROPOSED_PSNR_DB}"
        );
    }
}

//! Paper-table and figure generators (the reproduction harness).
//!
//! Every table and figure of the paper's evaluation has a generator here
//! that prints the same rows/series the paper reports and returns the
//! data for tests/benches, plus the beyond-paper [`opmatrix`] (design ×
//! operator PSNR) and [`nnmatrix`] (design × quantized-inference layer
//! accuracy). `sfcmul tables --id
//! <t1|t2|t3|t4|t5|f9|f10|ops|nn|all>` is the CLI entry.

pub mod t1;
pub mod t2t3;
pub mod t4;
pub mod t5;
pub mod f9;
pub mod f10;
pub mod ablation;
pub mod nnmatrix;
pub mod opmatrix;
pub mod sweep;

pub use ablation::report as ablation_report;

/// Generate a table/figure by id; returns its printable text.
pub fn generate(id: &str, seed: u64, out_dir: &std::path::Path) -> crate::Result<String> {
    match id {
        "t1" => Ok(t1::render()),
        "t2" => Ok(t2t3::render_t2()),
        "t3" => Ok(t2t3::render_t3()),
        "t4" => Ok(t4::render()),
        "t5" => Ok(t5::render(seed)),
        "f9" => f9::render(seed, out_dir),
        "f10" => Ok(f10::render(seed)),
        "ops" => Ok(opmatrix::render(seed)),
        "nn" => Ok(nnmatrix::render(seed)),
        "sweep" => Ok(sweep::render()),
        "all" => {
            let mut s = String::new();
            for id in ["t1", "t2", "t3", "t4", "t5", "f9", "f10", "ops", "nn"] {
                s.push_str(&generate(id, seed, out_dir)?);
                s.push('\n');
            }
            Ok(s)
        }
        other => Err(crate::util::error::Error::msg(format!(
            "unknown table id {other:?} (t1..t5, f9, f10, ops, nn, sweep, all)"
        ))),
    }
}

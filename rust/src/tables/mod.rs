//! Paper-table and figure generators (the reproduction harness).
//!
//! Every table and figure of the paper's evaluation has a generator here
//! that prints the same rows/series the paper reports and returns the
//! data for tests/benches, plus the beyond-paper extensions ([`opmatrix`],
//! [`nnmatrix`], [`sweep`], [`ablation`], [`gates`]). `sfcmul tables --id
//! <ID>` is the CLI entry.
//!
//! Dispatch is data-driven: each generator registers itself as a
//! [`TableSpec`] in the [`TABLES`] slice (id, title, whether `--id all`
//! includes it, and a uniform `fn(seed, out_dir) -> Result<String>`
//! runner). Adding a table is one slice entry — no `match` to extend, and
//! the CLI usage line, the `all` bundle, and the unknown-id error message
//! all derive from the same slice.

pub mod t1;
pub mod t2t3;
pub mod t4;
pub mod t5;
pub mod f9;
pub mod f10;
pub mod ablation;
pub mod gates;
pub mod nnmatrix;
pub mod opmatrix;
pub mod sweep;

pub use ablation::report as ablation_report;

/// One table/figure generator: a self-describing registry entry.
pub struct TableSpec {
    /// CLI id (`tables --id <id>`).
    pub id: &'static str,
    /// One-line description, shown by the CLI usage text.
    pub title: &'static str,
    /// Whether `--id all` includes this table (paper tables/figures yes;
    /// the long-running extension studies opt out and run by id).
    pub in_all: bool,
    /// Uniform runner: `(seed, out_dir)` → printable text. Generators
    /// that need neither simply ignore them.
    pub run: fn(u64, &std::path::Path) -> crate::Result<String>,
}

/// Every generator, in presentation order (paper artifacts first, then
/// the beyond-paper extensions).
pub const TABLES: &[TableSpec] = &[
    TableSpec {
        id: "t1",
        title: "Table 1: Baugh-Wooley worked example (N=4)",
        in_all: true,
        run: |_seed, _out| Ok(t1::render()),
    },
    TableSpec {
        id: "t2",
        title: "Table 2: A+B+C+D+1 compressor truth table & errors",
        in_all: true,
        run: |_seed, _out| Ok(t2t3::render_t2()),
    },
    TableSpec {
        id: "t3",
        title: "Table 3: A+B+C+1 compressor truth table & errors",
        in_all: true,
        run: |_seed, _out| Ok(t2t3::render_t3()),
    },
    TableSpec {
        id: "t4",
        title: "Table 4: ER/NMED/MRED per design, exhaustive at N=8",
        in_all: true,
        run: |_seed, _out| Ok(t4::render()),
    },
    TableSpec {
        id: "t5",
        title: "Table 5: area/power/delay/PDP per design (calibrated)",
        in_all: true,
        run: |seed, _out| Ok(t5::render(seed)),
    },
    TableSpec {
        id: "f9",
        title: "Fig. 9: edge-detection outputs + PSNR per design",
        in_all: true,
        run: f9::render,
    },
    TableSpec {
        id: "f10",
        title: "Fig. 10: PDP vs MRED scatter",
        in_all: true,
        run: |seed, _out| Ok(f10::render(seed)),
    },
    TableSpec {
        id: "ops",
        title: "Extension: design x operator PSNR matrix",
        in_all: true,
        run: |seed, _out| Ok(opmatrix::render(seed)),
    },
    TableSpec {
        id: "nn",
        title: "Extension: design x quantized-inference accuracy",
        in_all: true,
        run: |seed, _out| Ok(nnmatrix::render(seed)),
    },
    TableSpec {
        id: "sweep",
        title: "Extension: width scaling N=4..16",
        in_all: false,
        run: |_seed, _out| Ok(sweep::render()),
    },
    TableSpec {
        id: "ablation",
        title: "Extension: reconstruction design-space ablation",
        in_all: false,
        run: |seed, _out| Ok(ablation::report(seed)),
    },
    TableSpec {
        id: "gates",
        title: "Netlist gate stats pre/post optimization (TSV, CI-gated)",
        in_all: false,
        run: |seed, _out| gates::render(seed),
    },
];

/// Look up a generator by CLI id.
pub fn spec(id: &str) -> Option<&'static TableSpec> {
    TABLES.iter().find(|t| t.id == id)
}

/// All CLI ids in presentation order (drives usage text and errors).
pub fn ids() -> Vec<&'static str> {
    TABLES.iter().map(|t| t.id).collect()
}

/// Generate a table/figure by id (or the `all` bundle); returns its
/// printable text.
pub fn generate(id: &str, seed: u64, out_dir: &std::path::Path) -> crate::Result<String> {
    if id == "all" {
        let mut s = String::new();
        for t in TABLES.iter().filter(|t| t.in_all) {
            s.push_str(&(t.run)(seed, out_dir)?);
            s.push('\n');
        }
        return Ok(s);
    }
    match spec(id) {
        Some(t) => (t.run)(seed, out_dir),
        None => Err(crate::util::error::Error::msg(format!(
            "unknown table id {id:?} ({}, all)",
            ids().join(", ")
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_are_unique_and_stable() {
        let ids = ids();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ids.len(), "duplicate table id");
        for must in ["t1", "t2", "t3", "t4", "t5", "f9", "f10", "ops", "nn", "sweep", "ablation", "gates"] {
            assert!(ids.contains(&must), "{must} missing from TABLES");
        }
        assert!(spec("all").is_none(), "'all' is a bundle, not an entry");
    }

    #[test]
    fn unknown_id_error_lists_registry() {
        let err = generate("nope", 1, std::path::Path::new(".")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("gates") && msg.contains("t5"), "{msg}");
    }
}

//! Tables 2 and 3: sign-focused compressor truth tables with row
//! probabilities, per-design approximate values, errors, `P_E`, `E_mean`.

use crate::compressors::{abc1_stats, abcd1_stats, all_abc1_designs, all_abcd1_designs};

pub fn render_t2() -> String {
    let designs = all_abc1_designs();
    let stats: Vec<_> = designs.iter().map(|d| abc1_stats(d.as_ref())).collect();
    let mut s = String::new();
    s.push_str("== Table 2: A+B+C+1 sign-focused compressors (P(A)=3/4, P(B)=P(C)=1/4) ==\n");
    s.push_str("  A B C   P(row)  exact");
    for st in &stats {
        s.push_str(&format!(" | {:>18}", st.name));
    }
    s.push('\n');
    for row in 0..8 {
        let bits = stats[0].rows[row].0;
        let (a, b, c) = (bits >> 2 & 1, bits >> 1 & 1, bits & 1);
        s.push_str(&format!(
            "  {a} {b} {c}   {:>5.3}   {:>5}",
            stats[0].rows[row].1, stats[0].rows[row].2
        ));
        for st in &stats {
            let (_, _, _, approx, err) = st.rows[row];
            s.push_str(&format!(" | {:>8} (err {:+2})", approx, err));
        }
        s.push('\n');
    }
    s.push_str("  P_E   ");
    for st in &stats {
        s.push_str(&format!(" | {:>18.4}", st.error_probability));
    }
    s.push_str("\n  E_mean");
    for st in &stats {
        s.push_str(&format!(" | {:>18.4}", st.mean_error));
    }
    s.push('\n');
    s.push_str(
        "  note: paper's printed P_E/E_mean summary row for 'Proposed' (0.0140/-0.0468)\n  \
         is inconsistent with its own Err column; values above are derived from the\n  \
         truth table (P_E = 9/64 = 0.1406, E_mean = +3/64). See EXPERIMENTS.md.\n",
    );
    s
}

pub fn render_t3() -> String {
    let designs = all_abcd1_designs();
    let mut s = String::new();
    s.push_str("== Table 3: A+B+C+D+1 compressors (P(A)=3/4, P(B..D)=1/4) ==\n");
    for d in &designs {
        let st = abcd1_stats(d.as_ref());
        s.push_str(&format!(
            "  {:<18} P_E = {:>6.4}  E_mean = {:>+7.4}  E|err| = {:>6.4}\n",
            st.name, st.error_probability, st.mean_error, st.mean_abs_error
        ));
    }
    // full truth table for the shipped proposed design
    let proposed = abcd1_stats(&crate::compressors::proposed::ProposedApproxAbcd1);
    s.push_str("  proposed (C5) truth table: A B C D | P(row) exact approx err\n");
    for (bits, p, exact, approx, err) in &proposed.rows {
        s.push_str(&format!(
            "    {} {} {} {}  | {:>6.4}  {exact}  {approx}  {err:+}\n",
            bits >> 3 & 1,
            bits >> 2 & 1,
            bits >> 1 & 1,
            bits & 1,
            p
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn t2_contains_all_designs_and_sums() {
        let s = super::render_t2();
        for name in ["AC1 [4]", "AC2 [5]", "AC3 [12]", "AC4 [3]", "AC5 [2]", "Proposed"] {
            assert!(s.contains(name), "{name} missing:\n{s}");
        }
        assert!(s.contains("0.1406"));
    }

    #[test]
    fn t3_contains_proposed_rows() {
        let s = super::render_t3();
        assert!(s.contains("truth table"));
        assert!(s.contains("P_E"));
    }
}

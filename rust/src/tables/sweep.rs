//! Extension study (beyond the paper): how the proposed architecture
//! scales with operand width N — error metrics and hardware figures for
//! N = 4..16, plus the Booth-vs-Baugh-Wooley substrate comparison the
//! paper's introduction motivates. `sfcmul sweep` prints it.

use crate::error::{error_metrics_netlist, error_metrics_sampled};
use crate::hwmodel::raw_hw;
use crate::multipliers::{registry, BoothRadix4, MultiplierModel, Optimized};
use crate::netlist::OptLevel;
use std::sync::Arc;

pub struct SweepRow {
    pub n: usize,
    pub nmed_pct: f64,
    pub mred_pct: f64,
    pub area_ge: f64,
    pub delay_units: f64,
    pub area_vs_exact: f64,
}

pub fn rows() -> Vec<SweepRow> {
    [4usize, 6, 8, 10, 12, 16]
        .into_iter()
        .map(|n| {
            let prop = registry().build_str(&format!("proposed@{n}")).expect("registered");
            let exact = registry().build_str(&format!("exact@{n}")).expect("registered");
            // Exhaustive widths run on the gate-level netlist through the
            // bitsliced sweep; wider widths stay on the (fast) functional
            // model, where exhaustion is intractable and the model is the
            // sampled stand-in.
            let e = if n <= 10 {
                error_metrics_netlist(prop.as_ref())
            } else {
                error_metrics_sampled(prop.as_ref(), 200_000, 42)
            };
            let hw_p = raw_hw(prop.as_ref(), 42);
            let hw_e = raw_hw(exact.as_ref(), 42);
            SweepRow {
                n,
                nmed_pct: e.nmed * 100.0,
                mred_pct: e.mred * 100.0,
                area_ge: hw_p.area_ge,
                delay_units: hw_p.delay_units,
                area_vs_exact: hw_p.area_ge / hw_e.area_ge,
            }
        })
        .collect()
}

pub fn render() -> String {
    let mut s = String::new();
    s.push_str("== Extension: width scaling of the proposed architecture ==\n");
    s.push_str("   N   NMED (%)  MRED (%)   area (GE)  delay   area/exact\n");
    for r in rows() {
        s.push_str(&format!(
            "  {:>2}   {:>7.3}   {:>7.2}   {:>8.1}   {:>5.1}   {:>6.2}\n",
            r.n, r.nmed_pct, r.mred_pct, r.area_ge, r.delay_units, r.area_vs_exact
        ));
    }
    s.push_str(
        "  finding: the architecture needs width headroom — at N=4 truncation\n   \
         dominates the product (NMED ~19%); from N=8 the paper's regime holds.\n",
    );
    s.push_str("\n== Extension: signed-multiplication substrates at N = 8 (paper §1) ==\n");
    // Direct constructions bypass the registry, so optimize here to match
    // the synthesis treatment registry designs get by default.
    let bw = Optimized::new(
        Arc::new(crate::multipliers::ExactBaughWooley::new(8)),
        OptLevel::Full,
    );
    let booth = Optimized::new(Arc::new(BoothRadix4::new(8)), OptLevel::Full);
    for m in [&bw as &dyn MultiplierModel, &booth as &dyn MultiplierModel] {
        let hw = raw_hw(m, 42);
        s.push_str(&format!(
            "  {:<16} area {:>7.1} GE  delay {:>5.1}  swcap {:>7.1}  gates {:>4}\n",
            m.name(),
            hw.area_ge,
            hw.delay_units,
            hw.switched_cap,
            hw.gates
        ));
    }
    s.push_str(
        "  (Baugh-Wooley's AND/NAND matrix is what the sign-focused compressors\n   \
         and the truncation scheme exploit; Booth's recoded rows resist both —\n   \
         the basis of the paper's §1 algorithm choice)\n",
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Relative error is roughly width-independent (truncation tracks the
    /// compensation), while area saving vs exact improves with N.
    #[test]
    fn scaling_trends_hold() {
        let rows = rows();
        for w in rows.windows(2) {
            assert!(w[1].area_ge > w[0].area_ge, "area grows with N");
        }
        let n8 = rows.iter().find(|r| r.n == 8).unwrap();
        let n16 = rows.iter().find(|r| r.n == 16).unwrap();
        assert!(
            n16.area_vs_exact < n8.area_vs_exact,
            "wider operands truncate proportionally more: {} vs {}",
            n16.area_vs_exact,
            n8.area_vs_exact
        );
        // N=4 is a legitimate negative finding (truncating 3 of 7 columns
        // of a 4-bit product leaves no headroom); from N=8 up the relative
        // error settles under 1%.
        for r in rows.iter().filter(|r| r.n >= 8) {
            assert!(r.nmed_pct < 1.5, "N={}: NMED {}", r.n, r.nmed_pct);
        }
        let n4 = rows.iter().find(|r| r.n == 4).unwrap();
        assert!(n4.nmed_pct > 5.0, "N=4 should show the breakdown the render notes");
    }
}

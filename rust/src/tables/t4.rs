//! Table 4: ER / NMED / MRED of every design, exhaustive over all 65 536
//! signed 8-bit operand pairs (paper §5.1, Eqs. 7–8).
//!
//! Products come from the *gate-level netlists* via the bitsliced 64-lane
//! sweep ([`crate::error::error_metrics_netlist`]), so this table reports
//! hardware truth directly; the test suite separately proves the
//! functional models bit-exact against the same netlists.

use crate::error::error_metrics_netlist;
use crate::multipliers::{build_design, DesignId};

/// Paper's Table 4 values, for the side-by-side report.
pub const PAPER_T4: [(&str, f64, f64, f64); 7] = [
    ("Design [12]", 98.47, 1.128, 32.80),
    ("Design [5]", 98.95, 0.829, 30.00),
    ("Design [4]", 99.42, 0.786, 35.25),
    ("Design [1]", 97.37, 0.738, 29.02),
    ("Design [7]", 98.95, 0.542, 33.00),
    ("Design [2]", 98.15, 0.731, 26.84),
    ("Proposed Design", 98.04, 0.682, 26.29),
];

pub fn rows() -> Vec<(DesignId, crate::error::ErrorMetrics)> {
    DesignId::table4_order()
        .into_iter()
        .map(|id| {
            let m = build_design(id, 8);
            (id, error_metrics_netlist(m.as_ref()))
        })
        .collect()
}

pub fn render() -> String {
    let mut s = String::new();
    s.push_str("== Table 4: error metrics (exhaustive, 65536 pairs) ==\n");
    s.push_str(
        "  design            |   ER (%)          |  NMED (%)         |  MRED (%)\n  \
                            |  measured  paper  |  measured  paper  |  measured  paper\n",
    );
    for ((id, m), (pname, p_er, p_nmed, p_mred)) in rows().iter().zip(PAPER_T4) {
        debug_assert_eq!(id.paper_name(), pname);
        s.push_str(&format!(
            "  {:<17} |  {:>7.2}  {:>6.2}  |  {:>7.3}  {:>6.3}  |  {:>7.2}  {:>6.2}\n",
            id.paper_name(),
            m.er * 100.0,
            p_er,
            m.nmed * 100.0,
            p_nmed,
            m.mred * 100.0,
            p_mred,
        ));
    }
    s.push_str("  (ME and max|ED| diagnostics)\n");
    for (id, m) in rows() {
        s.push_str(&format!(
            "  {:<17}   ME = {:>+8.2}   max|ED| = {:>5}\n",
            id.paper_name(),
            m.me,
            m.max_ed
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Table-4 shape: proposed has the lowest MRED of all
    /// designs and a lower NMED than the best truncating baseline [2].
    #[test]
    fn proposed_wins_mred_and_beats_d2() {
        let rows = rows();
        let get = |id: DesignId| rows.iter().find(|(i, _)| *i == id).unwrap().1.clone();
        let prop = get(DesignId::Proposed);
        let d2 = get(DesignId::D2);
        assert!(prop.nmed < d2.nmed, "NMED {} vs D2 {}", prop.nmed, d2.nmed);
        assert!(prop.mred < d2.mred, "MRED {} vs D2 {}", prop.mred, d2.mred);
        for (id, m) in &rows {
            if *id != DesignId::Proposed {
                assert!(prop.mred <= m.mred + 1e-12, "MRED vs {id:?}");
            }
        }
    }

    #[test]
    fn render_includes_both_columns() {
        let s = render();
        assert!(s.contains("Proposed Design"));
        assert!(s.contains("paper"));
    }
}

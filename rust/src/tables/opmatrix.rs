//! Per-operator PSNR matrix: every registered design × every registered
//! operator, against the exact multiplier running the *same* operator —
//! the Fig.-9-style fidelity evaluation widened from the single Laplacian
//! workload to the whole operator registry (`sfcmul tables --id ops`).
//!
//! Error behaviour is operator-dependent: the signed gradient operators
//! drive the negative-partial-product path of the sign-focused
//! compressors much harder than the Laplacian, and the saturating
//! filters (sharpen, gaussian3) display at a lower normalisation shift,
//! so the same per-product error shows up magnified. The matrix makes
//! those differences visible per design.

use crate::image::ops::{apply_operator_lut, Operator};
use crate::image::{psnr, synthetic_scene};
use crate::multipliers::{lut::product_table, registry, DesignSpec};

/// The matrix rows: for each registered design (Table-5 order), the PSNR
/// in dB against the exact multiplier per operator
/// ([`Operator::all`] order). The exact design's row is all `inf`.
pub fn rows(seed: u64, size: usize) -> Vec<(DesignSpec, Vec<f64>)> {
    let img = synthetic_scene(size, size, seed);
    let exact = registry().build_str("exact@8").expect("exact design");
    let exact_lut = product_table(exact.as_ref());
    let references: Vec<_> = Operator::all()
        .iter()
        .map(|&op| apply_operator_lut(&img, op, &exact_lut))
        .collect();
    registry()
        .specs(8)
        .into_iter()
        .map(|spec| {
            let model = registry().build(&spec).expect("registered design builds");
            let lut = product_table(model.as_ref());
            let dbs = Operator::all()
                .iter()
                .zip(&references)
                .map(|(&op, reference)| psnr(reference, &apply_operator_lut(&img, op, &lut)))
                .collect();
            (spec, dbs)
        })
        .collect()
}

pub fn render(seed: u64) -> String {
    let mut s = String::new();
    s.push_str(
        "== Operator PSNR matrix: design x operator, dB vs exact multiplier \
         (synthetic 256x256 scene) ==\n",
    );
    s.push_str(&format!("  {:<17}", "design"));
    for op in Operator::all() {
        s.push_str(&format!(" {:>9}", op.key()));
    }
    s.push('\n');
    for (spec, dbs) in rows(seed, 256) {
        s.push_str(&format!("  {:<17}", spec.display_name()));
        for db in dbs {
            if db.is_infinite() {
                s.push_str(&format!(" {:>9}", "inf"));
            } else {
                s.push_str(&format!(" {db:>9.2}"));
            }
        }
        s.push('\n');
    }
    s.push_str(
        "  (gradient operators: |Gx|+|Gy| saturating; sharpen/gaussian3: \
         saturate clamp — regenerate with `sfcmul tables --id ops`)\n",
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Matrix shape and sanity: one row per registered design, one
    /// column per operator; the exact row is infinite everywhere and
    /// every approximate entry is a finite positive dB figure.
    #[test]
    fn matrix_covers_every_design_operator_pair() {
        let rows = rows(11, 64);
        assert_eq!(rows.len(), registry().specs(8).len());
        for (spec, dbs) in &rows {
            assert_eq!(dbs.len(), Operator::all().len(), "{spec}");
            if spec.compressors.key() == "exact" {
                assert!(dbs.iter().all(|d| d.is_infinite()), "exact row must be lossless");
            } else {
                assert!(dbs.iter().all(|d| *d > 0.0), "{spec}: non-positive PSNR {dbs:?}");
            }
        }
    }
}

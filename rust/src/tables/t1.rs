//! Table 1: the Baugh-Wooley worked example for N = 4 — rendered
//! symbolically from the same partial-product rules the multipliers use,
//! plus a numeric verification column.

use crate::multipliers::traits::{from_bits, pp_kind, to_bits, PpKind};

pub fn render() -> String {
    let n = 4;
    let mut s = String::new();
    s.push_str("== Table 1: Baugh-Wooley multiplication, N = 4 ==\n");
    s.push_str("final reduced matrix (rows shifted by weight; ~ marks NAND terms):\n");
    // rows by operand-b bit, as the paper's final form prints them
    for j in 0..n {
        let mut row = format!("  b{j}: ");
        for w in (0..2 * n).rev() {
            let i = w as isize - j as isize;
            if i >= 0 && (i as usize) < n {
                let i = i as usize;
                let t = match pp_kind(i, j, n) {
                    PpKind::And => format!(" a{i}b{j} "),
                    PpKind::Nand => format!("~a{i}b{j} "),
                };
                row.push_str(&t);
            } else {
                row.push_str("  .   ");
            }
        }
        row.push('\n');
        s.push_str(&row);
    }
    s.push_str(&format!(
        "  constants: +1 at column {} (2^N) and +1 at column {} (2^(2N-1))\n",
        n,
        2 * n - 1
    ));
    // numeric spot-check across the full N=4 range
    let mut checked = 0;
    for a in -8i64..8 {
        for b in -8i64..8 {
            let ua = to_bits(a, n);
            let ub = to_bits(b, n);
            let mut acc: u64 = (1 << n) + (1 << (2 * n - 1));
            for i in 0..n {
                for j in 0..n {
                    if crate::multipliers::traits::pp_value(ua, ub, i, j, n) {
                        acc = acc.wrapping_add(1 << (i + j));
                    }
                }
            }
            assert_eq!(from_bits(acc, 2 * n), a * b);
            checked += 1;
        }
    }
    s.push_str(&format!(
        "  identity verified numerically for all {checked} signed 4-bit pairs\n"
    ));
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_and_verifies() {
        let s = super::render();
        assert!(s.contains("~a3b0"), "NAND row terms present:\n{s}");
        assert!(s.contains("all 256 signed 4-bit pairs"));
    }
}

//! Table 5: area / power / delay / PDP per design, unit-gate model
//! calibrated to the paper's exact-multiplier row (see [`crate::hwmodel`]).

use crate::hwmodel::evaluate_all;
use crate::multipliers::DesignId;

/// Paper's Table 5 (area μm², power μW, delay ns, PDP fJ).
pub const PAPER_T5: [(&str, f64, f64, f64, f64); 8] = [
    ("Exact", 2204.75, 178.10, 3.28, 584.17),
    ("Design [4]", 1242.07, 136.95, 2.17, 297.41),
    ("Design [1]", 1972.91, 122.19, 2.65, 324.08),
    ("Design [5]", 1164.34, 116.05, 2.49, 289.15),
    ("Design [12]", 1386.62, 129.96, 2.32, 302.48),
    ("Design [7]", 1306.84, 124.89, 2.35, 293.95),
    ("Design [2]", 1013.07, 110.42, 2.54, 280.48),
    ("Proposed", 809.23, 94.52, 2.10, 198.54),
];

pub fn render(seed: u64) -> String {
    let rows = evaluate_all(8, seed);
    let mut s = String::new();
    s.push_str("== Table 5: hardware metrics (unit-gate model, calibrated to paper's Exact row) ==\n");
    s.push_str(
        "  design        |  area (µm²)        |  power (µW)       |  delay (ns)      |  PDP (fJ)\n  \
                        |  measured   paper  |  measured  paper  |  measured paper  |  measured  paper\n",
    );
    for ((id, hw), (pname, pa, pp, pd, ppdp)) in rows.iter().zip(PAPER_T5) {
        let _ = pname;
        s.push_str(&format!(
            "  {:<13} | {:>9.2}  {:>7.2} | {:>8.2}  {:>6.2} | {:>7.2}  {:>5.2} | {:>8.2}  {:>6.2}\n",
            id.paper_name(),
            hw.area_um2,
            pa,
            hw.power_uw,
            pp,
            hw.delay_ns,
            pd,
            hw.pdp_fj,
            ppdp,
        ));
    }
    let get = |id: DesignId| rows.iter().find(|(i, _)| *i == id).unwrap().1.clone();
    let prop = get(DesignId::Proposed);
    let d2 = get(DesignId::D2);
    s.push_str(&format!(
        "  headline: proposed vs best existing [2]: power -{:.2}% (paper -14.39%), PDP -{:.2}% (paper -29.21%)\n",
        (1.0 - prop.power_uw / d2.power_uw) * 100.0,
        (1.0 - prop.pdp_fj / d2.pdp_fj) * 100.0,
    ));
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_with_headline() {
        let s = super::render(42);
        assert!(s.contains("headline"));
        assert!(s.contains("Proposed"));
    }
}

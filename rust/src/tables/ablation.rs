//! Design-space ablation: quantifies every reconstruction decision that
//! DESIGN.md documents (compressor candidate, third-slot mode, error
//! compensation, truncation width) at the multiplier level.

use crate::compressors::exact::{ExactAbc1, ExactAbcd1};
use crate::compressors::proposed::*;
use crate::compressors::Abcd1Compressor;
use crate::error::error_metrics;
use crate::multipliers::{
    ApproxMulConfig, ApproxSignedMultiplier, Compensation, MultiplierModel, Sf3Mode,
};
use crate::netlist::prelude::{optimize_netlist, OptLevel};
use std::sync::Arc;

fn base() -> ApproxMulConfig {
    let mut cfg = ApproxMulConfig::paper_default(
        "ablation",
        8,
        Arc::new(ProposedApproxAbcd1),
        Arc::new(ProposedApproxAbc1),
        false,
    );
    cfg.sf3 = Sf3Mode::ExactEncoder;
    cfg
}

fn line(name: &str, cfg: ApproxMulConfig) -> String {
    let m = ApproxSignedMultiplier::new(cfg);
    let e = error_metrics(&m);
    // Area figures after the full pass pipeline — same treatment every
    // registry design gets, so the axes compare like with like.
    let (nl, _) = optimize_netlist(&m.build_netlist(), OptLevel::Full);
    format!(
        "  {:<34} NMED {:>6.3}%  MRED {:>6.2}%  ME {:>+8.2}  max|ED| {:>5}  area {:>5.1} GE\n",
        name,
        e.nmed * 100.0,
        e.mred * 100.0,
        e.me,
        e.max_ed,
        nl.area()
    )
}

pub fn report(_seed: u64) -> String {
    let mut s = String::new();
    s.push_str("== Ablation: reconstruction design space (N = 8) ==\n");

    s.push_str("-- A+B+C+D+1 candidate (CSP compressor) --\n");
    let candidates: Vec<(&str, Arc<dyn Abcd1Compressor>)> = vec![
        ("C5 maj-carry (shipped)", Arc::new(ProposedApproxAbcd1)),
        ("C4 fully-gated", Arc::new(AblationAbcd1Gated)),
        ("C1 ungated parity", Arc::new(AblationAbcd1Parity)),
        ("C3 OR-sum (cheapest)", Arc::new(AblationAbcd1OrSum)),
        ("exact 4:2 (upper bound)", Arc::new(ExactAbcd1)),
    ];
    for (name, c) in candidates {
        let mut cfg = base();
        cfg.abcd1 = c;
        s.push_str(&line(name, cfg));
    }

    s.push_str("-- third compressor slot --\n");
    for (name, mode) in [
        ("exact encoder (shipped)", Sf3Mode::ExactEncoder),
        ("design cell", Sf3Mode::DesignCell),
        ("skip (no replacement)", Sf3Mode::Skip),
    ] {
        let mut cfg = base();
        cfg.sf3 = mode;
        s.push_str(&line(name, cfg));
    }

    s.push_str("-- error compensation --\n");
    for (name, comp) in [
        ("paper (CSP constants, shipped)", Compensation::Paper),
        ("literal (+ standalone bit)", Compensation::Literal),
        ("none", Compensation::None),
    ] {
        let mut cfg = base();
        cfg.compensation = comp;
        s.push_str(&line(name, cfg));
    }

    s.push_str("-- truncation width (columns dropped) --\n");
    for t in [0usize, 3, 5, 7] {
        let mut cfg = base();
        cfg.truncate_cols = t;
        if t == 0 {
            cfg.compensation = Compensation::None;
        }
        s.push_str(&line(&format!("truncate {t} columns"), cfg));
    }

    s.push_str("-- exact CSP everywhere (approximation = truncation only) --\n");
    let mut cfg = base();
    cfg.abcd1 = Arc::new(ExactAbcd1);
    cfg.abc1 = Arc::new(ExactAbc1);
    s.push_str(&line("all-exact CSP", cfg));
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn report_covers_all_axes() {
        let s = super::report(1);
        for needle in [
            "C5 maj-carry",
            "C4 fully-gated",
            "third compressor",
            "compensation",
            "truncation width",
        ] {
            assert!(s.contains(needle), "{needle} missing");
        }
    }

    /// The shipped configuration must be the best candidate on MRED —
    /// the empirical basis for DESIGN.md's reconstruction choice.
    #[test]
    fn shipped_candidate_wins_mred() {
        use super::*;
        let shipped = {
            let m = ApproxSignedMultiplier::new(base());
            error_metrics(&m).mred
        };
        for alt in [
            Arc::new(AblationAbcd1Gated) as Arc<dyn Abcd1Compressor>,
            Arc::new(AblationAbcd1Parity),
            Arc::new(AblationAbcd1OrSum),
        ] {
            let mut cfg = base();
            cfg.abcd1 = alt;
            let m = ApproxSignedMultiplier::new(cfg);
            assert!(shipped < error_metrics(&m).mred + 1e-12);
        }
    }
}

//! Fig. 10: PDP vs MRED scatter — joins Table 5's PDP axis with Table 4's
//! MRED axis, printed as an aligned series plus an ASCII scatter.

use crate::error::error_metrics;
use crate::hwmodel::evaluate_all;
use crate::multipliers::{build_design, DesignId};

pub struct ScatterPoint {
    pub id: DesignId,
    pub pdp_fj: f64,
    pub mred_pct: f64,
}

pub fn points(seed: u64) -> Vec<ScatterPoint> {
    let hw = evaluate_all(8, seed);
    DesignId::table5_order()
        .into_iter()
        .filter(|id| *id != DesignId::Exact) // the paper plots approximate designs
        .map(|id| {
            let m = build_design(id, 8);
            let e = error_metrics(m.as_ref());
            let pdp = hw.iter().find(|(i, _)| *i == id).unwrap().1.pdp_fj;
            ScatterPoint { id, pdp_fj: pdp, mred_pct: e.mred * 100.0 }
        })
        .collect()
}

pub fn render(seed: u64) -> String {
    let pts = points(seed);
    let mut s = String::new();
    s.push_str("== Fig 10: PDP vs MRED trade-off ==\n");
    s.push_str("  design            PDP (fJ)   MRED (%)\n");
    for p in &pts {
        let star = if p.id == DesignId::Proposed { "  *proposed*" } else { "" };
        s.push_str(&format!(
            "  {:<17} {:>8.2}   {:>7.2}{star}\n",
            p.id.paper_name(),
            p.pdp_fj,
            p.mred_pct
        ));
    }
    // ASCII scatter: x = MRED, y = PDP (top = high)
    let (w, h) = (64usize, 16usize);
    let max_pdp = pts.iter().map(|p| p.pdp_fj).fold(0.0f64, f64::max) * 1.05;
    let max_mred = pts.iter().map(|p| p.mred_pct).fold(0.0f64, f64::max) * 1.05;
    let mut grid = vec![vec![' '; w]; h];
    for p in &pts {
        let x = ((p.mred_pct / max_mred) * (w - 1) as f64) as usize;
        let y = h - 1 - ((p.pdp_fj / max_pdp) * (h - 1) as f64) as usize;
        grid[y][x] = if p.id == DesignId::Proposed { '*' } else { 'o' };
    }
    s.push_str(&format!("  PDP ^ (max {max_pdp:.0} fJ)\n"));
    for row in grid {
        s.push_str("      |");
        s.extend(row);
        s.push('\n');
    }
    s.push_str(&format!(
        "      +{}> MRED (max {max_mred:.0}%)   (* = proposed, lower-left is better)\n",
        "-".repeat(w)
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The proposed design must be Pareto-optimal: no design has both
    /// lower PDP and lower MRED (paper: it is the lower-left corner).
    #[test]
    fn proposed_is_pareto_optimal() {
        let pts = points(42);
        let prop = pts.iter().find(|p| p.id == DesignId::Proposed).unwrap();
        for p in &pts {
            if p.id != DesignId::Proposed {
                assert!(
                    !(p.pdp_fj < prop.pdp_fj && p.mred_pct < prop.mred_pct),
                    "{:?} dominates proposed",
                    p.id
                );
            }
        }
        // stronger: the paper claims BOTH axes are best
        for p in &pts {
            assert!(prop.pdp_fj <= p.pdp_fj + 1e-9, "PDP vs {:?}", p.id);
            assert!(prop.mred_pct <= p.mred_pct + 1e-9, "MRED vs {:?}", p.id);
        }
    }
}

//! ER / MED / NMED / MRED computation (paper Eqs. 7–8).
//!
//! * **ER** — error rate: fraction of input pairs with `approx ≠ exact`.
//! * **MED** — mean |error distance|.
//! * **NMED** — MED normalised by `max |exact product|` (= `2^(2N-2)` for
//!   signed N-bit operands; 16 384 for N=8), as in Eq. (8).
//! * **MRED** — mean relative error distance, Eq. (7); pairs with
//!   `exact == 0` are skipped (the relative error is undefined there — the
//!   convention used throughout the approximate-arithmetic literature).
//! * **ME** — signed mean error (bias); not printed by the paper but
//!   essential for diagnosing compensation quality.
//!
//! Two exhaustive entry points share one accumulator: [`error_metrics`]
//! sweeps the functional model, [`error_metrics_netlist`] sweeps the
//! gate-level netlist through the bitsliced 64-lane simulator
//! ([`crate::netlist::bitslice::BitSim`]) — the paper-table path.

use crate::multipliers::traits::{from_bits, mask};
use crate::multipliers::verify::netlist_multiply_all;
use crate::multipliers::MultiplierModel;
use crate::util::prng::Xoshiro256;

#[derive(Debug, Clone)]
pub struct ErrorMetrics {
    pub name: String,
    /// Fraction in [0,1].
    pub er: f64,
    pub med: f64,
    pub nmed: f64,
    pub mred: f64,
    /// Signed mean error (bias).
    pub me: f64,
    /// Largest |error| observed.
    pub max_ed: i64,
    /// Number of evaluated pairs.
    pub pairs: usize,
}

/// Accumulate metrics over `(a, b, approx)` triples — the shared core of
/// the functional-model and netlist-backed entry points.
fn accumulate(
    name: String,
    n: usize,
    triples: impl Iterator<Item = (i64, i64, i64)>,
) -> ErrorMetrics {
    let max_exact = 1i64 << (2 * n - 2);
    let mut count = 0usize;
    let mut errors = 0usize;
    let mut sum_ed = 0f64;
    let mut sum_e = 0f64;
    let mut sum_red = 0f64;
    let mut red_count = 0usize;
    let mut max_ed = 0i64;
    for (a, b, approx) in triples {
        let exact = a * b;
        let e = approx - exact;
        count += 1;
        if e != 0 {
            errors += 1;
        }
        sum_ed += e.abs() as f64;
        sum_e += e as f64;
        max_ed = max_ed.max(e.abs());
        if exact != 0 {
            sum_red += e.abs() as f64 / exact.abs() as f64;
            red_count += 1;
        }
    }
    let med = sum_ed / count as f64;
    ErrorMetrics {
        name,
        er: errors as f64 / count as f64,
        med,
        nmed: med / max_exact as f64,
        mred: sum_red / red_count.max(1) as f64,
        me: sum_e / count as f64,
        max_ed,
        pairs: count,
    }
}

/// Exhaustive metrics over all `4^N` signed pairs (use for N ≤ 10),
/// computed from the *functional model*.
pub fn error_metrics(model: &dyn MultiplierModel) -> ErrorMetrics {
    let n = model.bits();
    assert!(n <= 10, "exhaustive metrics limited to N<=10; use _sampled");
    let half = 1i64 << (n - 1);
    let pairs = (-half..half).flat_map(move |a| (-half..half).map(move |b| (a, b)));
    accumulate(
        model.name(),
        n,
        pairs.map(|(a, b)| (a, b, model.multiply(a, b))),
    )
}

/// Exhaustive metrics over all `4^N` signed pairs (N ≤ 10) measured on
/// the *gate-level netlist*: products come from a bitsliced sweep
/// ([`netlist_multiply_all`], 64 operand pairs per netlist pass) rather
/// than the functional model. This is the path the paper tables run
/// through — the reported numbers are hardware truth by construction,
/// independent of the model/netlist equivalence the test suite proves
/// separately.
pub fn error_metrics_netlist(model: &dyn MultiplierModel) -> ErrorMetrics {
    let n = model.bits();
    assert!(n <= 10, "exhaustive netlist metrics limited to N<=10");
    let nl = model.build_netlist();
    let products = netlist_multiply_all(&nl, n);
    let m = mask(n);
    accumulate(
        model.name(),
        n,
        products.into_iter().enumerate().map(move |(idx, p)| {
            let a = from_bits((idx >> n) as u64, n);
            let b = from_bits(idx as u64 & m, n);
            (a, b, p)
        }),
    )
}

/// Metrics over an explicit operand-pair list, evaluated on the
/// functional model. This is the offline comparator for the live
/// quality sampler ([`crate::obs::quality`]): feed it the exact operand
/// multiset a sampled workload pushed through an engine and the result
/// must equal the sampler's running MED/NMED/max-ED bit-for-bit (both
/// sides sum integer error distances whose totals stay far below 2^53,
/// so the f64 divisions agree exactly — asserted by the observability
/// test suite).
pub fn error_metrics_for_pairs(
    model: &dyn MultiplierModel,
    pairs: impl Iterator<Item = (i64, i64)>,
) -> ErrorMetrics {
    accumulate(
        model.name(),
        model.bits(),
        pairs.map(|(a, b)| (a, b, model.multiply(a, b))),
    )
}

/// Monte-Carlo metrics over `samples` uniform pairs (wide operands).
pub fn error_metrics_sampled(model: &dyn MultiplierModel, samples: usize, seed: u64) -> ErrorMetrics {
    let n = model.bits();
    let half = 1i64 << (n - 1);
    let mut rng = Xoshiro256::seeded(seed);
    let pairs = (0..samples).map(move |_| {
        (rng.range_i64(-half, half - 1), rng.range_i64(-half, half - 1))
    });
    accumulate(
        model.name(),
        n,
        pairs.map(|(a, b)| (a, b, model.multiply(a, b))),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::{all_designs, build_design, DesignId};

    #[test]
    fn exact_multiplier_has_zero_error() {
        let m = build_design(DesignId::Exact, 8);
        let e = error_metrics(m.as_ref());
        assert_eq!(e.er, 0.0);
        assert_eq!(e.med, 0.0);
        assert_eq!(e.mred, 0.0);
        assert_eq!(e.max_ed, 0);
        assert_eq!(e.pairs, 65536);
    }

    #[test]
    fn sampled_converges_to_exhaustive() {
        let m = build_design(DesignId::Proposed, 8);
        let full = error_metrics(m.as_ref());
        let sampled = error_metrics_sampled(m.as_ref(), 40_000, 7);
        assert!((full.nmed - sampled.nmed).abs() / full.nmed < 0.1,
            "nmed {} vs sampled {}", full.nmed, sampled.nmed);
        assert!((full.mred - sampled.mred).abs() / full.mred < 0.15,
            "mred {} vs sampled {}", full.mred, sampled.mred);
    }

    /// All approximate designs: ER in the high-90s% (paper Table 4),
    /// NMED within an order of magnitude of the paper's column, MRED
    /// between 10% and 80%.
    #[test]
    fn approximate_designs_metric_ranges() {
        for (id, m) in all_designs(8) {
            if id == DesignId::Exact {
                continue;
            }
            let e = error_metrics(m.as_ref());
            assert!(e.er > 0.9, "{id:?}: ER {}", e.er);
            assert!(e.nmed > 0.001 && e.nmed < 0.05, "{id:?}: NMED {}", e.nmed);
            assert!(e.mred > 0.05 && e.mred < 0.9, "{id:?}: MRED {}", e.mred);
        }
    }

    /// The netlist-backed (bitsliced) metrics must agree field-for-field
    /// with the functional-model metrics: the two forms are proved
    /// bit-exact at N=8, so any divergence here is a sweep-plumbing bug.
    #[test]
    fn netlist_metrics_equal_model_metrics() {
        for id in [DesignId::Proposed, DesignId::Exact, DesignId::D2] {
            let m = build_design(id, 8);
            let via_model = error_metrics(m.as_ref());
            let via_netlist = error_metrics_netlist(m.as_ref());
            assert_eq!(via_model.pairs, via_netlist.pairs, "{id:?}");
            assert_eq!(via_model.er, via_netlist.er, "{id:?}");
            assert_eq!(via_model.med, via_netlist.med, "{id:?}");
            assert_eq!(via_model.nmed, via_netlist.nmed, "{id:?}");
            // MRED sums non-integer ratios, so the different sweep orders
            // may accumulate rounding differently; everything else is
            // integer-exact in f64 and must match bit-for-bit.
            assert!(
                (via_model.mred - via_netlist.mred).abs() < 1e-9,
                "{id:?}: mred {} vs {}",
                via_model.mred,
                via_netlist.mred
            );
            assert_eq!(via_model.me, via_netlist.me, "{id:?}");
            assert_eq!(via_model.max_ed, via_netlist.max_ed, "{id:?}");
        }
    }

    /// The pair-list entry point over the full operand grid must equal
    /// the exhaustive sweep — same accumulator, same order.
    #[test]
    fn pair_list_metrics_match_exhaustive_on_full_grid() {
        let m = build_design(DesignId::Proposed, 8);
        let full = error_metrics(m.as_ref());
        let grid = (-128i64..128).flat_map(|a| (-128i64..128).map(move |b| (a, b)));
        let via_pairs = error_metrics_for_pairs(m.as_ref(), grid);
        assert_eq!(via_pairs.pairs, full.pairs);
        assert_eq!(via_pairs.med, full.med);
        assert_eq!(via_pairs.nmed, full.nmed);
        assert_eq!(via_pairs.er, full.er);
        assert_eq!(via_pairs.max_ed, full.max_ed);
    }

    #[test]
    fn metrics_are_deterministic() {
        let m = build_design(DesignId::Proposed, 8);
        let a = error_metrics(m.as_ref());
        let b = error_metrics(m.as_ref());
        assert_eq!(a.nmed, b.nmed);
        assert_eq!(a.er, b.er);
    }
}

//! Error-metric harness (paper §5.1, Eqs. 7-8, Table 4).

pub mod metrics;

pub use metrics::{
    error_metrics, error_metrics_for_pairs, error_metrics_netlist, error_metrics_sampled,
    ErrorMetrics,
};

//! Deterministic fault injection for soak-testing the fleet.
//!
//! [`FaultEngine`] wraps any [`TileEngine`] and misbehaves on a fixed
//! schedule — panic, stall, or corrupt the output of every Nth tile —
//! so the coordinator's panic isolation, deadline watchdog, and circuit
//! breaker can be exercised reproducibly (no randomness: the schedule
//! is a counter, so a failing soak run replays exactly). Select it from
//! the CLI with `--fault panic@4` (see [`FaultPlan`]'s grammar) or from
//! an engine spec string like `fault/panic@4,limit=8/lut`.

use super::engine::{NnBackend, TileEngine};
use super::tiler::{Tile, TileOut};
use crate::image::ops::Operator;
use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// What the injected fault does to the victim tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// Panic inside `process_batch` — exercises `catch_unwind` isolation
    /// and the breaker.
    Panic,
    /// Sleep before computing the tile — exercises the deadline watchdog.
    Delay,
    /// Compute the tile, then flip bits in its output — exercises
    /// result-integrity checks downstream (the soak test's byte-compare).
    Wrong,
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            FaultKind::Panic => "panic",
            FaultKind::Delay => "delay",
            FaultKind::Wrong => "wrong",
        })
    }
}

/// A deterministic fault schedule: fault every `every`-th tile, at most
/// `limit` times.
///
/// Text grammar (the `--fault` knob): `<kind>@<every>[,ms=<delay>][,limit=<n>]`
/// where `<kind>` is `panic` | `delay` | `wrong`, e.g. `panic@4`,
/// `delay@3,ms=50`, `wrong@2,limit=10`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct FaultPlan {
    pub kind: FaultKind,
    /// Fault every Nth tile (1 = every tile). Must be ≥ 1.
    pub every: u64,
    /// Stall duration for [`FaultKind::Delay`] faults.
    pub delay_ms: u64,
    /// Stop injecting after this many faults (`None` = forever) — lets a
    /// soak scenario fault an engine K times, then recover so the
    /// half-open probe can close the breaker again.
    pub limit: Option<u64>,
}

impl FaultPlan {
    pub fn new(kind: FaultKind, every: u64) -> Self {
        assert!(every >= 1, "fault period must be >= 1");
        Self { kind, every, delay_ms: 5, limit: None }
    }

    /// Whether tick number `tick` (1-based) is a fault tick.
    fn fires(&self, tick: u64) -> bool {
        if tick % self.every != 0 {
            return false;
        }
        match self.limit {
            Some(limit) => tick / self.every <= limit,
            None => true,
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}", self.kind, self.every)?;
        if self.kind == FaultKind::Delay {
            write!(f, ",ms={}", self.delay_ms)?;
        }
        if let Some(limit) = self.limit {
            write!(f, ",limit={limit}")?;
        }
        Ok(())
    }
}

impl FromStr for FaultPlan {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        let usage = "expected <panic|delay|wrong>@<every>[,ms=<delay>][,limit=<n>]";
        let mut parts = s.split(',');
        let head = parts.next().unwrap_or_default();
        let (kind_s, every_s) = head
            .split_once('@')
            .ok_or_else(|| format!("bad fault plan {s:?}: {usage}"))?;
        let kind = match kind_s {
            "panic" => FaultKind::Panic,
            "delay" => FaultKind::Delay,
            "wrong" => FaultKind::Wrong,
            other => return Err(format!("unknown fault kind {other:?}: {usage}")),
        };
        let every: u64 = every_s
            .parse()
            .map_err(|_| format!("bad fault period {every_s:?}: {usage}"))?;
        if every == 0 {
            return Err(format!("fault period must be >= 1: {usage}"));
        }
        let mut plan = FaultPlan::new(kind, every);
        for part in parts {
            match part.split_once('=') {
                Some(("ms", v)) => {
                    plan.delay_ms = v
                        .parse()
                        .map_err(|_| format!("bad fault delay {v:?}: {usage}"))?;
                }
                Some(("limit", v)) => {
                    let n: u64 = v
                        .parse()
                        .map_err(|_| format!("bad fault limit {v:?}: {usage}"))?;
                    plan.limit = Some(n);
                }
                _ => return Err(format!("bad fault option {part:?}: {usage}")),
            }
        }
        Ok(plan)
    }
}

/// A [`TileEngine`] wrapper that misbehaves on its [`FaultPlan`]'s
/// schedule. Tiles are processed one at a time through the inner engine
/// so a panic fault takes down exactly the scheduled tile's batch call.
///
/// Faults apply to the conv-tile datapath; the nn backend is delegated
/// untouched (GEMM fault paths are exercised with a panicking
/// [`crate::multipliers::MultiplierModel`] in tests).
pub struct FaultEngine {
    inner: Arc<dyn TileEngine>,
    plan: FaultPlan,
    /// Global tile tick — monotonically increasing across batches and
    /// threads, making the schedule deterministic per engine instance.
    ticks: AtomicU64,
}

impl FaultEngine {
    pub fn new(inner: Arc<dyn TileEngine>, plan: FaultPlan) -> Self {
        Self { inner, plan, ticks: AtomicU64::new(0) }
    }

    /// Faults injected so far (diagnostic).
    pub fn faults_fired(&self) -> u64 {
        let ticks = self.ticks.load(Ordering::Relaxed);
        let fired = ticks / self.plan.every;
        match self.plan.limit {
            Some(limit) => fired.min(limit),
            None => fired,
        }
    }
}

impl TileEngine for FaultEngine {
    fn name(&self) -> String {
        format!("fault[{}]:{}", self.plan, self.inner.name())
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        let mut out = Vec::with_capacity(tiles.len());
        for tile in tiles {
            let tick = self.ticks.fetch_add(1, Ordering::Relaxed) + 1;
            if self.plan.fires(tick) {
                match self.plan.kind {
                    FaultKind::Panic => {
                        panic!("injected fault: {} at tile tick {tick}", self.plan)
                    }
                    FaultKind::Delay => {
                        std::thread::sleep(Duration::from_millis(self.plan.delay_ms));
                    }
                    FaultKind::Wrong => {
                        let mut o = self
                            .inner
                            .process_batch(std::slice::from_ref(tile))
                            .pop()
                            .unwrap_or_else(|| {
                                panic!("inner engine returned empty batch for one tile")
                            });
                        for b in o.data.iter_mut() {
                            *b ^= 0x55;
                        }
                        out.push(o);
                        continue;
                    }
                }
            }
            match self.inner.process_batch(std::slice::from_ref(tile)).pop() {
                Some(o) => out.push(o),
                None => panic!("inner engine returned empty batch for one tile"),
            }
        }
        out
    }

    fn preferred_batch(&self) -> usize {
        self.inner.preferred_batch()
    }

    fn supports_op(&self, op: Operator) -> bool {
        self.inner.supports_op(op)
    }

    fn nn_backend(&self) -> Option<NnBackend> {
        self.inner.nn_backend()
    }
}

/// Install a process-wide panic hook that suppresses the default
/// stderr backtrace for panics on the coordinator's worker threads
/// (names starting with `sfcmul-coord-`) — the only threads where
/// engine code runs under `catch_unwind`, so injected faults are
/// *expected* to panic there; without this, a soak run floods the
/// console with noise that looks like real crashes. Panics on every
/// other thread — including the crate's own `sfcmul-conn-*` /
/// `sfcmul-accept` / `sfcmul-watchdog` threads, which have no
/// `catch_unwind` and where a panic is a genuine bug — still print
/// normally. Idempotent.
pub fn silence_worker_panics() {
    static INSTALLED: OnceLock<()> = OnceLock::new();
    INSTALLED.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let on_worker = std::thread::current()
                .name()
                .is_some_and(|n| n.starts_with("sfcmul-coord-"));
            if !on_worker {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::LutTileEngine;
    use crate::coordinator::tiler::tile_image;
    use crate::image::synthetic_scene;
    use crate::multipliers::{build_design, DesignId};

    fn lut_engine() -> Arc<dyn TileEngine> {
        let model = build_design(DesignId::Proposed, 8);
        Arc::new(LutTileEngine::new(model.as_ref()))
    }

    #[test]
    fn plan_parse_roundtrip() {
        for s in ["panic@4", "delay@3,ms=50", "wrong@2,limit=10", "delay@1,ms=5,limit=2"] {
            let plan: FaultPlan = s.parse().unwrap();
            assert_eq!(plan.to_string().parse::<FaultPlan>().unwrap(), plan, "{s}");
        }
        let p: FaultPlan = "panic@4".parse().unwrap();
        assert_eq!(p.kind, FaultKind::Panic);
        assert_eq!(p.every, 4);
        assert_eq!(p.limit, None);
        let d: FaultPlan = "delay@3,ms=50".parse().unwrap();
        assert_eq!(d.delay_ms, 50);
    }

    #[test]
    fn plan_parse_rejects_garbage() {
        for s in ["", "panic", "panic@0", "panic@x", "zap@2", "panic@2,bogus=1", "panic@2,ms=x"] {
            assert!(s.parse::<FaultPlan>().is_err(), "{s:?} should not parse");
        }
    }

    #[test]
    fn schedule_is_deterministic_counter() {
        let plan = FaultPlan::new(FaultKind::Panic, 3);
        let fired: Vec<u64> = (1..=10).filter(|&t| plan.fires(t)).collect();
        assert_eq!(fired, vec![3, 6, 9]);
        let limited = FaultPlan { limit: Some(2), ..plan };
        let fired: Vec<u64> = (1..=20).filter(|&t| limited.fires(t)).collect();
        assert_eq!(fired, vec![3, 6], "limit caps total injections");
    }

    #[test]
    fn panic_fault_panics_on_schedule_only() {
        let img = synthetic_scene(64, 64, 3);
        let tiles = tile_image(1, &img);
        assert!(tiles.len() >= 4, "need enough tiles to hit the schedule");
        let eng = FaultEngine::new(lut_engine(), FaultPlan::new(FaultKind::Panic, tiles.len() as u64 + 1));
        // Under the period, no panic:
        assert_eq!(eng.process_batch(&tiles).len(), tiles.len());
        // The next batch crosses the period boundary and must panic:
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eng.process_batch(&tiles)
        }));
        assert!(caught.is_err(), "scheduled fault must panic");
        assert!(eng.faults_fired() >= 1);
    }

    #[test]
    fn wrong_fault_corrupts_exactly_the_scheduled_tiles() {
        let img = synthetic_scene(96, 64, 9);
        let tiles = tile_image(2, &img);
        let clean = lut_engine().process_batch(&tiles);
        let eng = FaultEngine::new(lut_engine(), FaultPlan::new(FaultKind::Wrong, 2));
        let out = eng.process_batch(&tiles);
        assert_eq!(out.len(), clean.len());
        for (i, (got, want)) in out.iter().zip(clean.iter()).enumerate() {
            let tick = i as u64 + 1;
            if tick % 2 == 0 {
                assert_ne!(got.data, want.data, "tile {i} should be corrupted");
            } else {
                assert_eq!(got.data, want.data, "tile {i} should be clean");
            }
        }
    }

    #[test]
    fn delegates_capabilities_to_inner() {
        let inner = lut_engine();
        let eng = FaultEngine::new(inner.clone(), FaultPlan::new(FaultKind::Delay, 7));
        assert_eq!(eng.preferred_batch(), inner.preferred_batch());
        assert!(eng.nn_backend().is_some(), "nn capability passes through");
        assert!(eng.name().contains("delay@7"));
        assert!(eng.name().contains(&inner.name()));
    }
}

//! Engine specification + resolution — the one path every call site
//! (CLI, examples, serving) goes through to turn a *design* spec and an
//! *engine* spec into a running [`TileEngine`].
//!
//! # Engine grammar
//!
//! ```text
//! engine := 'lut' | 'model' | 'rowbuf' | 'bitsim' | 'bitsim-live' | 'pjrt'
//!         | 'fault/' plan '/' engine
//! ```
//!
//! * `lut` — in-process 256×256 product-table engine (8-bit designs only;
//!   the production default).
//! * `model` — calls the multiplier functional model per MAC (any width;
//!   the reference path).
//! * `rowbuf` — the Fig. 8 streaming line-buffer datapath (any width).
//! * `bitsim` — gate-level serving: tap tables swept out of the design's
//!   netlist by the bitsliced 64-lane simulator at engine construction
//!   (widths 8..=31) — batch jobs observe hardware truth.
//! * `bitsim-live` — serve-time gate streaming: **no tables**; every MAC
//!   of every tile runs through the netlist at serve time, 64 operand
//!   pairs per gate-program pass (widths 8..=31). Bit-exact with
//!   `bitsim`; the batched-serving witness that serving truth is gate
//!   truth.
//! * `pjrt` — the AOT-compiled JAX/Pallas executable via PJRT (8-bit
//!   designs; requires artifacts and the `pjrt` cargo feature).
//! * `fault/<plan>/<engine>` — the inner engine wrapped in the
//!   deterministic fault injector ([`super::fault::FaultEngine`]), e.g.
//!   `fault/panic@4/lut` panics on every 4th tile. Soak/chaos testing
//!   only — never a production backend.
//!
//! Every resolved in-process engine serves the **whole operator
//! registry** ([`crate::image::ops::Operator`]) — tap tables are built
//! per (design, operator) pair at construction. The PJRT artifact is
//! Laplacian-only; the coordinator rejects other operators for it at
//! submit time.
//!
//! Quantized-inference (GEMM/conv2d) jobs are served by the engines
//! with an i8 MAC source ([`super::engine::NnBackend`]): `lut` and
//! `bitsim` via product tables (bitsim sweeps the full operand space
//! out of the netlist on first nn use), `model` per element,
//! `bitsim-live` by streaming every MAC through the gates 64 lanes per
//! pass — all for 8-bit designs only. `rowbuf` and `pjrt` are
//! conv-datapath-only and reject nn jobs at submit time.

use super::engine::{
    BitsimLiveTileEngine, BitsimTileEngine, LutTileEngine, ModelTileEngine, RowbufTileEngine,
    TileEngine,
};
use super::fault::{FaultEngine, FaultPlan};
use crate::multipliers::spec::{registry, DesignSpec};
use crate::multipliers::lut::product_table;
use crate::runtime::{artifacts_available, artifacts_dir, pjrt_enabled, PjrtTileEngine};
use crate::util::error::Error;
use std::fmt;
use std::str::FromStr;
use std::sync::Arc;

/// Which tile-engine backend serves a design. (Not `Copy`: the fault
/// wrapper carries its plan and inner spec.)
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum EngineSpec {
    /// In-process product-table engine.
    Lut,
    /// Functional-model engine (reference).
    Model,
    /// Streaming row-buffer engine (paper Fig. 8 datapath).
    Rowbuf,
    /// Gate-level engine: netlist products swept by the bitsliced
    /// simulator (widths 8..=31).
    Bitsim,
    /// Serve-time gate streaming: every MAC through the netlist, 64
    /// lanes per pass, no tables (widths 8..=31).
    BitsimLive,
    /// AOT JAX/Pallas executable via PJRT.
    Pjrt,
    /// The inner engine wrapped in the deterministic fault injector —
    /// soak/chaos testing only.
    Fault {
        inner: Box<EngineSpec>,
        plan: FaultPlan,
    },
}

impl EngineSpec {
    pub fn key(&self) -> String {
        match self {
            EngineSpec::Lut => "lut".to_string(),
            EngineSpec::Model => "model".to_string(),
            EngineSpec::Rowbuf => "rowbuf".to_string(),
            EngineSpec::Bitsim => "bitsim".to_string(),
            EngineSpec::BitsimLive => "bitsim-live".to_string(),
            EngineSpec::Pjrt => "pjrt".to_string(),
            EngineSpec::Fault { inner, plan } => format!("fault/{plan}/{}", inner.key()),
        }
    }

    /// The base (non-wrapper) backends.
    pub fn all() -> [EngineSpec; 6] {
        [
            EngineSpec::Lut,
            EngineSpec::Model,
            EngineSpec::Rowbuf,
            EngineSpec::Bitsim,
            EngineSpec::BitsimLive,
            EngineSpec::Pjrt,
        ]
    }
}

impl fmt::Display for EngineSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.key())
    }
}

impl FromStr for EngineSpec {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self, Error> {
        let s = s.trim();
        if let Some(rest) = s.strip_prefix("fault/").or_else(|| s.strip_prefix("FAULT/")) {
            let (plan_s, inner_s) = rest.split_once('/').ok_or_else(|| {
                Error::msg(format!("bad fault engine spec {s:?}: expected fault/<plan>/<engine>"))
            })?;
            let plan: FaultPlan = plan_s.parse().map_err(Error::msg)?;
            let inner: EngineSpec = inner_s.parse()?;
            return Ok(EngineSpec::Fault { inner: Box::new(inner), plan });
        }
        match s.to_lowercase().as_str() {
            "lut" => Ok(EngineSpec::Lut),
            "model" => Ok(EngineSpec::Model),
            "rowbuf" => Ok(EngineSpec::Rowbuf),
            "bitsim" => Ok(EngineSpec::Bitsim),
            "bitsim-live" => Ok(EngineSpec::BitsimLive),
            "pjrt" => Ok(EngineSpec::Pjrt),
            other => Err(Error::msg(format!(
                "unknown engine {other:?} (lut | model | rowbuf | bitsim | bitsim-live | pjrt | fault/<plan>/<engine>)"
            ))),
        }
    }
}

/// Build the design a spec describes (through the global registry) and
/// wrap it in the requested engine backend.
pub fn resolve(engine: EngineSpec, design: &DesignSpec) -> crate::Result<Arc<dyn TileEngine>> {
    // The fault wrapper resolves its inner engine recursively, then
    // injects on top — no model of its own.
    if let EngineSpec::Fault { inner, plan } = engine {
        let inner_engine = resolve(*inner, design)?;
        return Ok(Arc::new(FaultEngine::new(inner_engine, plan)));
    }
    let model = registry().build(design)?;
    match engine {
        EngineSpec::Lut => {
            if design.bits != 8 {
                return Err(Error::msg(format!(
                    "engine lut requires an 8-bit design (got {design}); use engine model"
                )));
            }
            Ok(Arc::new(LutTileEngine::new(model.as_ref())))
        }
        EngineSpec::Model => Ok(Arc::new(ModelTileEngine::new(model))),
        EngineSpec::Rowbuf => Ok(Arc::new(RowbufTileEngine::new(model))),
        EngineSpec::Bitsim => {
            if !(8..=31).contains(&design.bits) {
                return Err(Error::msg(format!(
                    "engine bitsim requires an 8..=31-bit design (got {design})"
                )));
            }
            Ok(Arc::new(BitsimTileEngine::new(model.as_ref())))
        }
        EngineSpec::BitsimLive => {
            if !(8..=31).contains(&design.bits) {
                return Err(Error::msg(format!(
                    "engine bitsim-live requires an 8..=31-bit design (got {design})"
                )));
            }
            Ok(Arc::new(BitsimLiveTileEngine::new(model.as_ref())))
        }
        EngineSpec::Pjrt => {
            if design.bits != 8 {
                return Err(Error::msg(format!(
                    "engine pjrt requires an 8-bit design (got {design})"
                )));
            }
            let table = product_table(model.as_ref());
            let engine = PjrtTileEngine::new(&artifacts_dir(), &model.name(), table)?;
            Ok(Arc::new(engine))
        }
        EngineSpec::Fault { .. } => unreachable!("fault specs resolved above"),
    }
}

/// Parse both spec strings and resolve in one step — a convenience for
/// library/embedding callers holding raw strings. (The CLI itself parses
/// specs up front and goes through [`resolve_with_fallback`].)
pub fn resolve_str(engine: &str, design: &str) -> crate::Result<Arc<dyn TileEngine>> {
    let engine: EngineSpec = engine.parse()?;
    let design: DesignSpec = design.parse()?;
    resolve(engine, &design)
}

/// Resolve with the serving-path fallback: a PJRT request that cannot be
/// satisfied because the backend is genuinely unavailable (build without
/// the `pjrt` feature, or missing AOT artifacts) degrades to the
/// in-process LUT engine with a note on stderr. Returns the engine
/// together with the backend actually used. Every other failure — bad
/// design spec, wrong width, a real PJRT compile error — propagates.
pub fn resolve_with_fallback(
    engine: EngineSpec,
    design: &DesignSpec,
) -> crate::Result<(Arc<dyn TileEngine>, EngineSpec)> {
    let pjrt_unavailable = !pjrt_enabled() || !artifacts_available(&artifacts_dir());
    match resolve(engine.clone(), design) {
        Ok(e) => Ok((e, engine)),
        Err(err) if engine == EngineSpec::Pjrt && pjrt_unavailable => {
            eprintln!("pjrt engine unavailable for {design} ({err}); falling back to lut");
            Ok((resolve(EngineSpec::Lut, design)?, EngineSpec::Lut))
        }
        Err(err) => Err(err),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tiler::tile_image;
    use crate::image::synthetic_scene;

    #[test]
    fn engine_spec_roundtrips() {
        for e in EngineSpec::all() {
            assert_eq!(e.key().parse::<EngineSpec>().unwrap(), e);
        }
        assert!("turbo".parse::<EngineSpec>().is_err());
    }

    #[test]
    fn fault_spec_parses_and_roundtrips() {
        let spec: EngineSpec = "fault/panic@4,limit=8/lut".parse().unwrap();
        let EngineSpec::Fault { ref inner, ref plan } = spec else {
            panic!("expected fault spec, got {spec:?}");
        };
        assert_eq!(**inner, EngineSpec::Lut);
        assert_eq!(plan.every, 4);
        assert_eq!(plan.limit, Some(8));
        assert_eq!(spec.key().parse::<EngineSpec>().unwrap(), spec);
        // Nested wrapping parses too (delay outside, panic inside).
        let nested: EngineSpec = "fault/delay@2,ms=1/fault/panic@9/model".parse().unwrap();
        assert_eq!(nested.key().parse::<EngineSpec>().unwrap(), nested);
        assert!("fault/panic@4".parse::<EngineSpec>().is_err(), "missing inner engine");
        assert!("fault/zap@4/lut".parse::<EngineSpec>().is_err(), "bad kind");
    }

    #[test]
    fn resolve_wraps_fault_engine_around_inner() {
        let design: DesignSpec = "proposed@8".parse().unwrap();
        let spec: EngineSpec = "fault/wrong@2/lut".parse().unwrap();
        let faulty = resolve(spec, &design).unwrap();
        assert!(faulty.name().starts_with("fault["), "{}", faulty.name());
        let clean = resolve(EngineSpec::Lut, &design).unwrap();
        let img = synthetic_scene(96, 64, 2);
        let tiles = tile_image(0, &img);
        let a = faulty.process_batch(&tiles);
        let b = clean.process_batch(&tiles);
        let differing = a.iter().zip(b.iter()).filter(|(x, y)| x.data != y.data).count();
        assert_eq!(differing, tiles.len() / 2, "every 2nd tile corrupted");
    }

    #[test]
    fn resolve_builds_equivalent_engines() {
        let design: DesignSpec = "proposed@8".parse().unwrap();
        let img = synthetic_scene(100, 70, 3);
        let tiles = tile_image(0, &img);
        let lut = resolve(EngineSpec::Lut, &design).unwrap();
        let model = resolve(EngineSpec::Model, &design).unwrap();
        let rowbuf = resolve(EngineSpec::Rowbuf, &design).unwrap();
        let bitsim = resolve(EngineSpec::Bitsim, &design).unwrap();
        let live = resolve(EngineSpec::BitsimLive, &design).unwrap();
        let a = lut.process_batch(&tiles);
        let b = model.process_batch(&tiles);
        let c = rowbuf.process_batch(&tiles);
        let d = bitsim.process_batch(&tiles);
        let e = live.process_batch(&tiles);
        for ((((x, y), z), w), v) in
            a.iter().zip(b.iter()).zip(c.iter()).zip(d.iter()).zip(e.iter())
        {
            assert_eq!(x.data, y.data, "lut vs model");
            assert_eq!(x.data, z.data, "lut vs rowbuf");
            assert_eq!(x.data, w.data, "lut vs bitsim");
            assert_eq!(x.data, v.data, "lut vs bitsim-live");
        }
    }

    /// Resolved engines agree on the new operators too — the per-design
    /// operator programs are equivalent across backends.
    #[test]
    fn resolved_engines_agree_on_sobel() {
        use crate::image::ops::Operator;
        let design: DesignSpec = "proposed@8".parse().unwrap();
        let img = synthetic_scene(100, 70, 7);
        let mut tiles = tile_image(0, &img);
        for t in &mut tiles {
            t.op = Operator::Sobel.id();
        }
        let lut = resolve(EngineSpec::Lut, &design).unwrap();
        let model = resolve(EngineSpec::Model, &design).unwrap();
        let bitsim = resolve(EngineSpec::Bitsim, &design).unwrap();
        let a = lut.process_batch(&tiles);
        let b = model.process_batch(&tiles);
        let c = bitsim.process_batch(&tiles);
        for ((x, y), z) in a.iter().zip(b.iter()).zip(c.iter()) {
            assert_eq!(x.data, y.data, "lut vs model");
            assert_eq!(x.data, z.data, "lut vs bitsim");
        }
    }

    #[test]
    fn lut_rejects_wide_designs_model_accepts_them() {
        let wide: DesignSpec = "proposed@16".parse().unwrap();
        assert!(resolve(EngineSpec::Lut, &wide).is_err());
        let engine = resolve(EngineSpec::Model, &wide).unwrap();
        assert!(engine.name().contains("Proposed"));
    }

    /// The bitsim engine serves any width in 8..=31 and rejects the rest
    /// (a 4-bit design cannot carry the pre-shifted pixel operand).
    #[test]
    fn bitsim_width_bounds() {
        let wide: DesignSpec = "proposed@16".parse().unwrap();
        let engine = resolve(EngineSpec::Bitsim, &wide).unwrap();
        assert!(engine.name().starts_with("bitsim:"));
        let live = resolve(EngineSpec::BitsimLive, &wide).unwrap();
        assert!(live.name().starts_with("bitsim-live:"));
        let narrow: DesignSpec = "proposed@4".parse().unwrap();
        assert!(resolve(EngineSpec::Bitsim, &narrow).is_err());
        assert!(resolve(EngineSpec::BitsimLive, &narrow).is_err());
    }

    #[test]
    fn resolve_str_parses_both_specs() {
        let engine = resolve_str("model", "d2@8:trunc=none").unwrap();
        assert!(engine.name().starts_with("model:"));
        assert!(resolve_str("turbo", "proposed@8").is_err());
        assert!(resolve_str("lut", "nonsense spec").is_err());
    }
}

//! L3 serving coordinator — the hardware-oriented streaming framework of
//! paper Fig. 8, generalised into a deployable multi-design service.
//!
//! Images arrive as jobs; the coordinator splits them into fixed-size
//! tiles with a 1-pixel halo (the receptive field of the 3×3 Laplacian),
//! pushes them through a *bounded* queue (backpressure, the role the
//! paper's line buffers play), batches tiles dynamically, and dispatches
//! batches to [`engine::TileEngine`]s — the in-process LUT MAC path, the
//! functional-model and row-buffer reference paths, or the AOT-compiled
//! JAX/Pallas executable via PJRT ([`crate::runtime`]). Outputs are
//! reassembled in-place and each job's latency is recorded.
//!
//! One coordinator serves a *set of named engines* (typically one per
//! multiplier design, resolved from spec strings through
//! [`engines::resolve`]); each job may select its engine by name **and
//! its operator** ([`crate::image::ops::Operator`] — Sobel, Prewitt,
//! Scharr, Roberts, sharpen, Gaussian, or the classic Laplacian) at
//! submit time, and [`MetricsSnapshot`] carries per-design rows — a
//! single service instance A/B-tests exact vs. approximate designs
//! across heterogeneous workloads under load.
//!
//! Beyond image tiles, the same queue and worker fleet serve
//! **quantized-inference jobs** ([`Coordinator::submit_gemm`] /
//! [`Coordinator::submit_conv2d`]): an i8×i8 GEMM is split into
//! output-stationary row × column block tasks ([`crate::nn`]) and dispatched to
//! any engine advertising an [`engine::NnBackend`] (the product-table
//! engines and the functional-model reference; rowbuf/PJRT are
//! conv-datapath-only and reject nn jobs at submit time).
//!
//! The pipeline is fault tolerant end-to-end: worker batches run under
//! `catch_unwind` (a panicking engine fails only its own jobs, as
//! [`job::JobError`]s delivered on the reply channel — `wait()` never
//! hangs), an optional watchdog enforces per-job deadlines, per-engine
//! circuit breakers trip after consecutive failures and either reject or
//! reroute to a configured fallback engine, and [`fault::FaultEngine`]
//! injects deterministic panic/delay/wrong-output faults
//! (`fault/<plan>/<engine>` spec strings) to drive chaos tests.
//!
//! ```text
//!  submit(img, key?) ─┬─ tiler ─▶ [bounded tile queue] ─▶ batcher ─▶ engine[key] ─┐
//!                     │ (breaker/fallback route)          (worker × W,            │
//!                     │                                    catch_unwind)          │
//!                     └────────── reassembly ◀── watchdog deadline sweep ────────┘
//! ```
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod engine;
pub mod engines;
pub mod fault;
pub mod job;
pub mod metrics;
pub mod service;
pub mod tiler;

pub use engine::{
    BitsimLiveTileEngine, BitsimTileEngine, DualModeTileEngine, LutTileEngine, ModelTileEngine,
    NnBackend, Quality, RowbufTileEngine, TileEngine,
};
pub use engines::{resolve, resolve_str, resolve_with_fallback, EngineSpec};
pub use fault::{silence_worker_panics, FaultEngine, FaultKind, FaultPlan};
pub use job::{EdgeJob, GemmResult, JobError, JobResult};
pub use metrics::{
    BreakerDecision, BreakerState, EngineMetricsSnapshot, FailKind, Metrics, MetricsSnapshot,
};
pub use service::{Coordinator, CoordinatorConfig, GemmHandle, JobHandle};
pub use tiler::{reassemble, tile_image, Tile, TileOut, TILE_CORE, TILE_HALO, TILE_IN};

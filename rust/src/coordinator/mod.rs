//! L3 serving coordinator — the hardware-oriented streaming framework of
//! paper Fig. 8, generalised into a deployable service.
//!
//! Images arrive as jobs; the coordinator splits them into fixed-size
//! tiles with a 1-pixel halo (the receptive field of the 3×3 Laplacian),
//! pushes them through a *bounded* queue (backpressure, the role the
//! paper's line buffers play), batches tiles dynamically, and dispatches
//! batches to a [`engine::TileEngine`] — either the in-process LUT MAC
//! path or the AOT-compiled JAX/Pallas executable via PJRT
//! ([`crate::runtime`]). Outputs are reassembled in-place and each job's
//! latency is recorded.
//!
//! ```text
//!  submit(img) ─┬─ tiler ─▶ [bounded tile queue] ─▶ batcher ─▶ engine ─┐
//!               │                                   (worker × W)      │
//!               └──────────────── reassembly ◀──────────────────────── ┘
//! ```

pub mod engine;
pub mod job;
pub mod metrics;
pub mod service;
pub mod tiler;

pub use engine::{DualModeTileEngine, LutTileEngine, ModelTileEngine, Quality, TileEngine};
pub use job::{EdgeJob, JobResult};
pub use metrics::MetricsSnapshot;
pub use service::{Coordinator, CoordinatorConfig};
pub use tiler::{reassemble, tile_image, Tile, TileOut, TILE_CORE, TILE_HALO, TILE_IN};

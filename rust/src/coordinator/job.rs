//! Job types exchanged with the coordinator.

use crate::image::Image;
use crate::nn::MatI32;
use std::time::Duration;

/// An edge-detection request.
#[derive(Debug, Clone)]
pub struct EdgeJob {
    pub id: u64,
    pub image: Image,
}

/// A completed job.
#[derive(Debug)]
pub struct JobResult {
    pub id: u64,
    pub edges: Image,
    /// Wall-clock latency from submit to completion.
    pub latency: Duration,
    /// Number of tiles the job was split into.
    pub tiles: usize,
}

/// A completed quantized-inference (GEMM/conv2d) job: the raw i32
/// accumulator matrix (callers apply the layer epilogue — see
/// [`crate::nn::Conv2d::epilogue`]).
#[derive(Debug)]
pub struct GemmResult {
    pub id: u64,
    /// `C = A × B` accumulators through the engine's multiplier design.
    pub out: MatI32,
    /// Wall-clock latency from submit to completion.
    pub latency: Duration,
    /// Number of row-block tasks the GEMM was split into.
    pub blocks: usize,
}

//! Job types exchanged with the coordinator.

use crate::image::Image;
use std::time::Duration;

/// An edge-detection request.
#[derive(Debug, Clone)]
pub struct EdgeJob {
    pub id: u64,
    pub image: Image,
}

/// A completed job.
#[derive(Debug)]
pub struct JobResult {
    pub id: u64,
    pub edges: Image,
    /// Wall-clock latency from submit to completion.
    pub latency: Duration,
    /// Number of tiles the job was split into.
    pub tiles: usize,
}

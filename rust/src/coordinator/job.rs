//! Job types exchanged with the coordinator, including the failure
//! taxonomy every `wait()` can surface.

use crate::image::Image;
use crate::nn::MatI32;
use std::fmt;
use std::time::Duration;

/// An edge-detection request.
#[derive(Debug, Clone)]
pub struct EdgeJob {
    pub id: u64,
    pub image: Image,
}

/// Why a job failed. Every submit/wait path returns one of these instead
/// of panicking or hanging; the server maps each variant to a distinct
/// SFC/1 `ERR` code.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobError {
    /// The request was rejected at submit time (unknown engine,
    /// unsupported operator, shape mismatch, ...). Carries the
    /// human-readable reason.
    Invalid(String),
    /// The engine panicked or violated its output contract while
    /// processing this job, or its circuit breaker is open.
    EngineFailed { engine: String, detail: String },
    /// The job exceeded its deadline and was failed by the watchdog, or
    /// `wait_timeout` elapsed.
    Deadline { limit_ms: u64 },
    /// The coordinator's intake was closed before the job could be
    /// enqueued (submit after `shutdown`).
    Shutdown,
    /// The reply channel closed without delivering a result (the
    /// coordinator was dropped mid-job).
    QueueClosed,
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            // Submit-time rejections keep their raw message so existing
            // "unknown engine ..." / "does not support ..." diagnostics
            // (and their server-side classification) are unchanged.
            JobError::Invalid(msg) => write!(f, "{msg}"),
            JobError::EngineFailed { engine, detail } => {
                write!(f, "engine {engine:?} failed: {detail}")
            }
            JobError::Deadline { limit_ms } => {
                write!(f, "job exceeded its {limit_ms} ms deadline")
            }
            JobError::Shutdown => write!(f, "coordinator is shut down; job not accepted"),
            JobError::QueueClosed => {
                write!(f, "coordinator dropped before completing the job")
            }
        }
    }
}

impl std::error::Error for JobError {}

impl From<JobError> for crate::util::error::Error {
    fn from(e: JobError) -> Self {
        crate::util::error::Error::msg(e.to_string())
    }
}

/// A completed job.
#[derive(Debug)]
pub struct JobResult {
    pub id: u64,
    pub edges: Image,
    /// Wall-clock latency from submit to completion.
    pub latency: Duration,
    /// Number of tiles the job was split into.
    pub tiles: usize,
    /// Name of the engine that actually served the job (differs from the
    /// requested engine when the breaker rerouted it to a fallback).
    pub engine: String,
    /// `true` when the circuit breaker rerouted this job to a fallback
    /// engine — the result may use a different multiplier design than
    /// requested (exactness annotation).
    pub rerouted: bool,
}

/// A completed quantized-inference (GEMM/conv2d) job: the raw i32
/// accumulator matrix (callers apply the layer epilogue — see
/// [`crate::nn::Conv2d::epilogue`]).
#[derive(Debug)]
pub struct GemmResult {
    pub id: u64,
    /// `C = A × B` accumulators through the engine's multiplier design.
    pub out: MatI32,
    /// Wall-clock latency from submit to completion.
    pub latency: Duration,
    /// Number of row-block tasks the GEMM was split into.
    pub blocks: usize,
    /// Name of the engine that actually served the job.
    pub engine: String,
    /// `true` when the breaker rerouted this job to a fallback engine.
    pub rerouted: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invalid_displays_raw_message() {
        let e = JobError::Invalid("unknown engine \"zap\"".into());
        assert_eq!(e.to_string(), "unknown engine \"zap\"");
    }

    #[test]
    fn variants_render_distinct_messages() {
        let msgs = [
            JobError::EngineFailed { engine: "bitsim".into(), detail: "boom".into() }.to_string(),
            JobError::Deadline { limit_ms: 250 }.to_string(),
            JobError::Shutdown.to_string(),
            JobError::QueueClosed.to_string(),
        ];
        assert!(msgs[0].contains("bitsim") && msgs[0].contains("boom"));
        assert!(msgs[1].contains("250 ms"));
        assert!(msgs[2].contains("shut down"));
        assert!(msgs[3].contains("dropped"));
        let uniq: std::collections::HashSet<_> = msgs.iter().collect();
        assert_eq!(uniq.len(), msgs.len());
    }

    #[test]
    fn converts_into_crate_error() {
        let e: crate::util::error::Error = JobError::Shutdown.into();
        assert!(e.to_string().contains("shut down"));
    }
}

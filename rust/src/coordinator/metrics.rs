//! Coordinator metrics: counters and latency/batch-size distributions,
//! kept per named engine (per design) and aggregated across the fleet.
//!
//! Storage is **bounded**: a production coordinator serves an unbounded
//! job stream, so per-engine job latencies are kept in a fixed-capacity
//! [`Reservoir`] (Vitter's Algorithm R — every recorded latency has
//! equal probability of being retained, so the p50/p90/p99 read from
//! the sample converge on the stream quantiles), and batch sizes reduce
//! to running sums. Memory per engine is `O(RESERVOIR_CAP)` regardless
//! of how many jobs have been served. Units are generic: edge jobs
//! record tiles, quantized-inference jobs record GEMM blocks — both
//! land in the same per-engine rows.

use crate::util::prng::Xoshiro256;
use crate::util::stats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Latency samples retained per engine. 512 samples bound the p99
/// estimate's standard error near 1.5 percentile points while the whole
/// reservoir stays two cache pages.
pub const RESERVOIR_CAP: usize = 512;

/// Fixed-capacity uniform sample of a stream (Algorithm R). The
/// replacement PRNG is deterministic per reservoir, so metric snapshots
/// are reproducible for a fixed job order.
struct Reservoir {
    samples: Vec<f64>,
    seen: u64,
    rng: Xoshiro256,
}

impl Reservoir {
    fn new(seed: u64) -> Self {
        Self { samples: Vec::new(), seen: 0, rng: Xoshiro256::seeded(seed) }
    }

    fn record(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(v);
        } else {
            // Keep each of the `seen` values with probability CAP/seen.
            let j = self.rng.below(self.seen);
            if (j as usize) < RESERVOIR_CAP {
                self.samples[j as usize] = v;
            }
        }
    }
}

/// Live metrics of a running coordinator. One row per named engine;
/// the aggregate view sums/merges across rows.
pub struct Metrics {
    inner: Mutex<Vec<EngineInner>>,
    /// Jobs admitted at submit time (conv + GEMM, including empty GEMMs
    /// that complete without dispatching any task). Lock-free: recorded
    /// on the submit path, outside the per-engine rows.
    accepted: AtomicU64,
    /// Submissions rejected at validation time (unknown engine,
    /// unsupported operator, shape/capability errors). Network-level
    /// rejections (admission control, quotas) are counted separately by
    /// the server front-end.
    rejected: AtomicU64,
}

struct EngineInner {
    name: String,
    jobs_completed: u64,
    tiles_processed: u64,
    batches: u64,
    latencies_ms: Reservoir,
    busy: Duration,
}

impl EngineInner {
    fn new(name: String, seed: u64) -> Self {
        Self {
            name,
            jobs_completed: 0,
            tiles_processed: 0,
            batches: 0,
            latencies_ms: Reservoir::new(seed),
            busy: Duration::ZERO,
        }
    }
}

/// Point-in-time copy of one engine's metrics.
#[derive(Debug, Clone)]
pub struct EngineMetricsSnapshot {
    /// The engine's registered name (the design/engine key jobs select).
    pub name: String,
    pub jobs_completed: u64,
    /// Work units processed: conv tiles plus GEMM row-blocks.
    pub tiles_processed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    /// Job-latency quantiles, read from the engine's bounded reservoir
    /// (exact while ≤ [`RESERVOIR_CAP`] jobs have completed, a uniform
    /// sample estimate beyond that).
    pub latency_p50_ms: f64,
    pub latency_p90_ms: f64,
    pub latency_p99_ms: f64,
    pub engine_busy: Duration,
}

/// Point-in-time copy of the metrics: fleet-wide aggregates plus one
/// [`EngineMetricsSnapshot`] row per named engine.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Cumulative jobs admitted at submit time.
    pub jobs_accepted: u64,
    /// Cumulative submissions rejected at validation time.
    pub jobs_rejected: u64,
    /// Work units currently waiting in the bounded tile queue. Filled by
    /// [`super::Coordinator::metrics`] (a bare [`Metrics::snapshot`]
    /// reports 0 — the queue belongs to the coordinator).
    pub queue_depth: usize,
    pub jobs_completed: u64,
    pub tiles_processed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub latency_p50_ms: f64,
    pub latency_p90_ms: f64,
    pub latency_p99_ms: f64,
    pub engine_busy: Duration,
    /// Per-design/engine rows, in engine registration order.
    pub per_engine: Vec<EngineMetricsSnapshot>,
}

impl Metrics {
    /// Metrics tracking one row per engine name.
    pub fn new(engine_names: Vec<String>) -> Self {
        assert!(!engine_names.is_empty());
        Self {
            inner: Mutex::new(
                engine_names
                    .into_iter()
                    .enumerate()
                    // Distinct deterministic seed per row so reservoirs
                    // don't share replacement streams.
                    .map(|(i, n)| EngineInner::new(n, 0x5fc0_0db5 ^ i as u64))
                    .collect(),
            ),
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    /// Count one admitted submission (O(1), lock-free).
    pub fn record_accept(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one submission rejected at validation time (O(1), lock-free).
    pub fn record_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, engine: usize, size: usize, busy: Duration) {
        let mut rows = self.inner.lock().unwrap();
        let m = &mut rows[engine];
        m.batches += 1;
        m.tiles_processed += size as u64;
        m.busy += busy;
    }

    pub fn record_job(&self, engine: usize, latency: Duration) {
        let mut rows = self.inner.lock().unwrap();
        let m = &mut rows[engine];
        m.jobs_completed += 1;
        m.latencies_ms.record(latency.as_secs_f64() * 1e3);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let rows = self.inner.lock().unwrap();
        let mean_batch = |tiles: u64, batches: u64| {
            if batches == 0 {
                0.0
            } else {
                tiles as f64 / batches as f64
            }
        };
        let per_engine: Vec<EngineMetricsSnapshot> = rows
            .iter()
            .map(|m| {
                let (p50, p90, p99) = stats::p50_p90_p99(&m.latencies_ms.samples);
                EngineMetricsSnapshot {
                    name: m.name.clone(),
                    jobs_completed: m.jobs_completed,
                    tiles_processed: m.tiles_processed,
                    batches: m.batches,
                    mean_batch_size: mean_batch(m.tiles_processed, m.batches),
                    latency_p50_ms: p50,
                    latency_p90_ms: p90,
                    latency_p99_ms: p99,
                    engine_busy: m.busy,
                }
            })
            .collect();
        // Aggregate quantiles merge the per-engine reservoir samples —
        // a uniform sample of the whole stream when loads are balanced,
        // and at worst a per-engine-weighted estimate.
        let all_latencies: Vec<f64> =
            rows.iter().flat_map(|m| m.latencies_ms.samples.iter().copied()).collect();
        let (p50, p90, p99) = stats::p50_p90_p99(&all_latencies);
        let tiles: u64 = rows.iter().map(|m| m.tiles_processed).sum();
        let batches: u64 = rows.iter().map(|m| m.batches).sum();
        MetricsSnapshot {
            jobs_accepted: self.accepted.load(Ordering::Relaxed),
            jobs_rejected: self.rejected.load(Ordering::Relaxed),
            queue_depth: 0,
            jobs_completed: rows.iter().map(|m| m.jobs_completed).sum(),
            tiles_processed: tiles,
            batches,
            mean_batch_size: mean_batch(tiles, batches),
            latency_p50_ms: p50,
            latency_p90_ms: p90,
            latency_p99_ms: p99,
            engine_busy: rows.iter().map(|m| m.busy).sum(),
            per_engine,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new(vec!["only".into()]);
        m.record_batch(0, 4, Duration::from_millis(2));
        m.record_batch(0, 8, Duration::from_millis(3));
        m.record_job(0, Duration::from_millis(10));
        m.record_job(0, Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert_eq!(s.tiles_processed, 12);
        assert_eq!(s.jobs_completed, 2);
        assert!((s.mean_batch_size - 6.0).abs() < 1e-12);
        assert!(s.latency_p50_ms >= 10.0 && s.latency_p99_ms <= 20.0 + 1e-9);
        assert_eq!(s.engine_busy, Duration::from_millis(5));
        assert_eq!(s.per_engine.len(), 1);
        assert_eq!(s.per_engine[0].name, "only");
    }

    #[test]
    fn per_engine_rows_stay_separate() {
        let m = Metrics::new(vec!["approx".into(), "exact".into()]);
        m.record_batch(0, 4, Duration::from_millis(1));
        m.record_batch(1, 2, Duration::from_millis(5));
        m.record_job(0, Duration::from_millis(10));
        m.record_job(0, Duration::from_millis(30));
        m.record_job(1, Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.jobs_completed, 3);
        assert_eq!(s.tiles_processed, 6);
        let approx = &s.per_engine[0];
        let exact = &s.per_engine[1];
        assert_eq!(approx.name, "approx");
        assert_eq!(approx.jobs_completed, 2);
        assert_eq!(approx.tiles_processed, 4);
        assert!(approx.latency_p50_ms >= 10.0 && approx.latency_p99_ms <= 30.0 + 1e-9);
        assert_eq!(exact.name, "exact");
        assert_eq!(exact.jobs_completed, 1);
        assert_eq!(exact.batches, 1);
        assert!((exact.mean_batch_size - 2.0).abs() < 1e-12);
        assert!((exact.latency_p50_ms - 20.0).abs() < 1e-9, "single sample is its own p50");
        assert_eq!(exact.engine_busy, Duration::from_millis(5));
    }

    /// Below the reservoir capacity the quantiles are exact: every
    /// recorded latency is retained.
    #[test]
    fn quantiles_are_exact_below_capacity() {
        let m = Metrics::new(vec!["e".into()]);
        for i in 1..=100u64 {
            m.record_job(0, Duration::from_millis(i));
        }
        let s = m.snapshot();
        assert!((s.latency_p50_ms - 50.5).abs() < 1.0, "p50 {}", s.latency_p50_ms);
        assert!(s.latency_p99_ms > 98.0 && s.latency_p99_ms <= 100.0);
    }

    /// Past capacity, memory stays bounded and the sampled quantiles
    /// still land inside the stream's range (here: a uniform ramp, so
    /// p50 of any uniform subsample concentrates near the midpoint).
    #[test]
    fn reservoir_bounds_memory_past_capacity() {
        let m = Metrics::new(vec!["e".into()]);
        let total = RESERVOIR_CAP as u64 * 20;
        for i in 1..=total {
            m.record_job(0, Duration::from_millis(i));
        }
        let rows = m.inner.lock().unwrap();
        assert_eq!(rows[0].latencies_ms.samples.len(), RESERVOIR_CAP);
        assert_eq!(rows[0].latencies_ms.seen, total);
        drop(rows);
        let s = m.snapshot();
        assert_eq!(s.jobs_completed, total);
        let mid = total as f64 / 2.0;
        assert!(
            (s.latency_p50_ms - mid).abs() < mid * 0.25,
            "sampled p50 {} should concentrate near {mid}",
            s.latency_p50_ms
        );
        assert!(s.latency_p99_ms <= total as f64 && s.latency_p99_ms > mid);
    }

    /// Accepted/rejected are cumulative fleet-level counters, independent
    /// of the per-engine rows, and a bare snapshot reports queue depth 0
    /// (the coordinator fills the real value).
    #[test]
    fn accept_reject_counters_accumulate() {
        let m = Metrics::new(vec!["e".into()]);
        assert_eq!((m.snapshot().jobs_accepted, m.snapshot().jobs_rejected), (0, 0));
        m.record_accept();
        m.record_accept();
        m.record_reject();
        let s = m.snapshot();
        assert_eq!(s.jobs_accepted, 2);
        assert_eq!(s.jobs_rejected, 1);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.jobs_completed, 0, "accept/reject do not touch completion");
    }

    #[test]
    fn empty_engine_rows_report_zero_quantiles() {
        let m = Metrics::new(vec!["a".into(), "idle".into()]);
        m.record_job(0, Duration::from_millis(5));
        let s = m.snapshot();
        assert_eq!(s.per_engine[1].jobs_completed, 0);
        assert_eq!(s.per_engine[1].mean_batch_size, 0.0);
    }
}

//! Coordinator metrics: counters and latency/batch-size distributions.

use crate::util::stats;
use std::sync::Mutex;
use std::time::Duration;

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    jobs_completed: u64,
    tiles_processed: u64,
    batches: u64,
    batch_sizes: Vec<f64>,
    job_latencies_ms: Vec<f64>,
    busy: Duration,
}

/// Point-in-time copy of the metrics.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub jobs_completed: u64,
    pub tiles_processed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub latency_p50_ms: f64,
    pub latency_p90_ms: f64,
    pub latency_p99_ms: f64,
    pub engine_busy: Duration,
}

impl Metrics {
    pub fn record_batch(&self, size: usize, busy: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.tiles_processed += size as u64;
        m.batch_sizes.push(size as f64);
        m.busy += busy;
    }

    pub fn record_job(&self, latency: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.jobs_completed += 1;
        m.job_latencies_ms.push(latency.as_secs_f64() * 1e3);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let (p50, p90, p99) = stats::p50_p90_p99(&m.job_latencies_ms);
        MetricsSnapshot {
            jobs_completed: m.jobs_completed,
            tiles_processed: m.tiles_processed,
            batches: m.batches,
            mean_batch_size: stats::mean(&m.batch_sizes),
            latency_p50_ms: p50,
            latency_p90_ms: p90,
            latency_p99_ms: p99,
            engine_busy: m.busy,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.record_batch(4, Duration::from_millis(2));
        m.record_batch(8, Duration::from_millis(3));
        m.record_job(Duration::from_millis(10));
        m.record_job(Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert_eq!(s.tiles_processed, 12);
        assert_eq!(s.jobs_completed, 2);
        assert!((s.mean_batch_size - 6.0).abs() < 1e-12);
        assert!(s.latency_p50_ms >= 10.0 && s.latency_p99_ms <= 20.0 + 1e-9);
        assert_eq!(s.engine_busy, Duration::from_millis(5));
    }
}

//! Coordinator metrics: counters and latency/batch-size distributions,
//! kept per named engine (per design) and aggregated across the fleet.

use crate::util::stats;
use std::sync::Mutex;
use std::time::Duration;

/// Live metrics of a running coordinator. One row per named engine;
/// the aggregate view sums/merges across rows.
pub struct Metrics {
    inner: Mutex<Vec<EngineInner>>,
}

struct EngineInner {
    name: String,
    jobs_completed: u64,
    tiles_processed: u64,
    batches: u64,
    batch_sizes: Vec<f64>,
    job_latencies_ms: Vec<f64>,
    busy: Duration,
}

impl EngineInner {
    fn new(name: String) -> Self {
        Self {
            name,
            jobs_completed: 0,
            tiles_processed: 0,
            batches: 0,
            batch_sizes: Vec::new(),
            job_latencies_ms: Vec::new(),
            busy: Duration::ZERO,
        }
    }
}

/// Point-in-time copy of one engine's metrics.
#[derive(Debug, Clone)]
pub struct EngineMetricsSnapshot {
    /// The engine's registered name (the design/engine key jobs select).
    pub name: String,
    pub jobs_completed: u64,
    pub tiles_processed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub latency_p50_ms: f64,
    pub latency_p90_ms: f64,
    pub latency_p99_ms: f64,
    pub engine_busy: Duration,
}

/// Point-in-time copy of the metrics: fleet-wide aggregates plus one
/// [`EngineMetricsSnapshot`] row per named engine.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub jobs_completed: u64,
    pub tiles_processed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub latency_p50_ms: f64,
    pub latency_p90_ms: f64,
    pub latency_p99_ms: f64,
    pub engine_busy: Duration,
    /// Per-design/engine rows, in engine registration order.
    pub per_engine: Vec<EngineMetricsSnapshot>,
}

impl Metrics {
    /// Metrics tracking one row per engine name.
    pub fn new(engine_names: Vec<String>) -> Self {
        assert!(!engine_names.is_empty());
        Self {
            inner: Mutex::new(engine_names.into_iter().map(EngineInner::new).collect()),
        }
    }

    pub fn record_batch(&self, engine: usize, size: usize, busy: Duration) {
        let mut rows = self.inner.lock().unwrap();
        let m = &mut rows[engine];
        m.batches += 1;
        m.tiles_processed += size as u64;
        m.batch_sizes.push(size as f64);
        m.busy += busy;
    }

    pub fn record_job(&self, engine: usize, latency: Duration) {
        let mut rows = self.inner.lock().unwrap();
        let m = &mut rows[engine];
        m.jobs_completed += 1;
        m.job_latencies_ms.push(latency.as_secs_f64() * 1e3);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let rows = self.inner.lock().unwrap();
        let per_engine: Vec<EngineMetricsSnapshot> = rows
            .iter()
            .map(|m| {
                let (p50, p90, p99) = stats::p50_p90_p99(&m.job_latencies_ms);
                EngineMetricsSnapshot {
                    name: m.name.clone(),
                    jobs_completed: m.jobs_completed,
                    tiles_processed: m.tiles_processed,
                    batches: m.batches,
                    mean_batch_size: stats::mean(&m.batch_sizes),
                    latency_p50_ms: p50,
                    latency_p90_ms: p90,
                    latency_p99_ms: p99,
                    engine_busy: m.busy,
                }
            })
            .collect();
        let all_batches: Vec<f64> =
            rows.iter().flat_map(|m| m.batch_sizes.iter().copied()).collect();
        let all_latencies: Vec<f64> =
            rows.iter().flat_map(|m| m.job_latencies_ms.iter().copied()).collect();
        let (p50, p90, p99) = stats::p50_p90_p99(&all_latencies);
        MetricsSnapshot {
            jobs_completed: rows.iter().map(|m| m.jobs_completed).sum(),
            tiles_processed: rows.iter().map(|m| m.tiles_processed).sum(),
            batches: rows.iter().map(|m| m.batches).sum(),
            mean_batch_size: stats::mean(&all_batches),
            latency_p50_ms: p50,
            latency_p90_ms: p90,
            latency_p99_ms: p99,
            engine_busy: rows.iter().map(|m| m.busy).sum(),
            per_engine,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new(vec!["only".into()]);
        m.record_batch(0, 4, Duration::from_millis(2));
        m.record_batch(0, 8, Duration::from_millis(3));
        m.record_job(0, Duration::from_millis(10));
        m.record_job(0, Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert_eq!(s.tiles_processed, 12);
        assert_eq!(s.jobs_completed, 2);
        assert!((s.mean_batch_size - 6.0).abs() < 1e-12);
        assert!(s.latency_p50_ms >= 10.0 && s.latency_p99_ms <= 20.0 + 1e-9);
        assert_eq!(s.engine_busy, Duration::from_millis(5));
        assert_eq!(s.per_engine.len(), 1);
        assert_eq!(s.per_engine[0].name, "only");
    }

    #[test]
    fn per_engine_rows_stay_separate() {
        let m = Metrics::new(vec!["approx".into(), "exact".into()]);
        m.record_batch(0, 4, Duration::from_millis(1));
        m.record_batch(1, 2, Duration::from_millis(5));
        m.record_job(0, Duration::from_millis(10));
        m.record_job(0, Duration::from_millis(30));
        m.record_job(1, Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.jobs_completed, 3);
        assert_eq!(s.tiles_processed, 6);
        let approx = &s.per_engine[0];
        let exact = &s.per_engine[1];
        assert_eq!(approx.name, "approx");
        assert_eq!(approx.jobs_completed, 2);
        assert_eq!(approx.tiles_processed, 4);
        assert_eq!(exact.name, "exact");
        assert_eq!(exact.jobs_completed, 1);
        assert_eq!(exact.batches, 1);
        assert!((exact.mean_batch_size - 2.0).abs() < 1e-12);
        assert_eq!(exact.engine_busy, Duration::from_millis(5));
    }
}

//! Coordinator metrics: counters and latency/batch-size distributions,
//! kept per named engine (per design) and aggregated across the fleet.
//!
//! Storage is **bounded**: a production coordinator serves an unbounded
//! job stream, so per-engine job latencies are kept in a fixed-capacity
//! [`Reservoir`] (Vitter's Algorithm R — every recorded latency has
//! equal probability of being retained, so the p50/p90/p99 read from
//! the sample converge on the stream quantiles), and batch sizes reduce
//! to running sums. Memory per engine is `O(RESERVOIR_CAP)` regardless
//! of how many jobs have been served. Units are generic: edge jobs
//! record tiles, quantized-inference jobs record GEMM blocks — both
//! land in the same per-engine rows.

use crate::obs::hist::{HistSnapshot, Stage, StageHists};
use crate::obs::quality::{QualityStats, SampleGate};
use crate::util::prng::Xoshiro256;
use crate::util::stats;
use crate::util::sync::lock;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Latency samples retained per engine. 512 samples bound the p99
/// estimate's standard error near 1.5 percentile points while the whole
/// reservoir stays two cache pages.
pub const RESERVOIR_CAP: usize = 512;

/// Fixed-capacity uniform sample of a stream (Algorithm R). The
/// replacement PRNG is deterministic per reservoir, so metric snapshots
/// are reproducible for a fixed job order.
struct Reservoir {
    samples: Vec<f64>,
    seen: u64,
    rng: Xoshiro256,
}

impl Reservoir {
    fn new(seed: u64) -> Self {
        Self { samples: Vec::new(), seen: 0, rng: Xoshiro256::seeded(seed) }
    }

    fn record(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(v);
        } else {
            // Keep each of the `seen` values with probability CAP/seen.
            let j = self.rng.below(self.seen);
            if (j as usize) < RESERVOIR_CAP {
                self.samples[j as usize] = v;
            }
        }
    }
}

/// What kind of failure is being recorded against an engine. Each kind
/// feeds its own counter; all of them feed the circuit breaker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailKind {
    /// The engine panicked (caught by the worker's `catch_unwind`) or
    /// violated its output contract.
    Panic,
    /// The watchdog failed the job for exceeding its deadline.
    Deadline,
    /// Any other engine-attributed failure.
    Error,
}

/// Public view of an engine's circuit-breaker state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: jobs route normally.
    Closed,
    /// Cooling down after the probe window opened: exactly one probe job
    /// is allowed through; everything else is denied/rerouted.
    HalfOpen,
    /// Tripped: jobs are denied (or rerouted to a fallback) until the
    /// cooldown elapses.
    Open,
}

impl BreakerState {
    /// Stable numeric encoding for the Prometheus gauge
    /// (0 = closed, 1 = half-open, 2 = open).
    pub fn code(self) -> u64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::HalfOpen => 1,
            BreakerState::Open => 2,
        }
    }
}

impl fmt::Display for BreakerState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BreakerState::Closed => "closed",
            BreakerState::HalfOpen => "half-open",
            BreakerState::Open => "open",
        })
    }
}

/// Routing decision from [`Metrics::breaker_allow`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerDecision {
    /// Breaker closed: route normally.
    Allow,
    /// Breaker just transitioned open → half-open: this job is the probe.
    Probe,
    /// Breaker open (or a probe is already in flight): do not route here.
    Deny,
}

/// Internal breaker state machine; `Open` remembers when the cooldown
/// elapses so `breaker_allow` can promote it to a half-open probe.
enum Breaker {
    Closed,
    Open { until: Instant },
    HalfOpen,
}

/// Live metrics of a running coordinator. One row per named engine;
/// the aggregate view sums/merges across rows.
pub struct Metrics {
    inner: Mutex<Vec<EngineInner>>,
    /// Consecutive failures that trip an engine's breaker; `0` disables
    /// the breaker entirely.
    breaker_threshold: u32,
    /// How long a tripped breaker stays open before allowing a half-open
    /// probe.
    breaker_cooldown: Duration,
    /// Jobs admitted at submit time (conv + GEMM, including empty GEMMs
    /// that complete without dispatching any task). Lock-free: recorded
    /// on the submit path, outside the per-engine rows.
    accepted: AtomicU64,
    /// Submissions rejected at validation time (unknown engine,
    /// unsupported operator, shape/capability errors). Network-level
    /// rejections (admission control, quotas) are counted separately by
    /// the server front-end.
    rejected: AtomicU64,
    /// Live quality sampler window (`0` = off). Read lock-free on the
    /// worker fast path so disabled sampling costs one relaxed load.
    quality_sample_n: AtomicU64,
}

struct EngineInner {
    name: String,
    jobs_completed: u64,
    jobs_failed: u64,
    panics_caught: u64,
    deadline_misses: u64,
    consecutive_failures: u32,
    breaker: Breaker,
    tiles_processed: u64,
    batches: u64,
    latencies_ms: Reservoir,
    busy: Duration,
    /// Per-stage log₂ latency histograms (queue wait / compute / e2e).
    stages: StageHists,
    /// Deterministic 1-in-N admission for the quality sampler.
    quality_gate: SampleGate,
    /// Running shadow-recompute error totals.
    quality: QualityStats,
}

/// Seed base for per-engine quality-sampler gates (xor'd with the row
/// index, like the reservoir seeds).
const QUALITY_GATE_SEED: u64 = 0x0b5e_9a7e;

impl EngineInner {
    fn new(name: String, seed: u64) -> Self {
        Self {
            name,
            jobs_completed: 0,
            jobs_failed: 0,
            panics_caught: 0,
            deadline_misses: 0,
            consecutive_failures: 0,
            breaker: Breaker::Closed,
            tiles_processed: 0,
            batches: 0,
            latencies_ms: Reservoir::new(seed),
            busy: Duration::ZERO,
            stages: StageHists::new(),
            quality_gate: SampleGate::new(0, seed ^ QUALITY_GATE_SEED),
            quality: QualityStats::default(),
        }
    }
}

/// Point-in-time copy of one engine's metrics.
#[derive(Debug, Clone)]
pub struct EngineMetricsSnapshot {
    /// The engine's registered name (the design/engine key jobs select).
    pub name: String,
    pub jobs_completed: u64,
    /// Jobs that ended in a [`super::JobError`] attributed to this engine
    /// (panics, contract violations, deadline misses, open breaker).
    pub jobs_failed: u64,
    /// Engine panics caught by the worker's `catch_unwind`.
    pub panics_caught: u64,
    /// Jobs failed by the watchdog for exceeding their deadline.
    pub deadline_misses: u64,
    /// Circuit-breaker state at snapshot time.
    pub breaker: BreakerState,
    /// Work units processed: conv tiles plus GEMM row-blocks.
    pub tiles_processed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    /// Job-latency quantiles, read from the engine's bounded reservoir
    /// (exact while ≤ [`RESERVOIR_CAP`] jobs have completed, a uniform
    /// sample estimate beyond that).
    pub latency_p50_ms: f64,
    pub latency_p90_ms: f64,
    pub latency_p99_ms: f64,
    pub engine_busy: Duration,
    /// Per-stage latency histograms, [`Stage::ALL`] order
    /// (queue_wait, compute, e2e) — the `/metrics` histogram series.
    pub stages: [HistSnapshot; 3],
    /// Live quality-sampler totals; `pairs == 0` when sampling is off
    /// or the engine has no shadow-evaluable backend.
    pub quality: QualityStats,
}

/// Point-in-time copy of the metrics: fleet-wide aggregates plus one
/// [`EngineMetricsSnapshot`] row per named engine.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Cumulative jobs admitted at submit time.
    pub jobs_accepted: u64,
    /// Cumulative submissions rejected at validation time.
    pub jobs_rejected: u64,
    /// Work units currently waiting in the bounded tile queue. Filled by
    /// [`super::Coordinator::metrics`] (a bare [`Metrics::snapshot`]
    /// reports 0 — the queue belongs to the coordinator).
    pub queue_depth: usize,
    pub jobs_completed: u64,
    /// Cumulative failed jobs across all engines.
    pub jobs_failed: u64,
    pub tiles_processed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub latency_p50_ms: f64,
    pub latency_p90_ms: f64,
    pub latency_p99_ms: f64,
    pub engine_busy: Duration,
    /// Per-design/engine rows, in engine registration order.
    pub per_engine: Vec<EngineMetricsSnapshot>,
}

/// Default consecutive-failure count that trips a breaker.
pub const DEFAULT_BREAKER_THRESHOLD: u32 = 5;
/// Default open-state cooldown before a half-open probe is allowed.
pub const DEFAULT_BREAKER_COOLDOWN: Duration = Duration::from_millis(500);

impl Metrics {
    /// Metrics tracking one row per engine name, with the default
    /// circuit-breaker tuning.
    pub fn new(engine_names: Vec<String>) -> Self {
        Self::with_breaker(engine_names, DEFAULT_BREAKER_THRESHOLD, DEFAULT_BREAKER_COOLDOWN)
    }

    /// Metrics with explicit breaker tuning (`threshold == 0` disables
    /// the breaker: `breaker_allow` always answers `Allow`).
    pub fn with_breaker(engine_names: Vec<String>, threshold: u32, cooldown: Duration) -> Self {
        assert!(!engine_names.is_empty());
        Self {
            inner: Mutex::new(
                engine_names
                    .into_iter()
                    .enumerate()
                    // Distinct deterministic seed per row so reservoirs
                    // don't share replacement streams.
                    .map(|(i, n)| EngineInner::new(n, 0x5fc0_0db5 ^ i as u64))
                    .collect(),
            ),
            breaker_threshold: threshold,
            breaker_cooldown: cooldown,
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            quality_sample_n: AtomicU64::new(0),
        }
    }

    /// Builder: enable the live quality sampler with a 1-in-`n` window
    /// (`0` leaves it off). Reseeds every engine's gate, so call before
    /// the metrics are shared.
    pub fn with_quality(self, n: u64) -> Self {
        self.set_quality_sample_n(n);
        self
    }

    /// (Re)configure the quality sampling window; resets the per-engine
    /// gates to their deterministic seeds.
    pub fn set_quality_sample_n(&self, n: u64) {
        self.quality_sample_n.store(n, Ordering::Relaxed);
        let mut rows = lock(&self.inner);
        for (i, m) in rows.iter_mut().enumerate() {
            m.quality_gate = SampleGate::new(n, (0x5fc0_0db5 ^ i as u64) ^ QUALITY_GATE_SEED);
        }
    }

    pub fn quality_sample_n(&self) -> u64 {
        self.quality_sample_n.load(Ordering::Relaxed)
    }

    /// Advance `engine`'s sampling gate by one work unit; true when the
    /// unit should be shadow-recomputed. One relaxed load when sampling
    /// is disabled (the common case) — no lock is taken.
    pub fn quality_admit(&self, engine: usize) -> bool {
        if self.quality_sample_n.load(Ordering::Relaxed) == 0 {
            return false;
        }
        lock(&self.inner)[engine].quality_gate.admit()
    }

    /// Fold one sampled unit's shadow-recompute delta into `engine`'s
    /// running quality totals.
    pub fn record_quality(&self, engine: usize, delta: &QualityStats) {
        lock(&self.inner)[engine].quality.merge(delta);
    }

    /// Record queue-wait durations for a batch of work units picked up
    /// for `engine` (one lock acquisition for the whole batch).
    pub fn record_queue_waits(&self, engine: usize, waits: &[Duration]) {
        if waits.is_empty() {
            return;
        }
        let mut rows = lock(&self.inner);
        let m = &mut rows[engine];
        for &w in waits {
            m.stages.record(Stage::QueueWait, w);
        }
    }

    /// Count one admitted submission (O(1), lock-free).
    pub fn record_accept(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one submission rejected at validation time (O(1), lock-free).
    pub fn record_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, engine: usize, size: usize, busy: Duration) {
        let mut rows = lock(&self.inner);
        let m = &mut rows[engine];
        m.batches += 1;
        m.tiles_processed += size as u64;
        m.busy += busy;
        m.stages.record(Stage::Compute, busy);
    }

    pub fn record_job(&self, engine: usize, latency: Duration) {
        let mut rows = lock(&self.inner);
        let m = &mut rows[engine];
        m.jobs_completed += 1;
        m.latencies_ms.record(latency.as_secs_f64() * 1e3);
        m.stages.record(Stage::E2e, latency);
        // A success heals the breaker: a completed probe (or any
        // completion racing the trip) closes it and resets the streak.
        m.consecutive_failures = 0;
        m.breaker = Breaker::Closed;
    }

    /// Count a job that completed without dispatching any work unit to
    /// the engine (an empty-output GEMM). Books it as completed so
    /// `accepted == completed + failed` stays exact, but leaves the
    /// breaker and failure streak alone: the engine was never
    /// exercised, so the completion is no evidence of health.
    pub fn record_trivial_job(&self, engine: usize) {
        let mut rows = lock(&self.inner);
        let m = &mut rows[engine];
        m.jobs_completed += 1;
        m.latencies_ms.record(0.0);
    }

    /// Count one failed job against `engine` and advance its breaker
    /// state machine. O(1) like every other recorder.
    pub fn record_failure(&self, engine: usize, kind: FailKind) {
        let mut rows = lock(&self.inner);
        let m = &mut rows[engine];
        m.jobs_failed += 1;
        match kind {
            FailKind::Panic => m.panics_caught += 1,
            FailKind::Deadline => m.deadline_misses += 1,
            FailKind::Error => {}
        }
        if self.breaker_threshold == 0 {
            return;
        }
        m.consecutive_failures = m.consecutive_failures.saturating_add(1);
        match m.breaker {
            // A failed half-open probe re-opens for a full cooldown.
            Breaker::HalfOpen => {
                m.breaker = Breaker::Open { until: Instant::now() + self.breaker_cooldown };
            }
            Breaker::Closed if m.consecutive_failures >= self.breaker_threshold => {
                m.breaker = Breaker::Open { until: Instant::now() + self.breaker_cooldown };
            }
            _ => {}
        }
    }

    /// Consult `engine`'s breaker before routing a job to it. Promotes
    /// an expired `Open` to `HalfOpen` and nominates the caller's job as
    /// the probe; while half-open, everything but the probe is denied.
    pub fn breaker_allow(&self, engine: usize) -> BreakerDecision {
        if self.breaker_threshold == 0 {
            return BreakerDecision::Allow;
        }
        let mut rows = lock(&self.inner);
        let m = &mut rows[engine];
        match m.breaker {
            Breaker::Closed => BreakerDecision::Allow,
            Breaker::Open { until } if Instant::now() >= until => {
                m.breaker = Breaker::HalfOpen;
                BreakerDecision::Probe
            }
            Breaker::Open { .. } => BreakerDecision::Deny,
            Breaker::HalfOpen => BreakerDecision::Deny,
        }
    }

    /// Give back a half-open probe nomination whose job never reached
    /// the engine (the nominated submit failed to enqueue, e.g. intake
    /// closed mid-submit). `HalfOpen` has no timeout of its own — if
    /// the nomination leaked, the breaker would deny that engine
    /// forever — so revert to `Open` with a fresh cooldown and let a
    /// later submit re-probe. No-op unless the breaker is still
    /// half-open (a racing completion may already have closed it).
    pub fn probe_aborted(&self, engine: usize) {
        if self.breaker_threshold == 0 {
            return;
        }
        let mut rows = lock(&self.inner);
        let m = &mut rows[engine];
        if matches!(m.breaker, Breaker::HalfOpen) {
            m.breaker = Breaker::Open { until: Instant::now() + self.breaker_cooldown };
        }
    }

    /// `engine`'s breaker state as of now (for health endpoints).
    pub fn breaker_state(&self, engine: usize) -> BreakerState {
        let rows = lock(&self.inner);
        match rows[engine].breaker {
            Breaker::Closed => BreakerState::Closed,
            Breaker::Open { .. } => BreakerState::Open,
            Breaker::HalfOpen => BreakerState::HalfOpen,
        }
    }

    /// `true` when any engine's breaker is open or half-open — the
    /// `/healthz` degraded condition.
    pub fn any_breaker_open(&self) -> bool {
        let rows = lock(&self.inner);
        rows.iter().any(|m| !matches!(m.breaker, Breaker::Closed))
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let rows = lock(&self.inner);
        let mean_batch = |tiles: u64, batches: u64| {
            if batches == 0 {
                0.0
            } else {
                tiles as f64 / batches as f64
            }
        };
        let per_engine: Vec<EngineMetricsSnapshot> = rows
            .iter()
            .map(|m| {
                let (p50, p90, p99) = stats::p50_p90_p99(&m.latencies_ms.samples);
                EngineMetricsSnapshot {
                    name: m.name.clone(),
                    jobs_completed: m.jobs_completed,
                    jobs_failed: m.jobs_failed,
                    panics_caught: m.panics_caught,
                    deadline_misses: m.deadline_misses,
                    breaker: match m.breaker {
                        Breaker::Closed => BreakerState::Closed,
                        Breaker::Open { .. } => BreakerState::Open,
                        Breaker::HalfOpen => BreakerState::HalfOpen,
                    },
                    tiles_processed: m.tiles_processed,
                    batches: m.batches,
                    mean_batch_size: mean_batch(m.tiles_processed, m.batches),
                    latency_p50_ms: p50,
                    latency_p90_ms: p90,
                    latency_p99_ms: p99,
                    engine_busy: m.busy,
                    stages: m.stages.snapshot(),
                    quality: m.quality,
                }
            })
            .collect();
        // Aggregate quantiles merge the per-engine reservoir samples —
        // a uniform sample of the whole stream when loads are balanced,
        // and at worst a per-engine-weighted estimate.
        let all_latencies: Vec<f64> =
            rows.iter().flat_map(|m| m.latencies_ms.samples.iter().copied()).collect();
        let (p50, p90, p99) = stats::p50_p90_p99(&all_latencies);
        let tiles: u64 = rows.iter().map(|m| m.tiles_processed).sum();
        let batches: u64 = rows.iter().map(|m| m.batches).sum();
        MetricsSnapshot {
            jobs_accepted: self.accepted.load(Ordering::Relaxed),
            jobs_rejected: self.rejected.load(Ordering::Relaxed),
            queue_depth: 0,
            jobs_completed: rows.iter().map(|m| m.jobs_completed).sum(),
            jobs_failed: rows.iter().map(|m| m.jobs_failed).sum(),
            tiles_processed: tiles,
            batches,
            mean_batch_size: mean_batch(tiles, batches),
            latency_p50_ms: p50,
            latency_p90_ms: p90,
            latency_p99_ms: p99,
            engine_busy: rows.iter().map(|m| m.busy).sum(),
            per_engine,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new(vec!["only".into()]);
        m.record_batch(0, 4, Duration::from_millis(2));
        m.record_batch(0, 8, Duration::from_millis(3));
        m.record_job(0, Duration::from_millis(10));
        m.record_job(0, Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert_eq!(s.tiles_processed, 12);
        assert_eq!(s.jobs_completed, 2);
        assert!((s.mean_batch_size - 6.0).abs() < 1e-12);
        assert!(s.latency_p50_ms >= 10.0 && s.latency_p99_ms <= 20.0 + 1e-9);
        assert_eq!(s.engine_busy, Duration::from_millis(5));
        assert_eq!(s.per_engine.len(), 1);
        assert_eq!(s.per_engine[0].name, "only");
    }

    #[test]
    fn per_engine_rows_stay_separate() {
        let m = Metrics::new(vec!["approx".into(), "exact".into()]);
        m.record_batch(0, 4, Duration::from_millis(1));
        m.record_batch(1, 2, Duration::from_millis(5));
        m.record_job(0, Duration::from_millis(10));
        m.record_job(0, Duration::from_millis(30));
        m.record_job(1, Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.jobs_completed, 3);
        assert_eq!(s.tiles_processed, 6);
        let approx = &s.per_engine[0];
        let exact = &s.per_engine[1];
        assert_eq!(approx.name, "approx");
        assert_eq!(approx.jobs_completed, 2);
        assert_eq!(approx.tiles_processed, 4);
        assert!(approx.latency_p50_ms >= 10.0 && approx.latency_p99_ms <= 30.0 + 1e-9);
        assert_eq!(exact.name, "exact");
        assert_eq!(exact.jobs_completed, 1);
        assert_eq!(exact.batches, 1);
        assert!((exact.mean_batch_size - 2.0).abs() < 1e-12);
        assert!((exact.latency_p50_ms - 20.0).abs() < 1e-9, "single sample is its own p50");
        assert_eq!(exact.engine_busy, Duration::from_millis(5));
    }

    /// Below the reservoir capacity the quantiles are exact: every
    /// recorded latency is retained.
    #[test]
    fn quantiles_are_exact_below_capacity() {
        let m = Metrics::new(vec!["e".into()]);
        for i in 1..=100u64 {
            m.record_job(0, Duration::from_millis(i));
        }
        let s = m.snapshot();
        assert!((s.latency_p50_ms - 50.5).abs() < 1.0, "p50 {}", s.latency_p50_ms);
        assert!(s.latency_p99_ms > 98.0 && s.latency_p99_ms <= 100.0);
    }

    /// Past capacity, memory stays bounded and the sampled quantiles
    /// still land inside the stream's range (here: a uniform ramp, so
    /// p50 of any uniform subsample concentrates near the midpoint).
    #[test]
    fn reservoir_bounds_memory_past_capacity() {
        let m = Metrics::new(vec!["e".into()]);
        let total = RESERVOIR_CAP as u64 * 20;
        for i in 1..=total {
            m.record_job(0, Duration::from_millis(i));
        }
        let rows = m.inner.lock().unwrap();
        assert_eq!(rows[0].latencies_ms.samples.len(), RESERVOIR_CAP);
        assert_eq!(rows[0].latencies_ms.seen, total);
        drop(rows);
        let s = m.snapshot();
        assert_eq!(s.jobs_completed, total);
        let mid = total as f64 / 2.0;
        assert!(
            (s.latency_p50_ms - mid).abs() < mid * 0.25,
            "sampled p50 {} should concentrate near {mid}",
            s.latency_p50_ms
        );
        assert!(s.latency_p99_ms <= total as f64 && s.latency_p99_ms > mid);
    }

    /// Accepted/rejected are cumulative fleet-level counters, independent
    /// of the per-engine rows, and a bare snapshot reports queue depth 0
    /// (the coordinator fills the real value).
    #[test]
    fn accept_reject_counters_accumulate() {
        let m = Metrics::new(vec!["e".into()]);
        assert_eq!((m.snapshot().jobs_accepted, m.snapshot().jobs_rejected), (0, 0));
        m.record_accept();
        m.record_accept();
        m.record_reject();
        let s = m.snapshot();
        assert_eq!(s.jobs_accepted, 2);
        assert_eq!(s.jobs_rejected, 1);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.jobs_completed, 0, "accept/reject do not touch completion");
    }

    #[test]
    fn failure_counters_split_by_kind() {
        let m = Metrics::new(vec!["e".into()]);
        m.record_failure(0, FailKind::Panic);
        m.record_failure(0, FailKind::Deadline);
        m.record_failure(0, FailKind::Error);
        let s = m.snapshot();
        assert_eq!(s.jobs_failed, 3);
        assert_eq!(s.per_engine[0].jobs_failed, 3);
        assert_eq!(s.per_engine[0].panics_caught, 1);
        assert_eq!(s.per_engine[0].deadline_misses, 1);
        assert_eq!(s.jobs_completed, 0, "failures are not completions");
    }

    #[test]
    fn breaker_trips_after_threshold_consecutive_failures() {
        let m = Metrics::with_breaker(vec!["e".into()], 3, Duration::from_secs(60));
        m.record_failure(0, FailKind::Panic);
        m.record_failure(0, FailKind::Panic);
        assert_eq!(m.breaker_state(0), BreakerState::Closed);
        assert_eq!(m.breaker_allow(0), BreakerDecision::Allow);
        m.record_failure(0, FailKind::Panic);
        assert_eq!(m.breaker_state(0), BreakerState::Open);
        assert_eq!(m.breaker_allow(0), BreakerDecision::Deny);
        assert!(m.any_breaker_open());
    }

    #[test]
    fn success_resets_streak_and_closes_breaker() {
        let m = Metrics::with_breaker(vec!["e".into()], 2, Duration::from_secs(60));
        m.record_failure(0, FailKind::Error);
        m.record_job(0, Duration::from_millis(1));
        m.record_failure(0, FailKind::Error);
        assert_eq!(m.breaker_state(0), BreakerState::Closed, "streak was reset");
        m.record_failure(0, FailKind::Error);
        assert_eq!(m.breaker_state(0), BreakerState::Open);
        m.record_job(0, Duration::from_millis(1));
        assert_eq!(m.breaker_state(0), BreakerState::Closed, "success heals");
        assert!(!m.any_breaker_open());
    }

    #[test]
    fn half_open_allows_one_probe_then_denies() {
        let m = Metrics::with_breaker(vec!["e".into()], 1, Duration::from_millis(1));
        m.record_failure(0, FailKind::Panic);
        assert_eq!(m.breaker_state(0), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(m.breaker_allow(0), BreakerDecision::Probe, "cooldown elapsed");
        assert_eq!(m.breaker_state(0), BreakerState::HalfOpen);
        assert_eq!(m.breaker_allow(0), BreakerDecision::Deny, "one probe at a time");
        // Probe fails → reopen for a fresh cooldown.
        m.record_failure(0, FailKind::Error);
        assert_eq!(m.breaker_state(0), BreakerState::Open);
    }

    #[test]
    fn aborted_probe_reopens_for_a_fresh_cooldown() {
        let m = Metrics::with_breaker(vec!["e".into()], 1, Duration::from_millis(1));
        m.record_failure(0, FailKind::Panic);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(m.breaker_allow(0), BreakerDecision::Probe);
        assert_eq!(m.breaker_state(0), BreakerState::HalfOpen);
        // The nominated probe never made it to the engine: the
        // nomination is given back instead of leaking a forever-denied
        // half-open state.
        m.probe_aborted(0);
        assert_eq!(m.breaker_state(0), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(m.breaker_allow(0), BreakerDecision::Probe, "re-probes after the cooldown");
        // A racing success already closed the breaker: probe_aborted
        // must not reopen it.
        m.record_job(0, Duration::from_millis(1));
        m.probe_aborted(0);
        assert_eq!(m.breaker_state(0), BreakerState::Closed);
    }

    #[test]
    fn trivial_job_counts_completion_without_healing_breaker() {
        let m = Metrics::with_breaker(vec!["e".into()], 1, Duration::from_secs(60));
        m.record_failure(0, FailKind::Error);
        assert_eq!(m.breaker_state(0), BreakerState::Open);
        m.record_trivial_job(0);
        let s = m.snapshot();
        assert_eq!(s.jobs_completed, 1, "trivial jobs keep the books balanced");
        assert_eq!(m.breaker_state(0), BreakerState::Open, "no spurious heal");
    }

    #[test]
    fn zero_threshold_disables_breaker() {
        let m = Metrics::with_breaker(vec!["e".into()], 0, Duration::from_secs(1));
        for _ in 0..50 {
            m.record_failure(0, FailKind::Panic);
        }
        assert_eq!(m.breaker_state(0), BreakerState::Closed);
        assert_eq!(m.breaker_allow(0), BreakerDecision::Allow);
        assert_eq!(m.snapshot().per_engine[0].panics_caught, 50, "counters still count");
    }

    #[test]
    fn breaker_state_codes_are_stable() {
        assert_eq!(BreakerState::Closed.code(), 0);
        assert_eq!(BreakerState::HalfOpen.code(), 1);
        assert_eq!(BreakerState::Open.code(), 2);
        assert_eq!(BreakerState::HalfOpen.to_string(), "half-open");
    }

    #[test]
    fn empty_engine_rows_report_zero_quantiles() {
        let m = Metrics::new(vec!["a".into(), "idle".into()]);
        m.record_job(0, Duration::from_millis(5));
        let s = m.snapshot();
        assert_eq!(s.per_engine[1].jobs_completed, 0);
        assert_eq!(s.per_engine[1].mean_batch_size, 0.0);
    }

    /// record_batch feeds the compute histogram, record_job the e2e
    /// histogram, record_queue_waits the queue-wait histogram — and the
    /// rows stay per-engine.
    #[test]
    fn stage_histograms_populate_from_recorders() {
        let m = Metrics::new(vec!["a".into(), "b".into()]);
        m.record_batch(0, 4, Duration::from_millis(2));
        m.record_job(0, Duration::from_millis(10));
        m.record_queue_waits(0, &[Duration::from_micros(3), Duration::from_micros(900)]);
        m.record_queue_waits(0, &[]);
        let s = m.snapshot();
        let stages = &s.per_engine[0].stages;
        assert_eq!(stages[Stage::QueueWait as usize].count, 2);
        assert_eq!(stages[Stage::Compute as usize].count, 1);
        assert_eq!(stages[Stage::E2e as usize].count, 1);
        assert!(stages[Stage::Compute as usize].sum_seconds > 0.0019);
        let idle = &s.per_engine[1].stages;
        assert_eq!(idle[Stage::QueueWait as usize].count, 0);
        assert_eq!(idle[Stage::E2e as usize].count, 0);
    }

    /// With the sampler off, quality_admit is always false; at n=1 every
    /// unit is admitted; recorded deltas surface in the snapshot.
    #[test]
    fn quality_sampler_gates_and_accumulates() {
        let m = Metrics::new(vec!["e".into()]);
        assert_eq!(m.quality_sample_n(), 0);
        assert!(!m.quality_admit(0), "disabled sampler admits nothing");
        m.set_quality_sample_n(1);
        for _ in 0..5 {
            assert!(m.quality_admit(0), "n=1 admits every unit");
        }
        let mut d = QualityStats { units: 1, ..QualityStats::default() };
        d.record_pair(100, 90);
        d.record_pair(50, 50);
        m.record_quality(0, &d);
        m.record_quality(0, &d);
        let q = m.snapshot().per_engine[0].quality;
        assert_eq!(q.units, 2);
        assert_eq!(q.pairs, 4);
        assert_eq!(q.mismatches, 2);
        assert_eq!(q.sum_ed, 20);
        assert_eq!(q.max_ed, 10);
        assert_eq!(q.med(), 5.0);
    }

    /// The builder form wires the window through construction.
    #[test]
    fn with_quality_builder_sets_window() {
        let m = Metrics::new(vec!["e".into()]).with_quality(4);
        assert_eq!(m.quality_sample_n(), 4);
        // Exactly one admit per window of 4.
        let admits: usize = (0..16).filter(|_| m.quality_admit(0)).count();
        assert_eq!(admits, 4);
    }
}

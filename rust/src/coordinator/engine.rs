//! Tile-processing engines.
//!
//! [`TileEngine`] is the pluggable compute backend of the coordinator.
//! Two in-process engines live here; the PJRT engine (AOT-compiled
//! JAX/Pallas executable) is in [`crate::runtime`] and implements the
//! same trait.

use super::tiler::{Tile, TileOut, TILE_HALO, TILE_IN};
use crate::image::colsum::{laplacian_taps_i64, postprocess, ColSumKernel};
use crate::image::conv::{conv3x3_rowbuf, KERNEL_PRESCALE_SHIFT, LAPLACIAN, PIXEL_SHIFT};
use crate::image::Image;
use crate::multipliers::MultiplierModel;
use std::sync::Arc;

/// A batched tile processor.
pub trait TileEngine: Send + Sync {
    fn name(&self) -> String;

    /// Process a batch of input tiles into output cores, in order.
    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut>;

    /// Preferred maximum batch size (the PJRT engine compiles a fixed
    /// batch dimension; in-process engines take anything).
    fn preferred_batch(&self) -> usize {
        16
    }
}

/// Sliding column-sum tile convolution — the production hot path of
/// every table-backed engine (LUT and bitsim): ≈2 lookups + 5 adds per
/// output pixel through the shared [`crate::image::colsum`] core. The
/// tile's haloed input window *is* the padded source the core expects,
/// so edge tiles need no special-casing.
fn conv_tile_colsum(tile: &Tile, kernel: &ColSumKernel) -> TileOut {
    let mut data = vec![0u8; tile.core_w * tile.core_h];
    kernel.run(&tile.data, TILE_IN, &mut data, tile.core_w, tile.core_w, tile.core_h);
    TileOut {
        job_id: tile.job_id,
        x0: tile.x0,
        y0: tile.y0,
        core_w: tile.core_w,
        core_h: tile.core_h,
        data,
    }
}

/// The pre-colsum folded-tap tile kernel: per-coefficient i64 tap tables,
/// 9 loads + 8 adds per output pixel. Retained verbatim (i) as the
/// serving fallback for wide netlist designs whose tap products exceed
/// [`crate::image::colsum::MAX_TAP_ABS`] and (ii) as the measured
/// baseline `bench_conv` and the committed `BENCH_conv.json` trajectory
/// compare the column-sum kernel against.
pub fn conv_tile_taps(tile: &Tile, tc: &[i64; 256], tr: &[i64; 256]) -> TileOut {
    let mut data = vec![0u8; tile.core_w * tile.core_h];
    let src = &tile.data;
    for cy in 0..tile.core_h {
        let r0 = &src[cy * TILE_IN..cy * TILE_IN + tile.core_w + 2];
        let r1 = &src[(cy + 1) * TILE_IN..(cy + 1) * TILE_IN + tile.core_w + 2];
        let r2 = &src[(cy + 2) * TILE_IN..(cy + 2) * TILE_IN + tile.core_w + 2];
        let out_row = &mut data[cy * tile.core_w..(cy + 1) * tile.core_w];
        for (cx, out_px) in out_row.iter_mut().enumerate() {
            let acc = tr[r0[cx] as usize]
                + tr[r0[cx + 1] as usize]
                + tr[r0[cx + 2] as usize]
                + tr[r1[cx] as usize]
                + tc[r1[cx + 1] as usize]
                + tr[r1[cx + 2] as usize]
                + tr[r2[cx] as usize]
                + tr[r2[cx + 1] as usize]
                + tr[r2[cx + 2] as usize];
            *out_px = postprocess(acc);
        }
    }
    TileOut {
        job_id: tile.job_id,
        x0: tile.x0,
        y0: tile.y0,
        core_w: tile.core_w,
        core_h: tile.core_h,
        data,
    }
}

/// Shared tile-convolution core over a product function.
fn conv_tile(tile: &Tile, product: &dyn Fn(u8, i8) -> i64) -> TileOut {
    let mut data = vec![0u8; tile.core_w * tile.core_h];
    for cy in 0..tile.core_h {
        for cx in 0..tile.core_w {
            let mut acc = 0i64;
            for ky in 0..3 {
                for kx in 0..3 {
                    let px =
                        tile.data[(cy + ky) * TILE_IN + cx + kx] >> PIXEL_SHIFT;
                    let k = (LAPLACIAN[ky][kx] << KERNEL_PRESCALE_SHIFT) as i8;
                    acc += product(px, k);
                }
            }
            data[cy * tile.core_w + cx] = postprocess(acc);
        }
    }
    debug_assert_eq!(TILE_HALO, 1);
    TileOut {
        job_id: tile.job_id,
        x0: tile.x0,
        y0: tile.y0,
        core_w: tile.core_w,
        core_h: tile.core_h,
        data,
    }
}

/// A table-backed engine's per-tile kernel: the column-sum fast path
/// when the folded taps fit the i32-safe bound (every real product
/// table), the retained i64 9-lookup kernel otherwise (reachable only
/// through hand-built tables / very wide compensated netlists whose taps
/// exceed [`crate::image::colsum::MAX_TAP_ABS`]).
enum TapKernel {
    ColSum(ColSumKernel),
    Wide { tap_center: Box<[i64; 256]>, tap_ring: Box<[i64; 256]> },
}

impl TapKernel {
    fn from_taps_i64(tap_center: Box<[i64; 256]>, tap_ring: Box<[i64; 256]>) -> Self {
        match ColSumKernel::try_from_taps(&tap_center, &tap_ring) {
            Some(k) => TapKernel::ColSum(k),
            None => TapKernel::Wide { tap_center, tap_ring },
        }
    }

    fn conv_tile(&self, tile: &Tile) -> TileOut {
        match self {
            TapKernel::ColSum(k) => conv_tile_colsum(tile, k),
            TapKernel::Wide { tap_center, tap_ring } => {
                conv_tile_taps(tile, tap_center, tap_ring)
            }
        }
    }
}

/// LUT-backed engine: products come from a 256×256 table generated from a
/// multiplier design — the production in-process path.
///
/// Perf (EXPERIMENTS.md §Perf, iterations L3-1, L3-4): the 3×3 Laplacian
/// has only two distinct pre-scaled coefficients (centre +64, ring −8),
/// so the 256×256 table folds into two 256-entry L1-resident `i32` tap
/// tables, and the per-tile inner loop is the sliding column-sum kernel
/// of [`crate::image::colsum`] — ≈2 loads + 5 adds per output pixel
/// (down from the 9 loads + 8 adds of [`conv_tile_taps`]).
pub struct LutTileEngine {
    name: String,
    lut: Vec<i32>,
    kernel: TapKernel,
}

impl LutTileEngine {
    pub fn new(model: &dyn MultiplierModel) -> Self {
        Self::from_table(&format!("lut:{}", model.name()), crate::multipliers::lut::product_table(model))
    }

    pub fn from_table(name: &str, lut: Vec<i32>) -> Self {
        let (tap_center, tap_ring) = laplacian_taps_i64(&lut);
        let kernel = TapKernel::from_taps_i64(tap_center, tap_ring);
        Self { name: name.to_string(), lut, kernel }
    }

    pub fn lut(&self) -> &[i32] {
        &self.lut
    }
}

impl TileEngine for LutTileEngine {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        tiles.iter().map(|t| self.kernel.conv_tile(t)).collect()
    }
}

/// Quality classes for dynamically configurable accuracy — the
/// system-level analogue of ref. [1]'s dual-quality compressors: a job can
/// request the approximate (low-power) or exact table at runtime without
/// recompiling anything.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quality {
    /// Approximate multiplier (default).
    Approx = 0,
    /// Exact multiplier.
    Exact = 1,
}

/// Dual-quality engine: holds one product table per quality class and
/// routes each tile by its job's requested quality.
pub struct DualModeTileEngine {
    approx: LutTileEngine,
    exact: LutTileEngine,
}

impl DualModeTileEngine {
    pub fn new(approx: &dyn MultiplierModel, exact: &dyn MultiplierModel) -> Self {
        Self {
            approx: LutTileEngine::new(approx),
            exact: LutTileEngine::new(exact),
        }
    }
}

impl TileEngine for DualModeTileEngine {
    fn name(&self) -> String {
        format!("dual[{} | {}]", self.approx.name(), self.exact.name())
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        tiles
            .iter()
            .map(|t| {
                let engine = if t.quality == Quality::Exact as u8 {
                    &self.exact
                } else {
                    &self.approx
                };
                engine.process_batch(std::slice::from_ref(t)).pop().unwrap()
            })
            .collect()
    }
}

/// Streaming row-buffer engine: runs the Fig. 8 line-buffer datapath
/// (two line buffers + 3×3 window register file) over each tile's haloed
/// input window. Bit-exact with the direct engines — the tile window
/// already carries the zero padding the whole-image path would see — so
/// `--engine rowbuf` serves through the coordinator like any other
/// backend while exercising the hardware-faithful datapath.
pub struct RowbufTileEngine {
    model: Arc<dyn MultiplierModel>,
}

impl RowbufTileEngine {
    pub fn new(model: Arc<dyn MultiplierModel>) -> Self {
        Self { model }
    }
}

impl TileEngine for RowbufTileEngine {
    fn name(&self) -> String {
        format!("rowbuf:{}", self.model.name())
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        tiles
            .iter()
            .map(|t| {
                let window = Image {
                    width: TILE_IN,
                    height: TILE_IN,
                    data: t.data.clone(),
                };
                let full = conv3x3_rowbuf(&window, &LAPLACIAN, self.model.as_ref());
                let mut data = vec![0u8; t.core_w * t.core_h];
                for cy in 0..t.core_h {
                    for cx in 0..t.core_w {
                        data[cy * t.core_w + cx] =
                            full.get(cx + TILE_HALO, cy + TILE_HALO);
                    }
                }
                TileOut {
                    job_id: t.job_id,
                    x0: t.x0,
                    y0: t.y0,
                    core_w: t.core_w,
                    core_h: t.core_h,
                    data,
                }
            })
            .collect()
    }
}

/// Gate-level serving engine: the design's per-coefficient tap tables are
/// computed by running its *netlist* through the bitsliced 64-lane
/// simulator ([`crate::netlist::bitslice::BitSim`]) at construction — 256
/// operand pairs in 4 netlist passes — so the serving path computes what
/// the hardware computes, not what the functional model claims. Works for
/// any design width in `8..=31` (the LUT engine is 8-bit only); the
/// per-tile convolution then matches the LUT engine's folded-tap fast
/// path.
pub struct BitsimTileEngine {
    name: String,
    kernel: TapKernel,
}

impl BitsimTileEngine {
    /// Width bounds: the pre-shifted pixel (0..=127) must fit the signed
    /// operand range (N ≥ 8) and the 2N-bit product bus must fit one
    /// 64-bit simulator code (N ≤ 31).
    pub fn new(model: &dyn MultiplierModel) -> Self {
        let n = model.bits();
        assert!((8..=31).contains(&n), "bitsim engine supports 8..=31-bit designs");
        let nl = model.build_netlist();
        let k_center = ((LAPLACIAN[1][1] << KERNEL_PRESCALE_SHIFT) as i8) as i64;
        let k_ring = ((LAPLACIAN[0][0] << KERNEL_PRESCALE_SHIFT) as i8) as i64;
        // All distinct MAC operand pairs of the Laplacian datapath: every
        // pre-shifted pixel value × the two pre-scaled coefficients. The
        // domain is derived from PIXEL_SHIFT so the tap fold below can
        // never index past the product list.
        let dom = 256usize >> PIXEL_SHIFT;
        let pairs: Vec<(i64, i64)> = (0..dom as i64)
            .flat_map(|px| [(px, k_center), (px, k_ring)])
            .collect();
        let products = crate::multipliers::verify::netlist_multiply_batch(&nl, n, &pairs);
        let mut tap_center = Box::new([0i64; 256]);
        let mut tap_ring = Box::new([0i64; 256]);
        for px in 0..256usize {
            let shifted = px >> PIXEL_SHIFT;
            tap_center[px] = products[2 * shifted];
            tap_ring[px] = products[2 * shifted + 1];
        }
        let kernel = TapKernel::from_taps_i64(tap_center, tap_ring);
        Self { name: format!("bitsim:{}", model.name()), kernel }
    }
}

impl TileEngine for BitsimTileEngine {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        tiles.iter().map(|t| self.kernel.conv_tile(t)).collect()
    }
}

/// Model-backed engine: calls the multiplier functional model directly
/// (slow reference; used to validate the LUT and PJRT engines).
pub struct ModelTileEngine {
    model: Arc<dyn MultiplierModel>,
}

impl ModelTileEngine {
    pub fn new(model: Arc<dyn MultiplierModel>) -> Self {
        Self { model }
    }
}

impl TileEngine for ModelTileEngine {
    fn name(&self) -> String {
        format!("model:{}", self.model.name())
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        tiles
            .iter()
            .map(|t| conv_tile(t, &|px, k| self.model.multiply(px as i64, k as i64)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tiler::{reassemble, tile_image};
    use crate::image::{edge_detect, synthetic_scene, Image};
    use crate::multipliers::{build_design, DesignId};

    /// Tiled LUT engine output must equal the whole-image convolution —
    /// halos make tiling invisible.
    #[test]
    fn tiled_equals_whole_image() {
        for id in [DesignId::Exact, DesignId::Proposed] {
            let model = build_design(id, 8);
            let img = synthetic_scene(150, 100, 4);
            let reference = edge_detect(&img, model.as_ref());
            let engine = LutTileEngine::new(model.as_ref());
            let tiles = tile_image(0, &img);
            let mut out = Image::new(150, 100);
            for to in engine.process_batch(&tiles) {
                reassemble(&mut out, &to);
            }
            assert_eq!(out, reference, "{id:?}");
        }
    }

    #[test]
    fn model_engine_equals_lut_engine() {
        let model = build_design(DesignId::Proposed, 8);
        let img = synthetic_scene(70, 70, 8);
        let tiles = tile_image(1, &img);
        let lut = LutTileEngine::new(model.as_ref());
        let slow = ModelTileEngine::new(model);
        let a = lut.process_batch(&tiles);
        let b = slow.process_batch(&tiles);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.data, y.data);
        }
    }

    /// The gate-level bitsim engine is bit-exact with the LUT engine for
    /// 8-bit designs (netlist ≡ model is proved exhaustively elsewhere),
    /// including on partial edge tiles.
    #[test]
    fn bitsim_engine_equals_lut_engine() {
        for id in [DesignId::Exact, DesignId::Proposed] {
            let model = build_design(id, 8);
            let img = synthetic_scene(150, 90, 17);
            let tiles = tile_image(3, &img);
            let lut = LutTileEngine::new(model.as_ref());
            let bitsim = BitsimTileEngine::new(model.as_ref());
            let a = lut.process_batch(&tiles);
            let b = bitsim.process_batch(&tiles);
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.data, y.data, "{id:?} tile at ({},{})", x.x0, x.y0);
            }
        }
    }

    /// For wide designs (no LUT possible) the bitsim engine must agree
    /// with the functional-model engine.
    #[test]
    fn bitsim_engine_equals_model_engine_wide() {
        let model = crate::multipliers::registry().build_str("proposed@16").unwrap();
        let img = synthetic_scene(96, 70, 23);
        let tiles = tile_image(4, &img);
        let bitsim = BitsimTileEngine::new(model.as_ref());
        let slow = ModelTileEngine::new(model);
        let a = bitsim.process_batch(&tiles);
        let b = slow.process_batch(&tiles);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.data, y.data, "tile at ({},{})", x.x0, x.y0);
        }
    }

    /// The streaming row-buffer engine is bit-exact with the LUT engine,
    /// including on partial edge tiles.
    #[test]
    fn rowbuf_engine_equals_lut_engine() {
        for id in [DesignId::Exact, DesignId::Proposed] {
            let model = build_design(id, 8);
            let img = synthetic_scene(150, 90, 13);
            let tiles = tile_image(2, &img);
            let lut = LutTileEngine::new(model.as_ref());
            let rowbuf = RowbufTileEngine::new(model.clone());
            let a = lut.process_batch(&tiles);
            let b = rowbuf.process_batch(&tiles);
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.data, y.data, "{id:?} tile at ({},{})", x.x0, x.y0);
            }
        }
    }
}

//! Tile-processing engines.
//!
//! [`TileEngine`] is the pluggable compute backend of the coordinator.
//! Engines are *multi-operator*: each tile carries an operator id
//! ([`Tile::op`]) and the table-backed engines hold one compiled
//! [`OpProgram`] per registered operator — tap tables are keyed per
//! (design, operator) pair at construction, so concurrent jobs running
//! different operators on the same engine never clobber each other.
//! The PJRT engine (AOT-compiled JAX/Pallas executable) is in
//! [`crate::runtime`] and implements the same trait (Laplacian-only:
//! see [`TileEngine::supports_op`]).

use super::tiler::{Tile, TileOut, TILE_HALO, TILE_IN};
use crate::image::colsum::postprocess;
use crate::image::conv::{conv3x3_rowbuf, KERNEL_PRESCALE_SHIFT, PIXEL_SHIFT};
use crate::image::ops::{combine_magnitude, OpProgram, Operator, Pass};
use crate::image::Image;
use crate::multipliers::traits::from_bits;
use crate::multipliers::verify::{netlist_multiply_all, operand_code};
use crate::multipliers::MultiplierModel;
use crate::netlist::prelude::{BitSim, Netlist};
use std::collections::BTreeSet;
use std::sync::{Arc, OnceLock};

/// How an engine computes quantized-inference (GEMM/conv2d) MACs — the
/// nn analogue of the per-operator tap tables. Returned by
/// [`TileEngine::nn_backend`]; `None` means the engine cannot serve nn
/// jobs and the coordinator rejects them at submit time (the same
/// contract as [`TileEngine::supports_op`] for operators).
#[derive(Clone)]
pub enum NnBackend {
    /// 256×256 i8×i8 product table (the
    /// [`crate::multipliers::lut::product_table`] layout) — the tiled
    /// GEMM fast path.
    Table(Arc<Vec<i32>>),
    /// Per-element calls into the multiplier functional model — the
    /// reference path.
    PerElement(Arc<dyn MultiplierModel>),
    /// Live gate-level MACs: every product is computed at serve time by
    /// streaming 64 operand pairs per gate-program pass through the
    /// design's netlist ([`crate::nn::gemm_block_bitsim`]) — no product
    /// table, no construction-time sweep. 8-bit designs only (the i8
    /// datapath).
    BitsimLive(Arc<Netlist>),
}

/// A batched tile processor.
pub trait TileEngine: Send + Sync {
    fn name(&self) -> String;

    /// Process a batch of input tiles into output cores, in order.
    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut>;

    /// Preferred maximum batch size (the PJRT engine compiles a fixed
    /// batch dimension; in-process engines take anything).
    fn preferred_batch(&self) -> usize {
        16
    }

    /// Whether this engine can serve `op`. In-process engines serve the
    /// whole registry; the PJRT engine's compiled artifact is
    /// Laplacian-only. Checked by the coordinator at submit time.
    fn supports_op(&self, _op: Operator) -> bool {
        true
    }

    /// Quantized-inference capability: the MAC product source for i8
    /// GEMM/conv2d jobs, or `None` when the engine is conv-datapath-only
    /// (rowbuf, PJRT) or its design is not 8-bit. Checked by the
    /// coordinator at [`super::Coordinator::submit_gemm`] /
    /// [`super::Coordinator::submit_conv2d`] time.
    fn nn_backend(&self) -> Option<NnBackend> {
        None
    }
}

fn tile_out(tile: &Tile, data: Vec<u8>) -> TileOut {
    TileOut {
        job_id: tile.job_id,
        x0: tile.x0,
        y0: tile.y0,
        core_w: tile.core_w,
        core_h: tile.core_h,
        data,
    }
}

/// One compiled [`OpProgram`] per registered operator for a single
/// design — the per-(design, operator) tap tables of every table-backed
/// engine. Uniform-ring operators run the sliding column-sum core
/// (≈2 lookups + 5 adds/pixel); the rest run the zero-tap-elided folded
/// path; wide netlist designs whose products exceed the i32-safe bound
/// fall back to i64 tables inside [`OpProgram`] transparently.
struct OpSet {
    programs: Vec<OpProgram>,
}

impl OpSet {
    /// Compile all operators against a product source (`prod(a, b)` =
    /// the design's product of pre-shifted pixel `a` and pre-scaled
    /// coefficient `b`).
    fn build(prod: &dyn Fn(u8, i8) -> i64) -> Self {
        let programs = Operator::all().iter().map(|&op| OpProgram::build(op, prod)).collect();
        Self { programs }
    }

    fn from_lut(lut: &[i32]) -> Self {
        let programs =
            Operator::all().iter().map(|&op| OpProgram::from_lut(op, lut)).collect();
        Self { programs }
    }

    /// Run the tile's operator over its haloed window — the window *is*
    /// the zero-padded source the program cores expect, so edge tiles
    /// need no special-casing.
    fn conv_tile(&self, tile: &Tile) -> TileOut {
        // Operator ids are validated at submit time; a bad one here is an
        // engine-contract violation the worker's catch_unwind converts
        // into a clean per-job failure.
        let Some(op) = Operator::from_id(tile.op) else {
            panic!("invalid operator id {} on tile", tile.op)
        };
        let mut data = vec![0u8; tile.core_w * tile.core_h];
        self.programs[op.id() as usize].run_window(
            &tile.data,
            TILE_IN,
            &mut data,
            tile.core_w,
            tile.core_w,
            tile.core_h,
        );
        tile_out(tile, data)
    }
}

/// The pre-colsum folded-tap tile kernel: per-coefficient i64 tap tables,
/// 9 loads + 8 adds per output pixel, the Laplacian's historical output
/// rule. Retained verbatim as the measured baseline `bench_conv` and the
/// committed `BENCH_conv.json` trajectory compare the column-sum kernel
/// against (the serving wide-design fallback now lives inside
/// [`OpProgram`]).
pub fn conv_tile_taps(tile: &Tile, tc: &[i64; 256], tr: &[i64; 256]) -> TileOut {
    let mut data = vec![0u8; tile.core_w * tile.core_h];
    let src = &tile.data;
    for cy in 0..tile.core_h {
        let r0 = &src[cy * TILE_IN..cy * TILE_IN + tile.core_w + 2];
        let r1 = &src[(cy + 1) * TILE_IN..(cy + 1) * TILE_IN + tile.core_w + 2];
        let r2 = &src[(cy + 2) * TILE_IN..(cy + 2) * TILE_IN + tile.core_w + 2];
        let out_row = &mut data[cy * tile.core_w..(cy + 1) * tile.core_w];
        for (cx, out_px) in out_row.iter_mut().enumerate() {
            let acc = tr[r0[cx] as usize]
                + tr[r0[cx + 1] as usize]
                + tr[r0[cx + 2] as usize]
                + tr[r1[cx] as usize]
                + tc[r1[cx + 1] as usize]
                + tr[r1[cx + 2] as usize]
                + tr[r2[cx] as usize]
                + tr[r2[cx + 1] as usize]
                + tr[r2[cx + 2] as usize];
            *out_px = postprocess(acc);
        }
    }
    tile_out(tile, data)
}

/// Reference tile convolution through a raw product function: the tile's
/// operator passes run as direct MACs (no folded tables), gradient
/// components combined with the saturating magnitude sum. The slow path
/// the table-backed engines are validated against.
fn conv_tile_model(tile: &Tile, product: &dyn Fn(u8, i8) -> i64) -> TileOut {
    let Some(op) = Operator::from_id(tile.op) else {
        panic!("invalid operator id {} on tile", tile.op)
    };
    let mut data = vec![0u8; tile.core_w * tile.core_h];
    let mut component = vec![0u8; tile.core_w * tile.core_h];
    for (pi, pass) in op.passes().iter().enumerate() {
        for cy in 0..tile.core_h {
            for cx in 0..tile.core_w {
                let mut acc = 0i64;
                for ky in 0..3 {
                    for kx in 0..3 {
                        let px = tile.data[(cy + ky) * TILE_IN + cx + kx] >> PIXEL_SHIFT;
                        let k = (pass.kernel[ky][kx] << KERNEL_PRESCALE_SHIFT) as i8;
                        acc += product(px, k);
                    }
                }
                component[cy * tile.core_w + cx] = pass.post.apply(acc);
            }
        }
        if pi == 0 {
            std::mem::swap(&mut data, &mut component);
        } else {
            combine_magnitude(&mut data, &component);
        }
    }
    debug_assert_eq!(TILE_HALO, 1);
    tile_out(tile, data)
}

/// LUT-backed engine: products come from a 256×256 table generated from a
/// multiplier design — the production in-process path.
///
/// Perf (EXPERIMENTS.md §Perf, iterations L3-1, L3-4): per operator the
/// table folds into 256-entry L1-resident tap tables; uniform-ring
/// operators (the Laplacian) run the sliding column-sum kernel of
/// [`crate::image::colsum`] (≈2 loads + 5 adds per output pixel),
/// directional operators run the zero-tap-elided folded path (6 loads
/// for the Gx/Gy family, 2 for Roberts).
pub struct LutTileEngine {
    name: String,
    /// Shared so [`TileEngine::nn_backend`] hands the GEMM path the same
    /// table without copying 256 KiB per job.
    lut: Arc<Vec<i32>>,
    ops: OpSet,
}

impl LutTileEngine {
    pub fn new(model: &dyn MultiplierModel) -> Self {
        Self::from_table(&format!("lut:{}", model.name()), crate::multipliers::lut::product_table(model))
    }

    pub fn from_table(name: &str, lut: Vec<i32>) -> Self {
        let ops = OpSet::from_lut(&lut);
        Self { name: name.to_string(), lut: Arc::new(lut), ops }
    }

    pub fn lut(&self) -> &[i32] {
        &self.lut
    }
}

impl TileEngine for LutTileEngine {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        tiles.iter().map(|t| self.ops.conv_tile(t)).collect()
    }

    fn nn_backend(&self) -> Option<NnBackend> {
        Some(NnBackend::Table(self.lut.clone()))
    }
}

/// Quality classes for dynamically configurable accuracy — the
/// system-level analogue of ref. [1]'s dual-quality compressors: a job can
/// request the approximate (low-power) or exact table at runtime without
/// recompiling anything.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quality {
    /// Approximate multiplier (default).
    Approx = 0,
    /// Exact multiplier.
    Exact = 1,
}

/// Dual-quality engine: holds one product table per quality class and
/// routes each tile by its job's requested quality.
pub struct DualModeTileEngine {
    approx: LutTileEngine,
    exact: LutTileEngine,
}

impl DualModeTileEngine {
    pub fn new(approx: &dyn MultiplierModel, exact: &dyn MultiplierModel) -> Self {
        Self {
            approx: LutTileEngine::new(approx),
            exact: LutTileEngine::new(exact),
        }
    }
}

impl TileEngine for DualModeTileEngine {
    fn name(&self) -> String {
        format!("dual[{} | {}]", self.approx.name(), self.exact.name())
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        tiles
            .iter()
            .map(|t| {
                let engine = if t.quality == Quality::Exact as u8 {
                    &self.exact
                } else {
                    &self.approx
                };
                match engine.process_batch(std::slice::from_ref(t)).pop() {
                    Some(out) => out,
                    None => panic!("lut engine returned empty batch for one tile"),
                }
            })
            .collect()
    }
}

/// Streaming row-buffer engine: runs the Fig. 8 line-buffer datapath
/// (two line buffers + 3×3 window register file) over each tile's haloed
/// input window, once per operator pass. Bit-exact with the direct
/// engines — the tile window already carries the zero padding the
/// whole-image path would see — so `--engine rowbuf` serves through the
/// coordinator like any other backend while exercising the
/// hardware-faithful datapath.
pub struct RowbufTileEngine {
    model: Arc<dyn MultiplierModel>,
}

impl RowbufTileEngine {
    pub fn new(model: Arc<dyn MultiplierModel>) -> Self {
        Self { model }
    }
}

impl TileEngine for RowbufTileEngine {
    fn name(&self) -> String {
        format!("rowbuf:{}", self.model.name())
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        tiles
            .iter()
            .map(|t| {
                let Some(op) = Operator::from_id(t.op) else {
                    panic!("invalid operator id {} on tile", t.op)
                };
                let window = Image {
                    width: TILE_IN,
                    height: TILE_IN,
                    data: t.data.clone(),
                };
                let mut data = vec![0u8; t.core_w * t.core_h];
                let mut component = vec![0u8; t.core_w * t.core_h];
                for (pi, pass) in op.passes().iter().enumerate() {
                    let full =
                        conv3x3_rowbuf(&window, &pass.kernel, self.model.as_ref(), pass.post);
                    for cy in 0..t.core_h {
                        for cx in 0..t.core_w {
                            component[cy * t.core_w + cx] =
                                full.get(cx + TILE_HALO, cy + TILE_HALO);
                        }
                    }
                    if pi == 0 {
                        std::mem::swap(&mut data, &mut component);
                    } else {
                        combine_magnitude(&mut data, &component);
                    }
                }
                tile_out(t, data)
            })
            .collect()
    }
}

/// Gate-level serving engine: the per-(design, operator) tap tables are
/// computed by running the design's *netlist* through the bitsliced
/// 64-lane simulator ([`crate::netlist::bitslice::BitSim`]) at
/// construction — every distinct (pre-shifted pixel, pre-scaled
/// coefficient) operand pair across the whole operator registry in a
/// handful of netlist passes — so the serving path computes what the
/// hardware computes, not what the functional model claims. Works for
/// any design width in `8..=31` (the LUT engine is 8-bit only); the
/// per-tile convolution then matches the LUT engine's program exactly.
pub struct BitsimTileEngine {
    name: String,
    ops: OpSet,
    /// The design's netlist + width, kept so the nn path can sweep the
    /// full 256×256 product table out of the gates on first use.
    nl: Netlist,
    bits: usize,
    nn_table: OnceLock<Arc<Vec<i32>>>,
}

impl BitsimTileEngine {
    /// Width bounds: the pre-shifted pixel (0..=127) must fit the signed
    /// operand range (N ≥ 8) and the 2N-bit product bus must fit one
    /// 64-bit simulator code (N ≤ 31).
    pub fn new(model: &dyn MultiplierModel) -> Self {
        let n = model.bits();
        assert!((8..=31).contains(&n), "bitsim engine supports 8..=31-bit designs");
        let nl = model.build_netlist();
        // The distinct pre-scaled coefficients of every registered
        // operator pass — the full MAC operand alphabet of the serving
        // surface.
        let mut ks: BTreeSet<i8> = BTreeSet::new();
        for op in Operator::all() {
            for pass in op.passes() {
                for row in &pass.kernel {
                    for &k in row {
                        ks.insert((k << KERNEL_PRESCALE_SHIFT) as i8);
                    }
                }
            }
        }
        let ks: Vec<i8> = ks.into_iter().collect();
        let dom = 256usize >> PIXEL_SHIFT;
        let pairs: Vec<(i64, i64)> = ks
            .iter()
            .flat_map(|&k| (0..dom as i64).map(move |px| (px, k as i64)))
            .collect();
        let products = crate::multipliers::verify::netlist_multiply_batch(&nl, n, &pairs);
        let prod = move |a: u8, b: i8| {
            let ki = match ks.binary_search(&b) {
                Ok(i) => i,
                Err(_) => panic!("coefficient {b} not swept at construction"),
            };
            products[ki * dom + a as usize]
        };
        let ops = OpSet::build(&prod);
        Self {
            name: format!("bitsim:{}", model.name()),
            ops,
            nl,
            bits: n,
            nn_table: OnceLock::new(),
        }
    }
}

impl TileEngine for BitsimTileEngine {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        tiles.iter().map(|t| self.ops.conv_tile(t)).collect()
    }

    /// Netlist-true GEMM: the full 65 536-pair operand space is swept
    /// out of the gates by the bitsliced simulator on first nn use
    /// (~1 000 passes, cached for the engine's lifetime), so quantized
    /// inference observes hardware truth exactly like the paper tables.
    /// The nn datapath is i8, so only 8-bit designs qualify.
    fn nn_backend(&self) -> Option<NnBackend> {
        if self.bits != 8 {
            return None;
        }
        let table = self.nn_table.get_or_init(|| {
            // netlist_multiply_all indexes by (a_bits << 8) | b_bits —
            // the product_table layout; 8-bit products fit 16 bits.
            Arc::new(
                netlist_multiply_all(&self.nl, 8)
                    .into_iter()
                    .map(|p| p as i32)
                    .collect(),
            )
        });
        Some(NnBackend::Table(table.clone()))
    }
}

/// Serve-time gate-level engine (`bitsim-live`): where [`BitsimTileEngine`]
/// sweeps tap tables out of the gates *at construction* and then serves
/// from tables, this engine keeps **no tables at all** — every MAC of
/// every tile is streamed through the design's netlist at serve time,
/// 64 operand pairs per gate-program pass ([`BitSim::run_codes_into`]).
/// That is the batched-serving path the bitsliced simulator was built
/// for: one gate walk retires 64 products, so live gate-level serving
/// runs at ~64× the scalar `eval_bool` walk instead of being 3–4 orders
/// of magnitude off the table path. Bit-exact with the `bitsim` and
/// (at 8 bit) `lut` engines; useful when the operand working set is too
/// sparse or too wide to justify a sweep, and as the end-to-end witness
/// that serving truth *is* gate truth.
pub struct BitsimLiveTileEngine {
    name: String,
    /// Shared with [`NnBackend::BitsimLive`] so GEMM workers compile
    /// their own [`BitSim`] from the same gate program.
    nl: Arc<Netlist>,
    bits: usize,
}

impl BitsimLiveTileEngine {
    /// Same width bounds as [`BitsimTileEngine::new`]: pre-shifted pixels
    /// need N ≥ 8, the 2N-bit product bus needs N ≤ 31.
    pub fn new(model: &dyn MultiplierModel) -> Self {
        let n = model.bits();
        assert!((8..=31).contains(&n), "bitsim-live engine supports 8..=31-bit designs");
        Self {
            name: format!("bitsim-live:{}", model.name()),
            nl: Arc::new(model.build_netlist()),
            bits: n,
        }
    }

    /// One live convolution pass over a tile's haloed window: all nine
    /// taps of every output pixel go through the gates, 64 codes per
    /// pass, accumulated per pixel exactly like [`conv_tile_model`]'s
    /// MAC loop (zero-coefficient taps included — hardware multiplies
    /// them too).
    fn live_pass(&self, sim: &mut BitSim, pass: &Pass, tile: &Tile, component: &mut [u8]) {
        let mut ks = [[0i8; 3]; 3];
        for (ky, row) in pass.kernel.iter().enumerate() {
            for (kx, &k) in row.iter().enumerate() {
                ks[ky][kx] = (k << KERNEL_PRESCALE_SHIFT) as i8;
            }
        }
        let n = self.bits;
        let mut acc = vec![0i64; tile.core_w * tile.core_h];
        let mut codes = [0u64; 64];
        let mut prods = [0u64; 64];
        let mut slots = [0usize; 64];
        let mut lanes = 0usize;
        for cy in 0..tile.core_h {
            for cx in 0..tile.core_w {
                let slot = cy * tile.core_w + cx;
                for (ky, krow) in ks.iter().enumerate() {
                    let srow = &tile.data[(cy + ky) * TILE_IN + cx..(cy + ky) * TILE_IN + cx + 3];
                    for (&px, &k) in srow.iter().zip(krow) {
                        codes[lanes] = operand_code((px >> PIXEL_SHIFT) as i64, k as i64, n);
                        slots[lanes] = slot;
                        lanes += 1;
                        if lanes == 64 {
                            sim.run_codes_into(&codes, &mut prods);
                            for (&s, &p) in slots.iter().zip(&prods) {
                                acc[s] += from_bits(p, 2 * n);
                            }
                            lanes = 0;
                        }
                    }
                }
            }
        }
        if lanes > 0 {
            sim.run_codes_into(&codes[..lanes], &mut prods[..lanes]);
            for (&s, &p) in slots[..lanes].iter().zip(&prods[..lanes]) {
                acc[s] += from_bits(p, 2 * n);
            }
        }
        for (o, &a) in component.iter_mut().zip(&acc) {
            *o = pass.post.apply(a);
        }
    }
}

impl TileEngine for BitsimLiveTileEngine {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        // One compiled gate program per batch, recycled across tiles —
        // BitSim construction copies the gate list, so per-batch (not
        // per-tile or per-pass) amortizes it away.
        let mut sim = BitSim::new(&self.nl);
        tiles
            .iter()
            .map(|t| {
                let Some(op) = Operator::from_id(t.op) else {
                    panic!("invalid operator id {} on tile", t.op)
                };
                let mut data = vec![0u8; t.core_w * t.core_h];
                let mut component = vec![0u8; t.core_w * t.core_h];
                for (pi, pass) in op.passes().iter().enumerate() {
                    self.live_pass(&mut sim, pass, t, &mut component);
                    if pi == 0 {
                        std::mem::swap(&mut data, &mut component);
                    } else {
                        combine_magnitude(&mut data, &component);
                    }
                }
                tile_out(t, data)
            })
            .collect()
    }

    /// Live gate-level GEMM ([`crate::nn::gemm_block_bitsim`]): 8-bit
    /// designs only — the i8 datapath.
    fn nn_backend(&self) -> Option<NnBackend> {
        if self.bits == 8 {
            Some(NnBackend::BitsimLive(self.nl.clone()))
        } else {
            None
        }
    }
}

/// Model-backed engine: calls the multiplier functional model directly
/// per MAC (slow reference; used to validate the LUT and PJRT engines).
pub struct ModelTileEngine {
    model: Arc<dyn MultiplierModel>,
}

impl ModelTileEngine {
    pub fn new(model: Arc<dyn MultiplierModel>) -> Self {
        Self { model }
    }
}

impl TileEngine for ModelTileEngine {
    fn name(&self) -> String {
        format!("model:{}", self.model.name())
    }

    fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
        tiles
            .iter()
            .map(|t| conv_tile_model(t, &|px, k| self.model.multiply(px as i64, k as i64)))
            .collect()
    }

    /// Per-element reference path for nn jobs (8-bit designs; the i8
    /// datapath cannot carry wider operands).
    fn nn_backend(&self) -> Option<NnBackend> {
        if self.model.bits() == 8 {
            Some(NnBackend::PerElement(self.model.clone()))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tiler::{reassemble, tile_image};
    use crate::image::ops::{apply_operator, apply_operator_lut};
    use crate::image::{edge_detect, synthetic_scene, Image};
    use crate::multipliers::{build_design, lut::product_table, DesignId};

    fn tiles_for_op(job: u64, img: &Image, op: Operator) -> Vec<Tile> {
        let mut tiles = tile_image(job, img);
        for t in &mut tiles {
            t.op = op.id();
        }
        tiles
    }

    fn reassembled(engine: &dyn TileEngine, tiles: &[Tile], w: usize, h: usize) -> Image {
        let mut out = Image::new(w, h);
        for to in engine.process_batch(tiles) {
            reassemble(&mut out, &to);
        }
        out
    }

    /// Tiled LUT engine output must equal the whole-image convolution —
    /// halos make tiling invisible.
    #[test]
    fn tiled_equals_whole_image() {
        for id in [DesignId::Exact, DesignId::Proposed] {
            let model = build_design(id, 8);
            let img = synthetic_scene(150, 100, 4);
            let reference = edge_detect(&img, model.as_ref());
            let engine = LutTileEngine::new(model.as_ref());
            let tiles = tile_image(0, &img);
            let out = reassembled(&engine, &tiles, 150, 100);
            assert_eq!(out, reference, "{id:?}");
        }
    }

    /// Every engine backend serves every registered operator, and the
    /// tiled result equals the whole-image operator pipeline — tap
    /// tables are keyed per (design, operator).
    #[test]
    fn engines_serve_every_operator_tiled() {
        let model = build_design(DesignId::Proposed, 8);
        let lut_table = product_table(model.as_ref());
        let img = synthetic_scene(150, 90, 31);
        let lut = LutTileEngine::new(model.as_ref());
        let slow = ModelTileEngine::new(model.clone());
        let rowbuf = RowbufTileEngine::new(model.clone());
        for op in Operator::all() {
            let tiles = tiles_for_op(1, &img, op);
            let want = apply_operator(&img, op, model.as_ref());
            assert_eq!(
                apply_operator_lut(&img, op, &lut_table),
                want,
                "{op}: direct lut vs model"
            );
            assert_eq!(reassembled(&lut, &tiles, 150, 90), want, "{op}: lut engine");
            assert_eq!(reassembled(&slow, &tiles, 150, 90), want, "{op}: model engine");
            assert_eq!(reassembled(&rowbuf, &tiles, 150, 90), want, "{op}: rowbuf engine");
        }
    }

    #[test]
    fn model_engine_equals_lut_engine() {
        let model = build_design(DesignId::Proposed, 8);
        let img = synthetic_scene(70, 70, 8);
        let tiles = tile_image(1, &img);
        let lut = LutTileEngine::new(model.as_ref());
        let slow = ModelTileEngine::new(model);
        let a = lut.process_batch(&tiles);
        let b = slow.process_batch(&tiles);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.data, y.data);
        }
    }

    /// The gate-level bitsim engine is bit-exact with the LUT engine for
    /// 8-bit designs (netlist ≡ model is proved exhaustively elsewhere),
    /// including on partial edge tiles — for every operator.
    #[test]
    fn bitsim_engine_equals_lut_engine() {
        for id in [DesignId::Exact, DesignId::Proposed] {
            let model = build_design(id, 8);
            let img = synthetic_scene(150, 90, 17);
            let lut = LutTileEngine::new(model.as_ref());
            let bitsim = BitsimTileEngine::new(model.as_ref());
            for op in [Operator::Laplacian, Operator::Sobel, Operator::Gaussian3] {
                let tiles = tiles_for_op(3, &img, op);
                let a = lut.process_batch(&tiles);
                let b = bitsim.process_batch(&tiles);
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.data, y.data, "{id:?} {op} tile at ({},{})", x.x0, x.y0);
                }
            }
        }
    }

    /// For wide designs (no LUT possible) the bitsim engine must agree
    /// with the functional-model engine — the wide-tap i64 fallback
    /// inside the operator programs engages here.
    #[test]
    fn bitsim_engine_equals_model_engine_wide() {
        let model = crate::multipliers::registry().build_str("proposed@16").unwrap();
        let img = synthetic_scene(96, 70, 23);
        let bitsim = BitsimTileEngine::new(model.as_ref());
        let slow = ModelTileEngine::new(model);
        for op in [Operator::Laplacian, Operator::Scharr] {
            let tiles = tiles_for_op(4, &img, op);
            let a = bitsim.process_batch(&tiles);
            let b = slow.process_batch(&tiles);
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.data, y.data, "{op} tile at ({},{})", x.x0, x.y0);
            }
        }
    }

    /// The serve-time gate-streaming engine is bit-exact with the LUT
    /// engine for every 8-bit registry design and every operator —
    /// batched 64-lane serving computes exactly what the swept tables
    /// hold, including on partial edge tiles and ragged final batches.
    #[test]
    fn bitsim_live_engine_equals_lut_engine_all_designs() {
        let img = synthetic_scene(96, 70, 29);
        for spec in crate::multipliers::registry().specs(8) {
            let model = crate::multipliers::registry()
                .build(&spec)
                .unwrap_or_else(|e| panic!("{spec}: {e}"));
            let lut = LutTileEngine::new(model.as_ref());
            let live = BitsimLiveTileEngine::new(model.as_ref());
            for op in [Operator::Laplacian, Operator::Sobel] {
                let tiles = tiles_for_op(5, &img, op);
                let a = lut.process_batch(&tiles);
                let b = live.process_batch(&tiles);
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.data, y.data, "{spec} {op} tile at ({},{})", x.x0, x.y0);
                }
            }
        }
    }

    /// Wide designs: live gate streaming must agree with the functional
    /// model (no LUT exists above 8 bit).
    #[test]
    fn bitsim_live_engine_equals_model_engine_wide() {
        let model = crate::multipliers::registry().build_str("proposed@16").unwrap();
        let img = synthetic_scene(70, 50, 11);
        let live = BitsimLiveTileEngine::new(model.as_ref());
        let slow = ModelTileEngine::new(model);
        for op in [Operator::Laplacian, Operator::Roberts] {
            let tiles = tiles_for_op(6, &img, op);
            let a = live.process_batch(&tiles);
            let b = slow.process_batch(&tiles);
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.data, y.data, "{op} tile at ({},{})", x.x0, x.y0);
            }
        }
    }

    /// The streaming row-buffer engine is bit-exact with the LUT engine,
    /// including on partial edge tiles.
    #[test]
    fn rowbuf_engine_equals_lut_engine() {
        for id in [DesignId::Exact, DesignId::Proposed] {
            let model = build_design(id, 8);
            let img = synthetic_scene(150, 90, 13);
            let tiles = tile_image(2, &img);
            let lut = LutTileEngine::new(model.as_ref());
            let rowbuf = RowbufTileEngine::new(model.clone());
            let a = lut.process_batch(&tiles);
            let b = rowbuf.process_batch(&tiles);
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.data, y.data, "{id:?} tile at ({},{})", x.x0, x.y0);
            }
        }
    }

    /// A single batch mixing tiles of different operators routes each
    /// tile through its own program (no shared mutable state).
    #[test]
    fn mixed_operator_batch_is_routed_per_tile() {
        let model = build_design(DesignId::Proposed, 8);
        let engine = LutTileEngine::new(model.as_ref());
        let img = synthetic_scene(64, 64, 5);
        let mut mixed = Vec::new();
        for op in Operator::all() {
            mixed.extend(tiles_for_op(op.id() as u64, &img, op));
        }
        let outs = engine.process_batch(&mixed);
        for (tile, out) in mixed.iter().zip(outs.iter()) {
            let op = Operator::from_id(tile.op).unwrap();
            let want = apply_operator(&img, op, model.as_ref());
            assert_eq!(out.data, want.data, "{op}");
        }
    }

    /// nn capability matrix: table-backed and model engines serve the
    /// i8 GEMM path (bitsim's table is swept from the gates and must
    /// equal the model LUT at 8 bit); rowbuf is conv-datapath-only and
    /// wide designs cannot carry the i8 operands.
    #[test]
    fn nn_backend_capability_matrix() {
        let model = build_design(DesignId::Proposed, 8);
        let lut = LutTileEngine::new(model.as_ref());
        assert!(matches!(lut.nn_backend(), Some(NnBackend::Table(_))));
        let bitsim = BitsimTileEngine::new(model.as_ref());
        let Some(NnBackend::Table(t)) = bitsim.nn_backend() else {
            panic!("bitsim engine must serve nn jobs at 8 bit");
        };
        assert_eq!(t.as_slice(), lut.lut(), "netlist-swept table == model LUT");
        assert!(matches!(
            ModelTileEngine::new(model.clone()).nn_backend(),
            Some(NnBackend::PerElement(_))
        ));
        let live = BitsimLiveTileEngine::new(model.as_ref());
        assert!(matches!(live.nn_backend(), Some(NnBackend::BitsimLive(_))));
        assert!(RowbufTileEngine::new(model).nn_backend().is_none(), "rowbuf is conv-only");
        let wide = crate::multipliers::registry().build_str("proposed@16").unwrap();
        assert!(BitsimTileEngine::new(wide.as_ref()).nn_backend().is_none());
        assert!(BitsimLiveTileEngine::new(wide.as_ref()).nn_backend().is_none());
        assert!(ModelTileEngine::new(wide).nn_backend().is_none());
    }

    #[test]
    fn in_process_engines_support_all_operators() {
        let model = build_design(DesignId::Proposed, 8);
        let engines: Vec<Box<dyn TileEngine>> = vec![
            Box::new(LutTileEngine::new(model.as_ref())),
            Box::new(ModelTileEngine::new(model.clone())),
            Box::new(RowbufTileEngine::new(model.clone())),
        ];
        for e in &engines {
            for op in Operator::all() {
                assert!(e.supports_op(op), "{} {op}", e.name());
            }
        }
        assert_eq!(Operator::all().len(), crate::image::ops::OPERATOR_COUNT);
    }
}

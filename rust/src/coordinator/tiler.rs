//! Halo tiling and reassembly.
//!
//! A tile's *core* is the region it produces output for; its *input*
//! includes a 1-pixel halo on every side (the 3×3 kernel's receptive
//! field). Halos that fall outside the image are zero — identical to the
//! zero padding of the whole-image convolution, so tiled results are
//! bit-exact with the untiled path (verified by tests).

use crate::image::Image;

/// Output pixels per tile side.
pub const TILE_CORE: usize = 64;
/// Halo width on each side.
pub const TILE_HALO: usize = 1;
/// Input pixels per tile side.
pub const TILE_IN: usize = TILE_CORE + 2 * TILE_HALO;

/// An input tile: `TILE_IN × TILE_IN` samples centred on the core at
/// `(x0, y0)` in job `job_id`.
#[derive(Debug, Clone)]
pub struct Tile {
    pub job_id: u64,
    /// Index of the named engine this tile is routed to (see
    /// [`super::service::Coordinator::start_named`]); 0 is the default
    /// engine, so single-engine coordinators ignore it.
    pub engine: u8,
    /// Accuracy class requested by the job (see [`super::engine::Quality`]);
    /// engines without quality support ignore it.
    pub quality: u8,
    /// Operator id this tile is convolved with
    /// ([`crate::image::ops::Operator::id`]); 0 is the Laplacian, the
    /// historical default.
    pub op: u8,
    pub x0: usize,
    pub y0: usize,
    /// Valid core size (edge tiles may be smaller than TILE_CORE).
    pub core_w: usize,
    pub core_h: usize,
    /// Row-major `TILE_IN × TILE_IN` input window (zero outside image).
    pub data: Vec<u8>,
}

/// A processed tile: the core output region.
#[derive(Debug, Clone)]
pub struct TileOut {
    pub job_id: u64,
    pub x0: usize,
    pub y0: usize,
    pub core_w: usize,
    pub core_h: usize,
    /// Row-major `core_h × core_w` output pixels.
    pub data: Vec<u8>,
}

/// Split an image into halo tiles, row-major tile order.
///
/// Perf (EXPERIMENTS.md §Perf, iteration L3-3): rows inside the image are
/// copied as slices (`copy_from_slice`); only rows/columns that cross the
/// image border fall back to per-pixel zero padding.
pub fn tile_image(job_id: u64, img: &Image) -> Vec<Tile> {
    let mut tiles = Vec::new();
    let (w, h) = (img.width, img.height);
    let mut y0 = 0;
    while y0 < h {
        let core_h = TILE_CORE.min(h - y0);
        let mut x0 = 0;
        while x0 < w {
            let core_w = TILE_CORE.min(w - x0);
            let mut data = vec![0u8; TILE_IN * TILE_IN];
            // source window: x in [x0-1, x0-1+TILE_IN), y likewise
            let sx0 = x0 as isize - TILE_HALO as isize;
            for ty in 0..TILE_IN {
                let sy = y0 as isize + ty as isize - TILE_HALO as isize;
                if sy < 0 || sy as usize >= h {
                    continue; // stays zero
                }
                let row = &img.data[sy as usize * w..sy as usize * w + w];
                let dst = &mut data[ty * TILE_IN..(ty + 1) * TILE_IN];
                // clip [sx0, sx0+TILE_IN) to [0, w)
                let src_lo = sx0.max(0) as usize;
                let src_hi = ((sx0 + TILE_IN as isize) as usize).min(w);
                if src_lo < src_hi {
                    let dst_off = (src_lo as isize - sx0) as usize;
                    dst[dst_off..dst_off + (src_hi - src_lo)]
                        .copy_from_slice(&row[src_lo..src_hi]);
                }
            }
            tiles.push(Tile { job_id, engine: 0, quality: 0, op: 0, x0, y0, core_w, core_h, data });
            x0 += TILE_CORE;
        }
        y0 += TILE_CORE;
    }
    tiles
}

/// Number of tiles [`tile_image`] produces for a `w × h` image.
pub fn tile_count(w: usize, h: usize) -> usize {
    w.div_ceil(TILE_CORE) * h.div_ceil(TILE_CORE)
}

/// Write a processed tile's core into the output image (row slice copies).
pub fn reassemble(out: &mut Image, tile: &TileOut) {
    let w = out.width;
    for ty in 0..tile.core_h {
        let dst_base = (tile.y0 + ty) * w + tile.x0;
        out.data[dst_base..dst_base + tile.core_w]
            .copy_from_slice(&tile.data[ty * tile.core_w..(ty + 1) * tile.core_w]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::synthetic_scene;

    #[test]
    fn tile_counts() {
        assert_eq!(tile_count(64, 64), 1);
        assert_eq!(tile_count(65, 64), 2);
        assert_eq!(tile_count(128, 128), 4);
        assert_eq!(tile_count(1, 1), 1);
    }

    #[test]
    fn tiles_cover_image_exactly_once() {
        let img = synthetic_scene(150, 90, 3);
        let tiles = tile_image(7, &img);
        assert_eq!(tiles.len(), tile_count(150, 90));
        let mut covered = vec![0u32; 150 * 90];
        for t in &tiles {
            assert_eq!(t.job_id, 7);
            for ty in 0..t.core_h {
                for tx in 0..t.core_w {
                    covered[(t.y0 + ty) * 150 + (t.x0 + tx)] += 1;
                }
            }
        }
        assert!(covered.iter().all(|&c| c == 1), "every pixel exactly once");
    }

    #[test]
    fn halo_matches_padded_source() {
        let img = synthetic_scene(100, 100, 5);
        for t in tile_image(0, &img) {
            for ty in 0..TILE_IN {
                for tx in 0..TILE_IN {
                    let sx = t.x0 as isize + tx as isize - 1;
                    let sy = t.y0 as isize + ty as isize - 1;
                    assert_eq!(t.data[ty * TILE_IN + tx], img.get_padded(sx, sy));
                }
            }
        }
    }

    #[test]
    fn reassembly_roundtrip_identity() {
        // Tiling then copying cores back must reproduce the image.
        let img = synthetic_scene(130, 70, 9);
        let tiles = tile_image(0, &img);
        let mut out = Image::new(130, 70);
        for t in tiles {
            let mut core = vec![0u8; t.core_w * t.core_h];
            for ty in 0..t.core_h {
                for tx in 0..t.core_w {
                    core[ty * t.core_w + tx] =
                        t.data[(ty + TILE_HALO) * TILE_IN + tx + TILE_HALO];
                }
            }
            reassemble(
                &mut out,
                &TileOut {
                    job_id: t.job_id,
                    x0: t.x0,
                    y0: t.y0,
                    core_w: t.core_w,
                    core_h: t.core_h,
                    data: core,
                },
            );
        }
        assert_eq!(out, img);
    }
}

//! The coordinator service: intake → bounded tile queue → dynamic batcher
//! → worker pool → reassembly.
//!
//! A coordinator serves a *set of named engines* — typically one per
//! multiplier design (e.g. `proposed@8` next to `exact@8`), each resolved
//! through [`super::engines::resolve`]. Jobs pick an engine by name at
//! submit time ([`Coordinator::submit_to`]); [`Coordinator::submit`]
//! keeps the classic single-engine behaviour by routing to the default
//! (first) engine. Metrics are kept per engine, so one service instance
//! can A/B exact vs. approximate designs under load (the Fig. 8 serving
//! story scaled up).
//!
//! Contention (EXPERIMENTS.md §Perf, iteration L3-4): job state lives in
//! a [`JOB_SHARDS`]-way sharded map keyed by `job_id`, so workers
//! finishing tiles of *different* jobs update disjoint mutexes instead of
//! serialising on one global lock; and the batch clamp is per engine at
//! dispatch time — one small-`preferred_batch` engine no longer shrinks
//! every other engine's batches to the fleet-wide minimum.

use super::engine::{NnBackend, TileEngine};
use super::job::{GemmResult, JobResult};
use super::metrics::{Metrics, MetricsSnapshot};
use super::tiler::{reassemble, tile_image, Tile};
use crate::image::ops::Operator;
use crate::image::Image;
use crate::multipliers::MultiplierModel;
use crate::nn::{gemm_block_lut, gemm_block_mul, Conv2d, MatI32, MatI8, TensorI8};
use crate::util::error::Error;
use crate::util::pool::{bounded, Receiver, Sender};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker threads draining the tile queue.
    pub workers: usize,
    /// Bounded tile-queue capacity — the backpressure knob. Producers
    /// block when the fleet is saturated, exactly like the line-buffer
    /// stall in the paper's Fig. 8 datapath.
    pub queue_capacity: usize,
    /// Maximum tiles per engine batch. Clamped *per engine* at dispatch
    /// time to that engine's [`TileEngine::preferred_batch`]; other
    /// engines in the fleet are unaffected.
    pub max_batch: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self { workers: 4, queue_capacity: 256, max_batch: 16 }
    }
}

/// One unit of queued work. Edge jobs travel as halo tiles; quantized
/// inference travels as output-stationary GEMM row-block tasks — both
/// share the bounded queue (backpressure), the worker fleet, the
/// per-engine batch regrouping and the per-design metrics.
enum Work {
    Conv(Tile),
    Gemm(GemmTask),
}

impl Work {
    fn engine(&self) -> u8 {
        match self {
            Work::Conv(t) => t.engine,
            Work::Gemm(g) => g.engine,
        }
    }
}

/// One GEMM block task: compute the `rows × cols` block of `C = A × B`
/// at `(row0, col0)` (see [`crate::nn::gemm_block_lut`]). Jobs split
/// along *both* C dimensions ([`crate::nn::MC`] rows ×
/// [`crate::nn::NC`] columns): convolution GEMMs have only `out_c` rows
/// but thousands of im2col columns, so the column split is what spreads
/// a conv layer across the fleet. Operands are shared across the job's
/// tasks, never copied per task.
struct GemmTask {
    job_id: u64,
    engine: u8,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    a: Arc<MatI8>,
    b: Arc<MatI8>,
}

/// Where a job's finished units accumulate, paired with the reply
/// channel its result returns on — one enum, so a sink/reply kind
/// mismatch is unrepresentable.
enum Sink {
    Image(Image, Sender<JobResult>),
    Mat(MatI32, Sender<GemmResult>),
}

struct JobState {
    sink: Sink,
    remaining: usize,
    started: Instant,
    /// Total units (tiles or GEMM blocks) the job was split into.
    units: usize,
    /// Index of the engine serving this job (metrics attribution).
    engine: usize,
}

/// Shard count of the job map. Power of two so the shard pick is one
/// mask; 16 shards keep the collision probability low for any plausible
/// worker count while the whole table stays a few cache lines of
/// mutexes.
const JOB_SHARDS: usize = 16;

/// Job state sharded by `job_id`: workers completing tiles of different
/// jobs lock different mutexes, removing the single global job-map lock
/// from the reassembly path.
struct JobTable {
    shards: [Mutex<HashMap<u64, JobState>>; JOB_SHARDS],
}

impl JobTable {
    fn new() -> Self {
        Self { shards: std::array::from_fn(|_| Mutex::new(HashMap::new())) }
    }

    fn shard(&self, job_id: u64) -> &Mutex<HashMap<u64, JobState>> {
        &self.shards[job_id as usize & (JOB_SHARDS - 1)]
    }
}

struct Shared {
    jobs: JobTable,
    metrics: Metrics,
}

/// Handle for one submitted job.
pub struct JobHandle {
    pub id: u64,
    rx: Receiver<JobResult>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> JobResult {
        self.rx.recv().expect("coordinator dropped before completing job")
    }
}

/// Handle for one submitted quantized-inference (GEMM/conv2d) job.
pub struct GemmHandle {
    pub id: u64,
    rx: Receiver<GemmResult>,
}

impl GemmHandle {
    /// Block until the job completes.
    pub fn wait(self) -> GemmResult {
        self.rx.recv().expect("coordinator dropped before completing job")
    }
}

/// The running service. Dropping it shuts the workers down gracefully
/// (queued work is drained first).
pub struct Coordinator {
    shared: Arc<Shared>,
    tile_tx: Option<Sender<Work>>,
    workers: Vec<JoinHandle<()>>,
    next_job: AtomicU64,
    engine_names: Vec<String>,
    /// The engine fleet, kept for submit-time capability checks
    /// ([`TileEngine::supports_op`], [`TileEngine::nn_backend`]);
    /// workers hold their own clone.
    fleet: Arc<Vec<Arc<dyn TileEngine>>>,
}

impl Coordinator {
    /// Single-engine service (the classic entry): the engine is
    /// registered under its own reported name and serves every job.
    pub fn start(engine: Arc<dyn TileEngine>, cfg: CoordinatorConfig) -> Self {
        let name = engine.name();
        Self::start_named(vec![(name, engine)], cfg)
    }

    /// Multi-design service: a set of named engines. The first entry is
    /// the default; [`Coordinator::submit_to`] routes jobs to any of them
    /// by name. Panics on an empty set, duplicate names, or more than 256
    /// engines (tile routing is a `u8`).
    pub fn start_named(
        engines: Vec<(String, Arc<dyn TileEngine>)>,
        cfg: CoordinatorConfig,
    ) -> Self {
        assert!(cfg.workers >= 1 && cfg.max_batch >= 1);
        assert!(!engines.is_empty(), "coordinator needs at least one engine");
        assert!(engines.len() <= 256, "at most 256 named engines");
        let engine_names: Vec<String> = engines.iter().map(|(n, _)| n.clone()).collect();
        {
            let mut sorted = engine_names.clone();
            sorted.sort();
            sorted.dedup();
            assert_eq!(sorted.len(), engine_names.len(), "duplicate engine names");
        }
        let fleet: Arc<Vec<Arc<dyn TileEngine>>> =
            Arc::new(engines.into_iter().map(|(_, e)| e).collect());
        let (tile_tx, tile_rx) = bounded::<Work>(cfg.queue_capacity);
        let shared = Arc::new(Shared {
            jobs: JobTable::new(),
            metrics: Metrics::new(engine_names.clone()),
        });
        // The queue drain bound; each engine's own preferred_batch()
        // clamps further at dispatch time (per engine, not fleet-wide).
        let max_batch = cfg.max_batch;
        let workers = (0..cfg.workers)
            .map(|i| {
                let rx = tile_rx.clone();
                let fleet = fleet.clone();
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("sfcmul-coord-{i}"))
                    .spawn(move || worker_loop(rx, fleet, shared, max_batch))
                    .expect("spawn coordinator worker")
            })
            .collect();
        Self {
            shared,
            tile_tx: Some(tile_tx),
            workers,
            next_job: AtomicU64::new(1),
            engine_names,
            fleet,
        }
    }

    /// Name of the default engine (the routing target of [`submit`]).
    ///
    /// [`submit`]: Coordinator::submit
    pub fn engine_name(&self) -> &str {
        &self.engine_names[0]
    }

    /// All registered engine names, in registration order.
    pub fn engine_names(&self) -> &[String] {
        &self.engine_names
    }

    /// Submit an image to the default engine with the default operator
    /// (Laplacian); returns a handle to wait on. Blocks (backpressure)
    /// when the tile queue is full.
    pub fn submit(&self, image: Image) -> JobHandle {
        self.submit_inner(image, 0, 0, Operator::Laplacian)
    }

    /// Submit to a named engine with an explicit operator (per-job design
    /// *and* workload selection). `None` routes to the default engine; an
    /// unknown name, or an engine that cannot serve `op` (the PJRT
    /// artifact is Laplacian-only), is an error.
    pub fn submit_to(
        &self,
        image: Image,
        engine: Option<&str>,
        op: Operator,
    ) -> crate::Result<JobHandle> {
        let idx = match self.engine_index(engine) {
            Ok(idx) => idx,
            Err(e) => {
                self.shared.metrics.record_reject();
                return Err(e);
            }
        };
        if !self.fleet[idx].supports_op(op) {
            self.shared.metrics.record_reject();
            return Err(Error::msg(format!(
                "engine {:?} does not support operator {op}",
                self.engine_names[idx]
            )));
        }
        Ok(self.submit_inner(image, idx, 0, op))
    }

    /// Resolve an engine selector to a fleet index (None = default).
    fn engine_index(&self, engine: Option<&str>) -> crate::Result<usize> {
        match engine {
            None => Ok(0),
            Some(name) => self
                .engine_names
                .iter()
                .position(|n| n == name)
                .ok_or_else(|| {
                    Error::msg(format!(
                        "unknown engine {name:?} (registered: {})",
                        self.engine_names.join(", ")
                    ))
                }),
        }
    }

    /// Submit a quantized-inference GEMM job: `C = A × B` with every MAC
    /// through the selected engine's multiplier design. The job is split
    /// into [`crate::nn::MC`]-row × [`crate::nn::NC`]-column
    /// output-stationary block tasks that share the tile queue and
    /// worker fleet. Engines opt in via [`TileEngine::nn_backend`] — a
    /// conv-only engine (rowbuf, PJRT) or a non-8-bit design is rejected
    /// here, at submit time.
    pub fn submit_gemm(
        &self,
        a: MatI8,
        b: MatI8,
        engine: Option<&str>,
    ) -> crate::Result<GemmHandle> {
        match self.submit_gemm_inner(a, b, engine) {
            Ok(h) => {
                self.shared.metrics.record_accept();
                Ok(h)
            }
            Err(e) => {
                self.shared.metrics.record_reject();
                Err(e)
            }
        }
    }

    fn submit_gemm_inner(
        &self,
        a: MatI8,
        b: MatI8,
        engine: Option<&str>,
    ) -> crate::Result<GemmHandle> {
        let idx = self.engine_index(engine)?;
        // Cheap shape validation first: the capability probe below can be
        // expensive (a fresh bitsim engine sweeps its netlist table on
        // first nn use) and malformed submits should fail fast.
        if a.cols != b.rows {
            return Err(Error::msg(format!(
                "GEMM shape mismatch: {}x{} × {}x{}",
                a.rows, a.cols, b.rows, b.cols
            )));
        }
        if a.cols > crate::nn::MAX_GEMM_DEPTH {
            return Err(Error::msg(format!(
                "GEMM depth {} exceeds the i32-safe bound {}",
                a.cols,
                crate::nn::MAX_GEMM_DEPTH
            )));
        }
        if self.fleet[idx].nn_backend().is_none() {
            return Err(Error::msg(format!(
                "engine {:?} does not serve quantized-inference (GEMM) jobs",
                self.engine_names[idx]
            )));
        }
        let id = self.next_job.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = bounded::<GemmResult>(1);
        if a.rows == 0 || b.cols == 0 {
            // Empty output: no tasks to dispatch, complete immediately.
            let _ = reply_tx.send(GemmResult {
                id,
                out: MatI32::new(a.rows, b.cols),
                latency: Duration::ZERO,
                blocks: 0,
            });
            return Ok(GemmHandle { id, rx: reply_rx });
        }
        let blocks = a.rows.div_ceil(crate::nn::MC) * b.cols.div_ceil(crate::nn::NC);
        {
            let mut jobs = self.shared.jobs.shard(id).lock().unwrap();
            jobs.insert(
                id,
                JobState {
                    sink: Sink::Mat(MatI32::new(a.rows, b.cols), reply_tx),
                    remaining: blocks,
                    started: Instant::now(),
                    units: blocks,
                    engine: idx,
                },
            );
        }
        let (a, b) = (Arc::new(a), Arc::new(b));
        let tx = self.tile_tx.as_ref().expect("coordinator running");
        let mut row0 = 0;
        while row0 < a.rows {
            let rows = crate::nn::MC.min(a.rows - row0);
            let mut col0 = 0;
            while col0 < b.cols {
                let cols = crate::nn::NC.min(b.cols - col0);
                tx.send(Work::Gemm(GemmTask {
                    job_id: id,
                    engine: idx as u8,
                    row0,
                    rows,
                    col0,
                    cols,
                    a: a.clone(),
                    b: b.clone(),
                }))
                .expect("tile queue closed");
                col0 += cols;
            }
            row0 += rows;
        }
        Ok(GemmHandle { id, rx: reply_rx })
    }

    /// Submit one quantized convolution layer: the input is lowered via
    /// [`crate::nn::im2col`] at submit time and served as a GEMM job
    /// (`layer.weight × im2col(x)`). The result carries the raw i32
    /// accumulators; apply [`Conv2d::epilogue`] (bias/requant/ReLU) —
    /// [`crate::nn::Network::run_served`] does both per layer.
    pub fn submit_conv2d(
        &self,
        x: &TensorI8,
        layer: &Conv2d,
        engine: Option<&str>,
    ) -> crate::Result<GemmHandle> {
        if x.c != layer.in_c {
            self.shared.metrics.record_reject();
            return Err(Error::msg(format!(
                "conv2d input has {} channels, layer expects {}",
                x.c, layer.in_c
            )));
        }
        let cols = crate::nn::im2col(x, layer.kh, layer.kw, layer.stride, layer.pad);
        self.submit_gemm(layer.weight.clone(), cols, engine)
    }

    /// Submit with an explicit quality class (dual-quality serving; see
    /// [`crate::coordinator::engine::Quality`]).
    pub fn submit_with_quality(&self, image: Image, quality: u8) -> JobHandle {
        self.submit_inner(image, 0, quality, Operator::Laplacian)
    }

    fn submit_inner(&self, image: Image, engine: usize, quality: u8, op: Operator) -> JobHandle {
        self.shared.metrics.record_accept();
        let id = self.next_job.fetch_add(1, Ordering::Relaxed);
        let mut tiles = tile_image(id, &image);
        for t in &mut tiles {
            t.engine = engine as u8;
            t.quality = quality;
            t.op = op.id();
        }
        let (reply_tx, reply_rx) = bounded::<JobResult>(1);
        {
            let mut jobs = self.shared.jobs.shard(id).lock().unwrap();
            jobs.insert(
                id,
                JobState {
                    sink: Sink::Image(Image::new(image.width, image.height), reply_tx),
                    remaining: tiles.len(),
                    started: Instant::now(),
                    units: tiles.len(),
                    engine,
                },
            );
        }
        let tx = self.tile_tx.as_ref().expect("coordinator running");
        for t in tiles {
            tx.send(Work::Conv(t)).expect("tile queue closed");
        }
        JobHandle { id, rx: reply_rx }
    }

    /// Convenience: submit to the default engine and wait.
    pub fn run(&self, image: Image) -> JobResult {
        self.submit(image).wait()
    }

    /// Work units currently waiting in the bounded tile queue (racy by
    /// nature; 0 once the coordinator has shut down). The live
    /// backpressure signal behind the server front-end's gauge.
    pub fn queue_depth(&self) -> usize {
        self.tile_tx.as_ref().map(|tx| tx.len()).unwrap_or(0)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        let mut s = self.shared.metrics.snapshot();
        s.queue_depth = self.queue_depth();
        s
    }

    /// Graceful shutdown: close intake, drain queue, join workers.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shutdown_inner();
        self.shared.metrics.snapshot()
    }

    fn shutdown_inner(&mut self) {
        if let Some(tx) = self.tile_tx.take() {
            drop(tx); // last sender closes the stream; workers drain
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_loop(
    rx: Receiver<Work>,
    fleet: Arc<Vec<Arc<dyn TileEngine>>>,
    shared: Arc<Shared>,
    max_batch: usize,
) {
    loop {
        let batch = rx.recv_batch(max_batch);
        if batch.is_empty() {
            return; // queue closed and drained
        }
        // Regroup the batch by engine (stable: queue order kept within
        // each group). Concurrent submitters interleave units of
        // different jobs in the shared queue, so coalescing — not
        // run-splitting — keeps engine batches large; batching across
        // designs is never correct, and reassembly is position-keyed so
        // cross-engine reordering is safe.
        let mut groups: Vec<(u8, Vec<Work>)> = Vec::new();
        for t in batch {
            if let Some(pos) = groups.iter().position(|(e, _)| *e == t.engine()) {
                groups[pos].1.push(t);
            } else {
                groups.push((t.engine(), vec![t]));
            }
        }
        for (engine_idx, items) in groups {
            let engine = &fleet[engine_idx as usize];
            let mut tiles: Vec<Tile> = Vec::new();
            let mut gemms: Vec<GemmTask> = Vec::new();
            for it in items {
                match it {
                    Work::Conv(t) => tiles.push(t),
                    Work::Gemm(g) => gemms.push(g),
                }
            }
            // Per-engine batch clamp at dispatch time: each engine's
            // preference bounds only its own chunks, so a small-batch
            // engine in the fleet no longer shrinks everyone's batches.
            let clamp = engine.preferred_batch().clamp(1, max_batch);
            for chunk in tiles.chunks(clamp) {
                let t0 = Instant::now();
                let outs = engine.process_batch(chunk);
                shared
                    .metrics
                    .record_batch(engine_idx as usize, chunk.len(), t0.elapsed());
                debug_assert_eq!(outs.len(), chunk.len());
                for to in outs {
                    let mut jobs = shared.jobs.shard(to.job_id).lock().unwrap();
                    let done = {
                        let st = jobs.get_mut(&to.job_id).expect("job state");
                        match &mut st.sink {
                            Sink::Image(out, _) => reassemble(out, &to),
                            Sink::Mat(..) => unreachable!("conv tile routed to a GEMM job"),
                        }
                        st.remaining -= 1;
                        st.remaining == 0
                    };
                    if done {
                        let st = jobs.remove(&to.job_id).unwrap();
                        drop(jobs); // finish the job outside the shard lock
                        finish_job(&shared, to.job_id, st);
                    }
                }
            }
            if gemms.is_empty() {
                continue;
            }
            // GEMM block tasks: each is already a block-sized unit
            // (nn::MC rows × nn::NC columns), so they dispatch one at a
            // time through the engine's nn backend (validated present at
            // submit).
            let backend = engine
                .nn_backend()
                .expect("nn-capable engine validated at submit time");
            for task in gemms {
                let n = task.b.cols;
                let t0 = Instant::now();
                let mut block = vec![0i32; task.rows * task.cols];
                match &backend {
                    NnBackend::Table(table) => {
                        gemm_block_lut(
                            &task.a, &task.b, table, task.row0, task.rows, task.col0,
                            task.cols, &mut block,
                        );
                    }
                    NnBackend::PerElement(m) => {
                        gemm_block_mul(
                            &task.a,
                            &task.b,
                            &|x, y| m.multiply(x as i64, y as i64) as i32,
                            task.row0,
                            task.rows,
                            task.col0,
                            task.cols,
                            &mut block,
                        );
                    }
                }
                shared.metrics.record_batch(engine_idx as usize, 1, t0.elapsed());
                let mut jobs = shared.jobs.shard(task.job_id).lock().unwrap();
                let done = {
                    let st = jobs.get_mut(&task.job_id).expect("job state");
                    match &mut st.sink {
                        Sink::Mat(out, _) => {
                            for i in 0..task.rows {
                                let dst = (task.row0 + i) * n + task.col0;
                                out.data[dst..dst + task.cols]
                                    .copy_from_slice(&block[i * task.cols..(i + 1) * task.cols]);
                            }
                        }
                        Sink::Image(..) => unreachable!("GEMM task routed to a conv job"),
                    }
                    st.remaining -= 1;
                    st.remaining == 0
                };
                if done {
                    let st = jobs.remove(&task.job_id).unwrap();
                    drop(jobs);
                    finish_job(&shared, task.job_id, st);
                }
            }
        }
    }
}

/// Record the job's latency and send its result — outside the shard
/// lock. The sink carries its own reply channel, so the result kind
/// always matches.
fn finish_job(shared: &Shared, id: u64, st: JobState) {
    let latency = st.started.elapsed();
    shared.metrics.record_job(st.engine, latency);
    match st.sink {
        Sink::Image(out, tx) => {
            let _ = tx.send(JobResult { id, edges: out, latency, tiles: st.units });
        }
        Sink::Mat(out, tx) => {
            let _ = tx.send(GemmResult { id, out, latency, blocks: st.units });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::LutTileEngine;
    use crate::image::{edge_detect, synthetic_scene};
    use crate::multipliers::{build_design, DesignId};

    fn coordinator(workers: usize) -> Coordinator {
        let model = build_design(DesignId::Proposed, 8);
        let engine = Arc::new(LutTileEngine::new(model.as_ref()));
        Coordinator::start(
            engine,
            CoordinatorConfig { workers, queue_capacity: 32, max_batch: 8 },
        )
    }

    #[test]
    fn single_job_matches_direct_path() {
        let model = build_design(DesignId::Proposed, 8);
        let img = synthetic_scene(200, 130, 6);
        let expect = edge_detect(&img, model.as_ref());
        let coord = coordinator(3);
        let res = coord.run(img);
        assert_eq!(res.edges, expect);
        assert_eq!(res.tiles, 4 * 3);
        let m = coord.shutdown();
        assert_eq!(m.jobs_completed, 1);
        assert_eq!(m.tiles_processed, 12);
    }

    #[test]
    fn many_concurrent_jobs_complete_correctly() {
        let model = build_design(DesignId::Proposed, 8);
        let coord = Arc::new(coordinator(4));
        let mut expected = Vec::new();
        let mut handles = Vec::new();
        for seed in 0..12u64 {
            let img = synthetic_scene(100 + (seed as usize % 3) * 30, 80, seed);
            expected.push(edge_detect(&img, model.as_ref()));
            handles.push(coord.submit(img));
        }
        for (h, exp) in handles.into_iter().zip(expected) {
            let res = h.wait();
            assert_eq!(res.edges, exp, "job {}", res.id);
        }
        let m = coord.metrics();
        assert_eq!(m.jobs_completed, 12);
        assert!(m.mean_batch_size >= 1.0);
    }

    #[test]
    fn submissions_from_multiple_threads() {
        let coord = Arc::new(coordinator(2));
        let mut joins = Vec::new();
        for t in 0..4 {
            let coord = coord.clone();
            joins.push(std::thread::spawn(move || {
                let img = synthetic_scene(96, 96, t);
                let res = coord.run(img);
                assert_eq!(res.edges.width, 96);
                res.latency
            }));
        }
        for j in joins {
            assert!(j.join().unwrap().as_nanos() > 0);
        }
        assert_eq!(coord.metrics().jobs_completed, 4);
    }

    #[test]
    fn tiny_queue_applies_backpressure_without_deadlock() {
        let model = build_design(DesignId::Exact, 8);
        let engine = Arc::new(LutTileEngine::new(model.as_ref()));
        let coord = Coordinator::start(
            engine,
            CoordinatorConfig { workers: 1, queue_capacity: 1, max_batch: 1 },
        );
        // 4 tiles through a depth-1 queue: submit blocks internally but
        // must still complete.
        let img = synthetic_scene(128, 128, 2);
        let res = coord.run(img);
        assert_eq!(res.tiles, 4);
    }

    /// 40 concurrent jobs span every shard of the job table (ids 1..=40
    /// cover all 16 residues); each must reassemble bit-exactly and be
    /// removed, leaving no stranded state.
    #[test]
    fn jobs_across_all_shards_complete_correctly() {
        let model = build_design(DesignId::Proposed, 8);
        let coord = coordinator(4);
        let mut expected = Vec::new();
        let mut handles = Vec::new();
        for seed in 0..40u64 {
            let img = synthetic_scene(48 + (seed as usize % 5) * 7, 33, seed);
            expected.push(edge_detect(&img, model.as_ref()));
            handles.push(coord.submit(img));
        }
        for (h, exp) in handles.into_iter().zip(expected) {
            let res = h.wait();
            assert_eq!(res.edges, exp, "job {}", res.id);
        }
        assert_eq!(coord.shutdown().jobs_completed, 40);
    }

    /// The cumulative accept/reject counters track submit-time admission:
    /// good submissions count as accepted, validation failures as
    /// rejected, and the post-drain queue depth is zero.
    #[test]
    fn accept_reject_counters_track_submissions() {
        let coord = coordinator(2);
        let img = synthetic_scene(64, 64, 5);
        let h = coord.submit(img.clone());
        let err = coord.submit_to(img, Some("nope"), Operator::Laplacian);
        assert!(err.is_err());
        assert!(coord
            .submit_gemm(crate::nn::MatI8::new(2, 3), crate::nn::MatI8::new(4, 2), None)
            .is_err());
        h.wait();
        let m = coord.metrics();
        assert_eq!(m.jobs_accepted, 1);
        assert_eq!(m.jobs_rejected, 2);
        assert_eq!(m.jobs_completed, 1);
        let m = coord.shutdown();
        assert_eq!(m.queue_depth, 0, "drained coordinator reports an empty queue");
    }

    #[test]
    fn shutdown_drains_pending_work() {
        let coord = coordinator(2);
        let img = synthetic_scene(256, 192, 1);
        let handle = coord.submit(img);
        let metrics = coord.shutdown(); // must drain, not drop
        assert_eq!(metrics.jobs_completed, 1);
        let res = handle.wait();
        assert_eq!(res.edges.width, 256);
    }
}

#[cfg(test)]
mod multi_design_tests {
    use super::*;
    use crate::coordinator::engine::{LutTileEngine, TileEngine};
    use crate::image::{edge_detect, synthetic_scene};
    use crate::multipliers::registry;

    fn two_design_coordinator(workers: usize) -> Coordinator {
        let approx = registry().build_str("proposed@8").unwrap();
        let exact = registry().build_str("exact@8").unwrap();
        let engines: Vec<(String, Arc<dyn TileEngine>)> = vec![
            (
                "proposed@8".to_string(),
                Arc::new(LutTileEngine::new(approx.as_ref())),
            ),
            (
                "exact@8".to_string(),
                Arc::new(LutTileEngine::new(exact.as_ref())),
            ),
        ];
        Coordinator::start_named(
            engines,
            CoordinatorConfig { workers, queue_capacity: 64, max_batch: 8 },
        )
    }

    /// Jobs routed to different designs get bit-exact results from their
    /// respective multiplier — concurrently, through one worker fleet —
    /// and the metrics report one row per design.
    #[test]
    fn jobs_route_by_engine_name_with_per_design_metrics() {
        let approx = registry().build_str("proposed@8").unwrap();
        let exact = registry().build_str("exact@8").unwrap();
        let coord = two_design_coordinator(3);
        assert_eq!(coord.engine_name(), "proposed@8");
        let img = synthetic_scene(192, 128, 21);
        let want_approx = edge_detect(&img, approx.as_ref());
        let want_exact = edge_detect(&img, exact.as_ref());
        let h1 = coord.submit_to(img.clone(), Some("proposed@8"), Operator::Laplacian).unwrap();
        let h2 = coord.submit_to(img.clone(), Some("exact@8"), Operator::Laplacian).unwrap();
        let h3 = coord.submit_to(img.clone(), None, Operator::Laplacian).unwrap(); // default
        let h4 = coord.submit(img.clone()); // also default
        assert_eq!(h1.wait().edges, want_approx);
        assert_eq!(h2.wait().edges, want_exact);
        assert_eq!(h3.wait().edges, want_approx);
        assert_eq!(h4.wait().edges, want_approx);
        assert_ne!(want_approx, want_exact, "the two designs genuinely differ");

        let m = coord.shutdown();
        assert_eq!(m.jobs_completed, 4);
        assert_eq!(m.per_engine.len(), 2);
        assert_eq!(m.per_engine[0].name, "proposed@8");
        assert_eq!(m.per_engine[0].jobs_completed, 3);
        assert_eq!(m.per_engine[1].name, "exact@8");
        assert_eq!(m.per_engine[1].jobs_completed, 1);
        assert_eq!(
            m.per_engine[0].tiles_processed + m.per_engine[1].tiles_processed,
            m.tiles_processed
        );
    }

    #[test]
    fn unknown_engine_name_is_an_error() {
        let coord = two_design_coordinator(1);
        let img = synthetic_scene(64, 64, 3);
        let err = coord.submit_to(img, Some("d2@8"), Operator::Laplacian).unwrap_err();
        assert!(format!("{err}").contains("unknown engine"));
    }

    #[test]
    fn ab_load_across_designs_from_many_threads() {
        let coord = Arc::new(two_design_coordinator(4));
        let names = ["proposed@8", "exact@8"];
        let mut joins = Vec::new();
        for t in 0..8u64 {
            let coord = coord.clone();
            let name = names[(t % 2) as usize];
            joins.push(std::thread::spawn(move || {
                let img = synthetic_scene(100, 90, t);
                coord.submit_to(img, Some(name), Operator::Laplacian).unwrap().wait().tiles
            }));
        }
        for j in joins {
            assert_eq!(j.join().unwrap(), 4);
        }
        let m = coord.metrics();
        assert_eq!(m.per_engine[0].jobs_completed, 4);
        assert_eq!(m.per_engine[1].jobs_completed, 4);
    }
}

#[cfg(test)]
mod batching_tests {
    use super::*;
    use crate::coordinator::tiler::TileOut;
    use crate::image::synthetic_scene;
    use std::sync::atomic::{AtomicBool, AtomicUsize};

    /// Engine that records the largest batch it was handed; an optional
    /// gate blocks the *first* `process_batch` call until the test
    /// releases it, so tiles pile up in the queue deterministically.
    struct ProbeEngine {
        preferred: usize,
        max_seen: AtomicUsize,
        gate: Option<Receiver<()>>,
        gate_used: AtomicBool,
    }

    impl ProbeEngine {
        fn new(preferred: usize, gate: Option<Receiver<()>>) -> Self {
            Self {
                preferred,
                max_seen: AtomicUsize::new(0),
                gate,
                gate_used: AtomicBool::new(false),
            }
        }
    }

    impl TileEngine for ProbeEngine {
        fn name(&self) -> String {
            format!("probe{}", self.preferred)
        }

        fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
            if let Some(g) = &self.gate {
                if !self.gate_used.swap(true, Ordering::SeqCst) {
                    let _ = g.recv();
                }
            }
            self.max_seen.fetch_max(tiles.len(), Ordering::SeqCst);
            tiles
                .iter()
                .map(|t| TileOut {
                    job_id: t.job_id,
                    x0: t.x0,
                    y0: t.y0,
                    core_w: t.core_w,
                    core_h: t.core_h,
                    data: vec![0u8; t.core_w * t.core_h],
                })
                .collect()
        }

        fn preferred_batch(&self) -> usize {
            self.preferred
        }
    }

    /// The batch clamp is per engine at dispatch time: an engine
    /// preferring batches of 4 gets batches of 4 even though a
    /// `preferred_batch() == 1` engine shares the fleet (the old
    /// fleet-wide-minimum clamp would have forced everyone to 1), while
    /// the batch-of-1 engine is never handed more than 1 tile.
    #[test]
    fn batch_clamp_is_per_engine_not_fleet_minimum() {
        let (gate_tx, gate_rx) = bounded::<()>(1);
        let big = Arc::new(ProbeEngine::new(4, Some(gate_rx)));
        let small = Arc::new(ProbeEngine::new(1, None));
        let coord = Coordinator::start_named(
            vec![
                ("big".to_string(), big.clone() as Arc<dyn TileEngine>),
                ("small".to_string(), small.clone() as Arc<dyn TileEngine>),
            ],
            CoordinatorConfig { workers: 1, queue_capacity: 256, max_batch: 8 },
        );
        // 12-tile job: the lone worker blocks inside its first
        // process_batch call (≤ 8 tiles) while the remaining tiles are
        // already queued; after release, at least one dispatch sees ≥ 8
        // pending tiles and must chunk them 4-and-4.
        let h_big = coord
            .submit_to(synthetic_scene(192, 256, 1), Some("big"), Operator::Laplacian)
            .unwrap();
        gate_tx.send(()).unwrap();
        let h_small = coord
            .submit_to(synthetic_scene(130, 70, 2), Some("small"), Operator::Laplacian)
            .unwrap();
        assert_eq!(h_big.wait().tiles, 12);
        assert_eq!(h_small.wait().tiles, 6);
        coord.shutdown();
        assert_eq!(
            big.max_seen.load(Ordering::SeqCst),
            4,
            "large-batch engine must reach its own preferred batch size"
        );
        assert_eq!(
            small.max_seen.load(Ordering::SeqCst),
            1,
            "batch-of-1 engine must never see more than one tile"
        );
    }
}

#[cfg(test)]
mod operator_routing_tests {
    use super::*;
    use crate::coordinator::engine::LutTileEngine;
    use crate::coordinator::tiler::TileOut;
    use crate::image::synthetic_scene;
    use crate::multipliers::{build_design, DesignId};

    /// Wrapper with a restricted operator surface (the shape of the PJRT
    /// engine, whose compiled artifact is Laplacian-only).
    struct LaplacianOnly(LutTileEngine);

    impl TileEngine for LaplacianOnly {
        fn name(&self) -> String {
            "laplacian-only".into()
        }

        fn process_batch(&self, tiles: &[Tile]) -> Vec<TileOut> {
            self.0.process_batch(tiles)
        }

        fn supports_op(&self, op: Operator) -> bool {
            op == Operator::Laplacian
        }
    }

    /// Jobs for an operator the engine cannot serve are rejected at
    /// submit time, not silently miscomputed.
    #[test]
    fn unsupported_operator_is_rejected_at_submit() {
        let model = build_design(DesignId::Exact, 8);
        let coord = Coordinator::start(
            Arc::new(LaplacianOnly(LutTileEngine::new(model.as_ref()))),
            CoordinatorConfig::default(),
        );
        let img = synthetic_scene(64, 64, 1);
        let ok = coord.submit_to(img.clone(), None, Operator::Laplacian).unwrap();
        assert_eq!(ok.wait().tiles, 1);
        let err = coord.submit_to(img, None, Operator::Sobel).unwrap_err();
        assert!(
            format!("{err}").contains("does not support operator sobel"),
            "unexpected message: {err}"
        );
    }
}

#[cfg(test)]
mod nn_job_tests {
    use super::*;
    use crate::coordinator::engine::{
        BitsimTileEngine, LutTileEngine, ModelTileEngine, RowbufTileEngine,
    };
    use crate::image::synthetic_scene;
    use crate::multipliers::{lut::product_table, registry};
    use crate::nn::{gemm_tiled, quantize_image, Network};
    use crate::util::prng::Xoshiro256;

    /// A fleet mixing nn-capable engines (lut, model, bitsim) with a
    /// conv-only one (rowbuf).
    fn nn_coordinator() -> Coordinator {
        let model = registry().build_str("proposed@8").unwrap();
        let engines: Vec<(String, Arc<dyn TileEngine>)> = vec![
            ("lut".into(), Arc::new(LutTileEngine::new(model.as_ref()))),
            ("model".into(), Arc::new(ModelTileEngine::new(model.clone()))),
            ("bitsim".into(), Arc::new(BitsimTileEngine::new(model.as_ref()))),
            ("rowbuf".into(), Arc::new(RowbufTileEngine::new(model))),
        ];
        Coordinator::start_named(
            engines,
            CoordinatorConfig { workers: 3, queue_capacity: 64, max_batch: 8 },
        )
    }

    /// Served GEMM equals the direct tiled product on every nn-capable
    /// backend — including a multi-block job (rows > nn::MC) — and the
    /// per-design metrics count the nn jobs.
    #[test]
    fn served_gemm_matches_direct_on_every_backend() {
        let design = registry().build_str("proposed@8").unwrap();
        let lut = product_table(design.as_ref());
        let mut rng = Xoshiro256::seeded(33);
        let a = crate::nn::MatI8::random(crate::nn::MC * 2 + 5, 37, &mut rng);
        let b = crate::nn::MatI8::random(37, 23, &mut rng);
        let want = gemm_tiled(&a, &b, &lut);
        let coord = nn_coordinator();
        for key in ["lut", "model", "bitsim"] {
            let res = coord.submit_gemm(a.clone(), b.clone(), Some(key)).unwrap().wait();
            assert_eq!(res.out, want, "{key}");
            assert_eq!(res.blocks, 3, "{key}: 69 rows in MC=32 blocks");
        }
        let m = coord.shutdown();
        assert_eq!(m.jobs_completed, 3);
        for row in &m.per_engine[..3] {
            assert_eq!(row.jobs_completed, 1, "{}", row.name);
            assert_eq!(row.tiles_processed, 3, "{}: one unit per GEMM block", row.name);
        }
        assert_eq!(m.per_engine[3].jobs_completed, 0, "rowbuf served nothing");
    }

    #[test]
    fn nn_jobs_are_validated_at_submit() {
        let coord = nn_coordinator();
        let a = crate::nn::MatI8::new(4, 3);
        let b = crate::nn::MatI8::new(3, 2);
        // conv-only engine
        let err = coord.submit_gemm(a.clone(), b.clone(), Some("rowbuf")).unwrap_err();
        assert!(
            format!("{err}").contains("does not serve quantized-inference"),
            "unexpected message: {err}"
        );
        // unknown engine
        assert!(coord.submit_gemm(a.clone(), b.clone(), Some("turbo")).is_err());
        // shape mismatch
        let err = coord.submit_gemm(a, crate::nn::MatI8::new(4, 2), None).unwrap_err();
        assert!(format!("{err}").contains("shape mismatch"), "unexpected message: {err}");
    }

    /// An empty-output GEMM (zero rows or zero columns) has no tasks to
    /// dispatch and must still complete (immediately), leaving no
    /// stranded job state.
    #[test]
    fn empty_gemm_completes_immediately() {
        let coord = nn_coordinator();
        let res = coord
            .submit_gemm(crate::nn::MatI8::new(0, 5), crate::nn::MatI8::new(5, 7), None)
            .unwrap()
            .wait();
        assert_eq!((res.out.rows, res.out.cols), (0, 7));
        assert_eq!(res.blocks, 0);
        let res = coord
            .submit_gemm(crate::nn::MatI8::new(3, 5), crate::nn::MatI8::new(5, 0), None)
            .unwrap()
            .wait();
        assert_eq!((res.out.rows, res.out.cols), (3, 0));
        assert_eq!(res.blocks, 0);
        assert_eq!(coord.shutdown().jobs_completed, 0, "no worker-side job recorded");
    }

    /// Conv-shaped GEMMs (few rows, many columns — A is the weight
    /// matrix) split along C's columns, so a single conv layer becomes
    /// several tasks the fleet can run in parallel, and the column-wise
    /// reassembly is bit-exact.
    #[test]
    fn wide_gemm_splits_along_columns() {
        let design = registry().build_str("proposed@8").unwrap();
        let lut = product_table(design.as_ref());
        let mut rng = Xoshiro256::seeded(91);
        let a = crate::nn::MatI8::random(3, 18, &mut rng);
        let b = crate::nn::MatI8::random(18, 2 * crate::nn::NC + 10, &mut rng);
        let want = gemm_tiled(&a, &b, &lut);
        let coord = nn_coordinator();
        let res = coord.submit_gemm(a, b, Some("lut")).unwrap().wait();
        assert_eq!(res.out, want);
        assert_eq!(res.blocks, 3, "1 row block x 3 column blocks");
        coord.shutdown();
    }

    /// submit_conv2d == the direct table-backed forward pass, and the
    /// whole served network equals the in-process tiled network.
    #[test]
    fn served_conv2d_and_network_match_direct() {
        let design = registry().build_str("proposed@8").unwrap();
        let lut = product_table(design.as_ref());
        let net = Network::demo();
        let x = quantize_image(&synthetic_scene(48, 40, 17));
        let coord = nn_coordinator();
        // one layer
        let l1 = &net.layers[0];
        let (oh, ow) = l1.out_dims(x.h, x.w);
        let res = coord.submit_conv2d(&x, l1, Some("lut")).unwrap().wait();
        assert_eq!(l1.epilogue(&res.out, oh, ow), l1.forward_tiled(&x, &lut));
        // channel mismatch is a submit-time error
        assert!(coord.submit_conv2d(&x, &net.layers[1], None).is_err());
        // whole network
        let served = net.run_served(&coord, Some("lut"), &x).unwrap();
        assert_eq!(served, net.run_tiled(&x, &lut));
    }

    /// Edge tiles and GEMM blocks interleave through one worker fleet:
    /// both job kinds complete correctly and the metrics attribute units
    /// to the right engines.
    #[test]
    fn conv_and_gemm_jobs_share_the_fleet() {
        let design = registry().build_str("proposed@8").unwrap();
        let lut = product_table(design.as_ref());
        let img = synthetic_scene(150, 90, 9);
        let want_edges = crate::image::edge_detect(&img, design.as_ref());
        let mut rng = Xoshiro256::seeded(71);
        let a = crate::nn::MatI8::random(40, 21, &mut rng);
        let b = crate::nn::MatI8::random(21, 33, &mut rng);
        let want_c = gemm_tiled(&a, &b, &lut);
        let coord = nn_coordinator();
        let mut edge_handles = Vec::new();
        let mut gemm_handles = Vec::new();
        for _ in 0..4 {
            edge_handles.push(
                coord.submit_to(img.clone(), Some("lut"), Operator::Laplacian).unwrap(),
            );
            gemm_handles.push(coord.submit_gemm(a.clone(), b.clone(), Some("lut")).unwrap());
        }
        for h in edge_handles {
            assert_eq!(h.wait().edges, want_edges);
        }
        for h in gemm_handles {
            assert_eq!(h.wait().out, want_c);
        }
        let m = coord.shutdown();
        assert_eq!(m.jobs_completed, 8);
        assert_eq!(m.per_engine[0].jobs_completed, 8, "all routed to the lut engine");
    }
}

#[cfg(test)]
mod dual_quality_tests {
    use super::*;
    use crate::coordinator::engine::{DualModeTileEngine, Quality};
    use crate::image::{edge_detect, synthetic_scene};
    use crate::multipliers::{build_design, DesignId};

    /// Dual-quality serving: jobs carrying different quality classes get
    /// bit-exact results from their respective multiplier — concurrently,
    /// through the same coordinator and worker fleet.
    #[test]
    fn mixed_quality_jobs_route_correctly() {
        let approx = build_design(DesignId::Proposed, 8);
        let exact = build_design(DesignId::Exact, 8);
        let engine = Arc::new(DualModeTileEngine::new(approx.as_ref(), exact.as_ref()));
        let coord = Coordinator::start(
            engine,
            CoordinatorConfig { workers: 3, queue_capacity: 64, max_batch: 8 },
        );
        let img = synthetic_scene(192, 128, 21);
        let want_approx = edge_detect(&img, approx.as_ref());
        let want_exact = edge_detect(&img, exact.as_ref());
        let h1 = coord.submit_with_quality(img.clone(), Quality::Approx as u8);
        let h2 = coord.submit_with_quality(img.clone(), Quality::Exact as u8);
        let h3 = coord.submit_with_quality(img.clone(), Quality::Approx as u8);
        assert_eq!(h1.wait().edges, want_approx);
        assert_eq!(h2.wait().edges, want_exact);
        assert_eq!(h3.wait().edges, want_approx);
        // the two classes genuinely differ
        assert_ne!(want_approx, want_exact);
    }
}
